"""Stale-docs gate: fail CI when a doc references something that is gone.

The round-lifecycle narrative and the example index name CLI flags,
file paths, `repro.*` module paths, and benchmark suites. Those
references rot silently — a renamed flag or a moved module leaves the
prose pointing at nothing, and stale docs are worse than no docs. This
script greps the references back OUT of the docs and checks each one
against the source tree:

  --some-flag        must be add_argument()'d in src/repro/launch/*.py
                     or benchmarks/*.py
  path/to/file.ext   must exist (relative to the repo root, the doc's
                     own directory, or the conventional dirs for bare
                     names: docs/ examples/ tools/ benchmarks/)
  repro.x.y          must resolve to src/repro/x/y.py or a package dir
  --only <suite>     must be a key of benchmarks/run.py's SUITES dict
  [text](target.md)  relative markdown link targets must exist

Pure stdlib on purpose: the CI job runs it without installing anything
(`python tools/docs_check.py`), so it must not import the package.

Exit 0 when every reference resolves; exit 1 with one line per stale
reference otherwise.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# the docs under the gate: the lifecycle narrative, the example index,
# and the front-door README (its quickstart commands rot the fastest)
CHECKED_DOCS = (
    "docs/ROUND_LIFECYCLE.md",
    "examples/README.md",
    "README.md",
)

# where CLI flags may legitimately be defined
FLAG_SOURCES = ("src/repro/launch", "benchmarks", "tools")

# bare filenames (no directory part) are searched here, in order
BARE_NAME_DIRS = ("", "docs", "examples", "tools", "benchmarks")

_FENCE = re.compile(r"^```.*?^```", re.M | re.S)
_INLINE = re.compile(r"`([^`\n]+)`")
_FLAG = re.compile(r"(?<![\w-])--[a-z][a-z0-9]*(?:-[a-z0-9]+)*\b")
_PATH = re.compile(r"(?<![\w./-])[\w./-]*\w\.(?:py|md|json|yml|yaml|toml|txt)\b")
_MODULE = re.compile(r"\brepro(?:\.[a-z_][a-z_0-9]*)+")
_SUITE = re.compile(r"--only\s+([a-z_]+)")
_MD_LINK = re.compile(r"\[[^\]]+\]\(([^)#\s]+)\)")


def code_spans(text: str) -> list[str]:
    """Inline-code spans plus fenced-block bodies — the only places a
    doc states a checkable reference (prose mentions stay advisory)."""
    spans = [m.group(0) for m in _FENCE.finditer(text)]
    spans += _INLINE.findall(_FENCE.sub("", text))
    return spans


def defined_flags() -> set:
    flags = set()
    for d in FLAG_SOURCES:
        for py in (ROOT / d).glob("*.py"):
            flags |= set(re.findall(
                r"add_argument\(\s*[\"'](--[\w-]+)[\"']", py.read_text()))
    return flags


def defined_suites() -> set:
    run_py = (ROOT / "benchmarks" / "run.py").read_text()
    m = re.search(r"SUITES\s*=\s*\{(.*?)\n\}", run_py, re.S)
    if not m:  # pragma: no cover - structural invariant of run.py
        raise SystemExit("benchmarks/run.py: SUITES dict not found")
    return set(re.findall(r"[\"'](\w+)[\"']\s*:", m.group(1)))


def path_exists(token: str, doc_dir: Path) -> bool:
    cands = [ROOT / token, doc_dir / token]
    if "/" not in token:
        cands += [ROOT / d / token for d in BARE_NAME_DIRS if d]
    return any(c.is_file() for c in cands)


def module_exists(dotted: str) -> bool:
    # `repro.fed.engine` -> src/repro/fed/engine.py (or a package); a
    # trailing attribute (`repro.fed.engine.run_round`) still resolves
    # via the longest prefix that is a module
    parts = dotted.split(".")
    for cut in range(len(parts), 0, -1):
        p = ROOT / "src" / Path(*parts[:cut])
        if p.with_suffix(".py").is_file() or (p / "__init__.py").is_file():
            return True
    return False


def check_doc(doc: str, flags: set, suites: set) -> list[str]:
    path = ROOT / doc
    if not path.is_file():
        return [f"{doc}: checked doc is itself missing"]
    text = path.read_text()
    stale = []
    for span in code_spans(text):
        for flag in _FLAG.findall(span):
            if flag not in flags:
                stale.append(f"{doc}: flag `{flag}` not defined in any "
                             f"argparse under {', '.join(FLAG_SOURCES)}")
        for token in _PATH.findall(span):
            if not path_exists(token, path.parent):
                stale.append(f"{doc}: path `{token}` does not exist")
        for dotted in _MODULE.findall(span):
            if not module_exists(dotted):
                stale.append(f"{doc}: module `{dotted}` not under src/")
        for suite in _SUITE.findall(span):
            if suite not in suites:
                stale.append(f"{doc}: benchmark suite `{suite}` not in "
                             "benchmarks/run.py SUITES")
    for target in _MD_LINK.findall(text):
        if "://" in target:
            continue
        if not (path.parent / target).is_file() and not (
                ROOT / target).is_file():
            stale.append(f"{doc}: markdown link target `{target}` missing")
    return stale


def main() -> int:
    flags, suites = defined_flags(), defined_suites()
    stale = []
    for doc in CHECKED_DOCS:
        stale += check_doc(doc, flags, suites)
    if stale:
        print(f"docs_check: {len(stale)} stale reference(s)")
        for line in sorted(set(stale)):
            print(f"  {line}")
        return 1
    print(f"docs_check: {len(CHECKED_DOCS)} docs clean "
          f"({len(flags)} flags, {len(suites)} suites indexed)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
