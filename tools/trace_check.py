"""Flight-recorder JSONL validator + summarizer (the CI trace gate).

Consumes the `<base>.jsonl` a traced run exports (see repro.obs.export)
and checks the contract the flight recorder promises:

  schema     every line is a JSON object with `kind` in {span, event},
             a string `name`, integer `seq`/`depth`, an object `attrs`;
             spans carry `dur_ns` (host clock) or `dur_sim` + `lane`
             (simulated clock); host records carry `t_ns`.
  ordering   `seq` strictly increases line over line (record order IS
             the order things happened; a ring-buffer wrap may start
             the file at seq > 0, but never reorders).
  depth      present on every record and never negative (spans push at
             exit, so depth — not position — recovers the tree).
  bytes      for every stream named in the closing `meter.final`
             record, the left-to-right sum of that stream's values
             over the `meter.absorb` events equals the final total
             EXACTLY (==, not allclose) — the meter emitted the same
             floats it folded, so any drift means dropped or forged
             records. Skipped with a warning when the ring buffer
             dropped records (the sum is then legitimately partial)
             or when no `meter.final` record is present.

Pure stdlib on purpose — CI runs `python tools/trace_check.py <file>`
(or pipes JSONL on stdin with `-`) without installing the package.

Exit 0 and a one-block summary on success; exit 1 with one line per
violation otherwise.
"""
from __future__ import annotations

import json
import sys
from collections import Counter
from typing import Any, Dict, List, Optional, TextIO

KINDS = ("span", "event")


def _err(errors: List[str], line_no: int, msg: str) -> None:
    errors.append(f"line {line_no}: {msg}")


def validate_record(rec: Any, line_no: int, errors: List[str]) -> bool:
    """Schema for one record; returns False when it is too malformed to
    feed into the stream checks."""
    if not isinstance(rec, dict):
        _err(errors, line_no, f"not a JSON object: {type(rec).__name__}")
        return False
    ok = True
    kind = rec.get("kind")
    if kind not in KINDS:
        _err(errors, line_no, f"kind must be one of {KINDS}, got {kind!r}")
        ok = False
    if not isinstance(rec.get("name"), str) or not rec.get("name"):
        _err(errors, line_no, f"name must be a non-empty string, "
                              f"got {rec.get('name')!r}")
        ok = False
    for key in ("seq", "depth"):
        v = rec.get(key)
        if not isinstance(v, int) or isinstance(v, bool):
            _err(errors, line_no, f"{key} must be an int, got {v!r}")
            ok = False
    if not isinstance(rec.get("attrs"), dict):
        _err(errors, line_no, f"attrs must be an object, "
                              f"got {type(rec.get('attrs')).__name__}")
        ok = False
    if isinstance(rec.get("depth"), int) and rec["depth"] < 0:
        _err(errors, line_no, f"negative depth {rec['depth']}")
        ok = False
    sim = "t_sim" in rec
    if sim and not isinstance(rec["t_sim"], (int, float)):
        _err(errors, line_no, f"t_sim must be a number, got {rec['t_sim']!r}")
        ok = False
    if kind == "span":
        if sim:
            if not isinstance(rec.get("dur_sim"), (int, float)):
                _err(errors, line_no, "sim span needs a numeric dur_sim")
                ok = False
            if not isinstance(rec.get("lane"), int):
                _err(errors, line_no, "sim span needs an integer lane")
                ok = False
        elif not isinstance(rec.get("dur_ns"), int):
            _err(errors, line_no, "host span needs an integer dur_ns")
            ok = False
    # meter.final is synthesized at export time and carries no clock;
    # every recorder-produced record stamps the host clock
    if (not sim and rec.get("name") != "meter.final"
            and not isinstance(rec.get("t_ns"), int)):
        _err(errors, line_no, "host record needs an integer t_ns")
        ok = False
    return ok


def check_stream(records: List[Dict[str, Any]],
                 errors: List[str], *, partial: bool) -> Dict[str, float]:
    """The byte-exactness gate: meter.absorb sums vs meter.final."""
    final: Optional[Dict[str, Any]] = None
    for rec in records:
        if rec.get("name") == "meter.final":
            final = rec.get("attrs", {})
    if final is None:
        return {}
    totals: Dict[str, float] = {}
    for stream, want in final.items():
        if stream == "rounds":
            continue
        got = 0.0
        for rec in records:
            if rec.get("name") == "meter.absorb":
                v = rec.get("attrs", {}).get(stream)
                if v is not None:
                    got += float(v)
        totals[stream] = got
        if partial:
            continue   # ring dropped records: sums are legitimately short
        if got != float(want):
            errors.append(
                f"stream {stream!r}: meter.absorb events sum to {got!r} "
                f"but meter.final says {float(want)!r} (must match "
                f"exactly)")
    return totals


def check(lines: TextIO) -> int:
    errors: List[str] = []
    records: List[Dict[str, Any]] = []
    prev_seq: Optional[int] = None
    for line_no, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            _err(errors, line_no, f"invalid JSON: {e}")
            continue
        if not validate_record(rec, line_no, errors):
            continue
        seq = rec.get("seq")
        if isinstance(seq, int):
            if prev_seq is not None and seq <= prev_seq:
                _err(errors, line_no,
                     f"seq must strictly increase: {prev_seq} -> {seq}")
            prev_seq = seq
        records.append(rec)

    if not records:
        print("trace_check: no records", file=sys.stderr)
        return 1

    # a file that starts mid-sequence means the ring buffer wrapped —
    # absorb sums would be partial, so the exactness gate stands down
    partial = records[0].get("seq", 0) != 0
    if partial:
        print(f"trace_check: WARNING ring buffer wrapped (first seq "
              f"{records[0]['seq']}); skipping byte-exactness gate",
              file=sys.stderr)
    sums = check_stream(records, errors, partial=partial)

    if errors:
        for e in errors:
            print(f"trace_check: {e}", file=sys.stderr)
        print(f"trace_check: FAIL ({len(errors)} violation(s) over "
              f"{len(records)} records)", file=sys.stderr)
        return 1

    by_name = Counter(r["name"] for r in records)
    n_spans = sum(1 for r in records if r["kind"] == "span")
    n_sim = sum(1 for r in records if "t_sim" in r)
    print(f"trace_check: OK — {len(records)} records "
          f"({n_spans} spans, {len(records) - n_spans} events, "
          f"{n_sim} on the sim clock)")
    for name, n in sorted(by_name.items()):
        print(f"  {name:24s} x{n}")
    if sums:
        print("  meter streams (bytes, exact vs meter.final):")
        for stream, total in sorted(sums.items()):
            print(f"    {stream:22s} {total:.1f}")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print("usage: python tools/trace_check.py <trace.jsonl | ->",
              file=sys.stderr)
        return 2
    if argv[0] == "-":
        return check(sys.stdin)
    with open(argv[0]) as f:
        return check(f)


if __name__ == "__main__":
    sys.exit(main())
