"""Paper Fig 5: accuracy + tuned-parameter count vs prompt length."""
from __future__ import annotations

from benchmarks.common import row, save
from benchmarks._train_harness import run_method


def run():
    out, lines = {}, []
    for plen in (2, 8, 32):
        r = run_method("sfprompt", "cifar100-syn", non_iid=False,
                       prompt_len=plen)
        out[plen] = {"acc": r["best_acc"], "tuned": r["tuned_params"]}
        lines.append(row(f"prompt_length/p={plen}", 0.0,
                         f"best={r['best_acc']:.3f} "
                         f"tuned={r['tuned_params']}"))
    save("prompt_length", out)
    return lines


if __name__ == "__main__":
    run()
