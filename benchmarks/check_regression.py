"""Benchmark-regression gate: fail CI when a kernel slows down >25%.

Compares the bench job's results JSONs (kernel microbench, serve
throughput, decode fast path — written by `REPRO_BENCH_FAST=1 python
benchmarks/run.py --only kernel_microbench --only serve --only decode`)
against the committed baseline `BENCH_kernels.json` at the repo root.

Two metric classes:
  * ratio metrics ("...speedup") — machine-independent (fused vs naive on
    the SAME host), so they gate by default: a speedup shrinking below
    (1 - threshold) x baseline fails.
  * absolute metrics ("..._us") — meaningful only on a pinned runner, so
    they gate only under --strict; on shared CI runners the jitter and
    hardware drift would make them pure noise.

The baseline carries two deliberate overrides next to the measured
"kernels" numbers, both preserved verbatim across `--update`:
  * "pins" — conservative drift-gate baselines for volatile ratios (the
    reference machine measures e.g. blocked ~4.7x, but shared CI hosts
    jitter, so the gate anchors on a pinned 2.0 instead of chasing the
    measurement). Pins OVERLAY the measured value at check time; the
    "kernels" section always records what the benchmark actually measured.
  * "floors" — HARD minimums on ratio metrics, enforced verbatim (never
    scaled by the threshold): e.g. `attention_2k/blocked_speedup >= 1.0`
    (the flash-style path must never be slower than the naive reference
    again) and `decode_scan/scan_speedup >= 2.0` (the multi-token scan
    must amortize at least 2x of the per-token dispatch cost). A drifting
    baseline can never re-bless a slowdown past its floor.
  * "ceilings" — the dual of floors: HARD maximums, enforced verbatim,
    for metrics where bigger is worse: e.g.
    `obs_overhead/traced_slowdown <= 1.05` (tracing a round must never
    cost more than 5% of it). Like floors they survive `--update`.

A kernel present in the results but absent from the baseline (or vice
versa) is SKIPPED with a note, never failed — new kernels get a baseline
via `--update`, which rewrites BENCH_kernels.json from the current results
(run it on the reference machine, commit the diff; pins and floors are
preserved).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(ROOT, "BENCH_kernels.json")
# every results file that can contribute (ref_us, <impl>_us) ratio pairs;
# missing files are skipped so partial bench runs still gate what they ran
DEFAULT_RESULTS = [
    os.path.join(ROOT, "benchmarks", "results", "kernel_microbench.json"),
    os.path.join(ROOT, "benchmarks", "results", "serve_throughput.json"),
    os.path.join(ROOT, "benchmarks", "results", "decode_throughput.json"),
    os.path.join(ROOT, "benchmarks", "results", "serve_paged.json"),
    os.path.join(ROOT, "benchmarks", "results", "secure_agg.json"),
    os.path.join(ROOT, "benchmarks", "results", "population_scale.json"),
    os.path.join(ROOT, "benchmarks", "results", "async_rounds.json"),
    os.path.join(ROOT, "benchmarks", "results", "mesh_tp.json"),
    os.path.join(ROOT, "benchmarks", "results", "obs_overhead.json"),
]


def flatten(results: Dict) -> Dict[str, float]:
    """kernel_microbench.json -> flat {kernel/metric: value}, plus derived
    speedup ratios for every (ref_us, <impl>_us) pair so the gate has a
    machine-independent number per kernel."""
    flat: Dict[str, float] = {}
    for kernel, metrics in results.items():
        if not isinstance(metrics, dict):
            continue
        for metric, value in metrics.items():
            if isinstance(value, (int, float)):
                flat[f"{kernel}/{metric}"] = float(value)
        ref = metrics.get("ref_us")
        if isinstance(ref, (int, float)):
            for metric, value in metrics.items():
                if (metric.endswith("_us") and metric != "ref_us"
                        and isinstance(value, (int, float)) and value > 0):
                    name = metric[: -len("_us")]
                    flat[f"{kernel}/{name}_speedup"] = float(ref) / value
    return flat


def check(baseline: Dict[str, float], current: Dict[str, float], *,
          threshold: float, strict: bool,
          floors: Dict[str, float] = None,
          ceilings: Dict[str, float] = None) -> int:
    failures, checked, skipped = [], 0, []
    floors = floors or {}
    ceilings = ceilings or {}
    for key, base in sorted(baseline.items()):
        if key not in current:
            skipped.append(f"{key} (no measurement this run)")
            continue
        cur = current[key]
        is_ratio = key.endswith("speedup")
        if not is_ratio and not (strict and key.endswith("_us")):
            # absolute wall times gate only on pinned runners; other
            # absolutes (clients_per_sec, bytes_per_round, shape counters)
            # have no slower-is-worse ceiling semantics — floors cover them
            continue
        checked += 1
        if is_ratio:
            floor = base * (1.0 - threshold)
            ok = cur >= floor
            detail = (f"{key}: {cur:.3f}x vs baseline {base:.3f}x "
                      f"(floor {floor:.3f}x)")
        else:
            ceil = base * (1.0 + threshold)
            ok = cur <= ceil
            detail = (f"{key}: {cur:.1f}us vs baseline {base:.1f}us "
                      f"(ceiling {ceil:.1f}us)")
        print(("ok   " if ok else "FAIL ") + detail)
        if not ok:
            failures.append(key)
    # hard floors: absolute minimums on ratio metrics, never threshold-scaled
    for key, floor in sorted(floors.items()):
        if key not in current:
            skipped.append(f"{key} (floor set, no measurement this run)")
            continue
        cur = current[key]
        ok = cur >= floor
        checked += 1
        print(("ok   " if ok else "FAIL ")
              + f"{key}: {cur:.3f}x vs HARD floor {floor:.3f}x")
        if not ok:
            failures.append(f"{key} (hard floor)")
    # hard ceilings: absolute maximums for bigger-is-worse metrics
    for key, ceil in sorted(ceilings.items()):
        if key not in current:
            skipped.append(f"{key} (ceiling set, no measurement this run)")
            continue
        cur = current[key]
        ok = cur <= ceil
        checked += 1
        print(("ok   " if ok else "FAIL ")
              + f"{key}: {cur:.3f}x vs HARD ceiling {ceil:.3f}x")
        if not ok:
            failures.append(f"{key} (hard ceiling)")
    for key in sorted(set(current) - set(baseline)):
        if key.endswith("speedup"):
            skipped.append(f"{key} (no baseline — run --update to add)")
    for note in skipped:
        print(f"skip {note}")
    if failures:
        print(f"REGRESSION: {len(failures)} kernel metric(s) degraded "
              f">{threshold:.0%} or outside a hard floor/ceiling: "
              f"{failures}")
        return 1
    print(f"OK: {checked} kernel metric(s) within {threshold:.0%} "
          f"of baseline")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--results", action="append", default=None,
                    help="results JSON (repeatable; default: kernel "
                         "microbench + serve throughput)")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="allowed fractional slowdown (default 0.25)")
    ap.add_argument("--strict", action="store_true",
                    help="also gate absolute _us wall times (pinned "
                         "runners only)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current results "
                         "(hand-set pins and floors are preserved)")
    ap.add_argument("--update-pins", action="store_true",
                    help="with --update: refresh each existing pin to the "
                         "currently measured value instead of preserving "
                         "it — a deliberate re-anchoring, run on the "
                         "reference machine only")
    args = ap.parse_args(argv)
    if args.update_pins and not args.update:
        ap.error("--update-pins only makes sense with --update")

    results_paths = args.results or DEFAULT_RESULTS
    current: Dict[str, float] = {}
    sources = []
    for path in results_paths:
        if not os.path.exists(path):
            print(f"skip: no benchmark results at {path}")
            continue
        with open(path) as f:
            current.update(flatten(json.load(f)))
        sources.append(os.path.relpath(path, ROOT))
    if not sources:
        print("skip: no benchmark results found "
              "(run benchmarks/run.py --only kernel_microbench first)")
        return 0

    prior_floors: Dict[str, float] = {}
    prior_pins: Dict[str, float] = {}
    prior_ceilings: Dict[str, float] = {}
    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            prior = json.load(f)
        prior_floors = prior.get("floors", {})
        prior_pins = prior.get("pins", {})
        prior_ceilings = prior.get("ceilings", {})

    if args.update:
        pins = prior_pins
        pins_note = f"{len(prior_pins)} pins preserved"
        if args.update_pins:
            pins = {k: current.get(k, v) for k, v in prior_pins.items()}
            pins_note = f"{len(pins)} pins refreshed from this run"
        payload = {"kernels": current,
                   "pins": pins,
                   "floors": prior_floors,
                   "ceilings": prior_ceilings,
                   "meta": {"source": sources,
                            "threshold": args.threshold}}
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.baseline} ({len(current)} metrics, "
              f"{pins_note} + {len(prior_floors)} floors + "
              f"{len(prior_ceilings)} ceilings preserved)")
        return 0

    if not os.path.exists(args.baseline):
        print(f"skip: no baseline at {args.baseline} — gate disabled "
              f"(create one with --update)")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f).get("kernels", {})
    baseline.update(prior_pins)   # pinned gate values override measured
    return check(baseline, current, threshold=args.threshold,
                 strict=args.strict, floors=prior_floors,
                 ceilings=prior_ceilings)


if __name__ == "__main__":
    sys.exit(main())
