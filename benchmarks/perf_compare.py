"""§Perf summary: baseline (paper-faithful, untagged dry-run records) vs
the optimized framework configuration (tag v2: fused loss, last-token
prefill logits, auto-FSDP threshold, tuned microbatches) across every
(arch x shape) pair. Prints per-pair collective-bytes and per-device-memory
deltas; writes results/perf_compare.json."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, row, save

DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")


def _load(tag: str):
    out = {}
    for path in glob.glob(os.path.join(DRYRUN_DIR, "*.json")):
        with open(path) as f:
            d = json.load(f)
        if d.get("mesh") != "pod16x16":
            continue
        if (d.get("tag") or "") != tag:
            continue
        out[(d["arch"], d["shape"])] = d
    return out


def run():
    base = _load("")
    opt = _load("v2")
    lines, table = [], {}
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        cb = b["collective_bytes"].get("total", 0)
        co = o["collective_bytes"].get("total", 0)
        mb = b.get("memory", {}).get("per_device_total_gb") or 0
        mo = o.get("memory", {}).get("per_device_total_gb") or 0
        entry = {
            "collective_bytes": {"base": cb, "v2": co,
                                 "speedup": (cb / co) if co else None},
            "per_device_gb": {"base": mb, "v2": mo},
        }
        table["|".join(key)] = entry
        sp = f"{cb/co:.2f}x" if co else "inf"
        lines.append(row(
            f"perf_compare/{key[0]}/{key[1]}", 0.0,
            f"coll {cb:.2e}->{co:.2e} ({sp}) mem {mb}->{mo} GB"))
    save("perf_compare", table)
    return lines


if __name__ == "__main__":
    run()
