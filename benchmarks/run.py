"""Benchmark driver: one function per paper table/figure + the roofline.
Prints ``name,us_per_call,derived`` CSV lines and writes JSON artifacts to
benchmarks/results/. Set REPRO_BENCH_FAST=1 for a quick pass.

Suites import LAZILY and fail INDEPENDENTLY: a suite whose module does not
even import (a broken dependency, a renamed symbol) is recorded as that
suite's failure and the driver moves on — the other suites still run and
the exit code still goes non-zero. `--only <suite>` (repeatable) runs a
subset, which is how CI shards the bench job; `--list` shows the names.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

# name -> (module, description)
SUITES = {
    "comm_cost": ("benchmarks.comm_cost", "Table 2 / Fig 2"),
    "compute_burden": ("benchmarks.compute_burden", "Table 2"),
    "latency_model": ("benchmarks.latency_model", "Table 1"),
    "roofline": ("benchmarks.roofline", "deliverable g"),
    "perf_compare": ("benchmarks.perf_compare", "baseline vs optimized"),
    "kernel_microbench": ("benchmarks.kernel_microbench", "kernel wall times"),
    "serve": ("benchmarks.serve_throughput",
              "serving engine tok/s + latency"),
    "decode": ("benchmarks.decode_throughput",
               "decode fast path: scan stepping + decode attention"),
    "serve_paged": ("benchmarks.serve_paged",
                    "paged KV: slots at fixed HBM + prefix reuse"),
    "secure": ("benchmarks.secure_agg",
               "privacy engine: secure-agg overhead + mask kernel"),
    "population": ("benchmarks.population_scale",
                   "mega-cohort rounds: clients/sec + bytes/round"),
    "mesh_tp": ("benchmarks.mesh_tp",
                "tensor-parallel body: per-device HBM ratio + round time"),
    "async": ("benchmarks.async_rounds",
              "buffered-async vs sync barrier round throughput"),
    "obs": ("benchmarks.obs_overhead",
            "flight-recorder overhead: traced vs untraced round"),
    "accuracy": ("benchmarks.accuracy", "Table 3 / Fig 4"),
    "prompt_length": ("benchmarks.prompt_length", "Fig 5"),
    "ablation_local_loss": ("benchmarks.ablation_local_loss", "Fig 6"),
    "ablation_pruning": ("benchmarks.ablation_pruning", "Fig 7"),
}


def run_suite(name: str) -> tuple:
    """(ok, seconds). Import errors count as THIS suite's failure."""
    module_name, desc = SUITES[name]
    t0 = time.time()
    print(f"# === {name} ({desc}) ===", flush=True)
    try:
        module = importlib.import_module(module_name)
        module.run()
        return True, time.time() - t0
    except Exception:
        traceback.print_exc()
        return False, time.time() - t0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", action="append", default=None,
                    metavar="SUITE", choices=list(SUITES),
                    help="run only this suite (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="print suite names and exit")
    args = ap.parse_args(argv)
    if args.list:
        for name, (_, desc) in SUITES.items():
            print(f"{name:>22}  {desc}")
        return 0

    names = args.only or list(SUITES)
    print("name,us_per_call,derived")
    results = {}
    for name in names:
        results[name] = run_suite(name)

    failures = [n for n, (ok, _) in results.items() if not ok]
    print("# --- summary ---")
    for name, (ok, secs) in results.items():
        print(f"# {name:>22}: {'ok' if ok else 'FAILED'} ({secs:.1f}s)")
    if failures:
        print(f"# {len(failures)}/{len(results)} suites FAILED: {failures}")
        return 1
    print(f"# all {len(results)} benchmark suites completed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
