"""Benchmark driver: one function per paper table/figure + the roofline.
Prints ``name,us_per_call,derived`` CSV lines and writes JSON artifacts to
benchmarks/results/. Set REPRO_BENCH_FAST=1 for a quick pass."""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (ablation_local_loss, ablation_pruning, accuracy,
                            comm_cost, compute_burden, kernel_microbench,
                            latency_model, perf_compare, prompt_length,
                            roofline)
    suites = [
        ("comm_cost (Table 2 / Fig 2)", comm_cost.run),
        ("compute_burden (Table 2)", compute_burden.run),
        ("latency_model (Table 1)", latency_model.run),
        ("roofline (deliverable g)", roofline.run),
        ("perf_compare (baseline vs optimized)", perf_compare.run),
        ("kernel_microbench", kernel_microbench.run),
        ("accuracy (Table 3 / Fig 4)", accuracy.run),
        ("prompt_length (Fig 5)", prompt_length.run),
        ("ablation_local_loss (Fig 6)", ablation_local_loss.run),
        ("ablation_pruning (Fig 7)", ablation_pruning.run),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            fn()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
    if failures:
        print(f"# {len(failures)} benchmark suites FAILED: {failures}")
        sys.exit(1)
    print("# all benchmark suites completed")


if __name__ == "__main__":
    main()
