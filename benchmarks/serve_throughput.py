"""Serving-engine throughput: continuous batching vs sequential decode.

Drives the deterministic synthetic workload (Poisson arrivals, mixed
prompt/output lengths) through the ServeEngine twice — once with one slot
(sequential baseline) and once with the full slot batch — and reports
tokens/s, p50/p99 request latency, slot occupancy, and measured wire
bytes. The `continuous_batching` entry carries a (ref_us, engine_us)
per-token pair, so check_regression.py derives the machine-independent
`engine_speedup` ratio and gates it against BENCH_kernels.json.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import FAST, row, save
from repro.configs import get_config
from repro.core import SplitConfig, SplitModel
from repro.core.comm import serve_comm_breakdown
from repro.runtime import WireSpec
from repro.runtime.meter import MB
from repro.serve import (ServeConfig, ServeEngine, TenantBank,
                         WorkloadConfig, synthetic_requests)

MAX_SEQ = 64
PROMPT_LEN = 4


def build():
    cfg = get_config("qwen2.5-14b").reduced(
        n_layers=3, d_model=64, d_ff=128, vocab_size=256)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=PROMPT_LEN)
    model = SplitModel(cfg, split, WireSpec.make("int8"))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def warm_engine(model, params, bank, reqs, *, n_slots):
    # both arms run the decode fast path (scan stepping, donated caches) so
    # the gated ratio isolates the continuous-batching win itself — the
    # dispatch-amortization win is gated separately by decode_throughput.py
    engine = ServeEngine(model, params, bank,
                         ServeConfig(n_slots=n_slots, max_seq=MAX_SEQ,
                                     max_queue=256,
                                     prefills_per_step=n_slots,
                                     decode_block=8))
    engine.run(reqs)   # warmup pass: compiles prefill buckets + decode
    return engine


def timed_replay(engine, reqs):
    """One timed replay of the trace on a warm engine (from step 0)."""
    engine.reset_stats()
    t0 = time.perf_counter()
    stats = engine.run(reqs)
    return time.perf_counter() - t0, stats


def run():
    cfg, model, params = build()
    n_tenants = 4
    bank = TenantBank.replicate(params["tail"], params["prompt"], n_tenants)
    slots = 4 if FAST else 8
    # heavy-traffic regime: arrivals much faster than service keeps the
    # slots saturated — the gated ratio is the continuous-batching win at
    # full occupancy, not an artifact of the arrival process
    wl = WorkloadConfig(
        n_requests=2 * slots if FAST else 3 * slots,
        mean_interarrival=0.0,
        prompt_choices=(8, 16), new_token_choices=(16,),
        n_tenants=n_tenants, vocab_size=cfg.vocab_size, seed=0)
    reqs = synthetic_requests(wl)

    seq_engine = warm_engine(model, params, bank, reqs, n_slots=1)
    batch_engine = warm_engine(model, params, bank, reqs, n_slots=slots)
    # INTERLEAVED best-of-reps: host contention is one-sided noise; taking
    # each arm's minimum over alternating replays keeps the gated ratio
    # stable under load (a burst covering one whole arm would skew it)
    seq_wall, eng_wall = float("inf"), float("inf")
    for _ in range(5):
        w, seq_stats = timed_replay(seq_engine, reqs)
        seq_wall = min(seq_wall, w)
        w, eng_stats = timed_replay(batch_engine, reqs)
        eng_wall = min(eng_wall, w)
    tokens_seq = sum(len(f.tokens) for f in seq_stats["finished"])
    tokens = sum(len(f.tokens) for f in eng_stats["finished"])
    assert tokens_seq == tokens, (tokens_seq, tokens)  # same served trace
    seq_us = seq_wall / max(1, tokens_seq) * 1e6
    eng_us = eng_wall / max(1, tokens) * 1e6

    analytical = serve_comm_breakdown(
        model.wire, d_model=cfg.d_model, soft_prompt_len=PROMPT_LEN,
        requests=[(len(f.req.tokens), f.req.max_new)
                  for f in eng_stats["finished"]])
    wire_mb = sum(analytical.values()) / MB

    row("serve/sequential", seq_us, "us_per_token_1slot")
    row("serve/continuous", eng_us, f"us_per_token_{slots}slots")
    row("serve/speedup", eng_us, f"{seq_us / eng_us:.2f}x")
    payload = {
        "continuous_batching": {"ref_us": seq_us, "engine_us": eng_us},
        "engine": {
            "n_slots": slots, "tokens": tokens,
            "tok_per_s": 1e6 / eng_us,
            "p50_ms": eng_stats["p50_latency_s"] * 1e3,
            "p99_ms": eng_stats["p99_latency_s"] * 1e3,
            "occupancy": eng_stats["occupancy"],
            "rejected": eng_stats["rejected"],
            "wire_mb_analytical": wire_mb,
        },
        "sequential": {
            "tok_per_s": 1e6 / seq_us,
            "p50_ms": seq_stats["p50_latency_s"] * 1e3,
            "p99_ms": seq_stats["p99_latency_s"] * 1e3,
        },
    }
    save("serve_throughput", payload)
    print(f"# serve: {1e6 / eng_us:.1f} tok/s at {slots} slots vs "
          f"{1e6 / seq_us:.1f} sequential "
          f"({seq_us / eng_us:.2f}x), occupancy "
          f"{eng_stats['occupancy']:.2f}, p99 "
          f"{eng_stats['p99_latency_s'] * 1e3:.0f} ms, "
          f"{wire_mb:.3f} MB wire/trace [int8]")


if __name__ == "__main__":
    run()
