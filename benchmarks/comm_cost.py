"""Paper Table 2 + Fig 2: per-round communication cost, FL vs SFL vs
SFPrompt, ViT-Base and ViT-Large with the paper's setup (1000 images/client,
K=5, U=10 local epochs, 224x224 -> 197 tokens).

Paper values: ViT-Base  FL 3910 MB (1x), SFL 30380.86 MB (7.77x), SFPrompt
1825.19 MB (0.47x); ViT-Large FL 12430, SFL 40507.81 (3.26x), SFPrompt
2433.59 (0.19x).

Calibration (reverse-engineered; see core/comm.py docstring): smashed
activations travel INT8 (1 B/float), parameters fp32, q excludes prompt
tokens, gamma_keep = 0.6, E = 1 split pass, |W| includes the ImageNet-21k
classifier head of the pre-trained checkpoint (391/1243 MB). With these the
model reproduces every Table-2 comm number to <= ~6%. We report calibrated
AND raw-fp32 variants.

Besides the closed-form table, `measured_vs_analytical()` runs an ACTUAL
SFPrompt round on a reduced ViT-Base with the int8 wire codec and compares
the TrafficMeter's measured per-boundary bytes against the analytical
model — the runnable version of the calibration above. `--check` runs only
that cross-check and exits nonzero if any boundary is off by > 5%.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

from benchmarks.common import row, save
from repro.configs import get_config
from repro.core.comm import (cost_inputs_from, crosscheck, fl_comm,
                             measured_cost_inputs, sfl_comm, sfprompt_comm)
from repro.core.split import SplitConfig

PAPER = {
    "vit-base": {"FL": 3910, "SFL": 30380.86, "SFPrompt": 1825.19},
    "vit-large": {"FL": 12430, "SFL": 40507.81, "SFPrompt": 2433.59},
}
MB = 2 ** 20


def _inputs(arch, *, calibrated: bool, U=10):
    cfg = get_config(arch)
    # the paper's |W| is the full pre-trained checkpoint incl. 21k head
    cfg_w = dataclasses.replace(cfg, num_classes=21843)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=16,
                        prune_gamma=(0.4 if calibrated else 0.4),
                        local_epochs=U)
    ci = cost_inputs_from(cfg_w, split, tokens_per_sample=197, D=1000,
                          K=5, U=U, E=1)
    if calibrated:
        ci.bytes_smashed = 1.0                    # int8 smashed data
        ci.q = cfg.d_model * 197                  # prompts not counted
        # paper's split: head = patch embedding, tail = the (new) task head
        # (ours defaults to a full transformer cycle per segment — reported
        # as the 'fp32' variant)
        embed = 16 * 16 * 3 * cfg.d_model + 198 * cfg.d_model
        task_head = cfg.d_model * 100
        ci.alpha = embed / ci.W
        ci.tau = 1 - ci.alpha - task_head / ci.W
    return ci


def run():
    out = {}
    lines = []
    for arch in ("vit-base", "vit-large"):
        for mode in ("calibrated", "fp32"):
            ci = _inputs(arch, calibrated=(mode == "calibrated"))
            ours = {"FL": fl_comm(ci) / MB, "SFL": sfl_comm(ci) / MB,
                    "SFPrompt": sfprompt_comm(ci) / MB}
            rel = {m: ours[m] / ours["FL"] for m in ours}
            entry = {"ours_mb": ours, "ours_rel": rel,
                     "paper_mb": PAPER[arch],
                     "paper_rel": {m: PAPER[arch][m] / PAPER[arch]["FL"]
                                   for m in PAPER[arch]},
                     "err_pct": {m: 100 * (ours[m] - PAPER[arch][m])
                                 / PAPER[arch][m] for m in ours}}
            out[f"{arch}/{mode}"] = entry
            if mode == "calibrated":
                for m in ours:
                    lines.append(row(
                        f"comm_cost/{arch}/{m}", 0.0,
                        f"ours={ours[m]:.0f}MB ({rel[m]:.2f}x) "
                        f"paper={PAPER[arch][m]:.0f}MB err="
                        f"{entry['err_pct'][m]:+.1f}%"))

    # Fig 2(b): per-round comm vs local epochs (ViT-Base, calibrated)
    curve = {}
    for U in (1, 2, 5, 10, 20, 50):
        ci = _inputs("vit-base", calibrated=True, U=U)
        curve[U] = {"FL": fl_comm(ci) / MB, "SFL": sfl_comm(ci) / MB,
                    "SFPrompt": sfprompt_comm(ci) / MB}
    out["fig2_epoch_curve_mb"] = curve
    out["measured_vs_analytical"] = measured_vs_analytical(lines)
    save("comm_cost", out)
    return lines


def measured_vs_analytical(lines=None, *, codec_name: str = "int8",
                           K: int = 2, n_local: int = 48, batch: int = 8):
    """One real SFPrompt round (reduced ViT-Base, int8 wire) — measured
    TrafficMeter bytes next to the analytical Table-1 prediction."""
    import jax
    import jax.numpy as jnp

    from repro.core import ProtocolConfig, SFPromptTrainer, SplitModel
    from repro.data import (DATASETS, iid_partition, stack_clients,
                            synthetic_image_dataset)
    from repro.runtime import WireSpec

    cfg = get_config("vit-base").reduced(n_layers=3, d_model=64, d_ff=128)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4,
                        prune_gamma=0.3, local_epochs=1)
    wire = WireSpec.make(codec_name)
    model = SplitModel(cfg, split, wire)
    pcfg = ProtocolConfig(clients_per_round=K, local_epochs=1,
                          batch_size=batch, momentum=0.0)
    tr = SFPromptTrainer(model, pcfg)
    state = tr.init(jax.random.PRNGKey(0))
    data = synthetic_image_dataset(DATASETS["cifar10-syn"], K * n_local,
                                   seed=0, image_hw=32)
    clients = iid_partition(data, K, seed=0)
    cbatch = {k: jnp.asarray(v) for k, v in
              stack_clients(clients, list(range(K))).items()}
    _, metrics = tr.round(state, cbatch)

    # analytical inputs matched to what actually ran: 32x32 images -> 4
    # patches + CLS + prompts; pruning kept `keep` of n_local samples
    n_tokens = 1 + (32 // 16) ** 2
    ci = measured_cost_inputs(model, tokens_per_sample=n_tokens,
                              n_local=n_local, batch_size=batch, K=K)
    cc = crosscheck(tr.meter.totals, ci)
    for name, entry in cc.items():
        if lines is not None:
            lines.append(row(
                f"comm_cost/measured/{name}", 0.0,
                f"measured={entry['measured']:.0f}B "
                f"analytical={entry['analytical']:.0f}B "
                f"err={entry['err_pct']:+.2f}%"))
    return cc


def check() -> int:
    """CI smoke: measured-vs-analytical within 5% per boundary."""
    cc = measured_vs_analytical([])
    bad = {k: v for k, v in cc.items() if abs(v["err_pct"]) > 5.0}
    for k, v in cc.items():
        print(f"{k}: measured={v['measured']:.0f}B "
              f"analytical={v['analytical']:.0f}B err={v['err_pct']:+.2f}%")
    if bad:
        print(f"FAIL: boundaries off by > 5%: {sorted(bad)}")
        return 1
    print("OK: measured wire bytes match the analytical model (<= 5%)")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="only the measured-vs-analytical cross-check")
    if ap.parse_args().check:
        sys.exit(check())
    run()
