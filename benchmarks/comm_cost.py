"""Paper Table 2 + Fig 2: per-round communication cost, FL vs SFL vs
SFPrompt, ViT-Base and ViT-Large with the paper's setup (1000 images/client,
K=5, U=10 local epochs, 224x224 -> 197 tokens).

Paper values: ViT-Base  FL 3910 MB (1x), SFL 30380.86 MB (7.77x), SFPrompt
1825.19 MB (0.47x); ViT-Large FL 12430, SFL 40507.81 (3.26x), SFPrompt
2433.59 (0.19x).

Calibration (reverse-engineered; see core/comm.py docstring): smashed
activations travel INT8 (1 B/float), parameters fp32, q excludes prompt
tokens, gamma_keep = 0.6, E = 1 split pass, |W| includes the ImageNet-21k
classifier head of the pre-trained checkpoint (391/1243 MB). With these the
model reproduces every Table-2 comm number to <= ~6%. We report calibrated
AND raw-fp32 variants.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import row, save
from repro.configs import get_config
from repro.core.comm import cost_inputs_from, fl_comm, sfl_comm, sfprompt_comm
from repro.core.split import SplitConfig

PAPER = {
    "vit-base": {"FL": 3910, "SFL": 30380.86, "SFPrompt": 1825.19},
    "vit-large": {"FL": 12430, "SFL": 40507.81, "SFPrompt": 2433.59},
}
MB = 2 ** 20


def _inputs(arch, *, calibrated: bool, U=10):
    cfg = get_config(arch)
    # the paper's |W| is the full pre-trained checkpoint incl. 21k head
    cfg_w = dataclasses.replace(cfg, num_classes=21843)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=16,
                        prune_gamma=(0.4 if calibrated else 0.4),
                        local_epochs=U)
    ci = cost_inputs_from(cfg_w, split, tokens_per_sample=197, D=1000,
                          K=5, U=U, E=1)
    if calibrated:
        ci.bytes_smashed = 1.0                    # int8 smashed data
        ci.q = cfg.d_model * 197                  # prompts not counted
        # paper's split: head = patch embedding, tail = the (new) task head
        # (ours defaults to a full transformer cycle per segment — reported
        # as the 'fp32' variant)
        embed = 16 * 16 * 3 * cfg.d_model + 198 * cfg.d_model
        task_head = cfg.d_model * 100
        ci.alpha = embed / ci.W
        ci.tau = 1 - ci.alpha - task_head / ci.W
    return ci


def run():
    out = {}
    lines = []
    for arch in ("vit-base", "vit-large"):
        for mode in ("calibrated", "fp32"):
            ci = _inputs(arch, calibrated=(mode == "calibrated"))
            ours = {"FL": fl_comm(ci) / MB, "SFL": sfl_comm(ci) / MB,
                    "SFPrompt": sfprompt_comm(ci) / MB}
            rel = {m: ours[m] / ours["FL"] for m in ours}
            entry = {"ours_mb": ours, "ours_rel": rel,
                     "paper_mb": PAPER[arch],
                     "paper_rel": {m: PAPER[arch][m] / PAPER[arch]["FL"]
                                   for m in PAPER[arch]},
                     "err_pct": {m: 100 * (ours[m] - PAPER[arch][m])
                                 / PAPER[arch][m] for m in ours}}
            out[f"{arch}/{mode}"] = entry
            if mode == "calibrated":
                for m in ours:
                    lines.append(row(
                        f"comm_cost/{arch}/{m}", 0.0,
                        f"ours={ours[m]:.0f}MB ({rel[m]:.2f}x) "
                        f"paper={PAPER[arch][m]:.0f}MB err="
                        f"{entry['err_pct'][m]:+.1f}%"))

    # Fig 2(b): per-round comm vs local epochs (ViT-Base, calibrated)
    curve = {}
    for U in (1, 2, 5, 10, 20, 50):
        ci = _inputs("vit-base", calibrated=True, U=U)
        curve[U] = {"FL": fl_comm(ci) / MB, "SFL": sfl_comm(ci) / MB,
                    "SFPrompt": sfprompt_comm(ci) / MB}
    out["fig2_epoch_curve_mb"] = curve
    save("comm_cost", out)
    return lines


if __name__ == "__main__":
    run()
