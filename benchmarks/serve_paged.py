"""Paged-KV serving: HBM headroom, throughput, and prefix reuse.

Drives a skewed multi-tenant Poisson workload through the PagedServeEngine
(page pool + block tables + copy-on-write shared prefix) and the dense
ServeEngine on the same trace. The headline metric is machine-independent:

  slots_at_fixed_hbm = (n_slots * blocks_per_window) / peak_pages

— the dense engine pins one full `max_seq` KV window per slot, while the
paged engine's PEAK page usage covers only tokens that exist (page-granular
allocation) minus pages deduplicated by prefix sharing. The ratio is "how
many more concurrent sequences fit in the same KV HBM", gated HARD >= 2.0
in BENCH_kernels.json. Wall-clock tok/s for both engines and the prefix
hit ratio ride along as context (not gated — host-dependent).
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import FAST, row, save
from repro.configs import get_config
from repro.core import SplitConfig, SplitModel
from repro.runtime import WireSpec
from repro.serve import (PagedServeConfig, PagedServeEngine, ServeConfig,
                         ServeEngine, TenantBank, WorkloadConfig,
                         synthetic_requests)

MAX_SEQ = 96
PROMPT_LEN = 4
PAGE = 8
PREFIX_LEN = 16


def build():
    cfg = get_config("qwen2.5-14b").reduced(
        n_layers=3, d_model=64, d_ff=128, vocab_size=256)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=PROMPT_LEN)
    model = SplitModel(cfg, split, WireSpec.make("int8"))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def timed_replay(engine, reqs):
    engine.reset_stats()
    t0 = time.perf_counter()
    stats = engine.run(reqs)
    return time.perf_counter() - t0, stats


def run():
    cfg, model, params = build()
    slots = 4 if FAST else 6
    n_tenants = 2          # skewed: many same-tenant overlaps -> prefix hits
    bank = TenantBank.replicate(params["tail"], params["prompt"], n_tenants)
    prefix = tuple(int(1 + (i * 13) % (cfg.vocab_size - 1))
                   for i in range(PREFIX_LEN))
    wl = WorkloadConfig(
        n_requests=2 * slots if FAST else 4 * slots,
        mean_interarrival=0.5,
        prompt_choices=(8, 12, 16), new_token_choices=(8,),
        n_tenants=n_tenants, vocab_size=cfg.vocab_size, seed=0)
    reqs = synthetic_requests(wl)

    paged = PagedServeEngine(
        model, params, bank,
        PagedServeConfig(n_slots=slots, max_seq=MAX_SEQ, max_queue=256,
                         prefills_per_step=slots, decode_block=8,
                         page_size=PAGE, shared_prefix=prefix))
    dense = ServeEngine(
        model, params, bank,
        ServeConfig(n_slots=slots, max_seq=MAX_SEQ, max_queue=256,
                    prefills_per_step=slots, decode_block=8))
    paged.run(reqs)        # warmup: compile prefill buckets + paged decode
    dense.run(reqs)
    paged_wall = dense_wall = float("inf")
    for _ in range(3):
        w, pstats = timed_replay(paged, reqs)
        paged_wall = min(paged_wall, w)
        w, dstats = timed_replay(dense, reqs)
        dense_wall = min(dense_wall, w)
    assert pstats["n_finished"] == dstats["n_finished"] == len(reqs)

    # KV-HBM headroom: dense pins slots * nb_max pages worth of window;
    # the paged pool never exceeded peak_pages for the same trace
    nb_max = -(-MAX_SEQ // PAGE)
    slots_at_fixed_hbm = (slots * nb_max) / max(1, pstats["peak_pages"])
    tok_paged = sum(len(f.tokens) for f in pstats["finished"])
    tok_dense = sum(len(f.tokens) for f in dstats["finished"])
    paged_tps = tok_paged / paged_wall
    dense_tps = tok_dense / dense_wall

    row("serve_paged/slots_at_fixed_hbm", paged_wall * 1e6,
        f"{slots_at_fixed_hbm:.2f}x")
    row("serve_paged/throughput", paged_wall / max(1, tok_paged) * 1e6,
        f"{paged_tps:.1f}tok_s")
    row("serve_paged/prefix_hit_ratio", 0.0,
        f"{pstats['prefix_hit_ratio']:.2f}")
    payload = {"serve_paged": {
        "slots_at_fixed_hbm": slots_at_fixed_hbm,
        "n_slots": slots,
        "page_size": PAGE,
        "n_pages": pstats["n_pages"],
        "peak_pages": pstats["peak_pages"],
        "dense_pages_equiv": slots * nb_max,
        "page_copies": pstats["page_copies"],
        "prefix_hit_ratio": pstats["prefix_hit_ratio"],
        "prefix_len": PREFIX_LEN,
        "tok_per_s": paged_tps,
        "dense_tok_per_s": dense_tps,
        "p50_ms": pstats["p50_latency_s"] * 1e3,
        "p99_ms": pstats["p99_latency_s"] * 1e3,
        "occupancy": pstats["occupancy"],
    }}
    save("serve_paged", payload)
    print(f"# serve_paged: {slots_at_fixed_hbm:.2f}x slots at fixed KV HBM "
          f"(peak {pstats['peak_pages']}/{slots * nb_max} pages), "
          f"{paged_tps:.1f} tok/s paged vs {dense_tps:.1f} dense, "
          f"prefix hit ratio {pstats['prefix_hit_ratio']:.2f}, "
          f"{pstats['page_copies']} COW copies")


if __name__ == "__main__":
    run()
