"""Population-scale benchmark: mega-cohort rounds as one (mesh-sharded)
dispatch.

Rows (all under population_scale/ in the regression baseline):
  clients_per_sec       — steady-state cohort training throughput: K divided
                          by the median wall time of one full three-phase
                          round (the number the ROADMAP's 10k-client regime
                          scales by).
  bytes_per_round       — metered wire bytes of one synchronous round
                          (boundaries + phase-3 params), from the
                          TrafficMeter, not the analytical model.
  hbm_per_client_bytes  — per-client live parameter state: trainable
                          (tail + prompt) + optimizer state. With the
                          broadcast-free frozen body this is what cohort
                          HBM actually scales with.
  body_bytes            — the frozen body size each client would ALSO pin
                          under the old K-broadcast regime; the HBM the
                          unbatched-operand round saves is K * body_bytes.

Runs sharded over a host mesh when more than one device is visible
(XLA_FLAGS=--xla_force_host_platform_device_count=8), single-device vmap
otherwise — same protocol, same bytes, different layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FAST, row, save, time_fn
from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.data import (DATASETS, iid_partition, stack_clients,
                        synthetic_image_dataset)
from repro.launch.mesh import make_host_mesh

K = 16 if FAST else 32
N_LOCAL = 8
BATCH = 4


def run():
    lines = []
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=32, d_ff=48)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2,
                        prune_gamma=0.5, local_epochs=1)
    model = SplitModel(cfg, split)
    data = synthetic_image_dataset(DATASETS["cifar10-syn"], K * N_LOCAL,
                                   seed=0, image_hw=32)
    clients = iid_partition(data, K, seed=0)
    batch = {kk: jnp.asarray(v) for kk, v in
             stack_clients(clients, list(range(K))).items()}
    pcfg = ProtocolConfig(clients_per_round=K, local_epochs=1,
                          batch_size=BATCH, momentum=0.0)
    n_dev = jax.device_count()
    mesh = make_host_mesh() if n_dev > 1 else None
    tr = SFPromptTrainer(model, pcfg, mesh=mesh)
    state = tr.init(jax.random.PRNGKey(0))

    t_round = time_fn(lambda: tr.round(state, batch),
                      iters=3 if FAST else 5, warmup=1)
    clients_per_sec = K / (t_round * 1e-6)

    meter_before = dict(tr.meter.totals)
    _, metrics = tr.round(state, batch)
    bytes_per_round = sum(tr.meter.totals[n] - meter_before[n]
                          for n in tr.meter.totals)

    params = state["params"]
    trainable_one = {"tail": params["tail"], "prompt": params["prompt"]}
    opt_one = tr.opt_split.init(trainable_one)
    nbytes = lambda t: float(sum(x.size * x.dtype.itemsize
                                 for x in jax.tree.leaves(t)))
    hbm_per_client = nbytes(trainable_one) + nbytes(opt_one)
    body_bytes = nbytes(params["body"])

    out = {"population_scale": {
        "clients_per_sec": clients_per_sec,
        "round_us": t_round,
        "bytes_per_round": bytes_per_round,
        "hbm_per_client_bytes": hbm_per_client,
        "body_bytes": body_bytes,
        "k": float(K),
        "devices": float(n_dev),
    }}
    lines.append(row("population/round", t_round,
                     f"K={K} devices={n_dev} "
                     f"clients_per_sec={clients_per_sec:.1f}"))
    lines.append(row("population/wire", bytes_per_round,
                     f"bytes_per_round={bytes_per_round:.0f} "
                     f"hbm_per_client={hbm_per_client:.0f}B "
                     f"body_saved={K * body_bytes / 2**20:.1f}MB"))
    save("population_scale", out)
    return lines


if __name__ == "__main__":
    run()
