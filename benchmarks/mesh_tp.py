"""Tensor-parallel frozen body: the 'model' mesh axis as a COMPUTE axis.

Rows (all under mesh_tp/ in the regression baseline):
  hbm_ratio    — replicated frozen-body bytes divided by the bytes a
                 single device actually holds under the params_pspecs
                 'model' shardings on the (data=2, model=4) mesh, measured
                 from addressable_shards (not predicted from specs). The
                 ideal is |model| = 4; sub-dividing leaves (norms, biases)
                 keep it below that, and BENCH_kernels.json floors it at
                 3.0 — the 'model' axis must never quietly degrade back to
                 storage-only replication.
  round_us     — one full K-cohort three-phase round on the 2D
                 (data=2, model=4) mesh: body TP compute + cohort data
                 parallelism in a single jitted dispatch.
  round_1d_us  — the same round on the 1-D data=8 mesh (PR-6 layout:
                 body replicated, storage-only). The TP round trades
                 collective latency for per-device HBM; on real
                 accelerators with fast interconnect the ratio flips,
                 on host-CPU virtual devices it is reported, not gated.

Needs 8 visible devices (XLA_FLAGS=--xla_force_host_platform_device_count=8);
below that it prints a skip note and writes NO results file, so the
regression gate skips the floor instead of failing a partial run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from benchmarks.common import FAST, row, save, time_fn
from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.data import DATASETS, synthetic_image_dataset
from repro.launch.mesh import make_host_mesh
from repro.sharding import params_pspecs

TP = 4
K = 16 if FAST else 32
N_LOCAL = 8
BATCH = 4


def _nbytes(tree) -> float:
    return float(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def run():
    n_dev = jax.device_count()
    if n_dev < 8:
        print(f"mesh_tp: needs 8 devices, have {n_dev} "
              "(XLA_FLAGS=--xla_force_host_platform_device_count=8); skipped")
        return [f"mesh_tp/skipped,0.0,devices={n_dev}"]

    lines = []
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=32, d_ff=48)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2,
                        prune_gamma=0.5, local_epochs=1)
    model = SplitModel(cfg, split)
    mesh_tp = make_host_mesh(8, model=TP)
    mesh_1d = make_host_mesh(8)

    # --- per-device frozen-body HBM under the TP shardings, measured
    params = model.init(jax.random.PRNGKey(0))
    specs = params_pspecs(params, mesh_tp)["body"]
    shardings = jax.tree.map(lambda s: NamedSharding(mesh_tp, s), specs,
                             is_leaf=lambda x: isinstance(x, PartitionSpec))
    body_tp = jax.device_put(params["body"], shardings)
    body_bytes = _nbytes(params["body"])
    per_dev_bytes = float(sum(
        x.addressable_shards[0].data.size * x.dtype.itemsize
        for x in jax.tree.leaves(body_tp)))
    hbm_ratio = body_bytes / per_dev_bytes

    # --- round wall time: 2D TP mesh vs the 1-D storage-only layout
    data = synthetic_image_dataset(DATASETS["cifar10-syn"], K * N_LOCAL,
                                   seed=0, image_hw=32)
    batch = {name: jnp.asarray(v).reshape((K, N_LOCAL) + v.shape[1:])
             for name, v in data.items()}
    pcfg = ProtocolConfig(clients_per_round=K, local_epochs=1,
                          batch_size=BATCH, momentum=0.0)
    iters = 3 if FAST else 5

    tr_tp = SFPromptTrainer(model, pcfg, mesh=mesh_tp)
    state = tr_tp.init(jax.random.PRNGKey(0))
    t_tp = time_fn(lambda: tr_tp.round(state, batch), iters=iters, warmup=1)

    tr_1d = SFPromptTrainer(model, pcfg, mesh=mesh_1d)
    t_1d = time_fn(lambda: tr_1d.round(state, batch), iters=iters, warmup=1)

    out = {"mesh_tp": {
        "hbm_ratio": hbm_ratio,
        "round_us": t_tp,
        "round_1d_us": t_1d,
        "body_bytes": body_bytes,
        "body_bytes_per_device": per_dev_bytes,
        "k": float(K),
        "model_axis": float(TP),
        "devices": float(n_dev),
    }}
    lines.append(row("mesh_tp/hbm", hbm_ratio,
                     f"body {body_bytes:.0f}B -> {per_dev_bytes:.0f}B/dev "
                     f"on model={TP} (ideal {TP}x)"))
    lines.append(row("mesh_tp/round", t_tp,
                     f"K={K} 2D(2,{TP}) vs 1D round {t_1d:.0f}us"))
    save("mesh_tp", out)
    return lines


if __name__ == "__main__":
    run()
