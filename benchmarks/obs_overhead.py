"""Observability overhead: the flight recorder must be ~free.

Times the SAME workload twice — once against the shared `NOOP` tracer
(the default every component ships with) and once fully traced at the
``step`` level — and gates on the ratio. Two measurements:

  * `obs_overhead/traced_slowdown` — traced / untraced wall time for a
    full SFPrompt protocol round on the tiny ViT. The round's jitted
    compute dominates (milliseconds); the recorder adds a handful of
    dict pushes (microseconds), so the ratio must stay ~1.0. Gated by a
    HARD ceiling of 1.05 in BENCH_kernels.json ("ceilings" section):
    if tracing ever costs more than 5% of a round, it is no longer
    observation.
  * `obs_overhead/event_ns` / `noop_event_ns` — microcost of one
    `Tracer.event` push vs the disabled path (informational: the noop
    path is the one every untraced hot loop pays).

Reps are INTERLEAVED (traced, untraced, traced, ...) and each side
takes its best (minimum) time, so shared-runner noise hits both arms
equally instead of biasing the ratio.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import FAST, row, save
from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.data import (DATASETS, iid_partition, select_clients,
                        stack_clients, synthetic_image_dataset)
from repro.obs import NOOP, Tracer

K = 3


def _setup():
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=64, d_ff=128)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4,
                        prune_gamma=0.5, local_epochs=1)
    model = SplitModel(cfg, split)
    data = synthetic_image_dataset(DATASETS["cifar10-syn"], 192, seed=0,
                                   image_hw=32)
    clients = iid_partition(data, 8, seed=0)
    return model, clients


def _batch(clients, r):
    import jax.numpy as jnp
    idx = select_clients(len(clients), K, seed=0, round_idx=r)
    return {k: jnp.asarray(v) for k, v in
            stack_clients(clients, idx).items()}


def _round_time(trainer, state, batch) -> float:
    t0 = time.perf_counter()
    out_state, _ = trainer.round(state, batch)
    jax.block_until_ready(out_state["params"])
    return time.perf_counter() - t0


def run():
    model, clients = _setup()
    pcfg = ProtocolConfig(clients_per_round=K, local_epochs=1, batch_size=8,
                          lr_local=0.05, lr_split=0.05)
    key = jax.random.PRNGKey(0)
    batch = _batch(clients, 0)

    traced = SFPromptTrainer(model, pcfg, tracer=Tracer("step"))
    plain = SFPromptTrainer(model, pcfg)   # NOOP tracer
    st_traced = traced.init(key)
    st_plain = plain.init(key)
    # compile both jitted rounds before any timed rep
    _round_time(traced, st_traced, batch)
    _round_time(plain, st_plain, batch)

    reps = 5 if FAST else 9
    best_traced = best_plain = float("inf")
    for _ in range(reps):
        best_traced = min(best_traced, _round_time(traced, st_traced, batch))
        best_plain = min(best_plain, _round_time(plain, st_plain, batch))
    slowdown = best_traced / best_plain

    # recorder microcost: one event push vs the disabled path
    n = 20_000 if FAST else 100_000
    live = Tracer("step", capacity=1 << 12)
    t0 = time.perf_counter()
    for i in range(n):
        live.event("bench.tick", level=2, i=i, a=1.0, b=2.0)
    event_ns = (time.perf_counter() - t0) / n * 1e9
    t0 = time.perf_counter()
    for i in range(n):
        NOOP.event("bench.tick", level=2, i=i, a=1.0, b=2.0)
    noop_ns = (time.perf_counter() - t0) / n * 1e9

    n_records = len(traced.tracer.records())
    out = {"obs_overhead": {
        "traced_slowdown": slowdown,
        "round_traced_s": best_traced,
        "round_plain_s": best_plain,
        "event_ns": event_ns,
        "noop_event_ns": noop_ns,
        "records_per_round": n_records / (reps + 1),
    }}
    save("obs_overhead", out)
    return [row("obs_overhead/round", best_traced * 1e6,
                f"traced={best_traced * 1e3:.1f}ms "
                f"plain={best_plain * 1e3:.1f}ms "
                f"slowdown={slowdown:.3f}x "
                f"event={event_ns:.0f}ns noop={noop_ns:.0f}ns")]


if __name__ == "__main__":
    run()
