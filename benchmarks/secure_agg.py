"""Secure-aggregation overhead benchmarks (privacy engine).

Two headline numbers, both machine-independent ratios:

  secure_agg/secure_speedup — one full phase-3 aggregation, masked ring
      path vs clear fedavg_partial on the SAME cohort tree (with a
      dropout, so the secure arm pays mask generation AND escrow
      recovery). This is < 1 by construction: the regression gate pins it
      as the ceiling on how much the privacy engine may cost.
  secure_mask/fused_speedup — one client's upload: the fused single-pass
      masked-encode (mask streams folded into the accumulator one at a
      time, O(n) memory — the shape of the Pallas kernel) vs the naive
      two-pass that materializes all (J, n) mask streams before summing.
      Floored at 1.0: fusing must never lose to materialization.

On CPU both arms run the XLA ref path (the Pallas kernel itself targets
TPU and is validated, not timed, here — same policy as kernel_microbench).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FAST, row, save, time_fn
from repro.kernels.secure_mask import ref
from repro.kernels.secure_mask.ops import ring_size
from repro.privacy.secure_agg import ClearAggregator, SecureAggregator


def run():
    out, lines = {}, []
    key = jax.random.PRNGKey(0)
    iters = 3 if FAST else 5

    # ---- full-round aggregation: clear vs masked (K clients, 1 dropout)
    K = 8
    n_tail = (1 << 14) if FAST else (1 << 16)
    tree = {"tail": {"w": jax.random.normal(key, (K, n_tail))},
            "prompt": jax.random.normal(jax.random.fold_in(key, 1),
                                        (K, 16, 64))}
    fb = jax.tree.map(lambda x: jnp.zeros_like(x[0]), tree)
    w = jnp.arange(1.0, K + 1.0).at[2].set(0.0)   # client 2 dropped
    clear_agg, secure_agg = ClearAggregator(), SecureAggregator(impl="ref")
    clear = jax.jit(lambda t, w, r: clear_agg.aggregate(t, w, fb, r)[0])
    secure = jax.jit(lambda t, w, r: secure_agg.aggregate(t, w, fb, r)[0])
    t_clear = time_fn(clear, tree, w, jnp.int32(1), iters=iters)
    t_secure = time_fn(secure, tree, w, jnp.int32(1), iters=iters)
    out["secure_agg"] = {"ref_us": t_clear, "secure_us": t_secure}
    lines.append(row("privacy/secure_agg", t_secure,
                     f"clear={t_clear:.0f}us "
                     f"overhead={t_secure / t_clear:.1f}x"))

    # ---- one client's upload: fused single-pass vs naive materialization
    n = ring_size((1 << 18) if FAST else (1 << 20))
    J = K - 1
    x = jax.random.normal(key, (n,), jnp.float32)
    seeds = jax.random.bits(key, (J,), jnp.uint32)
    signs = jnp.where(jnp.arange(J) % 2 == 0, 1, -1).astype(jnp.int32)

    fused = jax.jit(lambda x, s, g: ref.masked_encode(x, s, g))

    def naive_fn(x, s, g):
        masks = jax.vmap(lambda si: ref.mask_stream(si, n))(s)   # (J, n)!
        signed = jnp.where(g[:, None] < 0, jnp.uint32(0) - masks, masks)
        signed = jnp.where(g[:, None] == 0, jnp.uint32(0), signed)
        return ref.encode(x) + signed.sum(0)

    naive = jax.jit(naive_fn)
    t_fused = time_fn(fused, x, seeds, signs, iters=iters)
    t_naive = time_fn(naive, x, seeds, signs, iters=iters)
    out["secure_mask"] = {"ref_us": t_naive, "fused_us": t_fused}
    lines.append(row("privacy/secure_mask_fused", t_fused,
                     f"naive={t_naive:.0f}us "
                     f"speedup={t_naive / t_fused:.2f}x"))

    save("secure_agg", out)
    return lines


if __name__ == "__main__":
    run()
