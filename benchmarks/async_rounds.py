"""Async round throughput: buffered-async runtime vs synchronous barrier.

Simulates BOTH runtimes clock-only (no training steps) under the `wan`
link regime (25 Mbps consumer uplinks, `fed.scheduler.LINK_REGIMES`) with
the same per-client latency distribution:

  * sync barrier — every round waits for the slowest of its K sampled
    clients (FederatedEngine's implicit semantics with no deadline), so
    the straggler tail of the whole cohort gates every aggregation;
  * buffered async — `AsyncRoundEngine` in clock-only mode (trainer=None):
    `concurrency` dispatch groups of `group_size` clients stream arrivals
    into a `buffer_size` buffer; the tail is paid per GROUP and groups
    overlap, so contributions/second go up.

The gated metric is `async_rounds/throughput_speedup` (contributions per
simulated second, async / sync) — machine-independent (pure simulation),
with a HARD floor of 1.5x in BENCH_kernels.json. The analytical twin
(`core.comm.async_vs_sync_round_time`, lognormal order statistics) is
reported alongside as `model_speedup` for a sim-vs-model crosscheck.
"""
from __future__ import annotations

from benchmarks.common import FAST, row, save
from repro.core.comm import async_vs_sync_round_time
from repro.fed import AsyncConfig, AsyncRoundEngine, ClientSampler
from repro.fed.scheduler import (LINK_REGIMES, RoundScheduler,
                                 StragglerConfig)

N_CLIENTS = 512
K = 32            # sync cohort == async clients in flight (fair compare)
GROUP = 4
CONCURRENCY = 8   # GROUP * CONCURRENCY == K
BUFFER = 8
ROUND_BYTES = 1e6
ROUND_FLOPS = 1e12


def run():
    scfg = StragglerConfig(regime="wan", deadline_factor=1e9)
    n_flushes = 25 if FAST else 100

    # ---- sync barrier: round time = slowest sampled client
    sched = RoundScheduler(scfg, seed=0,
                           round_bytes_per_client=ROUND_BYTES,
                           round_flops_per_client=ROUND_FLOPS)
    sampler = ClientSampler(N_CLIENTS, K, seed=0)
    n_rounds = max(10, n_flushes * BUFFER // K)
    t_sync, contrib_sync = 0.0, 0
    for r in range(n_rounds):
        plan = sched.plan(sampler.sample(r), r)
        t_sync += float(plan.latency_s.max())
        contrib_sync += plan.n_active
    sync_rate = contrib_sync / t_sync

    # ---- buffered async, clock-only (same latency model, tag-13 stream)
    eng = AsyncRoundEngine(
        None, None, ClientSampler(N_CLIENTS, K, seed=0),
        RoundScheduler(scfg, seed=0, round_bytes_per_client=ROUND_BYTES,
                       round_flops_per_client=ROUND_FLOPS),
        AsyncConfig(buffer_size=BUFFER, concurrency=CONCURRENCY,
                    group_size=GROUP))
    eng.init(None)
    m = eng.run_flushes(n_flushes)
    async_rate = m["arrivals"] / m["sim_seconds"]
    speedup = async_rate / sync_rate

    regime = LINK_REGIMES["wan"]
    twin = async_vs_sync_round_time(
        t_comm=ROUND_BYTES / regime["R"], t_comp=ROUND_FLOPS / regime["P_C"],
        K=K, buffer_size=BUFFER, concurrency=CONCURRENCY, group_size=GROUP,
        link_sigma=scfg.link_sigma, speed_sigma=scfg.speed_sigma,
        jitter_sigma=scfg.jitter_sigma)

    out = {"async_rounds": {
        "throughput_speedup": speedup,
        "model_speedup": twin["throughput_speedup"],
        "sync_contrib_per_s": sync_rate,
        "async_contrib_per_s": async_rate,
        "mean_staleness": m["mean_staleness"],
        "max_staleness": m["max_staleness"],
        "parallelism": eng.meter.overlap()["parallelism"],
    }}
    save("async_rounds", out)
    return [row("async_rounds/throughput", 0.0,
                f"async={async_rate:.1f}/s sync={sync_rate:.1f}/s "
                f"speedup={speedup:.2f}x (model {twin['throughput_speedup']:.2f}x) "
                f"staleness mean={m['mean_staleness']:.2f}")]


if __name__ == "__main__":
    run()
