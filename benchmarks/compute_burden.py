"""Paper Table 2 (right column): per-client computational burden in GFLOPs.

Paper values: ViT-Base  FL 16862.93 (1x), SFL 131.5 (0.0078x), SFPrompt 78.9
(0.0046x); ViT-Large FL 59685.79, SFL 175.34 (0.0029x), SFPrompt 105.2
(0.0017x).

Decoding the convention: FL = |D| x one-forward-pass MACs of the full model
(ViT-B: ~16.9 GMACs/image x 1000 — the paper counts multiply-accumulates,
not 2xMAC FLOPs; our 2xMAC number is exactly 2.08x theirs). SFL = same with
the client submodel only; SFPrompt = SFL x gamma_keep (78.9 / 131.5 = 0.600
exactly — confirming the gamma_keep = 0.6 calibration).
"""
from __future__ import annotations

from benchmarks.common import row, save
from repro.configs import get_config

PAPER = {
    "vit-base": {"FL": 16862.93, "SFL": 131.5, "SFPrompt": 78.9},
    "vit-large": {"FL": 59685.79, "SFL": 175.34, "SFPrompt": 105.2},
}
D = 1000
TOKENS = 197
GAMMA_KEEP = 0.6


def vit_forward_flops(cfg, n_layers=None):
    """Per-image forward FLOPs (2*mults) of a ViT stack."""
    L = cfg.n_layers if n_layers is None else n_layers
    Dm, F, T = cfg.d_model, cfg.d_ff, TOKENS
    att = cfg.attention
    per_layer = (2 * T * Dm * (att.n_heads * att.head_dim) * 2   # q,o
                 + 2 * T * Dm * (2 * att.n_kv_heads * att.head_dim)  # k,v
                 + 2 * 2 * T * T * att.n_heads * att.head_dim     # scores+av
                 + 2 * T * Dm * F * 2)                            # mlp
    patchify = 2 * TOKENS * (16 * 16 * 3) * Dm
    return L * per_layer + patchify


def run():
    out, lines = {}, []
    for arch in ("vit-base", "vit-large"):
        cfg = get_config(arch)
        # paper counts MACs: one MAC = one "FLOP" in their Table 2
        full = vit_forward_flops(cfg) * D / 1e9 / 2
        # paper's client = patch embed (+ task head): ~0 transformer layers
        client_paper_split = (vit_forward_flops(cfg, n_layers=0) * D / 1e9
                              / 2)
        # our production split keeps 1 cycle on the client (head) + 1 (tail)
        client_ours = vit_forward_flops(cfg, n_layers=2) * D / 1e9 / 2
        ours = {"FL": full,
                "SFL": client_paper_split + 0.0078 * 0,  # see note below
                "SFPrompt": client_paper_split * GAMMA_KEEP}
        # The paper's SFL client (131.5 GF) corresponds to ~0.78% of the
        # model: patch embed + norms + head. Our analytic patch-embed-only
        # number is the closest first-principles match:
        out[arch] = {
            "ours_gflops": {"FL": full,
                            "client_paper_split": client_paper_split,
                            "client_paper_split_pruned":
                                client_paper_split * GAMMA_KEEP,
                            "client_our_split_2cycles": client_ours},
            "paper_gflops": PAPER[arch],
            "fl_err_pct": 100 * (full - PAPER[arch]["FL"])
            / PAPER[arch]["FL"],
            "sfprompt_to_sfl_ratio_ours": GAMMA_KEEP,
            "sfprompt_to_sfl_ratio_paper":
                PAPER[arch]["SFPrompt"] / PAPER[arch]["SFL"],
        }
        lines.append(row(f"compute_burden/{arch}/FL", 0.0,
                         f"ours={full:.0f}GF paper={PAPER[arch]['FL']:.0f}GF "
                         f"err={out[arch]['fl_err_pct']:+.1f}%"))
        lines.append(row(
            f"compute_burden/{arch}/SFPrompt_vs_SFL", 0.0,
            f"ratio ours={GAMMA_KEEP:.3f} paper="
            f"{out[arch]['sfprompt_to_sfl_ratio_paper']:.3f}"))
    save("compute_burden", out)
    return lines


if __name__ == "__main__":
    run()
