"""Paper Fig 7: accuracy vs local-dataset pruning fraction. The paper finds
keeping only 20% of data costs ~3.4 points (IID) / pruning 80% costs ~4.3
points (non-IID) — i.e. the curve is FLAT. We sweep gamma (fraction pruned)
and validate the flatness claim."""
from __future__ import annotations

from benchmarks.common import row, save
from benchmarks._train_harness import run_method


def run():
    out, lines = {}, []
    for non_iid in (False, True):
        tag = "noniid" if non_iid else "iid"
        accs = {}
        for gamma in (0.0, 0.4, 0.8):
            r = run_method("sfprompt", "cifar10-syn", non_iid=non_iid,
                           gamma=gamma)
            accs[gamma] = r["best_acc"]
            lines.append(row(f"ablation_pruning/{tag}/gamma={gamma}", 0.0,
                             f"best={r['best_acc']:.3f}"))
        drop = accs[0.0] - accs[0.8]
        out[tag] = {"acc_by_gamma": accs, "drop_full_to_80pct_pruned": drop}
    save("ablation_pruning", out)
    return lines


if __name__ == "__main__":
    run()
