"""Paper Fig 6: SFPrompt with vs without the phase-1 local-loss update."""
from __future__ import annotations

from benchmarks.common import row, save
from benchmarks._train_harness import run_method


def run():
    out, lines = {}, []
    for arm, use_local in (("with_local_loss", True),
                           ("without_local_loss", False)):
        r = run_method("sfprompt", "cifar100-syn", non_iid=False,
                       use_local_loss=use_local, local_epochs=2)
        out[arm] = r
        lines.append(row(f"ablation_local_loss/{arm}", 0.0,
                         f"best={r['best_acc']:.3f} history={r['history']}"))
    out["claim_validated"] = (out["with_local_loss"]["best_acc"]
                              >= out["without_local_loss"]["best_acc"] - 0.02)
    save("ablation_local_loss", out)
    return lines


if __name__ == "__main__":
    run()
