"""Roofline assembly (deliverable g): per (arch x shape x mesh) table from
the dry-run artifacts in benchmarks/results/dryrun/.

Terms (seconds, per production step):
  compute_s    = HLO_FLOPs / (chips x 197 TFLOP/s)   [global flops / fleet]
  memory_hlo_s = HLO_bytes / (chips x 819 GB/s)      [UNFUSED upper bound:
                 pre-optimization HLO counts every intermediate]
  memory_est_s = analytic TPU-fused estimate (params read once per pass,
                 activations once per layer boundary, flash-attention-style
                 attention traffic, KV cache read per decode step)
  collective_s = trip-count-corrected collective bytes / 50 GB/s ICI
                 (x2(n-1)/n ring amplification applied for all-reduce)

Bottleneck classification uses (compute_s, memory_est_s, collective_s).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, row, save
from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.specs import SHAPES

DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")


def analytic_memory_bytes(cfg, shape, n_chips: int, microbatches: int,
                          kind: str) -> float:
    """Per-device HBM traffic estimate for one step, assuming TPU-grade
    fusion (attention via the flash kernel: q/k/v/o only)."""
    att = cfg.attention
    D = cfg.d_model
    L = cfg.n_layers
    # params: frozen bf16 read twice (fwd+bwd) per microbatch pass;
    # trainable f32 read+written with grads+momentum
    params = cfg.param_count()
    ptraffic = params * 2 * (2 * microbatches if kind == "train" else 1)
    if kind == "train":
        tail_frac = 1.0 / max(cfg.n_cycles, 2)
        ptraffic += params * tail_frac * 4 * 4   # f32 param/grad/mom traffic

    if kind == "train":
        tokens = shape.global_batch * shape.seq
        passes = 4.0  # fwd write + bwd read + remat recompute
    elif kind == "prefill":
        tokens = shape.global_batch * shape.seq
        passes = 2.0
    else:
        tokens = shape.global_batch
        passes = 2.0
    act = tokens * D * 2 * passes * L

    attn = 0.0
    cache = 0.0
    if att is not None:
        n_attn = L
        kvdim = 2 * att.n_kv_heads * att.head_dim
        if att.mla:
            kvdim = att.mla.kv_lora_rank + att.mla.qk_rope_head_dim
        if kind in ("train", "prefill"):
            attn = tokens * (att.q_dim + kvdim + att.q_dim) * 2 * n_attn
        else:
            w = shape.seq
            if shape.name == "long_500k" and cfg.long_context_window:
                w = cfg.long_context_window
            cache = shape.global_batch * w * kvdim * 2 * n_attn
    if cfg.mamba2 is not None and kind == "decode":
        m = cfg.mamba2
        cache += (shape.global_batch * m.n_heads(D) * m.head_dim *
                  m.d_state * 4 * L)
    if cfg.rwkv6 is not None and kind == "decode":
        r6 = cfg.rwkv6
        cache += (shape.global_batch * (D // r6.head_size) * r6.head_size ** 2
                  * 4 * L)

    logits = 0.0
    if kind == "train":
        logits = tokens * cfg.vocab_size * 4 * 2
    elif kind == "decode":
        logits = shape.global_batch * cfg.vocab_size * 4

    return (ptraffic + act + attn + cache + logits) / n_chips


def run():
    lines = []
    table = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("tag"):
            continue
        arch, shp, mesh = d["arch"], d["shape"], d["mesh"]
        cfg = get_config(arch)
        shape = SHAPES[shp]
        n = d["n_chips"]
        flops_g = d.get("hlo_flops_global", 0.0)
        # HloCostAnalysis counts ragged_dot (grouped GEMM) as a DENSE dot
        # over all E expert groups; only top_k paths execute. Subtract the
        # phantom (E-1)/E share of the three grouped GEMMs per MoE layer.
        if cfg.moe is not None and flops_g:
            e = cfg.moe
            if shape.kind == "train":
                toks = shape.global_batch * shape.seq
                grad_mult = 3.0   # fwd + dgrad + wgrad-DCE'd? dgrad only: 2
            elif shape.kind == "prefill":
                toks, grad_mult = shape.global_batch * shape.seq, 1.0
            else:
                toks, grad_mult = shape.global_batch, 1.0
            n_moe = cfg.n_cycles  # moe layers
            dense_ragged = (2 * toks * e.top_k * cfg.d_model * e.d_ff_expert
                            * 3 * e.n_experts * n_moe * grad_mult)
            phantom = dense_ragged * (e.n_experts - 1) / e.n_experts
            flops_g = max(flops_g - phantom, flops_g / e.n_experts)
        compute_s = flops_g / (n * PEAK_FLOPS_BF16)
        mem_hlo_s = d.get("hlo_bytes_global", 0.0) / (n * HBM_BW)
        mem_est = analytic_memory_bytes(cfg, shape, n,
                                        d.get("microbatches", 1), d["kind"])
        mem_est_s = mem_est / HBM_BW
        coll = d.get("collective_bytes", {})
        ar = coll.get("all-reduce", 0) * 2  # ring 2(n-1)/n ~ 2
        other = sum(v for k, v in coll.items()
                    if k not in ("all-reduce", "total"))
        coll_s = (ar + other) / ICI_BW
        terms = {"compute_s": compute_s, "memory_est_s": mem_est_s,
                 "collective_s": coll_s}
        bottleneck = max(terms, key=terms.get)
        mf = d.get("model_flops", 0.0)
        useful = mf / flops_g if flops_g else 0.0
        entry = {**terms, "memory_hlo_upper_s": mem_hlo_s,
                 "bottleneck": bottleneck, "model_flops": mf,
                 "useful_flops_frac": useful,
                 "per_device_gb": d.get("memory", {}).get(
                     "per_device_total_gb"),
                 "compile_s": d.get("compile_s")}
        table[f"{arch}|{shp}|{mesh}"] = entry
        if mesh == "pod16x16":
            lines.append(row(
                f"roofline/{arch}/{shp}", 0.0,
                f"bottleneck={bottleneck.replace('_s','')} "
                f"compute={compute_s:.2e}s mem={mem_est_s:.2e}s "
                f"coll={coll_s:.2e}s useful={useful:.2f}"))
    save("roofline", table)
    return lines


if __name__ == "__main__":
    run()
