"""Shared training harness for the accuracy-style benchmarks.

Mirrors the paper's setup at CPU scale: a tiny ViT "pre-trained" centrally
on a disjoint synthetic split (stand-in for ImageNet-21k), then federated
fine-tuning on the downstream synthetic task (IID or Dirichlet non-IID),
comparing SFPrompt against SFL+FF / SFL+Linear. Accuracy claims are
validated at the TREND level (orderings/deltas), per DESIGN.md §Notes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FAST
from repro.configs import get_config
from repro.core import (BaselineConfig, ProtocolConfig, SFLTrainer,
                        SFPromptTrainer, SplitConfig, SplitModel)
from repro.core import losses
from repro.data import (DATASETS, dirichlet_partition, iid_partition,
                        select_clients, stack_clients,
                        synthetic_image_dataset)
from repro.optim import apply_updates, sgd

KEY = jax.random.PRNGKey(0)
IMAGE_HW = 32
N_CLIENTS = 8
K = 3
ROUNDS = 2 if FAST else 8
PRETRAIN_STEPS = 8 if FAST else 80


def build_model(prompt_len=4, gamma=0.4, local_epochs=1, n_classes=10):
    import dataclasses
    cfg = get_config("vit-base").reduced(n_layers=4, d_model=96, d_ff=192)
    cfg = dataclasses.replace(cfg, num_classes=n_classes)  # match dataset
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=prompt_len,
                        prune_gamma=gamma, local_epochs=local_epochs)
    return cfg, split, SplitModel(cfg, split)


def pretrain_backbone(cfg, model, params, *, steps=PRETRAIN_STEPS, seed=42,
                      dataset="cifar10-syn"):
    """Centralized warm-start = the paper's 'pre-trained on ImageNet-21k'
    (same family, disjoint samples)."""
    pre = synthetic_image_dataset(DATASETS[dataset], 512, seed=seed,
                                  image_hw=IMAGE_HW)
    opt = sgd(0.05)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        def loss_fn(p):
            out = model.forward(p, batch, route="split", mode="train")
            return losses.task_loss(cfg, out, batch, impl="ref")[0]
        g = jax.grad(loss_fn)(params)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state

    for i in range(steps):
        sl = slice((i * 32) % 512, (i * 32) % 512 + 32)
        batch = {k: jnp.asarray(v[sl]) for k, v in pre.items()}
        params, opt_state = step(params, opt_state, batch)
    return params


def make_federation(dataset: str, *, non_iid: bool, n=960, seed=0):
    data = synthetic_image_dataset(DATASETS[dataset], n, seed=seed,
                                   image_hw=IMAGE_HW)
    test = synthetic_image_dataset(DATASETS[dataset], 512, seed=seed + 99,
                                   image_hw=IMAGE_HW)
    part = dirichlet_partition if non_iid else iid_partition
    kw = dict(alpha=0.1) if non_iid else {}
    return part(data, N_CLIENTS, seed=seed, **kw), test


def run_method(method: str, dataset: str, *, non_iid: bool,
               prompt_len=4, gamma=0.4, local_epochs=1, rounds=ROUNDS,
               use_local_loss=True, use_pruning=True, seed=0):
    cfg, split, model = build_model(prompt_len, gamma, local_epochs,
                                    n_classes=DATASETS[dataset].n_classes)
    clients, test = make_federation(dataset, non_iid=non_iid, seed=seed)

    if method == "sfprompt":
        tr = SFPromptTrainer(model, ProtocolConfig(
            clients_per_round=K, local_epochs=local_epochs, batch_size=16,
            lr_local=0.03, lr_split=0.03, momentum=0.0,
            use_local_loss=use_local_loss, use_pruning=use_pruning))
    elif method in ("sfl-ff", "sfl-linear"):
        tr = SFLTrainer(model, BaselineConfig(
            local_epochs=local_epochs, batch_size=16, lr=0.03,
            momentum=0.0), mode=method.split("-")[1])
    else:
        raise ValueError(method)

    state = tr.init(KEY)
    state = dict(state)
    state["params"] = pretrain_backbone(cfg, model, state["params"],
                                        dataset=dataset)
    evaluator = tr if hasattr(tr, "evaluate") else None
    history = []
    sfp_eval = SFPromptTrainer(model, ProtocolConfig())  # eval reuses forward
    for r in range(rounds):
        idx = select_clients(N_CLIENTS, K, seed=seed, round_idx=r)
        batch = {k: jnp.asarray(v) for k, v in
                 stack_clients(clients, idx).items()}
        state, _ = tr.round(state, batch)
        ev = sfp_eval.evaluate(state["params"], test, batch_size=32)
        history.append(ev["acc"])
    import numpy as _np
    # At this CPU scale every method OVERFITS the small synthetic federation
    # after a few rounds (train CE falls while eval acc decays) — the paper's
    # pretrained-backbone regime does not. Trend claims therefore use the
    # best-round accuracy; the smoothed final and full history are reported
    # alongside (EXPERIMENTS.md §Accuracy).
    return {"final_acc": float(_np.mean(history[-3:])),
            "best_acc": float(_np.max(history)),
            "history": history,
            "tuned_params": tuned_params(model, method, prompt_len)}


def tuned_params(model: SplitModel, method: str, prompt_len: int) -> int:
    import numpy as np
    shapes = jax.eval_shape(model.init, KEY)
    count = lambda t: sum(int(np.prod(s.shape)) for s in jax.tree.leaves(t))
    if method == "sfprompt":
        return count(shapes["tail"]) + count(shapes["prompt"])
    if method == "sfl-linear":
        return count(shapes["tail"]["head"])
    return count(shapes)  # full fine-tuning
