"""Kernel-level microbenchmarks on CPU (wall time of the XLA-native paths;
the Pallas kernels themselves target TPU and are validated, not timed, on
this host). Headline: the fused EL2N path avoids the (N, V) probability
round-trip — visible as wall-time + memory wins even on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, save, time_fn
from repro.kernels.el2n.ops import el2n_scores
from repro.kernels.flash_attention.ops import flash_attention


def run():
    out, lines = {}, []
    key = jax.random.PRNGKey(0)

    # EL2N: the one-pass fused identity (impl="fused" — no onehot, no
    # (N, V) probability materialization; the CPU surrogate of the Pallas
    # kernel) vs naive two-pass materialization. The "ref" impl is NOT the
    # fused arm: it materializes the same (N, V) temps as naive — timing it
    # here once produced an honest-looking 0.98x "regression".
    N, V = 2048, 32000
    logits = jax.random.normal(key, (N, V))
    labels = jax.random.randint(key, (N,), 0, V)

    def naive(lg, lb):
        probs = jax.nn.softmax(lg, -1)
        onehot = jax.nn.one_hot(lb, V)
        return jnp.linalg.norm(probs - onehot, axis=-1)

    fused = jax.jit(lambda lg, lb: el2n_scores(lg, lb, impl="fused")[0])
    naive_j = jax.jit(naive)
    t_fused = time_fn(fused, logits, labels, iters=5)
    t_naive = time_fn(naive_j, logits, labels, iters=5)
    out["el2n"] = {"fused_us": t_fused, "naive_us": t_naive,
                   "speedup": t_naive / t_fused}
    lines.append(row("kernel/el2n_fused", t_fused,
                     f"naive={t_naive:.0f}us speedup={t_naive/t_fused:.2f}x"))

    # attention: blocked (flash-style, O(S*block) memory) vs full ref
    B, S, H, D = 1, 2048, 8, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(key, (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(key, (B, S, H, D), jnp.bfloat16)
    ref_fn = jax.jit(lambda q, k, v: flash_attention(q, k, v, impl="ref"))
    blk_fn = jax.jit(lambda q, k, v: flash_attention(q, k, v, impl="blocked"))
    t_ref = time_fn(ref_fn, q, k, v, iters=3)
    t_blk = time_fn(blk_fn, q, k, v, iters=3)
    out["attention_2k"] = {"ref_us": t_ref, "blocked_us": t_blk}
    lines.append(row("kernel/attention_blocked", t_blk,
                     f"full_ref={t_ref:.0f}us"))
    save("kernel_microbench", out)
    return lines


if __name__ == "__main__":
    run()
