"""Decode fast-path throughput: scan stepping, decode attention, slot sweep.

Three measurements of the serving hot loop:

  decode_scan       — per-token dispatch (one jitted call + host sync per
                      token, the pre-fast-path ServeEngine loop) vs
                      `make_multi_decode_step` running the same per-token
                      body inside one lax.scan. The gated
                      `decode_scan/scan_speedup` ratio is the dispatch
                      amortization win at 8 slots (floor 2.0 in
                      BENCH_kernels.json).
  decode_attention  — single-query cache-read attention: the full-path jnp
                      oracle (GQA head repeat materialized at the group x
                      cache footprint) vs the decode-specialized grouped
                      path (`decode.py` impl='xla'); gated as
                      `decode_attention/fused_speedup`.
  slots             — end-to-end engine tokens/s vs slot count with the
                      fast path on (decode_block=8), informational.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, row, save, time_fn
from repro.configs import get_config
from repro.core import SplitConfig, SplitModel
from repro.kernels.flash_attention.decode import decode_attention
from repro.runtime import WireSpec
from repro.serve import (ServeConfig, ServeEngine, TenantBank,
                         WorkloadConfig, make_batched_decode_step,
                         make_multi_decode_step, synthetic_requests)

MAX_SEQ = 64
PROMPT_LEN = 4
SLOTS = 8
SCAN_BLOCK = 16


def build():
    cfg = get_config("qwen2.5-14b").reduced(
        n_layers=3, d_model=64, d_ff=128, vocab_size=256)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=PROMPT_LEN)
    model = SplitModel(cfg, split, WireSpec.make("int8"))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def bench_scan_stepping(model, params, out, lines):
    """Per-token dispatch vs scan stepping over the same decode body.

    Timing is BEST-of-reps: dispatch-cost noise is one-sided (contention
    only ever adds), so the minimum is the stable estimator for the ratio
    the hard floor in BENCH_kernels.json (scan_speedup >= 2.0) gates."""
    S = SLOTS
    shared = {"head": params["head"], "body": params["body"]}
    bank = TenantBank.replicate(params["tail"], params["prompt"], 2)
    cache = model.init_cache(S, seq_len=MAX_SEQ, dtype=jnp.float32)
    tenants = jnp.zeros((S,), jnp.int32)
    tokens = jnp.arange(S, dtype=jnp.int32) % 100
    pos = jnp.full((S,), PROMPT_LEN + 4, jnp.int32)
    active = jnp.ones((S,), jnp.float32)
    remaining = jnp.full((S,), 10_000, jnp.int32)

    one = jax.jit(make_batched_decode_step(model))
    multi = jax.jit(make_multi_decode_step(model, SCAN_BLOCK))
    total = 16 if FAST else 32
    reps = 5 if FAST else 8

    def per_token():
        # the pre-fast-path ServeEngine loop: one dispatch, one token sync,
        # AND one wire-bytes float() sync per generated token
        c, t, p = cache, tokens, pos
        for _ in range(total):
            t, _, c, wb = one(shared, bank.tails, tenants, t, p, active, c)
            t.block_until_ready()
            _ = {k: float(v) for k, v in wb.items()}
            p = p + 1
        return c

    def scanned():
        c, t, p = cache, tokens, pos
        wire = None
        for _ in range(total // SCAN_BLOCK):
            ts, _, c, wb = multi(shared, bank.tails, tenants, t, p,
                                 remaining, c)
            ts.block_until_ready()      # one sync per SCAN_BLOCK tokens
            wire = wb if wire is None else jax.tree.map(jnp.add, wire, wb)
            t, p = ts[-1], p + SCAN_BLOCK
        _ = {k: float(v) for k, v in wire.items()}   # one flush at exit
        return c

    def timeit(fn):
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0

    per_token(), scanned()               # warmup compiles
    # INTERLEAVED best-of-reps: host contention is one-sided noise and
    # hits whatever happens to be running — alternating the two loops and
    # taking each side's minimum keeps the gated ratio stable under load
    t_tok, t_scan = [], []
    for _ in range(reps):
        t_tok.append(timeit(per_token))
        t_scan.append(timeit(scanned))
    t_tok = min(t_tok) / total * 1e6
    t_scan = min(t_scan) / total * 1e6
    out["decode_scan"] = {"ref_us": t_tok, "scan_us": t_scan}
    lines.append(row("decode/scan_stepping", t_scan,
                     f"per_token={t_tok:.0f}us "
                     f"speedup={t_tok / t_scan:.2f}x @{S}slots"))


def bench_decode_attention(out, lines):
    """Full-path oracle vs the decode-specialized grouped attention."""
    B, W, Hq, Hkv, D = SLOTS, 512 if FAST else 2048, 32, 8, 64
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (B, 1, Hq, D))
    k = jax.random.normal(key, (B, W, Hkv, D))
    v = jax.random.normal(key, (B, W, Hkv, D))
    kvp = jnp.broadcast_to(jnp.arange(W, dtype=jnp.int32)[None], (B, W))
    qp = jnp.full((B,), W - 1, jnp.int32)

    full = jax.jit(lambda q, k, v: decode_attention(
        q, k, v, q_positions=qp, kv_positions=kvp, impl="ref"))
    fused = jax.jit(lambda q, k, v: decode_attention(
        q, k, v, q_positions=qp, kv_positions=kvp, impl="xla"))
    t_ref = time_fn(full, q, k, v, iters=3)
    t_fused = time_fn(fused, q, k, v, iters=3)
    out["decode_attention"] = {"ref_us": t_ref, "fused_us": t_fused}
    lines.append(row("decode/attention_fused", t_fused,
                     f"full_ref={t_ref:.0f}us "
                     f"speedup={t_ref / t_fused:.2f}x GQA{Hq // Hkv}x W={W}"))


def bench_slot_sweep(cfg, model, params, out, lines):
    """End-to-end engine tokens/s vs slot count, fast path on."""
    bank = TenantBank.replicate(params["tail"], params["prompt"], 2)
    sweep = (1, 4) if FAST else (1, 2, 4, 8)
    tok_per_s = {}
    for n_slots in sweep:
        wl = WorkloadConfig(
            n_requests=2 * n_slots, mean_interarrival=0.0,
            prompt_choices=(8, 16), new_token_choices=(16,),
            n_tenants=2, vocab_size=cfg.vocab_size, seed=0)
        reqs = synthetic_requests(wl)
        engine = ServeEngine(model, params, bank,
                             ServeConfig(n_slots=n_slots, max_seq=MAX_SEQ,
                                         max_queue=256,
                                         prefills_per_step=n_slots,
                                         decode_block=SCAN_BLOCK))
        engine.run(reqs)        # warmup compiles
        engine.reset_stats()
        t0 = time.perf_counter()
        stats = engine.run(reqs)
        wall = time.perf_counter() - t0
        tokens = int(np.sum([len(f.tokens) for f in stats["finished"]]))
        tok_per_s[str(n_slots)] = tokens / max(wall, 1e-9)
        lines.append(row(f"decode/tok_per_s_{n_slots}slots",
                         wall / max(1, tokens) * 1e6,
                         f"{tokens / max(wall, 1e-9):.1f} tok/s"))
    out["slots"] = {"tok_per_s": tok_per_s, "decode_block": SCAN_BLOCK}


def run():
    out, lines = {}, []
    cfg, model, params = build()
    bench_scan_stepping(model, params, out, lines)
    bench_decode_attention(out, lines)
    bench_slot_sweep(cfg, model, params, out, lines)
    save("decode_throughput", out)
    return lines


if __name__ == "__main__":
    run()
