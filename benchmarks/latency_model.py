"""Paper Table 1: per-round latency model for FL / SFL / SFPrompt across
link-rate and client-compute regimes. Demonstrates the paper's crossover
claim: SFPrompt wins once |W| > 2*q*gamma/(alpha+tau) * |D| (large models,
constrained links).

The (R, P_C, P_S) regime constants live in `repro.fed.scheduler` — the same
numbers drive the straggler simulation's per-client latency model, so the
Table-1 analysis and the population engine cannot drift apart."""
from __future__ import annotations

from benchmarks.common import row, save
from repro.configs import get_config
from repro.core.comm import cost_inputs_from, summarize
from repro.core.split import SplitConfig
from repro.fed.scheduler import LINK_REGIMES


def run():
    out, lines = {}, []
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=16,
                        prune_gamma=0.4)
    regimes = LINK_REGIMES
    for arch in ("vit-base", "vit-large", "stablelm-12b", "nemotron-4-340b"):
        cfg = get_config(arch)
        toks = 197 if cfg.arch_type == "vit" else 512
        for rname, rkw in regimes.items():
            ci = cost_inputs_from(cfg, split, tokens_per_sample=toks,
                                  D=1000, K=5, U=10, bytes_smashed=1.0,
                                  **rkw)
            s = summarize(ci)
            lat = {m: s[m]["latency_s"] for m in s}
            out[f"{arch}/{rname}"] = lat
            best = min(lat, key=lat.get)
            lines.append(row(
                f"latency/{arch}/{rname}", 0.0,
                f"FL={lat['FL']:.1f}s SFL={lat['SFL']:.1f}s "
                f"SFPrompt={lat['SFPrompt']:.1f}s best={best}"))
    # crossover check (Sec 3.5): SFPrompt beats FL when W large
    save("latency_model", out)
    return lines


if __name__ == "__main__":
    run()
