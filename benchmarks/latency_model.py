"""Paper Table 1: per-round latency model for FL / SFL / SFPrompt across
link-rate and client-compute regimes. Demonstrates the paper's crossover
claim: SFPrompt wins once |W| > 2*q*gamma/(alpha+tau) * |D| (large models,
constrained links)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import row, save
from repro.configs import get_config
from repro.core.comm import cost_inputs_from, summarize
from repro.core.split import SplitConfig


def run():
    out, lines = {}, []
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=16,
                        prune_gamma=0.4)
    regimes = {
        "edge_wan": dict(R=12.5e6, P_C=5e12, P_S=500e12),     # 100 Mbps
        "fiber": dict(R=125e6, P_C=5e12, P_S=500e12),         # 1 Gbps
        "datacenter": dict(R=12.5e9, P_C=50e12, P_S=5000e12),
    }
    for arch in ("vit-base", "vit-large", "stablelm-12b", "nemotron-4-340b"):
        cfg = get_config(arch)
        toks = 197 if cfg.arch_type == "vit" else 512
        for rname, rkw in regimes.items():
            ci = cost_inputs_from(cfg, split, tokens_per_sample=toks,
                                  D=1000, K=5, U=10, bytes_smashed=1.0,
                                  **rkw)
            s = summarize(ci)
            lat = {m: s[m]["latency_s"] for m in s}
            out[f"{arch}/{rname}"] = lat
            best = min(lat, key=lat.get)
            lines.append(row(
                f"latency/{arch}/{rname}", 0.0,
                f"FL={lat['FL']:.1f}s SFL={lat['SFL']:.1f}s "
                f"SFPrompt={lat['SFPrompt']:.1f}s best={best}"))
    # crossover check (Sec 3.5): SFPrompt beats FL when W large
    save("latency_model", out)
    return lines


if __name__ == "__main__":
    run()
