"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
FAST = bool(int(os.environ.get("REPRO_BENCH_FAST", "0")))


def save(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (jit-compiled callables)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line
