"""Paper Table 3 / Fig 4 (trend-level): SFPrompt vs SFL+FF vs SFL+Linear on
IID and non-IID synthetic downstream tasks. Validated claims: SFPrompt is
competitive with full fine-tuning and >= linear probing, with the gap
growing on harder/non-IID tasks; it tunes ~0.2% of parameters."""
from __future__ import annotations

from benchmarks.common import row, save
from benchmarks._train_harness import run_method


def run():
    out, lines = {}, []
    for dataset in ("cifar10-syn", "cifar100-syn"):
        for non_iid in (False, True):
            tag = f"{dataset}/{'noniid' if non_iid else 'iid'}"
            res = {}
            for method in ("sfprompt", "sfl-ff", "sfl-linear"):
                r = run_method(method, dataset, non_iid=non_iid)
                res[method] = r
                lines.append(row(
                    f"accuracy/{tag}/{method}", 0.0,
                    f"best={r['best_acc']:.3f} final={r['final_acc']:.3f} "
                    f"tuned={r['tuned_params']}"))
            out[tag] = res
    save("accuracy", out)
    return lines


if __name__ == "__main__":
    run()
