"""EL2N dataset-pruning demo (paper Sec. 3.2 / Fig 7 mechanism).

Scores a client's local dataset with the W_h->W_t local model (body
skipped!), shows that samples with label noise / low class-signal get the
HIGHEST EL2N scores, and that pruning keeps the informative examples.

  PYTHONPATH=src python examples/el2n_pruning_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import SplitConfig, SplitModel
from repro.core.pruning import prune_indices, score_client_data
from repro.data import DATASETS, synthetic_image_dataset

cfg = get_config("vit-base").reduced(n_layers=4, d_model=96, d_ff=192)
split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4,
                    prune_gamma=0.5)
model = SplitModel(cfg, split)
params = model.init(jax.random.PRNGKey(0))

# EL2N needs a minimally-trained model (the paper scores AFTER the phase-1
# local-loss update) — warm up with a few local steps first
from repro.core import losses
from repro.optim import apply_updates, sgd

warm = synthetic_image_dataset(DATASETS["cifar10-syn"], 256, seed=9,
                               image_hw=32)
opt = sgd(0.05)
opt_state = opt.init(params)


@jax.jit
def wstep(params, opt_state, b):
    g = jax.grad(lambda p: losses.task_loss(
        cfg, model.forward(p, b, route="local", mode="train"), b,
        impl="ref")[0])(params)
    upd, opt_state = opt.update(g, opt_state, params)
    return apply_updates(params, upd), opt_state


for i in range(12):
    sl = slice((i * 16) % 256, (i * 16) % 256 + 16)
    params, opt_state = wstep(params, opt_state,
                              {k: jnp.asarray(v[sl]) for k, v in warm.items()})

# client dataset: 128 clean samples + 32 label-corrupted ones
clean = synthetic_image_dataset(DATASETS["cifar10-syn"], 128, image_hw=32)
noisy = synthetic_image_dataset(DATASETS["cifar10-syn"], 32, seed=3,
                                image_hw=32)
noisy["labels"] = (noisy["labels"] + 1) % 10  # corrupt the labels
data = {k: jnp.asarray(np.concatenate([clean[k], noisy[k]]))
        for k in clean}
is_noisy = np.arange(160) >= 128

scores = score_client_data(model, params["head"], params["tail"],
                           params["prompt"], data, batch_size=16)
scores = np.asarray(scores)
print(f"mean EL2N  clean: {scores[~is_noisy].mean():.4f}   "
      f"corrupted: {scores[is_noisy].mean():.4f}")

kept = np.asarray(prune_indices(jnp.asarray(scores), split.prune_gamma))
frac_noisy_kept = is_noisy[kept].mean()
print(f"pruning gamma={split.prune_gamma}: kept {len(kept)}/160 samples, "
      f"{frac_noisy_kept:.0%} of kept are corrupted "
      f"(corrupted = high-EL2N = retained, per Eq. 2: hard examples matter)")
print("top-10 EL2N sample indices:", kept[:10].tolist())
