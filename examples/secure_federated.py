"""Privacy engine end-to-end: blind aggregation + a DP epsilon ledger.

  PYTHONPATH=src python examples/secure_federated.py [--rounds 4]

What it shows, in order:
  1. Two identical federated runs — one aggregating in the clear, one
     through masked secure aggregation — whose global params agree to
     fixed-point tolerance every round, INCLUDING rounds where the
     straggler scheduler drops clients (escrowed-seed recovery).
  2. What the server actually receives on the secure path: a uint32 ring
     tensor statistically independent of any single client's update.
  3. The wire price of blindness: the metered secure/params streams vs
     the analytical `comm.secure_agg_breakdown`.
  4. A DP-metered run: per-round clipped + noised client deltas and the
     zCDP ledger composing round over round toward its calibrated
     (epsilon, delta) target.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.core.aggregation import get_aggregator
from repro.core.comm import secure_agg_breakdown
from repro.data import DATASETS, synthetic_image_dataset
from repro.fed import (ClientSampler, FederatedEngine, Population,
                       RoundScheduler, StragglerConfig)
from repro.privacy import calibrate_noise
from repro.privacy.fixed_point import roundtrip_tol
from repro.runtime import WireSpec


def build_engine(cfg, split, data, args, *, secure=False, dp_noise=0.0):
    pop = Population.from_partition(data, args.clients, scheme="dirichlet",
                                    alpha=0.1, seed=args.seed)
    model = SplitModel(cfg, split, WireSpec.make("fp32"))
    pcfg = ProtocolConfig(clients_per_round=args.k, local_epochs=1,
                          batch_size=args.batch, momentum=0.0,
                          dp_clip=(1.0 if dp_noise > 0 else 0.0),
                          dp_noise_multiplier=dp_noise, dp_delta=1e-5)
    aggregator = get_aggregator(secure=secure, seed=args.seed) if secure \
        else None
    trainer = SFPromptTrainer(model, pcfg, aggregator)
    sampler = ClientSampler(pop.n_clients, args.k, seed=args.seed)
    sched = RoundScheduler(StragglerConfig(dropout_rate=0.25), seed=args.seed)
    return FederatedEngine(trainer, pop, sampler, sched)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=40)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--epsilon", type=float, default=8.0,
                    help="DP target epsilon over the whole run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config("vit-base").reduced(n_layers=3, d_model=32, d_ff=64)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2,
                        prune_gamma=0.3, local_epochs=1)
    data = synthetic_image_dataset(DATASETS["cifar10-syn"],
                                   args.clients * 8, seed=args.seed,
                                   image_hw=32)

    # ---- 1. clear vs secure: same rounds, same dropouts, same params.
    # The secure engine re-syncs to the clear state before every round so
    # each comparison isolates THAT round's aggregation error (fixed-point
    # only) — without the re-sync the tiny per-round difference would be
    # amplified by the next round's local training and compound.
    clear = build_engine(cfg, split, data, args)
    secure = build_engine(cfg, split, data, args, secure=True)
    clear.init(jax.random.PRNGKey(args.seed))
    secure.init(jax.random.PRNGKey(args.seed))
    tol = roundtrip_tol(args.k)
    for _ in range(args.rounds):
        r = clear.round_idx
        secure.state = jax.tree.map(jnp.asarray, clear.state)
        plan, _ = clear.run_round()
        _, ms = secure.run_round()
        err = max(
            float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(clear.state["params"]),
                            jax.tree.leaves(secure.state["params"])))
        print(f"round {r}: dropped={int(plan.dropped.sum())} "
              f"|clear - secure|_max={err:.2e} (tol {tol:.2e}) "
              f"secure_wire={ms['wire/secure_bytes']:.0f}B")
        assert err <= tol, "secure aggregation diverged from clear FedAvg"

    # ---- 2. the server's view: a blinded ring tensor
    print("\nserver-side view of one upload (uint32 ring, masked):")
    tr = secure.trainer
    params = {"tail": tr.model.init(jax.random.PRNGKey(1))["tail"]}
    from repro.privacy.fixed_point import flatten_tree
    from repro.kernels.secure_mask.ops import masked_encode
    flat, *_ = flatten_tree(
        jax.tree.map(lambda x: x[None], params))
    upload = masked_encode(flat[0], jnp.asarray([7, 11], jnp.uint32),
                           jnp.asarray([1, -1], jnp.int32), impl="ref")
    print(f"  first 6 words: {np.asarray(upload[:6])}")
    print(f"  high-bit frequency: {float(jnp.mean(upload >> 31)):.3f} "
          f"(uniform = 0.5)")

    # ---- 3. measured vs analytical secure wire bytes (cumulative)
    trainable = {"tail": secure.state["params"]["tail"],
                 "prompt": secure.state["params"]["prompt"]}
    n_tr = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(trainable))
    pb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(trainable))
    measured = secure.trainer.meter.totals
    uploads = secure.trainer.meter.client_rounds
    bd = secure_agg_breakdown(
        n_trainable=n_tr, param_nbytes=pb, K=args.k,
        n_uploads=uploads / max(1, args.rounds))
    per_round = {k: v / args.rounds for k, v in measured.items()}
    print("\nwire bytes per round, measured vs analytical:")
    for name in ("params", "secure"):
        print(f"  {name:>7}: measured={per_round[name]:.0f}  "
              f"analytical={bd[name]:.0f}")

    # ---- 4. DP-metered run: the epsilon ledger
    z = calibrate_noise(args.epsilon, 1e-5, args.rounds)
    print(f"\nDP run: target eps={args.epsilon} at delta=1e-5 over "
          f"{args.rounds} rounds -> noise multiplier z={z:.3f}")
    dp = build_engine(cfg, split, data, args, secure=True, dp_noise=z)
    dp.init(jax.random.PRNGKey(args.seed))
    for _ in range(args.rounds):
        r = dp.round_idx
        _, m = dp.run_round()
        print(f"  round {r}: split_loss={m['split_loss']:.3f} "
              f"delta_norm={m['dp/delta_norm']:.3f} "
              f"eps so far={m['dp/epsilon']:.3f}")
    print(dp.trainer.accountant.report())


if __name__ == "__main__":
    main()
