"""End-to-end driver (deliverable b): the paper's full experimental loop.

1. "Pre-train" a ViT backbone centrally on a disjoint synthetic corpus
   (stand-in for ImageNet-21k).
2. Federated fine-tuning on the downstream task with SFPrompt, logging
   per-round accuracy, comm bytes (from the Table-1 cost model bound to this
   exact model/split) and client FLOPs.
3. Compare against SFL+FF and SFL+Linear on the same federation.

  PYTHONPATH=src python examples/federated_finetune.py [--rounds 8]
  PYTHONPATH=src python examples/federated_finetune.py --large   # ~100M model

The --large variant instantiates a ~100M-param ViT; rounds take minutes on a
single CPU core, so the default is a ~5M model with identical structure.
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (BaselineConfig, ProtocolConfig, SFLTrainer,
                        SFPromptTrainer, SplitConfig, SplitModel)
from repro.core import losses
from repro.core.comm import cost_inputs_from, fl_comm, sfl_comm, sfprompt_comm
from repro.data import (DATASETS, dirichlet_partition, iid_partition,
                        select_clients, stack_clients,
                        synthetic_image_dataset)
from repro.optim import apply_updates, sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--large", action="store_true",
                    help="~100M-param ViT instead of the ~5M default")
    ap.add_argument("--pretrain-steps", type=int, default=40)
    ap.add_argument("--out", default="runs/federated_finetune")
    args = ap.parse_args()

    if args.large:
        cfg = get_config("vit-base").reduced(
            n_layers=12, d_model=768, d_ff=3072, max_seq_len=512)
        image_hw, batch = 64, 8
    else:
        cfg = get_config("vit-base").reduced(n_layers=4, d_model=128,
                                             d_ff=256)
        image_hw, batch = 32, 16
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=8,
                        prune_gamma=0.4, local_epochs=2)
    model = SplitModel(cfg, split)
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params, split "
          f"alpha/tau = {model.segment_fractions()}")

    data = synthetic_image_dataset(DATASETS["cifar100-syn"], 800,
                                   image_hw=image_hw)
    test = synthetic_image_dataset(DATASETS["cifar100-syn"], 128, seed=7,
                                   image_hw=image_hw)
    part = dirichlet_partition if args.non_iid else iid_partition
    clients = part(data, args.clients)

    # ---------- 1. centralized pre-training of the backbone
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    pre = synthetic_image_dataset(DATASETS["cifar100-syn"], 512, seed=42,
                                  image_hw=image_hw)
    opt = sgd(0.05)
    opt_state = opt.init(params)

    @jax.jit
    def pstep(params, opt_state, b):
        g = jax.grad(lambda p: losses.task_loss(
            cfg, model.forward(p, b, route="split", mode="train"), b,
            impl="ref")[0])(params)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state

    t0 = time.time()
    for i in range(args.pretrain_steps):
        sl = slice((i * batch) % 512, (i * batch) % 512 + batch)
        params, opt_state = pstep(
            params, opt_state, {k: jnp.asarray(v[sl]) for k, v in pre.items()})
    print(f"pretrained backbone in {time.time()-t0:.1f}s")

    # ---------- 2/3. federated fine-tuning, three methods
    ci = cost_inputs_from(cfg, split, tokens_per_sample=(image_hw // 16) ** 2,
                          D=len(clients[0]["labels"]), K=args.k,
                          U=split.local_epochs, bytes_smashed=1.0)
    comm = {"sfprompt": sfprompt_comm(ci), "sfl-ff": sfl_comm(ci),
            "sfl-linear": sfl_comm(ci), "fl(ref)": fl_comm(ci)}

    os.makedirs(args.out, exist_ok=True)
    results = {}
    for method in ("sfprompt", "sfl-ff", "sfl-linear"):
        if method == "sfprompt":
            tr = SFPromptTrainer(model, ProtocolConfig(
                clients_per_round=args.k, local_epochs=split.local_epochs,
                batch_size=batch, lr_local=0.03, lr_split=0.03,
                momentum=0.0))
        else:
            tr = SFLTrainer(model, BaselineConfig(
                local_epochs=split.local_epochs, batch_size=batch, lr=0.03),
                mode=method.split("-")[1])
        state = tr.init(key)
        state = dict(state)
        state["params"] = jax.tree.map(jnp.copy, params)
        evaluator = SFPromptTrainer(model, ProtocolConfig())
        hist = []
        for r in range(args.rounds):
            idx = select_clients(args.clients, args.k, seed=0, round_idx=r)
            bt = {k: jnp.asarray(v) for k, v in
                  stack_clients(clients, idx).items()}
            state, m = tr.round(state, bt)
            ev = evaluator.evaluate(state["params"], test, batch_size=32)
            hist.append(ev["acc"])
            print(f"[{method}] round {r}: acc={ev['acc']:.3f} "
                  f"(train metrics {m})", flush=True)
        results[method] = {"history": hist, "final_acc": hist[-1],
                           "comm_bytes_per_round": comm[method]}

    with open(os.path.join(args.out, "results.json"), "w") as f:
        json.dump(results, f, indent=1)
    print(json.dumps({m: {"final_acc": r["final_acc"],
                          "comm_MB_per_round": r["comm_bytes_per_round"] / 2**20}
                      for m, r in results.items()}, indent=1))


if __name__ == "__main__":
    main()
