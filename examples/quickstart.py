"""Quickstart: SFPrompt in ~40 lines.

Splits a ViT three ways (client head / server body / client tail), runs two
full three-phase federated rounds (local-loss self-update -> EL2N pruning ->
split training -> FedAvg of tail+prompt) on synthetic data, and evaluates.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.data import (DATASETS, iid_partition, select_clients,
                        stack_clients, synthetic_image_dataset)

# 1. a reduced ViT-Base and its three-way split
cfg = get_config("vit-base").reduced(n_layers=4, d_model=96, d_ff=192)
split = SplitConfig(head_cycles=1, tail_cycles=1,  # W_h | W_b | W_t
                    prompt_len=8,                  # soft prompt tokens
                    prune_gamma=0.4,               # drop 40% by EL2N
                    local_epochs=2)                # U
model = SplitModel(cfg, split)
alpha, tau = model.segment_fractions()
print(f"split fractions: head={alpha:.1%} body={tau:.1%} "
      f"tail={1 - alpha - tau:.1%} of |W|")

# 2. a 10-client federation over synthetic CIFAR-like data
data = synthetic_image_dataset(DATASETS["cifar10-syn"], 600, image_hw=32)
test = synthetic_image_dataset(DATASETS["cifar10-syn"], 128, seed=1,
                               image_hw=32)
clients = iid_partition(data, 10)

# 3. the three-phase trainer
trainer = SFPromptTrainer(model, ProtocolConfig(
    clients_per_round=4, local_epochs=2, batch_size=16,
    lr_local=0.03, lr_split=0.03, momentum=0.0))
state = trainer.init(jax.random.PRNGKey(0))

print("before:", trainer.evaluate(state["params"], test))
for r in range(2):
    idx = select_clients(10, 4, seed=0, round_idx=r)
    batch = {k: jnp.asarray(v) for k, v in stack_clients(clients, idx).items()}
    state, metrics = trainer.round(state, batch)
    print(f"round {r}: {metrics}")
print("after:", trainer.evaluate(state["params"], test))
