"""Fine-tune, personalize, then SERVE: the full SFPrompt lifecycle.

  PYTHONPATH=src python examples/serve_tenants.py [--rounds 2]

What it shows, in order:
  1. A small federated LM run (SFPromptTrainer + FederatedEngine) with
     `return_client_trainable=True`, so the Population stores each sampled
     client's post-round personalized tail.
  2. A `TenantBank` built straight from those population tails
     (`TenantBank.from_population`) — every former client becomes a
     serving TENANT with its own (tail, prompt) over the shared frozen
     body.
  3. The continuous-batching `ServeEngine` driving a deterministic
     Poisson workload where requests from different tenants join the same
     in-flight batch, with measured wire bytes vs the analytical
     per-token model.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.core.comm import serve_comm_breakdown
from repro.data import synthetic_lm_dataset
from repro.fed import ClientSampler, FederatedEngine, Population
from repro.runtime import WireSpec
from repro.runtime.meter import MB
from repro.serve import (ServeConfig, ServeEngine, TenantBank,
                         WorkloadConfig, synthetic_requests)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--wire", default="int8")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config("qwen2.5-14b").reduced(
        n_layers=3, d_model=64, d_ff=128, vocab_size=256)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4,
                        prune_gamma=0.3, local_epochs=1)
    model = SplitModel(cfg, split, WireSpec.make(args.wire))

    # ---- 1. federate with personalized tails
    data = synthetic_lm_dataset(args.clients * 16, seq_len=24,
                                vocab=cfg.vocab_size, seed=args.seed)
    pop = Population.from_partition(data, args.clients, scheme="iid",
                                    seed=args.seed)
    trainer = SFPromptTrainer(model, ProtocolConfig(
        clients_per_round=args.k, local_epochs=1, batch_size=4,
        momentum=0.0, return_client_trainable=True))
    sampler = ClientSampler(pop.n_clients, args.k, kind="round_robin",
                            seed=args.seed)
    engine = FederatedEngine(trainer, pop, sampler,
                             personalize_tails=True)
    engine.init(jax.random.PRNGKey(args.seed))
    for _ in range(args.rounds):
        plan, m = engine.run_round()
        print(f"round {engine.round_idx - 1}: cohort="
              f"{plan.cohort.tolist()} split_loss={m['split_loss']:.3f}")
    params = engine.state["params"]
    personalized = sorted(pop._tails)
    print(f"population now holds {len(personalized)} personalized tails: "
          f"clients {personalized}")

    # ---- 2. clients become serving tenants
    tenant_ids = list(range(args.clients))
    bank = TenantBank.from_population(pop, tenant_ids, params["tail"],
                                      params["prompt"])
    print(f"TenantBank: {bank.n_tenants} tenants, "
          f"{bank.nbytes() / MB:.2f} MB of personalized (tail, prompt)")

    # ---- 3. serve a mixed-tenant workload
    serve = ServeEngine(model, params, bank,
                        ServeConfig(n_slots=args.slots, max_seq=64))
    reqs = synthetic_requests(WorkloadConfig(
        n_requests=args.requests, mean_interarrival=0.5,
        prompt_choices=(8, 16), new_token_choices=(4, 8),
        n_tenants=bank.n_tenants, vocab_size=cfg.vocab_size,
        seed=args.seed))
    stats = serve.run(reqs)
    served = [f.req for f in stats["finished"]]   # rejected requests
    # never crossed the wire, so the analytical model excludes them too
    analytical = serve_comm_breakdown(
        model.wire, d_model=cfg.d_model, soft_prompt_len=split.prompt_len,
        requests=[(len(r.tokens), r.max_new) for r in served])
    print(f"served {stats['n_finished']} requests "
          f"({stats['tokens_out']} tokens) at occupancy "
          f"{stats['occupancy']:.2f}; p50 "
          f"{stats['p50_latency_s'] * 1e3:.0f} ms, p99 "
          f"{stats['p99_latency_s'] * 1e3:.0f} ms")
    meas = stats["wire_bytes"]
    ana = sum(analytical.values())
    print(f"wire [{model.wire.describe()}]: {meas['total'] / MB:.3f} MB "
          f"measured vs {ana / MB:.3f} MB analytical "
          f"({100 * abs(meas['total'] - ana) / ana:.1f}% apart)")
    # tenants with personalized tails answer differently from the global
    # tail for the same prompt — the personalization is live in serving
    finished = {f.req.rid: f for f in stats["finished"]}
    by_tenant = {}
    for r in served:
        by_tenant.setdefault(r.tenant, finished[r.rid].tokens[:3])
    uniq = {tuple(np.asarray(v).tolist()) for v in by_tenant.values()}
    print(f"{len(by_tenant)} tenants produced {len(uniq)} distinct "
          f"3-token openings")


if __name__ == "__main__":
    main()
