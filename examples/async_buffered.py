"""Buffered-async federation: the equivalence anchor, then the payoff.

  PYTHONPATH=src python examples/async_buffered.py [--flushes 6]

What it shows, in order:
  1. The anchor: with buffer_size == K, concurrency 1, and
     staleness_beta 0, the AsyncRoundEngine reproduces the synchronous
     FederatedEngine's round BIT-EXACTLY — every aggregated param leaf
     and every metered byte — because async dispatch reuses the same
     compiled round. This is what licenses comparing async runs against
     their synchronous baselines.
  2. The payoff: the same protocol under the 25 Mbps `wan` regime with
     stragglers and dropouts, buffer smaller than the cohort and
     overlapping dispatch groups — flushes land on the simulated clock
     while slow clients are still in flight, the staleness ledger tracks
     how stale their updates were when applied, and the meter's
     wall-clock streams report how much client compute + wire time
     overlapped inside the span (the "parallelism" the barrier forfeits).
  3. Composition: the flush is the secure-aggregation cohort — the same
     async run aggregating through the masked uint32 ring (dropped
     clients become zero-weight rows, recovered via escrowed seeds)
     stays within fixed-point tolerance of the clear run.

docs/ROUND_LIFECYCLE.md tells the same story in prose.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.core.aggregation import get_aggregator
from repro.data import DATASETS, synthetic_image_dataset
from repro.fed import (AsyncConfig, AsyncRoundEngine, ClientSampler,
                       FederatedEngine, Population, RoundScheduler,
                       StragglerConfig)
from repro.privacy.fixed_point import roundtrip_tol
from repro.runtime import WireSpec


def build(args, data, cfg, split, *, scheduler=None, acfg=None,
          aggregator=None):
    """One engine — sync barrier if acfg is None, buffered async else."""
    pop = Population.from_partition(data, args.clients, scheme="dirichlet",
                                    alpha=0.1, seed=args.seed)
    model = SplitModel(cfg, split, WireSpec.make("fp32"))
    pcfg = ProtocolConfig(clients_per_round=args.k, local_epochs=1,
                          batch_size=args.batch, momentum=0.0,
                          return_client_trainable=True)
    trainer = SFPromptTrainer(model, pcfg)
    sampler = ClientSampler(pop.n_clients, args.k, seed=args.seed)
    if acfg is None:
        return FederatedEngine(trainer, pop, sampler, scheduler)
    return AsyncRoundEngine(trainer, pop, sampler, scheduler, acfg,
                            aggregator=aggregator)


def leaf_diffs(a, b):
    return sum(not np.array_equal(x, y)
               for x, y in zip(jax.tree.leaves(jax.tree.map(np.asarray, a)),
                               jax.tree.leaves(jax.tree.map(np.asarray, b))))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=40)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--flushes", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    key = jax.random.PRNGKey(args.seed)
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=32, d_ff=64)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2,
                        prune_gamma=0.3, local_epochs=1)
    data = synthetic_image_dataset(DATASETS["cifar10-syn"], args.clients * 8,
                                   seed=args.seed, image_hw=32)

    # ---- 1. the anchor: async(buffer=K, conc=1, beta=0) == sync, bitwise
    sync = build(args, data, cfg, split)
    sync.init(key)
    sync.run_round()
    anchored = build(args, data, cfg, split,
                     acfg=AsyncConfig(buffer_size=args.k, concurrency=1,
                                      staleness_beta=0.0))
    anchored.init(key)
    anchored.run_flushes(1)
    bad = leaf_diffs(sync.params, anchored.params)
    sm, am = sync.trainer.meter.as_dict(), anchored.meter.as_dict()
    meter_ok = all(sm[k] == am.get(k) for k in sm)
    print(f"anchor: {bad} param leaves differ, meter "
          f"{'identical' if meter_ok else 'MISMATCH'} "
          f"({sm['params']:.0f} param bytes both ways)")
    assert bad == 0 and meter_ok, "async lost bit-identity with the barrier"

    # ---- 2. the payoff: WAN stragglers, overlap, staleness
    scfg = StragglerConfig(regime="wan", dropout_rate=0.15)
    acfg = AsyncConfig(buffer_size=3, concurrency=2, group_size=args.k // 2,
                       staleness_beta=0.5)
    sched = RoundScheduler(scfg, seed=args.seed,
                           round_bytes_per_client=1e6,
                           round_flops_per_client=1e12)
    eng = build(args, data, cfg, split, scheduler=sched, acfg=acfg)
    eng.init(key)
    stats = eng.run_flushes(args.flushes)
    ov = eng.meter.overlap()
    print(f"\nwan run: {stats['flushes']:.0f} flushes from "
          f"{stats['arrivals']:.0f} arrivals in {stats['sim_seconds']:.1f} "
          f"simulated s ({stats['flushes_per_s']:.3f} flush/s)")
    print(f"staleness: mean {stats['mean_staleness']:.2f}, "
          f"max {stats['max_staleness']:.0f} versions")
    print(f"overlap: {ov['parallelism']:.2f}x work-seconds per span-second "
          f"(client compute {ov['client_compute_s']:.2f} + "
          f"wire {ov['wire_s']:.2f} + server {ov['server_busy_s']:.2f})")

    # ---- 3. composition: secure-agg over the SAME flush schedule. The
    # comparison is against flush 1 only — past that, the fixed-point
    # rounding feeds into the next dispatch's local training and the two
    # runs legitimately drift (tests/test_async.py pins the per-flush
    # equivalence; secure_federated.py shows the re-synced variant).
    clear1 = build(args, data, cfg, split,
                   scheduler=RoundScheduler(scfg, seed=args.seed,
                                            round_bytes_per_client=1e6,
                                            round_flops_per_client=1e12),
                   acfg=acfg)
    clear1.init(key)
    clear1.run_flushes(1)
    secure = build(args, data, cfg, split,
                   scheduler=RoundScheduler(scfg, seed=args.seed,
                                            round_bytes_per_client=1e6,
                                            round_flops_per_client=1e12),
                   acfg=acfg,
                   aggregator=get_aggregator(secure=True, seed=args.seed))
    secure.init(key)
    secure.run_flushes(1)
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(clear1.params),
                              jax.tree.leaves(secure.params)))
    tol = roundtrip_tol(acfg.buffer_size)
    secure.run_flushes(args.flushes - 1)   # and it keeps going
    print(f"\nsecure flush 1: |clear - secure|_max = {err:.2e} "
          f"(tol {tol:.2e}); after {args.flushes} flushes: secure wire "
          f"{secure.meter.as_dict().get('secure', 0.0):.0f} B, "
          f"staleness mean {secure.ledger.mean_staleness():.2f}")
    assert err <= tol, "secure flush diverged from clear flush"


if __name__ == "__main__":
    main()
