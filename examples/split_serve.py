"""Split-inference serving demo: the fine-tuned model served across the
client/server boundary — client head (+prompt from the cache), server body,
client tail — with batched requests, a prefill + decode loop, and a
ring-buffer KV cache (the long_500k mechanism, scaled down).

  PYTHONPATH=src python examples/split_serve.py [--arch gemma2-9b]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import SplitConfig, SplitModel
from repro.launch.steps import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-tokens", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--window", type=int, default=32,
                    help="ring-buffer KV window (long-context mechanism)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(n_layers=6)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4)
    model = SplitModel(cfg, split)
    params = model.init(jax.random.PRNGKey(0))
    print(f"{cfg.name}: serving with head|body|tail = "
          f"{model.n_head_layers}|{model.n_body_layers}|{model.n_tail_layers}"
          f" layers, ring window={args.window}")

    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))

    B = args.batch
    reqs = jax.random.randint(jax.random.PRNGKey(1),
                              (B, args.prompt_tokens), 0, cfg.vocab_size)
    batch = {"tokens": reqs}
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, 8, cfg.d_model))
    if cfg.arch_type == "audio":
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder.n_frames, cfg.d_model))

    cache = model.init_cache(B, seq_len=args.prompt_tokens + args.new_tokens
                             + split.prompt_len, window=args.window)
    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    print(f"prefill {B}x{args.prompt_tokens} in {time.time()-t0:.2f}s")

    extra = split.prompt_len + (8 if cfg.arch_type == "vlm" else 0)
    outs = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        pos = jnp.full((B,), args.prompt_tokens + extra + i, jnp.int32)
        tok, logits, cache = decode(params, {"tokens": tok[:, None],
                                             "pos": pos}, cache)
        outs.append(tok)
    dt = time.time() - t0
    gen = jnp.stack(outs, 1)
    print(f"decoded {B}x{args.new_tokens} tokens in {dt:.2f}s "
          f"({B * args.new_tokens / dt:.1f} tok/s on 1 CPU core)")
    print("generations (token ids):")
    for b in range(B):
        print(" ", gen[b].tolist())


if __name__ == "__main__":
    main()
