"""Population-scale SFPrompt: 1000 clients, sampled cohorts, stragglers,
and a mid-run kill-and-resume that continues byte-identically.

  PYTHONPATH=src python examples/population_scale.py [--clients 1000]

What it shows, in order:
  1. A 1000-client non-IID `Population` (Dirichlet alpha=0.1) built from
     one shared dataset + index arrays — no per-client copies.
  2. Rounds over weighted-sampled K=8 cohorts with a 20% dropout rate in
     the edge_wan regime; per-round metrics show who was dropped/late and
     how many bytes the partial cohort actually moved.
  3. A simulated preemption after round 2: the engine checkpoint is
     restored into a FRESH engine which finishes the run; final params are
     verified byte-identical to an uninterrupted reference run.

Mega-cohort extras (the mesh-parallel path):
  * With more than one visible device the cohort round runs as ONE sharded
    dispatch over a host mesh — try
    XLA_FLAGS=--xla_force_host_platform_device_count=8 to see the K-cohort
    spread over 8 virtual CPU devices (same numbers, different layout).
  * --edges E routes phase 3 through the hierarchical (client -> edge ->
    global) topology; the per-round wire report grows an `edge_global`
    stream for the backhaul.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.core.aggregation import get_aggregator
from repro.core.comm import cost_inputs_from, sfprompt_comm, sfprompt_compute
from repro.data import DATASETS, synthetic_image_dataset
from repro.fed import (ClientSampler, FederatedEngine, Population,
                       RoundScheduler, StragglerConfig)
from repro.launch.mesh import make_host_mesh
from repro.runtime import WireSpec


def build_engine(cfg, split, data, args, mesh=None):
    pop = Population.from_partition(data, args.clients, scheme="dirichlet",
                                    alpha=0.1, seed=args.seed)
    model = SplitModel(cfg, split, WireSpec.make("int8"))
    pcfg = ProtocolConfig(clients_per_round=args.k, local_epochs=1,
                          batch_size=args.batch, momentum=0.0)
    aggregator = (get_aggregator(n_edges=args.edges, cohort_size=args.k)
                  if args.edges > 0 else None)
    trainer = SFPromptTrainer(model, pcfg, aggregator, mesh=mesh)
    sampler = ClientSampler(pop.n_clients, args.k, kind="weighted",
                            seed=args.seed,
                            weights=pop.sizes.astype(float))
    ci = cost_inputs_from(cfg, split, tokens_per_sample=(32 // 16) ** 2 + 1,
                          D=pop.n_local, K=args.k, U=1)
    sched = RoundScheduler(
        StragglerConfig(regime="edge_wan", dropout_rate=0.2,
                        late_mode="partial"), seed=args.seed,
        round_bytes_per_client=sfprompt_comm(ci) / args.k,
        round_flops_per_client=sfprompt_compute(ci))
    return FederatedEngine(trainer, pop, sampler, sched)


def run_rounds(engine, n, label):
    for _ in range(n):
        r = engine.round_idx
        plan, m = engine.run_round()
        print(f"[{label}] round {r}: cohort={plan.cohort.tolist()} "
              f"dropped={int(plan.dropped.sum())} "
              f"late={int(plan.late.sum())} "
              f"split_loss={m['split_loss']:.3f} "
              f"wire_MB={sum(v for k, v in m.items() if k.startswith('wire/')) / 2**20:.2f}",
              flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=1000)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--edges", type=int, default=0,
                    help="hierarchical aggregation: number of edge "
                         "aggregators (0 = flat; must divide K)")
    args = ap.parse_args()
    if args.edges > 0 and args.k % args.edges != 0:
        ap.error(f"--edges {args.edges} must divide K={args.k}")

    cfg = get_config("vit-base").reduced(n_layers=3, d_model=32, d_ff=64)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2,
                        prune_gamma=0.3, local_epochs=1)
    data = synthetic_image_dataset(DATASETS["cifar10-syn"],
                                   args.clients * 8, seed=args.seed,
                                   image_hw=32)
    n_dev = jax.device_count()
    mesh = make_host_mesh() if n_dev > 1 else None
    layout = (f"one sharded dispatch over a {n_dev}-device host mesh"
              if mesh is not None else "single-device vmap")
    agg = (f"hierarchical ({args.edges} edges)" if args.edges > 0
           else "flat")
    print(f"population: {args.clients} clients, K={args.k} per round, "
          f"{len(data['labels'])} samples total")
    print(f"cohort layout: {layout}; phase-3 aggregation: {agg}")

    # --- uninterrupted reference
    ref = build_engine(cfg, split, data, args, mesh)
    ref.init(jax.random.PRNGKey(args.seed))
    run_rounds(ref, args.rounds, "reference")
    print(ref.trainer.meter.report())

    # --- killed-and-resumed run
    kill_at = max(1, args.rounds // 2)
    eng = build_engine(cfg, split, data, args, mesh)
    eng.init(jax.random.PRNGKey(args.seed))
    run_rounds(eng, kill_at, "pre-kill")
    with tempfile.TemporaryDirectory() as ckpt_dir:
        eng.save(ckpt_dir)
        print(f"--- simulated preemption after round {kill_at}; "
              f"restoring into a fresh engine ---")
        res = build_engine(cfg, split, data, args, mesh)
        assert res.restore(ckpt_dir)
        run_rounds(res, args.rounds - kill_at, "resumed")

    same = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(ref.state["params"]),
                        jax.tree.leaves(res.state["params"])))
    meters_match = ref.trainer.meter.as_dict() == res.trainer.meter.as_dict()
    print(f"resumed params byte-identical to uninterrupted run: {same}")
    print(f"meter totals identical: {meters_match}")
    if not (same and meters_match):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
