"""TenantBank: per-tenant (tail, prompt) parameters for split serving.

SFPrompt's end state is a fine-tuned split model serving real clients: the
frozen body is SHARED on the server, while each tenant (a client, or a
cohort of clients that fine-tuned together) owns its personalized tail and
soft prompt — the personalized-tail regime of flexible split FL
(arXiv:2508.10349) at serving time.

The bank stacks all tenants' tails/prompts with a leading tenant axis, so
one jitted decode step serves a heterogeneous batch: the engine gathers
`jnp.take(bank.tails, tenant_ids, axis=0)` per cache slot and vmaps the
tail segment over slots. Adding a tenant is a host-side restack, never a
recompile (the stacked shapes only depend on the architecture).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


class TenantBank:
    """Stacked per-tenant (tail, prompt) pytrees (leading axis = tenant)."""

    def __init__(self, tails: Params, prompts: jnp.ndarray):
        n_t = jax.tree.leaves(tails)[0].shape[0]
        if prompts.shape[0] != n_t:
            raise ValueError(
                f"tails carry {n_t} tenants but prompts {prompts.shape[0]}")
        self.tails = tails
        self.prompts = prompts
        self.n_tenants = n_t

    # ----------------------------------------------------------- builders
    @classmethod
    def from_lists(cls, tails: Sequence[Params],
                   prompts: Sequence[jnp.ndarray]) -> "TenantBank":
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *tails)
        return cls(stacked, jnp.stack(list(prompts)))

    @classmethod
    def replicate(cls, tail: Params, prompt: jnp.ndarray,
                  n_tenants: int) -> "TenantBank":
        """All tenants share the global (tail, prompt) — the pre-
        personalization deployment."""
        tails = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_tenants,) + x.shape),
            tail)
        prompts = jnp.broadcast_to(prompt[None],
                                   (n_tenants,) + prompt.shape)
        return cls(tails, prompts)

    @classmethod
    def from_population(cls, population, tenant_ids: Sequence[int],
                        global_tail: Params, global_prompt: jnp.ndarray,
                        prompts: Optional[Sequence[jnp.ndarray]] = None,
                        ) -> "TenantBank":
        """Source tenants from a `fed.Population`'s personalized tails
        (clients that trained with `return_client_trainable=True`); clients
        the federation never personalized serve the global tail."""
        tails: List[Params] = population.get_tails(
            tenant_ids, global_tail, always=True)
        pr = (list(prompts) if prompts is not None
              else [global_prompt] * len(tails))
        return cls.from_lists(tails, pr)

    # ------------------------------------------------------------- lookup
    def gather_tails(self, tenant_ids: jnp.ndarray) -> Params:
        """Per-slot tail params: leading axis becomes the slot axis."""
        return jax.tree.map(
            lambda x: jnp.take(x, tenant_ids, axis=0), self.tails)

    def prompt(self, tenant_id: int) -> jnp.ndarray:
        return self.prompts[int(tenant_id)]

    def tail(self, tenant_id: int) -> Params:
        return jax.tree.map(lambda x: x[int(tenant_id)], self.tails)

    def nbytes(self) -> int:
        """Host memory of the bank — the cost of personalization."""
        return int(sum(np.asarray(x).nbytes for x in
                       jax.tree.leaves((self.tails, self.prompts))))
