"""ServeEngine: multi-tenant continuous-batching engine for split inference.

Slot lifecycle (see ARCHITECTURE.md §Serving engine):

    queue ──admit──> FREE slot ──prefill──> ACTIVE ──max_new reached──> FREE
      ^                (batch=1, tenant         (joins the batched
      └ admission       tail+prompt, cache       decode every step)
        control         scattered into the
        (max_queue)     slot's cache rows)

The shared KV cache is `SplitModel.init_cache(n_slots, ...)`: batch row i
IS slot i, owned by at most one in-flight request. Scheduling interleaves
prefill and decode: each `step()` admits up to `prefills_per_step` queued
requests into free slots (a batch=1 prefill each, scattered via
`cache_write_slot`), then runs ONE batched decode step over all slots —
requests join and leave mid-flight without ever draining the batch.

Per-tenant personalization: every request carries a tenant id; decode
gathers that tenant's tail from the `TenantBank` per slot (vmapped tail,
one compiled step for heterogeneous tenants) and prefill injects the
tenant's soft prompt. The frozen head/body are shared by everyone.

All smashed tensors cross the `WireSpec` boundaries; the engine's
`TrafficMeter` holds measured bytes (decode metered per occupied row),
cross-checked against `core.comm.serve_comm_breakdown` in tests and CI.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.split import SplitModel
from repro.obs.trace import NOOP
from repro.runtime.boundary import BOUNDARY_NAMES
from repro.runtime.meter import TrafficMeter
from repro.serve.bank import TenantBank
from repro.serve.steps import (make_batched_decode_step,
                               make_multi_decode_step, make_step_shardings,
                               make_tenant_prefill_step)
from repro.serve.workload import Request
from repro.sharding.rules import report_fallbacks

_DONATION_WARNING_FILTERED = False


def _quiet_cpu_donation_warning() -> None:
    """On a backend without donation jax falls back to a copy and warns
    once per compile. That is the engine's EXPECTED state on CPU (tests,
    CI), so suppress exactly that diagnostic — once per process, and only
    when a donating engine is actually constructed on such a backend
    (never at import, never on TPU/GPU, no duplicate filter entries)."""
    global _DONATION_WARNING_FILTERED
    if not _DONATION_WARNING_FILTERED and jax.default_backend() == "cpu":
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        _DONATION_WARNING_FILTERED = True


@dataclass(frozen=True)
class ServeConfig:
    n_slots: int = 8          # concurrent requests (shared-cache batch)
    max_seq: int = 128        # per-slot KV window (prompt + soft prompt
    #                           + generated tokens must fit)
    max_queue: int = 64       # admission control: pending-request cap
    prefills_per_step: int = 2  # joins per engine step (prefill/decode mix)
    decode_block: int = 1     # decode fast path: tokens per dispatch — one
    #                           lax.scan of up to this many decode steps per
    #                           engine step (power-of-two buckets keep the
    #                           jit-cache count bounded); 1 = per-token
    donate: bool = True       # donate the KV-cache pytrees into the jitted
    #                           steps so they update in place (no-op copy
    #                           fallback on backends without donation)
    dtype: Any = jnp.float32
    impl: str = "ref"


@dataclass
class _SlotState:
    req: Request
    next_pos: int             # absolute position of the next decode token
    tokens: List[int] = field(default_factory=list)
    logits: List[np.ndarray] = field(default_factory=list)
    t_submit: float = 0.0


@dataclass
class Finished:
    req: Request
    tokens: np.ndarray                      # (max_new,) generated ids
    latency_s: float
    logits: Optional[np.ndarray] = None     # (max_new, V) if collected


class ServeEngine:
    def __init__(self, model: SplitModel, shared_params, bank: TenantBank,
                 cfg: ServeConfig, *, collect_logits: bool = False,
                 mesh=None, tracer=None):
        if model.cfg.arch_type in ("vit", "audio", "vlm") \
                or model.cfg.encoder is not None:
            raise ValueError(
                f"{model.cfg.name}: the serving engine decodes token "
                f"streams; arch_type {model.cfg.arch_type!r} has no "
                f"token decode loop")
        self.model = model
        self.shared = {"head": shared_params["head"],
                       "body": shared_params["body"]}
        self.bank = bank
        self.cfg = cfg
        self.collect_logits = collect_logits
        # flight recorder (repro.obs): observation only — the default NOOP
        # records nothing; byte-carrying records appear ONLY where the
        # host already folds bytes (the meter flush), never per token
        self.tracer = tracer if tracer is not None else NOOP
        self.meter = TrafficMeter()
        self.meter.attach_tracer(self.tracer)

        S = cfg.n_slots
        self.cache = model.init_cache(S, seq_len=cfg.max_seq,
                                      dtype=jnp.float32)
        self._blank = model.blank_slot_cache(cfg.max_seq,
                                             dtype=jnp.float32)
        self._tokens = np.zeros((S,), np.int32)     # next input per slot
        self._pos = np.zeros((S,), np.int32)
        self._tenants = np.zeros((S,), np.int32)
        self._slots: List[Optional[_SlotState]] = [None] * S
        self._free: List[int] = list(range(S))      # free-list (LIFO)
        self._queue: List[Request] = []
        self._t_enqueue: Dict[int, float] = {}      # rid -> submit time

        # The blank prefill cache is REUSED every admission, so the prefill
        # step donates nothing; the shared cache pytree is donated into the
        # decode steps and the slot scatter so it updates in place.
        donate = (6,) if cfg.donate else ()
        if cfg.donate:
            _quiet_cpu_donation_warning()
        # mesh: run the steps TENSOR-PARALLEL on a (data, model) mesh —
        # frozen head/body sharded over 'model' (head-parallel attention,
        # d_ff-parallel MLP), KV slots over 'data', kv-heads over 'model';
        # per-device body+cache HBM drops ~1/|model| while decode math
        # stays bit-comparable (tests pin logits dense-vs-TP)
        self._mesh = mesh
        self._step_sh = None
        pf_kw: Dict[str, Any] = {}
        self._dec_kw: Dict[str, Any] = {}
        ws_kw: Dict[str, Any] = {}
        if mesh is not None:
            sh = make_step_shardings(mesh, self.shared, cache=self.cache,
                                     blank=self._blank)
            self._report_fallbacks()
            self._step_sh = sh
            r = sh["repl"]
            self.shared = jax.device_put(self.shared, sh["shared"])
            self.cache = jax.device_put(self.cache, sh["cache"])
            self._blank = jax.device_put(self._blank, sh["blank"])
            pf_kw = dict(
                in_shardings=(sh["shared"], r, r, r, sh["blank"]),
                out_shardings=(r, r, sh["blank"], r))
            self._dec_kw = dict(
                in_shardings=(sh["shared"], r, r, r, r, r, sh["cache"]),
                out_shardings=(r, r, sh["cache"], r))
            ws_kw = dict(in_shardings=(sh["cache"], sh["blank"], r),
                         out_shardings=sh["cache"])
        self._prefill = jax.jit(make_tenant_prefill_step(
            model, impl=cfg.impl, dtype=cfg.dtype), **pf_kw)
        self._decode = jax.jit(make_batched_decode_step(
            model, impl=cfg.impl, dtype=cfg.dtype), donate_argnums=donate,
            **self._dec_kw)
        self._multi: Dict[int, Any] = {}    # decode_block bucket -> jit
        self._write_slot = (
            model.jit_slot_writer(donate=cfg.donate) if mesh is None
            else jax.jit(model.cache_write_slot,
                         donate_argnums=(0,) if cfg.donate else (),
                         **ws_kw))

        # measured wire bytes accumulate ON DEVICE (traced scalars chained
        # with jnp.add, never synced per token) and fold into the host-side
        # meter once per flush — stats()/reset_stats() — instead of forcing
        # a device->host transfer every decode step.
        self._wire_acc = self._zero_wire()

        # step accounting
        self.step_idx = 0
        self.decode_steps = 0
        self.prefill_count = 0
        self.rejected = 0
        self.tokens_out = 0
        self._occupancy_sum = 0.0

    def _report_fallbacks(self, context: str = "serve.steps") -> None:
        """Surface any divisibility fallbacks the spec builders recorded —
        a kv-head count that does not divide 'model' means this mesh is
        silently replicating what it was sized to shard. Routed through
        the structured event log when a tracer is attached; the warning
        stays either way."""
        report_fallbacks(context, self.tracer)

    # -------------------------------------------------------------- wire
    @staticmethod
    def _zero_wire() -> Dict[str, jnp.ndarray]:
        return {name: jnp.float32(0.0) for name in BOUNDARY_NAMES}

    def _absorb_wire(self, wb) -> None:
        """Chain a step's byte counters onto the device-side accumulator —
        a lazy device add, NO host sync (the old per-token float() absorb
        blocked the decode loop on a device->host transfer every step)."""
        self._wire_acc = {k: self._wire_acc[k] + wb[k]
                         for k in self._wire_acc}

    def _flush_wire(self) -> None:
        """Fold the device-side accumulator into the host meter (one sync
        per flush — called from stats()/reset_stats(), not per token)."""
        vals = {k: float(v) for k, v in self._wire_acc.items()}
        if any(vals.values()):
            self.meter.absorb(vals)
        self._wire_acc = self._zero_wire()

    # ------------------------------------------------------------- intake
    def _window_check(self, req: Request) -> None:
        """Reject requests that cannot fit a slot's KV window. Subclasses
        with coarser-grained capacity (the paged engine rounds up to whole
        pages) override this."""
        total = len(req.tokens) + self.model.split.prompt_len + req.max_new
        if total > self.cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt({len(req.tokens)}) + soft "
                f"prompt({self.model.split.prompt_len}) + "
                f"new({req.max_new}) = {total} exceeds the slot window "
                f"{self.cfg.max_seq}")

    def submit(self, req: Request) -> bool:
        """Admission control: False (rejected) once the queue is full."""
        self._window_check(req)
        if req.tenant >= self.bank.n_tenants:
            raise ValueError(f"request {req.rid}: unknown tenant "
                             f"{req.tenant} (bank has {self.bank.n_tenants})")
        if len(self._queue) >= self.cfg.max_queue:
            self.rejected += 1
            self.tracer.event("serve.reject", level=2, rid=req.rid,
                              tenant=req.tenant)
            return False
        self._t_enqueue[req.rid] = time.perf_counter()
        self._queue.append(req)
        self.tracer.event("serve.submit", level=2, rid=req.rid,
                          tenant=req.tenant, prompt_len=len(req.tokens),
                          max_new=req.max_new)
        return True

    @property
    def n_active(self) -> int:
        return self.cfg.n_slots - len(self._free)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def idle(self) -> bool:
        return self.n_active == 0 and not self._queue

    # ------------------------------------------------------------ prefill
    def _admit_one(self, req: Request) -> Optional[Finished]:
        slot = self._free.pop()
        prompt_np = np.asarray(req.tokens, np.int32)[None]
        batch = {"tokens": jnp.asarray(prompt_np)}
        tail = self.bank.tail(req.tenant)
        prompt = self.bank.prompt(req.tenant)
        with self.tracer.span("serve.prefill", rid=req.rid,
                              tenant=req.tenant, slot=slot,
                              prompt_len=len(req.tokens)):
            with self.tracer.annotate("serve.prefill"):
                tok, logits, slot_cache, wb = self._prefill(
                    self.shared, tail, prompt, batch, self._blank)
            self.cache = self._write_slot(self.cache, slot_cache,
                                          jnp.int32(slot))
        self._absorb_wire(wb)
        self.prefill_count += 1
        self.tokens_out += 1

        st = _SlotState(req=req,
                        t_submit=self._t_enqueue.pop(
                            req.rid, time.perf_counter()),
                        next_pos=len(req.tokens)
                        + self.model.split.prompt_len)
        st.tokens.append(int(tok[0]))
        if self.collect_logits:
            st.logits.append(np.asarray(logits[0]))
        if req.max_new <= 1:
            self._release_slot(slot)
            return self._finish(st)
        self._slots[slot] = st
        self._tokens[slot] = int(tok[0])
        self._pos[slot] = st.next_pos
        self._tenants[slot] = req.tenant
        return None

    def _finish(self, st: _SlotState) -> Finished:
        # retirement attrs stay deterministic — token COUNTS, never the
        # wall-clock latency (same-seed traces must compare equal)
        self.tracer.event("serve.retire", rid=st.req.rid,
                          tenant=st.req.tenant, n_tokens=len(st.tokens))
        return Finished(
            req=st.req, tokens=np.asarray(st.tokens, np.int32),
            latency_s=time.perf_counter() - st.t_submit,
            logits=(np.stack(st.logits) if st.logits else None))

    # -------------------------------------------------------------- step
    def _decode_bucket(self, max_remaining: int) -> int:
        """Tokens to decode in one dispatch: the largest power of two <=
        min(decode_block, max slot budget) — power-of-two buckets bound the
        number of compiled multi-step variants at log2(decode_block)."""
        n = min(self.cfg.decode_block, max_remaining)
        return 1 << (max(1, n).bit_length() - 1)

    def _get_multi(self, n_steps: int):
        fn = self._multi.get(n_steps)
        if fn is None:
            donate = (6,) if self.cfg.donate else ()
            fn = jax.jit(make_multi_decode_step(
                self.model, n_steps, impl=self.cfg.impl,
                dtype=self.cfg.dtype, with_logits=self.collect_logits),
                donate_argnums=donate, **self._dec_kw)
            self._multi[n_steps] = fn
        return fn

    def _can_admit(self, req: Request) -> bool:
        """Head-of-line admission gate beyond free slots (the paged engine
        waits here when the page pool cannot cover the request)."""
        return True

    def _admit_from_queue(self, done: List[Finished]) -> None:
        """Admit up to `prefills_per_step` queued requests into free slots
        (head-of-line order; `_can_admit` can stall the queue without
        dropping it)."""
        admitted = 0
        while (self._queue and self._free
               and admitted < self.cfg.prefills_per_step):
            if not self._can_admit(self._queue[0]):
                break
            fin = self._admit_one(self._queue.pop(0))
            admitted += 1
            if fin is not None:
                done.append(fin)

    def _dispatch_decode(self, remaining: np.ndarray, n_eff: int):
        """Run one decode dispatch (single-token or scanned multi-token)
        over the engine's cache state; returns ((n_eff, S) tokens,
        (n_eff, S, V) logits or None, wire bytes). Subclasses swap the
        cache representation here."""
        if n_eff <= 1:
            toks, logits, self.cache, wb = self._decode(
                self.shared, self.bank.tails,
                jnp.asarray(self._tenants), jnp.asarray(self._tokens),
                jnp.asarray(self._pos),
                jnp.asarray(remaining > 0, jnp.float32), self.cache)
            return toks[None], logits[None], wb         # (1, S[, V])
        toks, logits, self.cache, wb = self._get_multi(n_eff)(
            self.shared, self.bank.tails,
            jnp.asarray(self._tenants), jnp.asarray(self._tokens),
            jnp.asarray(self._pos), jnp.asarray(remaining), self.cache)
        return toks, logits, wb

    def _release_slot(self, slot: int) -> None:
        """Return a retired slot to the free list (the paged engine also
        releases the slot's pages and scrubs its block table)."""
        self._free.append(slot)

    def step(self) -> List[Finished]:
        """One engine step: admit up to `prefills_per_step` queued requests
        into free slots, then one batched decode over every occupied slot —
        a single token, or (decode fast path) up to `decode_block` tokens
        in one scanned dispatch, with retirement deferred to scan exit.
        Returns the requests that completed during this step."""
        done: List[Finished] = []
        self._admit_from_queue(done)

        remaining = np.array(
            [0 if s is None else s.req.max_new - len(s.tokens)
             for s in self._slots], np.int32)
        if not remaining.any():
            self.step_idx += 1
            return done
        n_eff = self._decode_bucket(int(remaining.max()))
        with self.tracer.span("serve.decode", level=2, step=self.step_idx,
                              n_tokens=n_eff,
                              active=int((remaining > 0).sum())):
            with self.tracer.annotate("serve.decode"):
                toks, logits, wb = self._dispatch_decode(remaining, n_eff)
        self._absorb_wire(wb)
        self.decode_steps += n_eff
        for t in range(n_eff):
            self._occupancy_sum += ((remaining > t).sum()
                                    / self.cfg.n_slots)
        tok_np = np.asarray(toks)
        logits_np = np.asarray(logits) if self.collect_logits else None
        for slot, st in enumerate(self._slots):
            if st is None:
                continue
            take = min(n_eff, int(remaining[slot]))
            for t in range(take):
                st.tokens.append(int(tok_np[t, slot]))
                if self.collect_logits:
                    st.logits.append(logits_np[t, slot])
                st.next_pos += 1
            self.tokens_out += take
            self._tokens[slot] = tok_np[take - 1, slot]
            self._pos[slot] = st.next_pos
            if len(st.tokens) >= st.req.max_new:
                done.append(self._finish(st))
                self._slots[slot] = None
                self._release_slot(slot)
        self.step_idx += n_eff
        return done

    # ------------------------------------------------------------- reset
    def reset_stats(self) -> None:
        """Zero the run counters and the meter (engine must be idle): one
        engine can then serve several measured traces without cross-run
        accumulation, and arrival schedules replay from step 0 while the
        jit caches stay warm (benchmarks warm up this way)."""
        if not self.idle:
            raise RuntimeError("reset_stats with requests in flight")
        self.meter = TrafficMeter()
        self.meter.attach_tracer(self.tracer)
        self._wire_acc = self._zero_wire()
        self.step_idx = 0
        self.decode_steps = 0
        self.prefill_count = 0
        self.rejected = 0
        self.tokens_out = 0
        self._occupancy_sum = 0.0

    # ------------------------------------------------------------ driver
    def run(self, requests: Sequence[Request], *,
            max_steps: int = 100_000,
            on_step=None) -> Dict[str, Any]:
        """Drive a full (arrival-sorted) request trace to completion.
        Deterministic in (engine seed state, trace): scheduling decisions
        depend only on arrival steps and queue/slot order. `on_step`
        (engine_step_idx -> None) fires after every step — the launcher's
        periodic-metrics hook; it must not mutate the engine."""
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        finished: List[Finished] = []
        t0 = time.perf_counter()
        i = 0
        while (i < len(pending) or not self.idle):
            while i < len(pending) and pending[i].arrival <= self.step_idx:
                self.submit(pending[i])
                i += 1
            finished.extend(self.step())
            if on_step is not None:
                on_step(self.step_idx)
            if self.step_idx > max_steps:
                raise RuntimeError(f"workload did not drain in "
                                   f"{max_steps} engine steps")
        wall = time.perf_counter() - t0
        return self.stats(finished, wall)

    def live_stats(self) -> Dict[str, Any]:
        """Zero-arg counters for mid-run polling (the MetricsRegistry
        source). Unlike `stats`, needs no finished list or wall clock and
        never forces a device sync — the wire numbers reflect the last
        flush, not in-flight accumulators."""
        return {
            "step_idx": self.step_idx,
            "rejected": self.rejected,
            "tokens_out": self.tokens_out,
            "decode_steps": self.decode_steps,
            "prefills": self.prefill_count,
            "occupancy": self._occupancy_sum / max(1, self.decode_steps),
            "wire_bytes": self.meter.as_dict(),
        }

    def stats(self, finished: List[Finished], wall_s: float,
              ) -> Dict[str, Any]:
        self._flush_wire()
        lat = sorted(f.latency_s for f in finished) or [0.0]

        def pct(p):
            return lat[min(len(lat) - 1, int(p * len(lat)))]

        return {
            "finished": finished,
            "n_finished": len(finished),
            "rejected": self.rejected,
            "tokens_out": self.tokens_out,
            "wall_s": wall_s,
            "tok_per_s": self.tokens_out / max(wall_s, 1e-9),
            "p50_latency_s": pct(0.50),
            "p99_latency_s": pct(0.99),
            "occupancy": (self._occupancy_sum
                          / max(1, self.decode_steps)),
            "decode_steps": self.decode_steps,
            "prefills": self.prefill_count,
            "wire_bytes": self.meter.as_dict(),
            "wire_per_token": self.meter.per_token(self.tokens_out),
        }
