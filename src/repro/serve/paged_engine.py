"""PagedServeEngine: the paged-KV-cache serving engine.

The dense `ServeEngine` gives every slot a full `max_seq` KV window up
front, so concurrent slots cap out on HBM long before compute does. This
engine replaces the (slot, window) cache with a PAGE POOL
(`SplitModel.init_paged_cache`): physical pages of `page_size` tokens, a
host-side refcounting allocator (`paging.PagePool`), and a per-slot BLOCK
TABLE mapping logical blocks to physical pages. Decode attends through the
tables (`paged_decode_attention`); prefill stays dense — a request prefills
into a batch=1 scratch cache and only its pages are scattered into the pool.

Three features stack on the tables:

* **Page-granular admission** — a request needs ceil(total/page_size) pages,
  not a whole window; `_window_check` rounds up to `capacity =
  n_blocks_max * page_size >= max_seq`, and `_can_admit` holds the queue's
  head (without dropping it) while the pool lacks pages.
* **Copy-on-write shared prefixes** — with `shared_prefix` tokens
  configured, the common [soft prompt | shared prefix] KV is prefilled ONCE
  per tenant (the soft prompt makes prefix KV tenant-specific) and its
  fully-covered pages are refcount-shared into every sharer's table. The
  partially-covered boundary page is a read-only master: a joining slot
  copies it into a private page before writing past the prefix — exactly
  one page copy per join. When the last sharer retires, the entry is
  evicted and its pages cascade back to the pool.
* **Chunked prefill** — `prefill_chunk` streams long prompts in pieces: the
  first chunk embeds the soft prompt (`make_tenant_prefill_step`), every
  later chunk runs write-then-attend at absolute positions
  (`make_chunk_continue_step`), so a long admission never stalls decode
  behind one monolithic prefill dispatch.

Paging is MEMORY-ONLY: wire accounting is identical to the dense engine
step for step (tests pin metered-byte equality), except that a shared
prefix honestly meters FEWER prefill bytes — its smashed tensors cross the
wire once per tenant instead of once per request.

Safety invariants (see paging.py): retired slots' table rows are scrubbed
to the scratch page so their in-flight (discarded) decode writes never
touch a live page; unallocated table entries point at the null page whose
positions stay -1, masking exactly like empty cache slots.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.split import SplitModel
from repro.serve.bank import TenantBank
from repro.serve.engine import Finished, ServeConfig, ServeEngine, _SlotState
from repro.serve.paging import PagePool, PrefixEntry
from repro.serve.steps import (make_chunk_continue_step,
                               make_paged_decode_step,
                               make_paged_multi_decode_step,
                               make_step_shardings)
from repro.serve.workload import Request

import time


@dataclass(frozen=True)
class PagedServeConfig(ServeConfig):
    page_size: int = 16         # tokens per physical KV page
    n_pages: Optional[int] = None   # pool size incl. the 2 reserved pages;
    #                                 None = n_slots full windows (dense-
    #                                 equivalent HBM, useful for identity
    #                                 tests; benchmarks shrink it)
    shared_prefix: Optional[Tuple[int, ...]] = None   # common base-prompt
    #                                 token ids prepended to every request;
    #                                 None/() disables prefix sharing
    prefill_chunk: Optional[int] = None   # stream prompts in pieces of this
    #                                 many tokens; None = monolithic prefill

    @property
    def prefix_tokens(self) -> Tuple[int, ...]:
        return tuple(self.shared_prefix or ())


class PagedServeEngine(ServeEngine):
    def __init__(self, model: SplitModel, shared_params, bank: TenantBank,
                 cfg: PagedServeConfig, *, collect_logits: bool = False,
                 mesh=None, tracer=None):
        reason = model.paged_cache_unsupported()
        if reason is not None:
            raise ValueError(f"{model.cfg.name}: paged serving unsupported "
                             f"— {reason}")
        if cfg.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {cfg.page_size}")
        if cfg.prefill_chunk is not None and cfg.prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, "
                             f"got {cfg.prefill_chunk}")
        super().__init__(model, shared_params, bank, cfg,
                         collect_logits=collect_logits, mesh=mesh,
                         tracer=tracer)
        ps = cfg.page_size
        self.nb_max = -(-cfg.max_seq // ps)         # blocks per slot table
        self.capacity = self.nb_max * ps            # page-rounded window
        n_pages = (cfg.n_pages if cfg.n_pages is not None
                   else cfg.n_slots * self.nb_max + PagePool.N_RESERVED)
        self.pool_alloc = PagePool(n_pages, ps, tracer=self.tracer)
        self.pool = model.init_paged_cache(n_pages, ps, dtype=jnp.float32)
        self.cache = None   # the dense shared cache is replaced by the pool
        self._blank = model.blank_slot_cache(self.capacity,
                                             dtype=jnp.float32)
        # idle rows point every block at the SCRATCH page: idle slots keep
        # decoding for shape stability and their (discarded) writes must
        # never land on NULL — that page's positions stay -1 forever so
        # active slots' unallocated table entries read as empty
        self._tables = np.full((cfg.n_slots, self.nb_max),
                               PagePool.SCRATCH_PAGE, np.int32)
        self._prefix: Dict[int, PrefixEntry] = {}   # tenant -> entry
        self._slot_shared: Dict[int, int] = {}      # slot -> sharing tenant

        donate = (6,) if cfg.donate else ()
        donate0 = (0,) if cfg.donate else ()
        # mesh: the page pool shards kv-heads over 'model' (pages stay
        # replicated over the client plane — any table can reference any
        # page), so paged decode attention runs head-parallel against the
        # same 'model'-sharded frozen body as the dense steps
        self._pdec_kw: Dict[str, Any] = {}
        cont_kw: Dict[str, Any] = {}
        gather_kw: Dict[str, Any] = {}
        scatter_kw: Dict[str, Any] = {}
        copy_kw: Dict[str, Any] = {}
        if mesh is not None:
            sh = make_step_shardings(mesh, self.shared, blank=self._blank,
                                     pool=self.pool)
            self._report_fallbacks()
            r = sh["repl"]
            self.pool = jax.device_put(self.pool, sh["pool"])
            self._blank = jax.device_put(self._blank, sh["blank"])
            self._pdec_kw = dict(
                in_shardings=(sh["shared"], r, r, r, r, r, sh["pool"], r),
                out_shardings=(r, r, sh["pool"], r))
            cont_kw = dict(
                in_shardings=(sh["shared"], r, r, sh["blank"], r),
                out_shardings=(r, r, sh["blank"], r))
            gather_kw = dict(in_shardings=(sh["pool"], r, r),
                             out_shardings=sh["blank"])
            scatter_kw = dict(in_shardings=(sh["pool"], sh["blank"], r, r),
                              out_shardings=sh["pool"])
            copy_kw = dict(in_shardings=(sh["pool"], r, r),
                           out_shardings=sh["pool"])
        self._paged_decode = jax.jit(make_paged_decode_step(
            model, impl=cfg.impl, dtype=cfg.dtype), donate_argnums=donate,
            **self._pdec_kw)
        self._paged_multi: Dict[int, Any] = {}
        self._continue = jax.jit(make_chunk_continue_step(
            model, impl=cfg.impl, dtype=cfg.dtype), **cont_kw)
        self._gather_slot = jax.jit(self._gather_slot_impl, **gather_kw)
        self._scatter_slot = jax.jit(self._scatter_slot_impl,
                                     donate_argnums=donate0, **scatter_kw)
        self._copy_page = jax.jit(self._copy_page_impl,
                                  donate_argnums=donate0, **copy_kw)

        # paged accounting
        self.page_copies = 0        # COW boundary-page copies
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefill_chunks = 0     # continuation-chunk dispatches
        self.prefill_step_calls = 0  # first-chunk/monolithic prefill calls
        self.peak_pages = 0

    # ----------------------------------------------------- jitted helpers
    def _gather_slot_impl(self, pool, table_row, valid_len):
        """One slot's pages as a dense batch=1 cache (width = capacity),
        with positions beyond `valid_len` cleaned to -1 — freshly allocated
        pages carry STALE positions from their previous owner, and a stale
        valid-looking position would unmask garbage KV."""
        dense = self.model.paged_gather(pool, table_row[None])

        def seg(s):
            out = {}
            for name, stack in s["stack"].items():
                d = dict(stack)
                w = d["positions"].shape[-1]
                keep = jnp.arange(w, dtype=jnp.int32)[None, None] < valid_len
                d["positions"] = jnp.where(keep, d["positions"], -1)
                out[name] = d
            return {"stack": out}
        return {k: seg(v) for k, v in dense.items()}

    def _scatter_slot_impl(self, pool, single, table_row, write_mask):
        """Masked write-back of a slot's dense cache into its pages; masked
        blocks (shared prefix pages, unallocated entries) land on the
        scratch page. The mask is a traced array, so one compilation covers
        every allocation pattern."""
        return self.model.paged_scatter_slot(
            pool, single, table_row, write_mask,
            jnp.int32(PagePool.SCRATCH_PAGE))

    def _copy_page_impl(self, pool, src, dst):
        return self.model.paged_copy_page(pool, src, dst)

    # ----------------------------------------------------------- sizing
    def _prefix_len(self) -> int:
        """Soft prompt + shared prefix tokens (0 when sharing is off)."""
        F = self.cfg.prefix_tokens
        if not F:
            return 0
        return self.model.split.prompt_len + len(F)

    def _total_len(self, req: Request) -> int:
        base = len(req.tokens) + self.model.split.prompt_len + req.max_new
        return base + len(self.cfg.prefix_tokens)

    def _n_blocks(self, req: Request) -> int:
        return -(-self._total_len(req) // self.cfg.page_size)

    def _window_check(self, req: Request) -> None:
        """Page-granular admission: a request fits iff its total length
        fits `capacity` = nb_max * page_size, which ROUNDS `max_seq` UP to
        whole pages — a request the dense window rejects by a few tokens is
        admissible when those tokens fit the last page's slack."""
        total = self._total_len(req)
        if total > self.capacity:
            raise ValueError(
                f"request {req.rid}: prompt({len(req.tokens)}) + soft "
                f"prompt({self.model.split.prompt_len}) + shared "
                f"prefix({len(self.cfg.prefix_tokens)}) + "
                f"new({req.max_new}) = {total} exceeds the paged capacity "
                f"{self.capacity} ({self.nb_max} pages x "
                f"{self.cfg.page_size})")

    def _pages_needed(self, req: Request) -> int:
        """Free pages the head-of-line request needs to admit NOW."""
        nb_total = self._n_blocks(req)
        L_pre = self._prefix_len()
        if not L_pre:
            return nb_total
        n_full = L_pre // self.cfg.page_size
        boundary = 1 if L_pre % self.cfg.page_size else 0
        entry = self._prefix.get(req.tenant)
        if entry is None:          # miss: the entry's own pages too
            return nb_total + boundary
        return nb_total - n_full   # hit: share full pages, alloc the rest

    def _can_admit(self, req: Request) -> bool:
        return self.pool_alloc.n_free >= self._pages_needed(req)

    def _note_alloc(self) -> None:
        self.peak_pages = max(self.peak_pages, self.pool_alloc.n_used)

    # ----------------------------------------------------------- prefill
    def _run_chunks(self, tail, tokens_np, cache, start: int):
        """Continuation-prefill `tokens_np` into `cache` beginning at
        absolute position `start`, in `prefill_chunk`-sized pieces."""
        c = self.cfg.prefill_chunk or len(tokens_np)
        tok = logits = None
        for i in range(0, len(tokens_np), c):
            chunk = tokens_np[i:i + c]
            with self.tracer.span("serve.chunk", level=2,
                                  start=start + i, n_tokens=len(chunk)):
                tok, logits, cache, wb = self._continue(
                    self.shared, tail, {"tokens": jnp.asarray(chunk[None])},
                    cache, jnp.asarray([start + i], jnp.int32))
            self._absorb_wire(wb)
            self.prefill_chunks += 1
        return tok, logits, cache

    def _run_prefill(self, tail, prompt, tokens_np):
        """Full prefill of `tokens_np` (soft prompt embedded) into a blank
        capacity-wide scratch cache, chunked if configured. Returns
        (next_tok, last_logits, cache)."""
        c = self.cfg.prefill_chunk
        p = self.model.split.prompt_len
        first = tokens_np if (c is None or c >= len(tokens_np)) \
            else tokens_np[:c]
        self.prefill_step_calls += 1
        tok, logits, cache, wb = self._prefill(
            self.shared, tail, prompt, {"tokens": jnp.asarray(first[None])},
            self._blank)
        self._absorb_wire(wb)
        if len(first) == len(tokens_np):
            return tok, logits, cache
        self.prefill_chunks += 1     # the first chunk counts as a chunk
        return self._run_chunks(tail, tokens_np[len(first):], cache,
                                p + len(first))

    def _build_prefix_entry(self, tenant: int) -> PrefixEntry:
        """MISS: prefill [soft prompt | shared prefix] once for this tenant
        into entry-owned pages. The scratch cache starts blank, so the
        boundary page's positions beyond the prefix are -1 by construction
        — the master needs no sanitizing before sharers copy it."""
        ps = self.cfg.page_size
        F = np.asarray(self.cfg.prefix_tokens, np.int32)
        L_pre = self._prefix_len()
        n_full, rem = divmod(L_pre, ps)
        _, _, cache = self._run_prefill(
            self.bank.tail(tenant), self.bank.prompt(tenant), F)
        n_entry = n_full + (1 if rem else 0)
        pages = self.pool_alloc.alloc_many(n_entry)
        self._note_alloc()
        table = np.full((self.nb_max,), PagePool.NULL_PAGE, np.int32)
        table[:n_entry] = pages
        mask = np.zeros((self.nb_max,), bool)
        mask[:n_entry] = True
        self.pool = self._scatter_slot(self.pool, cache,
                                       jnp.asarray(table),
                                       jnp.asarray(mask))
        entry = PrefixEntry(full_pages=pages[:n_full],
                            boundary_page=pages[n_full] if rem else None,
                            prefix_len=L_pre)
        self._prefix[tenant] = entry
        return entry

    # ---------------------------------------------------------- admission
    def _admit_one(self, req: Request) -> Optional[Finished]:
        ps = self.cfg.page_size
        nb_total = self._n_blocks(req)
        tail = self.bank.tail(req.tenant)
        prompt = self.bank.prompt(req.tenant)
        tokens_np = np.asarray(req.tokens, np.int32)
        slot = self._free.pop()
        table = np.full((self.nb_max,), PagePool.NULL_PAGE, np.int32)
        mask = np.zeros((self.nb_max,), bool)
        L_pre = self._prefix_len()

        if not L_pre:
            # plain paged admission: private pages for the whole lifetime,
            # dense prefill into blank scratch, scatter every block
            pages = self.pool_alloc.alloc_many(nb_total)
            self._note_alloc()
            table[:nb_total] = pages
            mask[:nb_total] = True
            tok, logits, cache = self._run_prefill(tail, prompt, tokens_np)
            next_pos = len(req.tokens) + self.model.split.prompt_len
        else:
            entry = self._prefix.get(req.tenant)
            if entry is None:
                entry = self._build_prefix_entry(req.tenant)
                self.prefix_misses += 1
                self.tracer.event("serve.prefix_miss", level=2,
                                  tenant=req.tenant,
                                  prefix_len=entry.prefix_len)
            else:
                self.prefix_hits += 1
                entry.hits += 1
                self.tracer.event("serve.prefix_hit", level=2,
                                  tenant=req.tenant, hits=entry.hits)
            n_full = len(entry.full_pages)
            for j, pg in enumerate(entry.full_pages):
                table[j] = self.pool_alloc.share(pg)
            priv = self.pool_alloc.alloc_many(nb_total - n_full)
            self._note_alloc()
            table[n_full:nb_total] = priv
            mask[n_full:nb_total] = True     # shared full pages stay masked
            if entry.boundary_page is not None:
                # COW divergence: the sharer's first writable page starts
                # as a copy of the read-only boundary master
                self.pool = self._copy_page(self.pool,
                                            jnp.int32(entry.boundary_page),
                                            jnp.int32(priv[0]))
                self.page_copies += 1
                self.tracer.event("page.cow_copy", level=2,
                                  src=int(entry.boundary_page),
                                  dst=int(priv[0]), tenant=req.tenant)
            entry.sharers += 1
            self._slot_shared[slot] = req.tenant
            cache = self._gather_slot(self.pool, jnp.asarray(table),
                                      jnp.int32(L_pre))
            tok, logits, cache = self._run_chunks(tail, tokens_np, cache,
                                                  L_pre)
            next_pos = L_pre + len(req.tokens)

        self._tables[slot] = table
        self.pool = self._scatter_slot(self.pool, cache,
                                       jnp.asarray(table),
                                       jnp.asarray(mask))
        self.prefill_count += 1
        self.tokens_out += 1
        self.tracer.event("serve.admit", rid=req.rid, tenant=req.tenant,
                          slot=slot, n_blocks=nb_total,
                          pages_in_use=self.pool_alloc.n_used)

        st = _SlotState(req=req,
                        t_submit=self._t_enqueue.pop(
                            req.rid, time.perf_counter()),
                        next_pos=next_pos)
        st.tokens.append(int(tok[0]))
        if self.collect_logits:
            st.logits.append(np.asarray(logits[0]))
        if req.max_new <= 1:
            self._release_slot(slot)
            return self._finish(st)
        self._slots[slot] = st
        self._tokens[slot] = int(tok[0])
        self._pos[slot] = st.next_pos
        self._tenants[slot] = req.tenant
        return None

    # ---------------------------------------------------------- lifecycle
    def _release_slot(self, slot: int) -> None:
        """Retire a slot: drop one reference per owned page (shared prefix
        pages survive while other sharers hold them), evict the tenant's
        prefix entry when its last sharer leaves, and scrub the table row
        to the scratch page so the slot's in-flight decode writes (it keeps
        computing for shape stability) land in garbage, never a live or
        freshly reallocated page."""
        for pid in self._tables[slot]:
            if int(pid) >= PagePool.N_RESERVED:
                self.pool_alloc.free(int(pid))
        tenant = self._slot_shared.pop(slot, None)
        if tenant is not None:
            entry = self._prefix[tenant]
            entry.sharers -= 1
            if entry.sharers == 0:
                for pg in entry.full_pages:
                    self.pool_alloc.free(pg)
                if entry.boundary_page is not None:
                    self.pool_alloc.free(entry.boundary_page)
                del self._prefix[tenant]
        self._tables[slot] = PagePool.SCRATCH_PAGE
        self._tokens[slot] = 0
        self._pos[slot] = 0
        self._free.append(slot)

    # ------------------------------------------------------------- decode
    def _get_paged_multi(self, n_steps: int):
        fn = self._paged_multi.get(n_steps)
        if fn is None:
            donate = (6,) if self.cfg.donate else ()
            fn = jax.jit(make_paged_multi_decode_step(
                self.model, n_steps, impl=self.cfg.impl,
                dtype=self.cfg.dtype, with_logits=self.collect_logits),
                donate_argnums=donate, **self._pdec_kw)
            self._paged_multi[n_steps] = fn
        return fn

    def _dispatch_decode(self, remaining: np.ndarray, n_eff: int):
        tables = jnp.asarray(self._tables)
        if n_eff <= 1:
            toks, logits, self.pool, wb = self._paged_decode(
                self.shared, self.bank.tails,
                jnp.asarray(self._tenants), jnp.asarray(self._tokens),
                jnp.asarray(self._pos),
                jnp.asarray(remaining > 0, jnp.float32), self.pool, tables)
            return toks[None], logits[None], wb
        toks, logits, self.pool, wb = self._get_paged_multi(n_eff)(
            self.shared, self.bank.tails,
            jnp.asarray(self._tenants), jnp.asarray(self._tokens),
            jnp.asarray(self._pos), jnp.asarray(remaining), self.pool,
            tables)
        return toks, logits, wb

    # -------------------------------------------------------------- stats
    def reset_stats(self) -> None:
        super().reset_stats()
        self.page_copies = 0
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefill_chunks = 0
        self.prefill_step_calls = 0
        self.peak_pages = 0

    def live_stats(self) -> Dict[str, Any]:
        out = super().live_stats()
        out.update(self._page_stats())
        return out

    def stats(self, finished: List[Finished], wall_s: float,
              ) -> Dict[str, Any]:
        out = super().stats(finished, wall_s)
        out.update(self._page_stats())
        return out

    def _page_stats(self) -> Dict[str, Any]:
        joins = self.prefix_hits + self.prefix_misses
        return {
            "page_size": self.cfg.page_size,
            "n_pages": self.pool_alloc.n_pages,
            "pages_in_use": self.pool_alloc.n_used,
            "peak_pages": self.peak_pages,
            "page_copies": self.page_copies,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "prefix_hit_ratio": self.prefix_hits / joins if joins else 0.0,
            "prefill_chunks": self.prefill_chunks,
        }
