"""Host-side page-pool bookkeeping for the paged serving engine.

The device side of paging is a pool pytree (`SplitModel.init_paged_cache`)
whose page axis replaces the dense cache's (slot, window) pair; this module
owns the HOST side: which physical page belongs to whom.

`PagePool` is a refcounting free-list allocator over page ids. Two ids are
reserved and never allocated:

  * ``NULL_PAGE`` (0) — the target of every *unallocated* block-table entry.
    Its positions row stays -1 forever, so gathers through it read "empty"
    and attention masks it out. Nothing ever writes it.
  * ``SCRATCH_PAGE`` (1) — the garbage dump. Idle slots' decode writes and
    masked scatter blocks are redirected here so the jitted steps stay
    shape-stable without ever touching a live page. Nothing ever reads it
    (only idle slots, whose outputs the engine discards).

Invariants (property-tested in tests/test_paged_alloc.py):
  * a page is free XOR allocated; alloc/free in reverse order restores the
    free-list exactly (LIFO);
  * refcount(page) > 1 only for shared-prefix pages (`share`); a private
    page's refcount is exactly 1;
  * refcount hits zero iff the page returns to the free list;
  * exhaustion raises `PagePoolExhausted` loudly — pages are never aliased.

`PrefixEntry` tracks one tenant's shared-prefix pages: the fully-covered
pages are refcount-shared across every slot serving that tenant, and the
partially-covered boundary page (if the prefix length is not page-aligned)
is kept as a read-only master that sharers copy-on-write. The entry holds
one reference per page of its own; when the last sharer retires, the entry
is evicted and its references drop, cascading the pages back to the pool.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


class PagePoolExhausted(RuntimeError):
    """The pool has no free pages — raised instead of aliasing a live one."""


class PagePool:
    NULL_PAGE = 0
    SCRATCH_PAGE = 1
    N_RESERVED = 2

    def __init__(self, n_pages: int, page_size: int, *, tracer=None):
        if n_pages < self.N_RESERVED + 1:
            raise ValueError(f"pool needs > {self.N_RESERVED} pages "
                             f"(2 reserved), got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO free-list: low page ids are handed out first
        self._free: List[int] = list(range(n_pages - 1, self.N_RESERVED - 1,
                                           -1))
        self._refcount = [0] * n_pages
        # flight-recorder hook (repro.obs): step-level page.alloc/share/
        # free events when attached; pure bookkeeping, never device state
        self.tracer = tracer

    # ------------------------------------------------------------- queries
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - self.N_RESERVED - len(self._free)

    def refcount(self, pid: int) -> int:
        return self._refcount[pid]

    def free_list(self) -> List[int]:
        return list(self._free)

    # ----------------------------------------------------------- mutation
    def alloc(self) -> int:
        """One fresh private page (refcount 1)."""
        if not self._free:
            raise PagePoolExhausted(
                f"page pool exhausted: {self.n_used} pages live, none free")
        pid = self._free.pop()
        self._refcount[pid] = 1
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.event("page.alloc", level=2, page=pid,
                              n_free=len(self._free))
        return pid

    def alloc_many(self, n: int) -> List[int]:
        """n pages, all-or-nothing: exhaustion allocates none."""
        if n > len(self._free):
            raise PagePoolExhausted(
                f"page pool exhausted: need {n} pages, {self.n_free} free")
        return [self.alloc() for _ in range(n)]

    def share(self, pid: int) -> int:
        """One more owner for an allocated (shared-prefix) page."""
        if self._refcount[pid] <= 0:
            raise ValueError(f"share of unallocated page {pid}")
        self._refcount[pid] += 1
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.event("page.share", level=2, page=pid,
                              refcount=self._refcount[pid])
        return pid

    def free(self, pid: int) -> bool:
        """Drop one reference; the page returns to the pool iff the count
        hits zero. Returns True when the page was actually released."""
        if pid < self.N_RESERVED:
            raise ValueError(f"free of reserved page {pid}")
        if self._refcount[pid] <= 0:
            raise ValueError(f"double free of page {pid}")
        self._refcount[pid] -= 1
        released = self._refcount[pid] == 0
        if released:
            self._free.append(pid)
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.event("page.free", level=2, page=pid,
                              released=released,
                              n_free=len(self._free))
        return released


@dataclass
class PrefixEntry:
    """One tenant's cached shared-prefix pages (soft prompt + base prompt).

    `full_pages` cover whole pages of prefix KV and are refcount-shared into
    every sharer's block table. `boundary_page` holds the partial last page
    (prefix length not page-aligned) as a read-only master: each sharer
    copies it into a private page before writing past the prefix (the COW
    divergence copy). The entry itself holds one reference per page; it is
    evicted — references dropped, pages released — when `sharers` returns
    to zero."""
    full_pages: List[int]
    boundary_page: Optional[int]
    prefix_len: int                      # soft prompt + prefix tokens
    sharers: int = 0
    hits: int = field(default=0)
