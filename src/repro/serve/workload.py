"""Deterministic synthetic serving workload.

Poisson arrivals (exponential inter-arrival gaps in engine-step units),
mixed prompt/output lengths drawn from small choice sets (so the prefill
step compiles once per distinct prompt length, not per request), and a
tenant id per request. The whole trace is a PURE FUNCTION of the seed via
one `np.random.default_rng(seed)` stream — the benchmark suite and the CI
smoke job replay byte-identical workloads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass
class Request:
    """One serving request: `tokens` is the user prompt; the tenant's soft
    prompt is prepended inside the model. `arrival` is the engine step at
    which the request reaches the queue."""
    rid: int
    tenant: int
    tokens: np.ndarray                 # (L,) int32
    max_new: int                       # tokens to generate (incl. the
    #                                    one the prefill itself yields)
    arrival: int = 0


@dataclass(frozen=True)
class WorkloadConfig:
    n_requests: int = 16
    mean_interarrival: float = 1.0     # engine steps; Poisson process
    prompt_choices: Tuple[int, ...] = (8, 16, 32)
    new_token_choices: Tuple[int, ...] = (4, 8, 16)
    n_tenants: int = 4
    vocab_size: int = 512
    seed: int = 0


def synthetic_requests(cfg: WorkloadConfig) -> List[Request]:
    """The full request trace, deterministically from cfg.seed."""
    rng = np.random.default_rng(cfg.seed)
    t = 0.0
    out: List[Request] = []
    for rid in range(cfg.n_requests):
        t += rng.exponential(cfg.mean_interarrival)
        length = int(rng.choice(cfg.prompt_choices))
        new = int(rng.choice(cfg.new_token_choices))
        tenant = int(rng.integers(cfg.n_tenants))
        tokens = rng.integers(0, cfg.vocab_size, length).astype(np.int32)
        out.append(Request(rid=rid, tenant=tenant, tokens=tokens,
                           max_new=new, arrival=int(t)))
    return out
