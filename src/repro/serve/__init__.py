"""Multi-tenant continuous-batching split-serving engine.

See ARCHITECTURE.md §Serving engine and `launch/serve.py` for the CLI.
Dense slot cache: `ServeEngine`; paged pool with copy-on-write shared
prefixes and chunked prefill: `PagedServeEngine` (serve/paged_engine.py).
"""
from repro.serve.bank import TenantBank
from repro.serve.engine import Finished, ServeConfig, ServeEngine
from repro.serve.paged_engine import PagedServeConfig, PagedServeEngine
from repro.serve.paging import PagePool, PagePoolExhausted, PrefixEntry
from repro.serve.steps import (make_batched_decode_step,
                               make_chunk_continue_step,
                               make_multi_decode_step,
                               make_paged_decode_step,
                               make_paged_multi_decode_step,
                               make_tenant_prefill_step)
from repro.serve.workload import Request, WorkloadConfig, synthetic_requests

__all__ = [
    "TenantBank", "ServeConfig", "ServeEngine", "Finished",
    "PagedServeConfig", "PagedServeEngine",
    "PagePool", "PagePoolExhausted", "PrefixEntry",
    "make_batched_decode_step", "make_multi_decode_step",
    "make_tenant_prefill_step", "make_paged_decode_step",
    "make_paged_multi_decode_step", "make_chunk_continue_step",
    "Request", "WorkloadConfig", "synthetic_requests",
]
