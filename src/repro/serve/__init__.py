"""Multi-tenant continuous-batching split-serving engine.

See ARCHITECTURE.md §Serving engine and `launch/serve.py` for the CLI.
"""
from repro.serve.bank import TenantBank
from repro.serve.engine import Finished, ServeConfig, ServeEngine
from repro.serve.steps import (make_batched_decode_step,
                               make_multi_decode_step,
                               make_tenant_prefill_step)
from repro.serve.workload import Request, WorkloadConfig, synthetic_requests

__all__ = [
    "TenantBank", "ServeConfig", "ServeEngine", "Finished",
    "make_batched_decode_step", "make_multi_decode_step",
    "make_tenant_prefill_step",
    "Request", "WorkloadConfig", "synthetic_requests",
]
