"""jit-able steps of the continuous-batching split-serving engine.

Two step shapes, both crossing the PR-1 wire boundaries:

* `make_tenant_prefill_step` — one request joins: head (+ the tenant's soft
  prompt) -> body -> the tenant's tail, at batch=1 against a blank slot
  cache. The engine scatters the resulting cache into the request's slot of
  the shared KV cache, so the join never drains the in-flight batch.
* `make_batched_decode_step` — one token for EVERY occupied slot: the
  frozen head and body run the whole slot batch through one jitted step
  (shared parameters), then the tail is vmapped over slots with each slot's
  TENANT tail gathered from the bank — heterogeneous tenants, one compiled
  function.
* `make_multi_decode_step` — the decode FAST PATH: `n_steps` tokens for
  every occupied slot inside ONE `lax.scan` over the same per-token body,
  so the host pays one dispatch (and one device->host token sync) per
  n_steps tokens instead of per token. Slot retirement is deferred to scan
  exit: a slot with fewer than n_steps tokens remaining keeps computing
  (shape stability — its cache rows are wholly overwritten at the next
  allocation) but its wire bytes stop counting the moment it retires,
  via the per-step `remaining > t` activity mask.

Wire accounting: prefill transmits exactly the request's smashed tensor;
decode transmits per OCCUPIED row (`Boundary.transmit(rows=n_active)`) —
idle slots ride through compute for shape stability but never count bytes,
mirroring a deployment that simply doesn't send those rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.split import SplitModel
from repro.runtime.boundary import BOUNDARY_NAMES
from repro.sharding import cache_pspecs, params_pspecs


def make_step_shardings(mesh, shared, *, cache=None, blank=None, pool=None):
    """NamedSharding trees for jitting the serve steps TENSOR-PARALLEL on a
    (data, model) mesh. The frozen head/body take their params_pspecs
    'model' shardings (attention head-parallel, MLP d_ff-parallel, vocab-
    parallel embeddings/LM head), so decode/prefill matmuls run split over
    'model' with XLA's all-reduces stitching the partial sums. KV caches
    shard the slot dim over the client plane and the kv-heads dim over
    'model' via cache_pspecs — page pools with paged=True keep the page
    axis replicated (any block table may reference any page). `blank` is
    the batch=1 scratch cache (its singleton slot dim replicates). `repl`
    is the catch-all replicated sharding, usable as a pytree PREFIX for
    per-slot vectors, tenant banks, token batches and wire-byte dicts."""
    def named(pspecs):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                            is_leaf=lambda x: isinstance(x, PartitionSpec))
    out = {"repl": NamedSharding(mesh, PartitionSpec()),
           "shared": named(params_pspecs(shared, mesh))}
    if cache is not None:
        out["cache"] = named(cache_pspecs(cache, mesh))
    if blank is not None:
        out["blank"] = named(cache_pspecs(blank, mesh))
    if pool is not None:
        out["pool"] = named(cache_pspecs(pool, mesh, paged=True))
    return out


def make_tenant_prefill_step(model: SplitModel, *, impl: str = "ref",
                             dtype=jnp.float32):
    """prefill_step(shared, tail, prompt, batch, cache) ->
    (next_tok (1,), last_logits (1, V), cache, wire_bytes)."""
    def prefill_step(shared, tail, prompt, batch, cache):
        params = {"head": shared["head"], "body": shared["body"],
                  "tail": tail, "prompt": prompt}
        out = model.forward(params, batch, route="split", mode="prefill",
                            cache=cache, impl=impl, dtype=dtype,
                            prompt=prompt)
        logits = out["logits"][:, -1, :].astype(jnp.float32)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return next_tok, logits, out["cache"], out["wire_bytes"]
    return prefill_step


def make_batched_decode_step(model: SplitModel, *, impl: str = "ref",
                             dtype=jnp.float32):
    """decode_step(shared, bank_tails, tenant_ids, tokens, pos, active,
    cache) -> (next_tok (S,), logits (S, V), cache, wire_bytes).

    `tokens`/`pos`/`tenant_ids` are per-slot (S,) arrays; `active` is the
    (S,) occupancy mask — idle slots compute garbage that the host ignores
    (their cache rows are wholly overwritten at the next allocation) and
    contribute zero wire bytes.
    """
    wire = model.wire

    def tail_one(tail_p, x_row, pos_row, stack_row):
        # one slot's tail, batch=1: vmap removes the slot axis, so rebuild
        # the singleton batch axis the segment stack expects
        head_out = {"mode": "decode", "positions": pos_row[None, None],
                    "seq_pos": pos_row[None, None], "impl": impl,
                    "remat": False, "unroll": False,
                    "encoder_out": None, "n_prefix": 0}
        cache1 = {"stack": jax.tree.map(lambda c: c[:, None], stack_row)}
        to = model.tail_fwd(tail_p, x_row[None], head_out, cache=cache1)
        new_stack = jax.tree.map(lambda c: c[:, 0], to["cache"]["stack"])
        return to["logits"][0, 0].astype(jnp.float32), new_stack

    # slot axis: 0 on gathered tails / smashed rows / positions, 1 on
    # every cache leaf (after the stacked-layer axis)
    tail_slots = jax.vmap(tail_one, in_axes=(0, 0, 0, 1), out_axes=(0, 1))

    def decode_step(shared, bank_tails, tenant_ids, tokens, pos, active,
                    cache):
        batch = {"tokens": tokens[:, None], "pos": pos}
        ho = model.head_fwd(shared["head"], None, batch, mode="decode",
                            cache=cache["head"], impl=impl, dtype=dtype)
        n_active = jnp.sum(active.astype(jnp.float32))
        x, b_hb = wire.head_body.transmit(ho["smashed"], train=False,
                                          rows=n_active)
        bo = model.body_fwd(shared["body"], x, ho, cache=cache["body"])
        x, b_bt = wire.body_tail.transmit(bo["smashed"], train=False,
                                          rows=n_active)
        tails = jax.tree.map(lambda t: jnp.take(t, tenant_ids, axis=0),
                             bank_tails)
        logits, new_tail_stack = tail_slots(tails, x, pos,
                                            cache["tail"]["stack"])
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        new_cache = {"head": ho["cache"], "body": bo["cache"],
                     "tail": {"stack": new_tail_stack}}
        return next_tok, logits, new_cache, {"head_body": b_hb,
                                             "body_tail": b_bt}
    return decode_step


def make_multi_decode_step(model: SplitModel, n_steps: int, *,
                           impl: str = "ref", dtype=jnp.float32,
                           with_logits: bool = True):
    """multi_decode_step(shared, bank_tails, tenant_ids, tokens, pos,
    remaining, cache) -> (toks (n_steps, S), logits (n_steps, S, V) or
    None, cache, wire_bytes).

    Runs `n_steps` greedy decode tokens for every slot inside one lax.scan
    over the EXACT per-token body `make_batched_decode_step` builds, so the
    fast path is logit-identical to per-token stepping by construction.
    `remaining` (S,) int32 is each slot's outstanding token budget (0 for
    idle slots): slot i is wire-active for the first remaining[i] scan
    steps and a dead weight (computed, discarded, unmetered) after — the
    engine discards trailing tokens and retires the slot at scan exit.

    `with_logits=False` keeps the logits out of the scan outputs: the
    engine only collects them on request, and stacking (n_steps, S, V) per
    dispatch would multiply the hot path's live logits memory by n_steps
    for a tensor the host immediately drops."""
    decode_step = make_batched_decode_step(model, impl=impl, dtype=dtype)

    def multi_decode_step(shared, bank_tails, tenant_ids, tokens, pos,
                          remaining, cache):
        def body(carry, t):
            tokens, pos, cache, acc = carry
            active = (remaining > t).astype(jnp.float32)
            tok, logits, cache, wb = decode_step(
                shared, bank_tails, tenant_ids, tokens, pos, active, cache)
            acc = {k: acc[k] + wb[k] for k in acc}
            ys = (tok, logits) if with_logits else tok
            return (tok, pos + 1, cache, acc), ys

        zero = {name: jnp.float32(0.0) for name in BOUNDARY_NAMES}
        (_, _, cache, wb), ys = jax.lax.scan(
            body, (tokens, pos, cache, zero),
            jnp.arange(n_steps, dtype=jnp.int32))
        toks, logits = ys if with_logits else (ys, None)
        return toks, logits, cache, wb
    return multi_decode_step


# ------------------------------------------------------------- paged steps
def make_paged_decode_step(model: SplitModel, *, impl: str = "ref",
                           dtype=jnp.float32):
    """paged_decode_step(shared, bank_tails, tenant_ids, tokens, pos,
    active, pool, tables) -> (next_tok (S,), logits (S, V), pool,
    wire_bytes).

    The paged twin of `make_batched_decode_step`: the shared KV cache is a
    PAGE POOL and `tables` (S, n_blocks) maps each slot's logical blocks to
    physical pages. Head and body attend through the block tables directly
    (`paged_decode_attention` — gather on XLA, scalar-prefetch on TPU); the
    tail's per-tenant vmap cannot scatter into one shared pool from inside
    vmap, so its pool is gathered to the dense per-slot view pre-vmap and
    only the single written token is scattered back after. Retired slots'
    table rows point every block at the scratch page, so their (discarded)
    writes never touch a live page. Wire accounting is IDENTICAL to the
    dense step — paging is memory-only.
    """
    wire = model.wire

    def tail_one(tail_p, x_row, pos_row, stack_row):
        head_out = {"mode": "decode", "positions": pos_row[None, None],
                    "seq_pos": pos_row[None, None], "impl": impl,
                    "remat": False, "unroll": False,
                    "encoder_out": None, "n_prefix": 0}
        cache1 = {"stack": jax.tree.map(lambda c: c[:, None], stack_row)}
        to = model.tail_fwd(tail_p, x_row[None], head_out, cache=cache1)
        new_stack = jax.tree.map(lambda c: c[:, 0], to["cache"]["stack"])
        return to["logits"][0, 0].astype(jnp.float32), new_stack

    tail_slots = jax.vmap(tail_one, in_axes=(0, 0, 0, 1), out_axes=(0, 1))

    def paged_decode_step(shared, bank_tails, tenant_ids, tokens, pos,
                          active, pool, tables):
        batch = {"tokens": tokens[:, None], "pos": pos}
        head_cache = model.paged_seg_view(pool["head"], tables)
        ho = model.head_fwd(shared["head"], None, batch, mode="decode",
                            cache=head_cache, impl=impl, dtype=dtype)
        n_active = jnp.sum(active.astype(jnp.float32))
        x, b_hb = wire.head_body.transmit(ho["smashed"], train=False,
                                          rows=n_active)
        body_cache = model.paged_seg_view(pool["body"], tables)
        bo = model.body_fwd(shared["body"], x, ho, cache=body_cache)
        x, b_bt = wire.body_tail.transmit(bo["smashed"], train=False,
                                          rows=n_active)
        tails = jax.tree.map(lambda t: jnp.take(t, tenant_ids, axis=0),
                             bank_tails)
        tail_dense = model.paged_gather(pool["tail"], tables)
        logits, new_tail_stack = tail_slots(tails, x, pos,
                                            tail_dense["stack"])
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        new_pool = {
            "head": model.strip_paged_view(ho["cache"]),
            "body": model.strip_paged_view(bo["cache"]),
            "tail": model.paged_scatter_token(
                pool["tail"], {"stack": new_tail_stack}, tables, pos),
        }
        return next_tok, logits, new_pool, {"head_body": b_hb,
                                            "body_tail": b_bt}
    return paged_decode_step


def make_paged_multi_decode_step(model: SplitModel, n_steps: int, *,
                                 impl: str = "ref", dtype=jnp.float32,
                                 with_logits: bool = True):
    """`make_multi_decode_step` over the page pool: n_steps greedy tokens
    per slot in one lax.scan of the paged per-token body (the block tables
    are loop constants — pages are preallocated for a request's whole
    lifetime at admission, so no table ever changes mid-dispatch)."""
    decode_step = make_paged_decode_step(model, impl=impl, dtype=dtype)

    def paged_multi_decode_step(shared, bank_tails, tenant_ids, tokens, pos,
                                remaining, pool, tables):
        def body(carry, t):
            tokens, pos, pool, acc = carry
            active = (remaining > t).astype(jnp.float32)
            tok, logits, pool, wb = decode_step(
                shared, bank_tails, tenant_ids, tokens, pos, active, pool,
                tables)
            acc = {k: acc[k] + wb[k] for k in acc}
            ys = (tok, logits) if with_logits else tok
            # a slot that retires mid-scan keeps computing but its position
            # FREEZES: advancing past the request total would walk the
            # write pointer off the slot's allocated pages into NULL table
            # entries (the dense ring just wraps; pages cannot)
            return (tok, pos + (remaining > t + 1), pool, acc), ys

        zero = {name: jnp.float32(0.0) for name in BOUNDARY_NAMES}
        (_, _, pool, wb), ys = jax.lax.scan(
            body, (tokens, pos, pool, zero),
            jnp.arange(n_steps, dtype=jnp.int32))
        toks, logits = ys if with_logits else (ys, None)
        return toks, logits, pool, wb
    return paged_multi_decode_step


def make_chunk_continue_step(model: SplitModel, *, impl: str = "ref",
                             dtype=jnp.float32):
    """chunk_step(shared, tail, batch, cache, chunk_start) ->
    (next_tok (1,), last_logits (1, V), cache, wire_bytes).

    A chunked-prefill CONTINUATION: `batch["tokens"]` (1, T) extends a
    partially-filled batch=1 prefill cache starting at absolute position
    `chunk_start` (1,). The soft prompt went in with the first chunk
    (`make_tenant_prefill_step`), so none is prepended; attention runs
    write-then-attend over the full cache. Wire bytes are the chunk's
    smashed tensors — summed over chunks they equal the monolithic
    prefill's bytes exactly (the smashed footprint is linear in tokens)."""
    def chunk_step(shared, tail, batch, cache, chunk_start):
        params = {"head": shared["head"], "body": shared["body"],
                  "tail": tail}
        out = model.forward(params, batch, route="split", mode="prefill",
                            cache=cache, impl=impl, dtype=dtype,
                            chunk_start=chunk_start)
        logits = out["logits"][:, -1, :].astype(jnp.float32)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return next_tok, logits, out["cache"], out["wire_bytes"]
    return chunk_step
