"""jit-able steps of the continuous-batching split-serving engine.

Two step shapes, both crossing the PR-1 wire boundaries:

* `make_tenant_prefill_step` — one request joins: head (+ the tenant's soft
  prompt) -> body -> the tenant's tail, at batch=1 against a blank slot
  cache. The engine scatters the resulting cache into the request's slot of
  the shared KV cache, so the join never drains the in-flight batch.
* `make_batched_decode_step` — one token for EVERY occupied slot: the
  frozen head and body run the whole slot batch through one jitted step
  (shared parameters), then the tail is vmapped over slots with each slot's
  TENANT tail gathered from the bank — heterogeneous tenants, one compiled
  function.

Wire accounting: prefill transmits exactly the request's smashed tensor;
decode transmits per OCCUPIED row (`Boundary.transmit(rows=n_active)`) —
idle slots ride through compute for shape stability but never count bytes,
mirroring a deployment that simply doesn't send those rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.split import SplitModel


def make_tenant_prefill_step(model: SplitModel, *, impl: str = "ref",
                             dtype=jnp.float32):
    """prefill_step(shared, tail, prompt, batch, cache) ->
    (next_tok (1,), last_logits (1, V), cache, wire_bytes)."""
    def prefill_step(shared, tail, prompt, batch, cache):
        params = {"head": shared["head"], "body": shared["body"],
                  "tail": tail, "prompt": prompt}
        out = model.forward(params, batch, route="split", mode="prefill",
                            cache=cache, impl=impl, dtype=dtype,
                            prompt=prompt)
        logits = out["logits"][:, -1, :].astype(jnp.float32)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return next_tok, logits, out["cache"], out["wire_bytes"]
    return prefill_step


def make_batched_decode_step(model: SplitModel, *, impl: str = "ref",
                             dtype=jnp.float32):
    """decode_step(shared, bank_tails, tenant_ids, tokens, pos, active,
    cache) -> (next_tok (S,), logits (S, V), cache, wire_bytes).

    `tokens`/`pos`/`tenant_ids` are per-slot (S,) arrays; `active` is the
    (S,) occupancy mask — idle slots compute garbage that the host ignores
    (their cache rows are wholly overwritten at the next allocation) and
    contribute zero wire bytes.
    """
    wire = model.wire

    def tail_one(tail_p, x_row, pos_row, stack_row):
        # one slot's tail, batch=1: vmap removes the slot axis, so rebuild
        # the singleton batch axis the segment stack expects
        head_out = {"mode": "decode", "positions": pos_row[None, None],
                    "seq_pos": pos_row[None, None], "impl": impl,
                    "remat": False, "unroll": False,
                    "encoder_out": None, "n_prefix": 0}
        cache1 = {"stack": jax.tree.map(lambda c: c[:, None], stack_row)}
        to = model.tail_fwd(tail_p, x_row[None], head_out, cache=cache1)
        new_stack = jax.tree.map(lambda c: c[:, 0], to["cache"]["stack"])
        return to["logits"][0, 0].astype(jnp.float32), new_stack

    # slot axis: 0 on gathered tails / smashed rows / positions, 1 on
    # every cache leaf (after the stacked-layer axis)
    tail_slots = jax.vmap(tail_one, in_axes=(0, 0, 0, 1), out_axes=(0, 1))

    def decode_step(shared, bank_tails, tenant_ids, tokens, pos, active,
                    cache):
        batch = {"tokens": tokens[:, None], "pos": pos}
        ho = model.head_fwd(shared["head"], None, batch, mode="decode",
                            cache=cache["head"], impl=impl, dtype=dtype)
        n_active = jnp.sum(active.astype(jnp.float32))
        x, b_hb = wire.head_body.transmit(ho["smashed"], train=False,
                                          rows=n_active)
        bo = model.body_fwd(shared["body"], x, ho, cache=cache["body"])
        x, b_bt = wire.body_tail.transmit(bo["smashed"], train=False,
                                          rows=n_active)
        tails = jax.tree.map(lambda t: jnp.take(t, tenant_ids, axis=0),
                             bank_tails)
        logits, new_tail_stack = tail_slots(tails, x, pos,
                                            cache["tail"]["stack"])
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        new_cache = {"head": ho["cache"], "body": bo["cache"],
                     "tail": {"stack": new_tail_stack}}
        return next_tok, logits, new_cache, {"head_body": b_hb,
                                             "body_tail": b_bt}
    return decode_step
