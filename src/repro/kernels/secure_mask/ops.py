"""Public fused masked-encode ops with impl dispatch.

The contract the secure aggregator relies on: within ONE impl, the stream
`summed_mask(seeds, signs, n)` is a pure function of its arguments, so the
masks a client folded into its upload are exactly the masks the server
regenerates for dropout recovery. Across impls the streams differ (threefry
ref vs pltpu TPU PRNG) but the cohort ring sum is impl-independent — masks
cancel before anything is decoded.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.secure_mask import ref
from repro.kernels.secure_mask.kernel import LANES, masked_encode_fwd
from repro.kernels.secure_mask.ref import (FRAC_BITS, decode,  # noqa: F401
                                           encode)


def _resolve(impl: str) -> str:
    if impl in ("auto", "analysis"):
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def ring_size(n: int) -> int:
    """Flattened uploads are padded to a LANES multiple so the Pallas path
    tiles cleanly; the pad rides the wire too (masks cover it), so both the
    meter and the analytical model count the PADDED length."""
    return n + (-n) % LANES


def _block_n(N: int, want: int = 8) -> int:
    """Largest row-block that divides N exactly — a remainder would leave
    trailing rows unwritten (the grid floor-divides), and N can be as
    small as 1 (one LANES-row upload)."""
    return next(b for b in (want, 4, 2, 1) if N % b == 0)


@functools.partial(jax.jit, static_argnames=("frac_bits", "impl"))
def masked_encode(x: jnp.ndarray, seeds: jnp.ndarray, signs: jnp.ndarray, *,
                  frac_bits: int = FRAC_BITS, impl: str = "auto"):
    """One client's secure upload: encode(x) + sum_j sign_j * PRG(seed_j).

    x: (n,) f32 with n % LANES == 0 (see ring_size); seeds (J,) uint32,
    signs (J,) int32 in {-1, 0, +1}. Returns (n,) uint32.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.masked_encode(x, seeds, signs, frac_bits)
    n = x.shape[0]
    x2 = x.reshape(-1, LANES)
    out = masked_encode_fwd(x2, seeds, signs, frac_bits=frac_bits,
                            block_n=_block_n(x2.shape[0]),
                            interpret=(impl == "interpret"))
    return out.reshape(n)


@functools.partial(jax.jit, static_argnames=("n", "frac_bits", "impl"))
def summed_mask(seeds: jnp.ndarray, signs: jnp.ndarray, n: int, *,
                frac_bits: int = FRAC_BITS, impl: str = "auto"):
    """The pure mask stream (encode of zero) — the server's dropout-recovery
    reconstruction. MUST ride the same impl as the uploads it corrects."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.summed_mask(seeds, signs, n)
    out = masked_encode_fwd(jnp.zeros((n // LANES, LANES), jnp.float32),
                            seeds, signs, frac_bits=frac_bits,
                            block_n=_block_n(n // LANES),
                            interpret=(impl == "interpret"))
    return out.reshape(n)
