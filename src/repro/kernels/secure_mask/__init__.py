from repro.kernels.secure_mask.ops import (  # noqa: F401
    masked_encode, ring_size, summed_mask)
