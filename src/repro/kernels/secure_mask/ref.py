"""Pure-jnp oracle for the fused secure-aggregation mask op.

One client's secure upload is its trainable delta fixed-point-encoded into
the uint32 ring with the client's summed pairwise mask folded in:

    upload = encode(x) + sum_j sign_j * PRG(seed_j)      (mod 2^32)

Fixed point: two's-complement at `frac_bits` fractional bits, saturating at
the int32 range edge on encode; ring arithmetic wraps mod 2^32 (uint32
overflow is DEFINED wraparound in XLA, which is exactly the ring the
masking algebra needs). decode() recenters: values >= 2^31 are negative.

The PRG here is jax.random.bits (threefry) keyed on the pair seed — NOT the
same bit stream as the Pallas kernel's pltpu PRNG, by design. Mask bits
never need to match across impls, only to CANCEL within one impl: the
cohort's ring sum (everything the server ever decodes) is bit-identical
across impls because the masks vanish from it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

FRAC_BITS = 16          # default fixed-point precision: ~1.5e-5 resolution
RING_EDGE = 2.0 ** 31   # signed-range boundary of the uint32 ring
# saturation bound: the largest f32 BELOW 2^31 — clipping at 2^31 - 1
# would round the bound up to exactly 2^31 in f32 and flip a saturated
# positive value into the negative ring half
SAT = RING_EDGE - 128


def encode(x: jnp.ndarray, frac_bits: int = FRAC_BITS) -> jnp.ndarray:
    """float -> uint32 two's-complement fixed point (saturating)."""
    q = jnp.round(x.astype(jnp.float32) * (2.0 ** frac_bits))
    q = jnp.clip(q, -SAT, SAT)
    mag = jnp.abs(q).astype(jnp.uint32)
    return jnp.where(q < 0, jnp.uint32(0) - mag, mag)


def decode(u: jnp.ndarray, frac_bits: int = FRAC_BITS) -> jnp.ndarray:
    """uint32 ring value -> f32, recentered (u >= 2^31 reads negative)."""
    neg = u >= jnp.uint32(RING_EDGE)
    mag = jnp.where(neg, jnp.uint32(0) - u, u).astype(jnp.float32)
    return jnp.where(neg, -mag, mag) / (2.0 ** frac_bits)


def mask_stream(seed, n: int) -> jnp.ndarray:
    """The (n,) uint32 PRG stream of one pairwise seed (ref impl)."""
    return jax.random.bits(jax.random.PRNGKey(seed), (n,), jnp.uint32)


def _signed(m: jnp.ndarray, sign) -> jnp.ndarray:
    m = jnp.where(sign < 0, jnp.uint32(0) - m, m)
    return jnp.where(sign == 0, jnp.uint32(0), m)


def summed_mask(seeds: jnp.ndarray, signs: jnp.ndarray, n: int) -> jnp.ndarray:
    """sum_j sign_j * PRG(seed_j) over the pair axis, O(n) memory (the
    streams are generated and folded one at a time under a scan)."""
    def one(carry, sj):
        seed, sign = sj
        return carry + _signed(mask_stream(seed, n), sign), None

    out, _ = jax.lax.scan(one, jnp.zeros((n,), jnp.uint32),
                          (jnp.asarray(seeds), jnp.asarray(signs)))
    return out


def masked_encode(x: jnp.ndarray, seeds: jnp.ndarray, signs: jnp.ndarray,
                  frac_bits: int = FRAC_BITS) -> jnp.ndarray:
    """encode(x) + summed pairwise mask, fused single pass over x."""
    def one(carry, sj):
        seed, sign = sj
        return carry + _signed(mask_stream(seed, x.shape[0]), sign), None

    out, _ = jax.lax.scan(one, encode(x, frac_bits),
                          (jnp.asarray(seeds), jnp.asarray(signs)))
    return out
