"""Fused fixed-point encode + pairwise-mask-add as a Pallas TPU kernel.

One VMEM pass per block of the flattened client delta: encode x into the
uint32 ring, then fold in every pairwise mask stream generated ON-CORE with
`pltpu.prng_random_bits` — the mask bits never exist in HBM, only their sum
folded into the upload. Grid over row blocks; each (pair, block) stream is
seeded with (pair seed, block index) so blocks draw disjoint streams and
the server's dropout-recovery pass (same seeds, x = 0) regenerates them
exactly.

Unlike kernels/quant, the PRG here is deliberately NOT host-fed: the mask
stream per client is O(n_pairs * n) bits — materializing it defeats the
one-pass point. The pure-jnp ref uses a different PRG (threefry); that is
fine because mask bits only ever need to cancel within one impl (see
ref.py). pltpu PRNG has no interpret-mode lowering in this JAX, so CPU CI
exercises the ref path and this kernel validates on real TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compiler_params
from repro.kernels.secure_mask.ref import FRAC_BITS, SAT

LANES = 128


def _masked_encode_kernel(seeds_ref, signs_ref, x_ref, o_ref, *,
                          frac_bits: int, n_pairs: int):
    # ---- fixed-point encode (saturating two's complement)
    q = jnp.round(x_ref[...].astype(jnp.float32) * (2.0 ** frac_bits))
    q = jnp.clip(q, -SAT, SAT)
    mag = jnp.abs(q).astype(jnp.uint32)
    acc = jnp.where(q < 0, jnp.uint32(0) - mag, mag)

    # ---- fold in each pairwise mask stream, generated on-core
    blk = pl.program_id(0)
    for j in range(n_pairs):          # n_pairs is static (K - 1), unrolled
        pltpu.prng_seed(seeds_ref[j], blk)
        bits = pltpu.bitcast(pltpu.prng_random_bits(x_ref.shape), jnp.uint32)
        sign = signs_ref[j]
        m = jnp.where(sign < 0, jnp.uint32(0) - bits, bits)
        acc = acc + jnp.where(sign == 0, jnp.uint32(0), m)
    o_ref[...] = acc


def masked_encode_fwd(x: jnp.ndarray, seeds: jnp.ndarray,
                      signs: jnp.ndarray, *, frac_bits: int = FRAC_BITS,
                      block_n: int = 8, interpret: bool = False):
    """x (N, LANES) f32, seeds/signs (n_pairs,) — N % block_n == 0.
    Returns the masked uint32 upload (N, LANES)."""
    N, D = x.shape
    n_pairs = seeds.shape[0]
    kernel = functools.partial(_masked_encode_kernel, frac_bits=frac_bits,
                               n_pairs=n_pairs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N // block_n,),
        in_specs=[pl.BlockSpec((block_n, D), lambda i, *_: (i, 0))],
        out_specs=pl.BlockSpec((block_n, D), lambda i, *_: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, D), jnp.uint32),
        compiler_params=compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="sfprompt_secure_masked_encode",
    )(seeds.astype(jnp.uint32), signs.astype(jnp.int32), x)
