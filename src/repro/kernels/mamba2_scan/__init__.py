from repro.kernels.mamba2_scan.ops import mamba2_scan  # noqa: F401
