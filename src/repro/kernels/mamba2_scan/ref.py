"""Pure-jnp oracle for the Mamba-2 SSD recurrence [arXiv:2405.21060-style,
as used by zamba2, arXiv:2411.15242].

Per head h with scalar log-decay rate A_h < 0, state h in R^{P x N}:

    a_t  = exp(dt_t * A)
    h_t  = a_t * h_{t-1} + (dt_t * x_t) B_t^T     (outer product, (P,N))
    y_t  = h_t C_t                                 ((P,N) @ (N,) -> (P,))
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def mamba2_scan(
    x: jnp.ndarray,     # (B, T, H, P)
    dt: jnp.ndarray,    # (B, T, H) positive
    A: jnp.ndarray,     # (H,) negative log-decay rate
    Bm: jnp.ndarray,    # (B, T, G, N) input matrix (G groups, H % G == 0)
    Cm: jnp.ndarray,    # (B, T, G, N) output matrix
    state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    f32 = jnp.float32
    x_, dt_, Bm_, Cm_ = (t.astype(f32) for t in (x, dt, Bm, Cm))
    Bh = jnp.repeat(Bm_, rep, axis=2)   # (B, T, H, N)
    Ch = jnp.repeat(Cm_, rep, axis=2)
    if state is None:
        state = jnp.zeros((B, H, P, N), f32)

    def step(h, inputs):
        xt, dtt, bt, ct = inputs
        a = jnp.exp(dtt * A.astype(f32))[..., None, None]     # (B,H,1,1)
        h = a * h + (dtt[..., None] * xt)[..., None] * bt[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (x_, dt_, Bh, Ch))
    final, ys = jax.lax.scan(step, state.astype(f32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def mamba2_chunked(
    x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, Bm: jnp.ndarray,
    Cm: jnp.ndarray, state: Optional[jnp.ndarray] = None, *,
    chunk: int = 128,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD chunked-matmul form with an UNROLLED python chunk loop (no lax
    control flow -> exact dry-run cost accounting). Same segsum math as the
    Pallas kernel; exact and f32-stable (scalar per-head decays, every
    exponent <= 0)."""
    B, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((B, H, P, N), f32)
    h = state.astype(f32)
    Af = A.astype(f32)
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    Bh = jnp.repeat(Bm.astype(f32), rep, axis=2)
    Ch = jnp.repeat(Cm.astype(f32), rep, axis=2)
    ys = []
    tri = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    for s0 in range(0, Tp, chunk):
        xc = x[:, s0:s0 + chunk].astype(f32)      # (B, c, H, P)
        dtc = dt[:, s0:s0 + chunk].astype(f32)    # (B, c, H)
        bc = Bh[:, s0:s0 + chunk]                 # (B, c, H, N)
        cc = Ch[:, s0:s0 + chunk]
        la = dtc * Af                             # (B, c, H) log decay <= 0
        acum = jnp.cumsum(la, axis=1)
        diff = acum[:, :, None] - acum[:, None, :]       # (B, t, s, H)
        L = jnp.where(tri[None, :, :, None],
                      jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        L = jnp.moveaxis(L, 3, 1)                 # (B, H, t, s)
        dtx = dtc[..., None] * xc
        cb = jnp.einsum("bthn,bshn->bhts", cc, bc)
        y = jnp.einsum("bhts,bshp->bthp", L * cb, dtx)
        # inter-chunk
        y = y + jnp.exp(acum)[..., None] * jnp.einsum(
            "bthn,bhpn->bthp", cc, h)
        ys.append(y)
        total = acum[:, -1]                        # (B, H)
        wgt = jnp.exp(total[:, None] - acum)       # (B, c, H)
        h = jnp.exp(total)[..., None, None] * h + jnp.einsum(
            "bshp,bshn->bhpn", dtx * wgt[..., None], bc)
    y = jnp.concatenate(ys, axis=1)[:, :T]
    return y.astype(x.dtype), h
