"""Public Mamba-2 SSD scan op with impl dispatch."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.mamba2_scan import ref
from repro.kernels.mamba2_scan.kernel import mamba2_fwd


@functools.partial(jax.jit, static_argnames=("impl", "chunk"))
def mamba2_scan(
    x: jnp.ndarray,     # (B, T, H, P)
    dt: jnp.ndarray,    # (B, T, H)
    A: jnp.ndarray,     # (H,)
    Bm: jnp.ndarray,    # (B, T, G, N)
    Cm: jnp.ndarray,    # (B, T, G, N)
    state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
    *,
    impl: str = "auto",
    chunk: int = 128,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl in ("chunked", "analysis"):
        return ref.mamba2_chunked(x, dt, A, Bm, Cm, state,
                                  chunk=min(chunk, x.shape[1]))
    if impl == "ref":
        return ref.mamba2_scan(x, dt, A, Bm, Cm, state)

    B, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    if state is None:
        state = jnp.zeros((B, H, P, N), jnp.float32)
    c = min(chunk, T)
    pad = (-T) % c
    xs = jnp.moveaxis(x, 2, 1).reshape(B * H, T, P)
    dts = jnp.moveaxis(dt, 2, 1).reshape(B * H, T, 1)
    Bs = jnp.moveaxis(Bm, 2, 1).reshape(B * G, T, N)
    Cs = jnp.moveaxis(Cm, 2, 1).reshape(B * G, T, N)
    if pad:
        w3 = ((0, 0), (0, pad), (0, 0))
        xs, Bs, Cs, dts = (jnp.pad(t, w3) for t in (xs, Bs, Cs, dts))
        # padded dt rows are zero: decay exp(0)=1 keeps state, dtx=0 adds nothing
    As = jnp.broadcast_to(A[None], (B, H)).reshape(B * H, 1)
    y, hout = mamba2_fwd(
        xs, dts, As, Bs, Cs, state.reshape(B * H, P, N),
        n_heads=H, n_groups=G, chunk=c, interpret=(impl == "interpret"))
    y = y[:, :T].reshape(B, H, T, P).swapaxes(1, 2)
    return y.astype(x.dtype), hout.reshape(B, H, P, N)
