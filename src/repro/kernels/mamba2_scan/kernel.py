"""Mamba-2 SSD as a chunked-matmul Pallas TPU kernel.

This is the TPU-native adaptation of the SSD algorithm: because the decay is
a *scalar per head*, the intra-chunk interaction matrix

    L[t, s] = exp(acum_t - acum_s) for s <= t (else 0),  acum = cumsum(dt * A)

is formed directly from the pairwise difference of the chunk-local cumsum —
every exponent is <= 0, so the factorization is f32-stable — and the chunk is
computed with three MXU matmuls instead of T rank-1 VPU updates:

    intra:  Y  = (L o (C B^T)) @ (dt * X)              (ct,ct)@(ct,P)
    inter:  Y += exp(acum)[:, None] * (C @ h_prev^T)   (ct,N)@(N,P)
    state:  h' = exp(acum_T) h_prev + (dtX)^T @ (B o exp(acum_T - acum))

Tiling: grid = (B*H, T/chunk), chunks sequential with the (P, N) state in
VMEM scratch. B/C are stored per-group (G groups) and mapped to heads in the
BlockSpec index map — no HBM-side repeat.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compiler_params


def _mamba2_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                   y_ref, hout_ref, h_scr, *,
                   chunk: int, n_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # (ct, P)
    dt = dt_ref[0].astype(jnp.float32)        # (ct, 1)
    A = a_ref[0].astype(jnp.float32)          # (1,) scalar decay rate
    Bm = b_ref[0].astype(jnp.float32)         # (ct, N)
    Cm = c_ref[0].astype(jnp.float32)         # (ct, N)

    la = dt * A                               # (ct, 1) per-step log decay <= 0
    acum = jnp.cumsum(la, axis=0)             # (ct, 1) inclusive
    # L[t, s] = exp(acum_t - acum_s + la_s)   for s <= t; la_s restores the
    # "decay applied after add" convention: contribution of s to h_t is
    # exp(sum_{r=s+1..t} la_r) = exp(acum_t - acum_s).
    diff = acum - acum.T                      # (ct, ct), [t,s] = acum_t - acum_s
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)

    dtx = dt * x                              # (ct, P)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (ct, ct)
    y = jax.lax.dot_general(L * cb, dtx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (ct, P)
    # inter-chunk: y_t += exp(acum_t) * C_t @ h_prev^T
    h_prev = h_scr[...]                        # (P, N)
    y += jnp.exp(acum) * jax.lax.dot_general(
        Cm, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)    # (ct, P)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update
    total = acum[-1:, :]                       # (1, 1)
    w = jnp.exp(total - acum)                  # (ct, 1), exponents <= 0
    h_new = jnp.exp(total) * h_prev + jax.lax.dot_general(
        dtx, Bm * w, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)    # (P, N)
    h_scr[...] = h_new

    @pl.when(ic == n_chunks - 1)
    def _finalize():
        hout_ref[0] = h_scr[...]


def mamba2_fwd(
    x: jnp.ndarray,     # (BH, T, P)
    dt: jnp.ndarray,    # (BH, T, 1)
    A: jnp.ndarray,     # (BH, 1)
    Bm: jnp.ndarray,    # (BG, T, N)  per-group
    Cm: jnp.ndarray,    # (BG, T, N)
    h0: jnp.ndarray,    # (BH, P, N)
    *,
    n_heads: int,
    n_groups: int,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    BH, T, P = x.shape
    N = Bm.shape[-1]
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk
    rep = n_heads // n_groups

    def head_seq(last):
        return pl.BlockSpec((1, chunk, last), lambda bh, ic: (bh, ic, 0))

    def group_seq(last):
        def idx(bh, ic):
            b, h = bh // n_heads, bh % n_heads
            return (b * n_groups + h // rep, ic, 0)
        return pl.BlockSpec((1, chunk, last), idx)

    kernel = functools.partial(_mamba2_kernel, chunk=chunk, n_chunks=n_chunks)
    y, hout = pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            head_seq(P), head_seq(1),
            pl.BlockSpec((1, 1), lambda bh, ic: (bh, 0)),
            group_seq(N), group_seq(N),
            pl.BlockSpec((1, P, N), lambda bh, ic: (bh, 0, 0)),
        ],
        out_specs=[head_seq(P), pl.BlockSpec((1, P, N), lambda bh, ic: (bh, 0, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="sfprompt_mamba2_ssd",
    )(x, dt, A, Bm, Cm, h0)
    return y, hout
