"""Public int8 wire quantize/dequantize ops with impl dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant import ref
from repro.kernels.quant.kernel import LANES, dequantize_fwd, quantize_fwd


def _resolve(impl: str) -> str:
    if impl in ("auto", "analysis"):
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def _block_n(N: int, want: int = 256) -> int:
    return next(b for b in (want, 128, 64, 32, 16, 8, 4, 2, 1) if N % b == 0)


@functools.partial(jax.jit, static_argnames=("impl",))
def quantize_int8(x: jnp.ndarray, u: jnp.ndarray, *, impl: str = "auto"):
    """Row-wise symmetric int8 quantization with stochastic rounding.

    x: (N, D) float; u: uniform noise in [0,1) broadcastable to (N, D)
    (pass 0.5 for deterministic round-to-nearest).
    Returns (values (N, D) int8, scales (N, 1) f32).
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.quantize(x, u)
    N, D = x.shape
    u = jnp.broadcast_to(jnp.asarray(u, jnp.float32), x.shape)
    padd = (-D) % LANES
    if padd:
        x = jnp.pad(x, ((0, 0), (0, padd)))
        u = jnp.pad(u, ((0, 0), (0, padd)))
    values, scales = quantize_fwd(x, u, block_n=_block_n(N),
                                  interpret=(impl == "interpret"))
    return values[:, :D], scales[:, :1]


@functools.partial(jax.jit, static_argnames=("impl", "dtype"))
def dequantize_int8(values: jnp.ndarray, scales: jnp.ndarray, *,
                    dtype=jnp.float32, impl: str = "auto"):
    """values (N, D) int8, scales (N, 1) f32 -> (N, D) dtype."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.dequantize(values, scales, dtype)
    N, D = values.shape
    padd = (-D) % LANES
    if padd:
        values = jnp.pad(values, ((0, 0), (0, padd)))
    scales = jnp.broadcast_to(scales.astype(jnp.float32), (N, LANES))
    out = dequantize_fwd(values, scales, dtype=dtype, block_n=_block_n(N),
                         interpret=(impl == "interpret"))
    return out[:, :D]
