"""Pure-jnp oracle for the int8 wire quantizer.

Per-row symmetric quantization over the last axis (one fp32 scale per
token-row of the smashed activation):

    scale = max|x_row| / 127          (clamped away from zero)
    q     = clip(floor(x/scale + u), -127, 127)   as int8

`u` is uniform noise in [0, 1): stochastic rounding (unbiased,
E[dequant(q)] = x).  `u = 0.5` reduces to round-to-nearest — the
deterministic mode used for eval/serving.  Dequantization is q * scale.
"""
from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-8
QMAX = 127.0


def quantize(x: jnp.ndarray, u) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., D) float; u broadcastable to x.shape in [0, 1).
    Returns (values int8 (..., D), scales f32 (..., 1))."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scales = jnp.maximum(amax / QMAX, EPS)
    q = jnp.floor(xf / scales + jnp.asarray(u, jnp.float32))
    values = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    return values, scales


def dequantize(values: jnp.ndarray, scales: jnp.ndarray,
               dtype=jnp.float32) -> jnp.ndarray:
    return (values.astype(jnp.float32) * scales).astype(dtype)
