"""Int8 stochastic quantize / dequantize as Pallas TPU kernels.

The wire codecs (runtime/codec.py) push every smashed activation and every
cut-layer gradient through this pair, so it sits on the head->body and
body->tail boundaries of phase-2 training AND the serving path — one HBM
pass each way.

Noise comes in as an explicit uniform input rather than pltpu.prng_*: the
host generates the bits from the protocol's PRNG key, which keeps the kernel
bit-identical to the pure-jnp ref (same noise -> same int8 payload) and
portable to interpret mode, where this JAX has no TPU PRNG lowering.

Tiling: grid over row blocks; a row (one token of the smashed tensor) never
spans tiles, so the per-row max/scale lives entirely in VMEM registers.
Scales are emitted LANES-wide (column 0 meaningful) like the el2n kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compiler_params

LANES = 128
EPS = 1e-8
QMAX = 127.0


def _quantize_kernel(x_ref, u_ref, v_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                  # (block_n, D)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / QMAX, EPS)               # (block_n, 1)
    q = jnp.floor(x / scale + u_ref[...].astype(jnp.float32))
    v_ref[...] = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    s_ref[...] = jnp.broadcast_to(scale, s_ref.shape)


def _dequantize_kernel(v_ref, s_ref, o_ref, *, dtype):
    scale = s_ref[:, :1]
    o_ref[...] = (v_ref[...].astype(jnp.float32) * scale).astype(dtype)


def quantize_fwd(x: jnp.ndarray, u: jnp.ndarray, *, block_n: int = 256,
                 interpret: bool = False):
    """x (N, D) float, u (N, D) uniform noise; N % block_n == 0.
    Returns (values (N, D) int8, scales (N, LANES) f32, col 0 meaningful)."""
    N, D = x.shape
    values, scales = pl.pallas_call(
        _quantize_kernel,
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec((block_n, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, D), jnp.int8),
            jax.ShapeDtypeStruct((N, LANES), jnp.float32),
        ],
        compiler_params=compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
        name="sfprompt_wire_quantize",
    )(x, u)
    return values, scales


def dequantize_fwd(values: jnp.ndarray, scales: jnp.ndarray, *,
                   dtype=jnp.float32, block_n: int = 256,
                   interpret: bool = False):
    """values (N, D) int8, scales (N, LANES) f32 -> (N, D) dtype."""
    N, D = values.shape
    kernel = functools.partial(_dequantize_kernel, dtype=dtype)
    return pl.pallas_call(
        kernel,
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec((block_n, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), dtype),
        compiler_params=compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
        name="sfprompt_wire_dequantize",
    )(values, scales)
