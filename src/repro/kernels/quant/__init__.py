from repro.kernels.quant.ops import dequantize_int8, quantize_int8  # noqa: F401
