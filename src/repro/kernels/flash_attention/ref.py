"""Pure-jnp oracle for flash attention.

Supports GQA (n_q_heads a multiple of n_kv_heads), causal masking with a
query position offset (prefill continuation / decode), sliding windows, logit
softcapping (gemma-2), and explicit kv position/validity arrays (ring-buffer
decode caches pass non-contiguous kv slot positions).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

NEG_INF = -2.0 ** 30  # finite large-negative: avoids NaNs for fully-masked rows


def attention(
    q: jnp.ndarray,              # (B, Sq, Hq, Dh)
    k: jnp.ndarray,              # (B, Skv, Hkv, Dh)
    v: jnp.ndarray,              # (B, Skv, Hkv, Dv)
    *,
    causal: bool = True,
    q_offset: Optional[jnp.ndarray] = None,   # (B,) absolute position of q[:,0]
    kv_positions: Optional[jnp.ndarray] = None,  # (B, Skv) absolute pos, -1 = empty
    sliding_window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if scale is None:
        scale = Dh ** -0.5

    if q_offset is None:
        q_offset = jnp.zeros((B,), jnp.int32)
    q_pos = q_offset[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]  # (B,Sq)
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(
            jnp.arange(Skv, dtype=jnp.int32)[None, :], (B, Skv))

    # (B, Sq, Skv) mask
    valid = kv_positions[:, None, :] >= 0
    if causal:
        valid &= kv_positions[:, None, :] <= q_pos[:, :, None]
    if sliding_window is not None:
        valid &= kv_positions[:, None, :] > q_pos[:, :, None] - sliding_window

    kg = jnp.repeat(k, group, axis=2)  # (B, Skv, Hq, Dh)
    vg = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kg.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(valid[:, None, :, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vg.astype(jnp.float32))
    return out.astype(q.dtype)


def _auto_block(s: int, target_blocks: int = 6) -> int:
    """Block edge targeting ~`target_blocks` blocks per axis, multiple of
    128: enough blocks that causal dead-block skipping recovers ~40% of the
    FLOPs, few enough that the unrolled HLO stays small (the dry-run
    analysis compile lowers this at 32k sequences)."""
    edge = -(-s // target_blocks)
    return max(128, -(-edge // 128) * 128)


def blocked_attention(
    q: jnp.ndarray,              # (B, Sq, Hq, Dh)
    k: jnp.ndarray,              # (B, Skv, Hkv, Dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: Optional[int] = None,
    block_kv: Optional[int] = None,
) -> jnp.ndarray:
    """XLA-native flash attention: UNROLLED python loops over (q, kv) block
    pairs with online-softmax accumulation — O(block_q * block_kv) live
    score memory, no lax control flow (so dry-run cost_analysis counts it
    correctly), same math as the Pallas kernel.

    Two things make it FASTER than the full ref path rather than a
    memory-only trade (BENCH_kernels.json pins blocked_speedup >= 1.0):
      * dead-block skipping — (q, kv) pairs entirely above the causal
        diagonal or left of every row's sliding window are never emitted,
        ~40% of the work at 6 blocks/axis;
      * grouped GQA contraction — q heads are folded to (Hkv, group) and
        contracted against the raw K/V, never materializing the
        group-repeated (B, Skv, Hq) tensors the oracle builds.
    Interior blocks (fully inside the causal region) also skip the mask
    materialization entirely."""
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    Dv = v.shape[-1]
    group = Hq // Hkv
    if scale is None:
        scale = Dh ** -0.5
    bq = block_q or _auto_block(Sq)
    bkv = block_kv or _auto_block(Skv)

    # head-major f32 layout once, group folded out of the head axis
    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)
    qf = qf.reshape(B, Hkv, group, Sq, Dh)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)   # (B, Hkv, Skv, Dh)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)   # (B, Hkv, Skv, Dv)

    out_blocks = []
    for qs in range(0, Sq, bq):
        qe = min(qs + bq, Sq)
        qb = qf[:, :, :, qs:qe]
        rows = jnp.arange(qs, qe, dtype=jnp.int32)
        acc = jnp.zeros((B, Hkv, group, qe - qs, Dv), jnp.float32)
        m = jnp.full((B, Hkv, group, qe - qs, 1), NEG_INF, jnp.float32)
        l = jnp.zeros((B, Hkv, group, qe - qs, 1), jnp.float32)
        for ks in range(0, Skv, bkv):
            ke = min(ks + bkv, Skv)
            if causal and ks > qe - 1:
                continue   # entirely above the diagonal
            if sliding_window is not None and ke - 1 <= qs - sliding_window:
                continue   # entirely left of every row's window
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kf[:, :, ks:ke])
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            needs_mask = (causal and ke - 1 > qs) or (
                sliding_window is not None
                and ks <= (qe - 1) - sliding_window)
            if needs_mask:
                cols = jnp.arange(ks, ke, dtype=jnp.int32)
                mask = jnp.ones((qe - qs, ke - ks), bool)
                if causal:
                    mask &= cols[None, :] <= rows[:, None]
                if sliding_window is not None:
                    mask &= cols[None, :] > rows[:, None] - sliding_window
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, -1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new)
            l = alpha * l + jnp.sum(p, -1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p,
                                           vf[:, :, ks:ke])
            m = m_new
        out_blocks.append(acc / jnp.maximum(l, 1e-30))

    out = jnp.concatenate(out_blocks, axis=3)          # (B, Hkv, G, Sq, Dv)
    return out.reshape(B, Hq, Sq, Dv).transpose(0, 2, 1, 3).astype(q.dtype)
