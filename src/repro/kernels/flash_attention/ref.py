"""Pure-jnp oracle for flash attention.

Supports GQA (n_q_heads a multiple of n_kv_heads), causal masking with a
query position offset (prefill continuation / decode), sliding windows, logit
softcapping (gemma-2), and explicit kv position/validity arrays (ring-buffer
decode caches pass non-contiguous kv slot positions).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

NEG_INF = -2.0 ** 30  # finite large-negative: avoids NaNs for fully-masked rows


def attention(
    q: jnp.ndarray,              # (B, Sq, Hq, Dh)
    k: jnp.ndarray,              # (B, Skv, Hkv, Dh)
    v: jnp.ndarray,              # (B, Skv, Hkv, Dv)
    *,
    causal: bool = True,
    q_offset: Optional[jnp.ndarray] = None,   # (B,) absolute position of q[:,0]
    kv_positions: Optional[jnp.ndarray] = None,  # (B, Skv) absolute pos, -1 = empty
    sliding_window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if scale is None:
        scale = Dh ** -0.5

    if q_offset is None:
        q_offset = jnp.zeros((B,), jnp.int32)
    q_pos = q_offset[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]  # (B,Sq)
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(
            jnp.arange(Skv, dtype=jnp.int32)[None, :], (B, Skv))

    # (B, Sq, Skv) mask
    valid = kv_positions[:, None, :] >= 0
    if causal:
        valid &= kv_positions[:, None, :] <= q_pos[:, :, None]
    if sliding_window is not None:
        valid &= kv_positions[:, None, :] > q_pos[:, :, None] - sliding_window

    kg = jnp.repeat(k, group, axis=2)  # (B, Skv, Hq, Dh)
    vg = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kg.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(valid[:, None, :, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vg.astype(jnp.float32))
    return out.astype(q.dtype)


def blocked_attention(
    q: jnp.ndarray,              # (B, Sq, Hq, Dh)
    k: jnp.ndarray,              # (B, Skv, Hkv, Dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_kv: int = 2048,
) -> jnp.ndarray:
    """XLA-native flash attention: an UNROLLED python loop over kv blocks
    with online-softmax accumulation — O(Sq * block) live memory, no lax
    control flow (so dry-run cost_analysis counts it correctly), same math
    as the Pallas kernel. Used for dry-run analysis compiles and as the
    production CPU path for long sequences."""
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = Dh ** -0.5
    qf = q.astype(jnp.float32) * scale
    q_pos = jnp.arange(Sq, dtype=jnp.int32)

    acc = jnp.zeros((B, Sq, Hq, v.shape[-1]), jnp.float32)
    m = jnp.full((B, Sq, Hq, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((B, Sq, Hq, 1), jnp.float32)

    for start in range(0, Skv, block_kv):
        end = min(start + block_kv, Skv)
        if causal and start > Sq - 1:
            break  # fully above the diagonal
        kb = jnp.repeat(k[:, start:end].astype(jnp.float32), group, axis=2)
        vb = jnp.repeat(v[:, start:end].astype(jnp.float32), group, axis=2)
        s = jnp.einsum("bqhd,bkhd->bqhk", qf, kb)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        cols = jnp.arange(start, end, dtype=jnp.int32)
        mask = jnp.ones((Sq, end - start), bool)
        if causal:
            mask &= cols[None, :] <= q_pos[:, None]
        if sliding_window is not None:
            mask &= cols[None, :] > q_pos[:, None] - sliding_window
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, -1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l = alpha * l + jnp.sum(p, -1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bqhk,bkhd->bqhd", p, vb)
        m = m_new

    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
