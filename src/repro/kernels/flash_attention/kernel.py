"""Flash attention as a Pallas TPU kernel.

Tiling: grid = (batch*q_heads, Sq/block_q, Skv/block_kv); the kv axis is the
innermost (sequential) dimension, carrying the online-softmax state
(running max m, denominator l, output accumulator acc) in VMEM scratch.
Block shapes are MXU-aligned (multiples of 128 on the lane dim). GQA is
handled in the BlockSpec index maps: each q head reads its kv group's block,
so kv tiles are fetched once per group member but never materialized at the
(B, Sq, Hq) footprint.

Supports causal masking, sliding windows (gemma-2 local layers / ring-buffer
long-context decode prefill), logit softcapping, and right-padded kv.
Self-correcting masked-softmax: fully-masked rows produce garbage that is
annihilated by alpha=exp(m_prev - m_new)=0 once a real logit arrives.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compiler_params

MASK_VALUE = -2.0 ** 30
LANES = 128


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: Optional[int],
               softcap: Optional[float], kv_len: int,
               block_q: int, block_kv: int, n_kv_blocks: int):
    iq = pl.program_id(1)
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, MASK_VALUE)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level skip: a kv block is dead if it is entirely above the causal
    # diagonal or entirely left of every row's sliding window.
    row_min = iq * block_q
    row_max = iq * block_q + block_q - 1
    col_min = ikv * block_kv
    col_max = ikv * block_kv + block_kv - 1
    live = jnp.asarray(True)
    if causal:
        live &= col_min <= row_max
    if window is not None:
        live &= col_max > row_min - window
    live &= col_min < kv_len

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (block_q, d)
        k = k_ref[0].astype(jnp.float32)          # (block_kv, d)
        v = v_ref[0].astype(jnp.float32)          # (block_kv, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        rows = row_min + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        cols = col_min + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = cols < kv_len
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, MASK_VALUE)

        m_prev = m_scr[:, :1]                      # (block_q, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ikv == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[:, :1], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,   # (BHq, Sq, Dh) — batch and q-heads flattened
    k: jnp.ndarray,   # (BHkv, Skv, Dh)
    v: jnp.ndarray,   # (BHkv, Skv, Dv)
    *,
    n_q_heads: int,
    n_kv_heads: int,
    causal: bool,
    sliding_window: Optional[int],
    softcap: Optional[float],
    scale: float,
    kv_len: int,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    BH, Sq, Dh = q.shape
    _, Skv, Dv = v.shape
    assert Sq % block_q == 0 and Skv % block_kv == 0, (Sq, Skv, block_q, block_kv)
    group = n_q_heads // n_kv_heads
    nq, nkv = Sq // block_q, Skv // block_kv

    def q_index(bh, iq, ikv):
        return (bh, iq, 0)

    def kv_index(bh, iq, ikv):
        b, h = bh // n_q_heads, bh % n_q_heads
        return (b * n_kv_heads + h // group, ikv, 0)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=sliding_window,
        softcap=softcap, kv_len=kv_len, block_q=block_q, block_kv=block_kv,
        n_kv_blocks=nkv)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, Dh), q_index),
            pl.BlockSpec((1, block_kv, Dh), kv_index),
            pl.BlockSpec((1, block_kv, Dv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv), q_index),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),   # m
            pltpu.VMEM((block_q, LANES), jnp.float32),   # l
            pltpu.VMEM((block_q, Dv), jnp.float32),      # acc
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="sfprompt_flash_attention",
    )(q, k, v)
