from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
from repro.kernels.flash_attention.decode import decode_attention  # noqa: F401
from repro.kernels.flash_attention.decode import paged_decode_attention  # noqa: F401
