"""Public attention op with impl dispatch.

impl:
  'ref'       — pure-jnp oracle (default on CPU; what dry-runs lower)
  'pallas'    — Pallas TPU kernel
  'interpret' — Pallas kernel executed by the interpreter on CPU (tests)
  'auto'      — 'pallas' on TPU, 'ref' elsewhere

The full kernel path covers train/prefill attention (contiguous positions
from 0). Single-query cache reads (Sq=1 with q_offset / explicit
kv_positions — the decode hot path, including ring-buffer caches) dispatch
to the dedicated decode-attention kernel in `decode.py`; multi-query calls
with explicit positions (prefill continuation) stay on the ref oracle.
Decode against a PAGE POOL (per-slot block tables instead of per-slot
caches) is `decode.paged_decode_attention`, re-exported here — callers hold
a pool + block tables, so it never routes through this dense entry point.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.decode import (decode_attention,
                                                  paged_decode_attention)
from repro.kernels.flash_attention.kernel import flash_attention_fwd

__all__ = ["flash_attention", "decode_attention", "paged_decode_attention"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sliding_window", "softcap", "scale", "impl",
                     "block_q", "block_kv"))
def flash_attention(
    q: jnp.ndarray,              # (B, Sq, Hq, Dh)
    k: jnp.ndarray,              # (B, Skv, Hkv, Dh)
    v: jnp.ndarray,              # (B, Skv, Hkv, Dv)
    *,
    q_offset: Optional[jnp.ndarray] = None,
    kv_positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    impl: str = "auto",
    block_q: int = 128,
    block_kv: int = 128,
) -> jnp.ndarray:
    needs_pos = q_offset is not None or kv_positions is not None
    if needs_pos and causal and q.shape[1] == 1:
        # decode hot path: one query token against a (ring-buffer) cache.
        # Dispatch BEFORE resolving 'auto' — decode_attention has its own
        # resolution ('pallas' on TPU, the grouped 'xla' path elsewhere),
        # so auto callers get the fast path on every backend.
        B, Skv = k.shape[0], k.shape[1]
        q_positions = (jnp.zeros((B,), jnp.int32) if q_offset is None
                       else q_offset)
        kvp = (jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32)[None],
                                (B, Skv))
               if kv_positions is None else kv_positions)
        return decode_attention(
            q, k, v, q_positions=q_positions, kv_positions=kvp,
            sliding_window=sliding_window, softcap=softcap, scale=scale,
            impl=impl, block_kv=block_kv)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "analysis":
        impl = "blocked"
    if impl == "blocked" and not needs_pos:
        return ref.blocked_attention(
            q, k, v, causal=causal, sliding_window=sliding_window,
            softcap=softcap, scale=scale)
    if impl in ("ref", "blocked") or needs_pos:
        return ref.attention(
            q, k, v, causal=causal, q_offset=q_offset,
            kv_positions=kv_positions, sliding_window=sliding_window,
            softcap=softcap, scale=scale)

    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, Dv = v.shape
    if scale is None:
        scale = Dh ** -0.5
    bq = min(block_q, max(16, 1 << (Sq - 1).bit_length()))
    bkv = min(block_kv, max(16, 1 << (Skv - 1).bit_length()))

    qt = _pad_to(q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, Dh), 1, bq)
    kt = _pad_to(k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, Dh), 1, bkv)
    vt = _pad_to(v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, Dv), 1, bkv)

    out = flash_attention_fwd(
        qt, kt, vt, n_q_heads=Hq, n_kv_heads=Hkv, causal=causal,
        sliding_window=sliding_window, softcap=softcap, scale=scale,
        kv_len=Skv, block_q=bq, block_kv=bkv,
        interpret=(impl == "interpret"))
    out = out[:, :Sq].reshape(B, Hq, Sq, Dv).transpose(0, 2, 1, 3)
    return out
