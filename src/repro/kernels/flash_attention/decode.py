"""Decode attention: one query token per slot against its KV cache.

The serving hot path. Every decode step attends a single query token per
slot to that slot's ring-buffer cache — reusing the full flash kernel there
wastes the whole q-blocking machinery on Sq=1 and (in the jnp oracle)
materializes the GQA-repeated K/V at the (B, W, Hq) footprint. This module
provides the cache-read specialization:

  impl='pallas'    — Pallas TPU kernel: grid (slots, kv_heads, kv_blocks),
                     the GQA group rides the sublane axis (group query rows
                     share their kv head's tiles), online softmax over kv
                     blocks in VMEM scratch. Masking is per-slot data:
                     kv_positions (-1 = empty slot) and the slot's absolute
                     query position, so ragged per-slot lengths, ring-buffer
                     wraparound, sliding windows, and softcap all work.
  impl='interpret' — the same kernel on the Pallas interpreter (CPU tests).
  impl='xla'       — XLA-native grouped path: einsum over (B, Hkv, G) with
                     NO materialized head repeat — the production CPU path.
  impl='ref'       — the pure-jnp oracle (`ref.attention`), bit-stable
                     with the pre-fast-path behavior.
  impl='auto'      — 'pallas' on TPU, 'xla' elsewhere.

`models/layers.py` routes every `mode="decode"` attention (GQA and MLA)
through `decode_attention` instead of the full-sequence flash call.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compiler_params
from repro.kernels.flash_attention import ref

MASK_VALUE = -2.0 ** 30
LANES = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ------------------------------------------------------------ pallas kernel
def _attend_kv_block(ikv, qp, kvp, q_ref, k_ref, v_ref, o_ref,
                     m_scr, l_scr, acc_scr, *, scale: float,
                     window: Optional[int], softcap: Optional[float],
                     n_kv_blocks: int):
    """Shared online-softmax body for one (slot, kv_head, kv_block) step:
    a (group, block_kv) score tile folded into VMEM scratch, initialized at
    the first kv block and normalized out at the last. `qp` is the slot's
    absolute query position (scalar), `kvp` the block's (1, block_kv)
    positions (-1 = empty)."""
    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, MASK_VALUE)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (group, Dh)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_kv, Dh)
    v = v_ref[0, 0].astype(jnp.float32)          # (block_kv, Dv)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    # per-slot masking: cache slots are valid when they hold a real position
    # (>= 0) at or before the query's absolute position — ragged per-slot
    # lengths and ring-buffer order come in through the data, not the grid
    valid = (kvp >= 0) & (kvp <= qp)
    if window is not None:
        valid &= kvp > qp - window
    s = jnp.where(valid, s, MASK_VALUE)          # broadcast over group rows

    m_prev = m_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = alpha * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ikv == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[:, :1], 1e-30)
                       ).astype(o_ref.dtype)


def _decode_kernel(qpos_ref, q_ref, k_ref, v_ref, kvpos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float,
                   window: Optional[int], softcap: Optional[float],
                   n_kv_blocks: int):
    """One (slot, kv_head) pair; kv blocks innermost (sequential), carrying
    the online-softmax state in VMEM scratch. Block rows are the GQA group's
    query heads for this kv head — a (group, block_kv) score tile."""
    _attend_kv_block(
        pl.program_id(2), qpos_ref[0, 0], kvpos_ref[0],
        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
        scale=scale, window=window, softcap=softcap, n_kv_blocks=n_kv_blocks)


def _paged_decode_kernel(bt_ref, qpos_ref, q_ref, k_ref, v_ref, kvpos_ref,
                         o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                         window: Optional[int], softcap: Optional[float],
                         n_kv_blocks: int):
    """Paged variant: the grid's kv-block axis walks the slot's BLOCK TABLE.
    `bt_ref`/`qpos_ref` are the scalar-prefetch operands — the same block
    table the in_specs index_maps used to pick this program's K/V page, so
    the kernel body only needs the slot's query position; the page indirection
    already happened in the prefetch."""
    del bt_ref  # consumed by the index_maps
    _attend_kv_block(
        pl.program_id(2), qpos_ref[pl.program_id(0)], kvpos_ref[...],
        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
        scale=scale, window=window, softcap=softcap, n_kv_blocks=n_kv_blocks)


def decode_attention_fwd(
    q: jnp.ndarray,            # (B, Hkv, group, Dh) — grouped query heads
    k: jnp.ndarray,            # (B, Hkv, W, Dh)
    v: jnp.ndarray,            # (B, Hkv, W, Dv)
    q_positions: jnp.ndarray,  # (B, 1) int32 — absolute query position
    kv_positions: jnp.ndarray,  # (B, 1, W) int32 — -1 marks empty slots
    *,
    scale: float,
    sliding_window: Optional[int],
    softcap: Optional[float],
    block_kv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hkv, G, Dh = q.shape
    _, _, W, Dv = v.shape
    assert W % block_kv == 0, (W, block_kv)
    nkv = W // block_kv

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=sliding_window, softcap=softcap,
        n_kv_blocks=nkv)

    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, nkv),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ikv: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, ikv: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, Dh),
                         lambda b, h, ikv: (b, h, ikv, 0)),
            pl.BlockSpec((1, 1, block_kv, Dv),
                         lambda b, h, ikv: (b, h, ikv, 0)),
            pl.BlockSpec((1, 1, block_kv), lambda b, h, ikv: (b, 0, ikv)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, ikv: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, LANES), jnp.float32),   # m
            pltpu.VMEM((G, LANES), jnp.float32),   # l
            pltpu.VMEM((G, Dv), jnp.float32),      # acc
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="sfprompt_decode_attention",
    )(q_positions, q, k, v, kv_positions)


# --------------------------------------------------------------- xla path
def _xla_decode(q, k, v, q_positions, kv_positions, *, scale,
                sliding_window, softcap):
    """Grouped single-query attention without the GQA head repeat: the
    (B, W, Hkv) cache is contracted directly against (B, Hkv, G) query rows,
    so memory traffic stays at the KV-cache footprint instead of group x."""
    B, Sq, Hq, Dh = q.shape
    _, W, Hkv, Dv = v.shape
    G = Hq // Hkv
    qg = q[:, 0].reshape(B, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    valid = (kv_positions >= 0) & (kv_positions <= q_positions[:, None])
    if sliding_window is not None:
        valid &= kv_positions > q_positions[:, None] - sliding_window
    s = jnp.where(valid[:, None, None, :], s, MASK_VALUE)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, 1, Hq, Dv).astype(q.dtype)


# --------------------------------------------------------------- public op
@functools.partial(
    jax.jit,
    static_argnames=("causal", "sliding_window", "softcap", "scale", "impl",
                     "block_kv"))
def decode_attention(
    q: jnp.ndarray,              # (B, 1, Hq, Dh) — ONE token per slot
    k: jnp.ndarray,              # (B, W, Hkv, Dh) — the slot's KV cache
    v: jnp.ndarray,              # (B, W, Hkv, Dv)
    *,
    q_positions: jnp.ndarray,    # (B,) absolute position of the query
    kv_positions: jnp.ndarray,   # (B, W) absolute positions, -1 = empty
    causal: bool = True,
    sliding_window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    impl: str = "auto",
    block_kv: int = 128,
) -> jnp.ndarray:
    """Single-query cache-read attention for the decode hot path.

    Masking is wholly data-driven (kv validity + position vs the slot's
    query position), so ragged per-slot lengths and ring-buffer layouts need
    no host-side bookkeeping. `causal=False` is rejected: decode attention
    is causal by construction.
    """
    assert q.shape[1] == 1, f"decode_attention is single-query, got {q.shape}"
    assert causal, "decode attention is causal by construction"
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl in ("blocked", "analysis"):
        impl = "xla"   # loop-free and exact for cost analysis either way
    B, _, Hq, Dh = q.shape
    _, W, Hkv, Dv = v.shape
    if scale is None:
        scale = Dh ** -0.5
    if impl == "ref":
        return ref.attention(
            q, k, v, causal=True, q_offset=q_positions,
            kv_positions=kv_positions, sliding_window=sliding_window,
            softcap=softcap, scale=scale)
    if impl == "xla":
        return _xla_decode(q, k, v, q_positions, kv_positions, scale=scale,
                           sliding_window=sliding_window, softcap=softcap)

    G = Hq // Hkv
    bkv = min(block_kv, max(16, 1 << (W - 1).bit_length()))
    pad = (-W) % bkv
    qg = q[:, 0].reshape(B, Hkv, G, Dh)
    kt = jnp.moveaxis(k, 2, 1)                   # (B, Hkv, W, Dh)
    vt = jnp.moveaxis(v, 2, 1)
    kvp = kv_positions[:, None, :]               # (B, 1, W)
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kvp = jnp.pad(kvp, ((0, 0), (0, 0), (0, pad)), constant_values=-1)
    out = decode_attention_fwd(
        qg, kt, vt, q_positions.astype(jnp.int32)[:, None],
        kvp.astype(jnp.int32), scale=scale, sliding_window=sliding_window,
        softcap=softcap, block_kv=bkv, interpret=(impl == "interpret"))
    return out.reshape(B, 1, Hq, Dv)


# ----------------------------------------------------------- paged variant
def paged_decode_attention_fwd(
    q: jnp.ndarray,             # (B, Hkv, group, Dh) — grouped query heads
    k_pool: jnp.ndarray,        # (P, Hkv, page, Dh) — the page pool
    v_pool: jnp.ndarray,        # (P, Hkv, page, Dv)
    block_tables: jnp.ndarray,  # (B, n_blocks) int32 physical page ids
    q_positions: jnp.ndarray,   # (B,) int32 — absolute query position
    kv_positions: jnp.ndarray,  # (P, page) int32 — -1 marks empty slots
    *,
    scale: float,
    sliding_window: Optional[int],
    softcap: Optional[float],
    interpret: bool = False,
) -> jnp.ndarray:
    """The Pallas paged kernel: the block table and query positions ride in
    as scalar-prefetch operands, so the in_specs index_maps translate each
    grid step's logical block to its physical page — the kernel streams
    exactly the slot's pages out of the pool, never a gathered copy."""
    B, Hkv, G, Dh = q.shape
    P, _, page, Dv = v_pool.shape
    nb = block_tables.shape[1]

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, window=sliding_window,
        softcap=softcap, n_kv_blocks=nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh),
                         lambda b, h, i, bt, qp: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page, Dh),
                         lambda b, h, i, bt, qp: (bt[b, i], h, 0, 0)),
            pl.BlockSpec((1, 1, page, Dv),
                         lambda b, h, i, bt, qp: (bt[b, i], h, 0, 0)),
            pl.BlockSpec((1, page),
                         lambda b, h, i, bt, qp: (bt[b, i], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv),
                               lambda b, h, i, bt, qp: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, LANES), jnp.float32),   # m
            pltpu.VMEM((G, LANES), jnp.float32),   # l
            pltpu.VMEM((G, Dv), jnp.float32),      # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="sfprompt_paged_decode_attention",
    )(block_tables.astype(jnp.int32), q_positions.astype(jnp.int32),
      q, k_pool, v_pool, kv_positions.astype(jnp.int32))


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sliding_window", "softcap", "scale", "impl"))
def paged_decode_attention(
    q: jnp.ndarray,              # (B, 1, Hq, Dh) — ONE token per slot
    k: jnp.ndarray,              # (P, page, Hkv, Dh) — the page POOL
    v: jnp.ndarray,              # (P, page, Hkv, Dv)
    *,
    block_tables: jnp.ndarray,   # (B, n_blocks) int32 physical page ids
    q_positions: jnp.ndarray,    # (B,) absolute position of the query
    kv_positions: jnp.ndarray,   # (P, page) absolute positions, -1 = empty
    causal: bool = True,
    sliding_window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    impl: str = "auto",
) -> jnp.ndarray:
    """`decode_attention` against a PAGE POOL instead of per-slot caches.

    Slot b's KV lives in pool pages `block_tables[b]` (logical block j =
    width range [j*page, (j+1)*page)). Masking stays wholly data-driven —
    unallocated blocks point at the null page whose positions are -1, so
    they mask out exactly like empty ring slots. On the XLA/ref paths the
    pool is gathered into the dense per-slot layout (bit-identical math to
    `decode_attention` when n_blocks*page == W); on TPU the Pallas kernel
    streams pages via scalar-prefetched block tables with no gather.
    """
    assert q.shape[1] == 1, f"decode_attention is single-query, got {q.shape}"
    assert causal, "decode attention is causal by construction"
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl in ("blocked", "analysis"):
        impl = "xla"
    B, _, Hq, Dh = q.shape
    P, page, Hkv, Dv = v.shape
    nb = block_tables.shape[1]
    if scale is None:
        scale = Dh ** -0.5
    if impl in ("ref", "xla"):
        kg = k[block_tables].reshape(B, nb * page, Hkv, Dh)
        vg = v[block_tables].reshape(B, nb * page, Hkv, Dv)
        kvp = kv_positions[block_tables].reshape(B, nb * page)
        if impl == "ref":
            return ref.attention(
                q, kg, vg, causal=True, q_offset=q_positions,
                kv_positions=kvp, sliding_window=sliding_window,
                softcap=softcap, scale=scale)
        return _xla_decode(q, kg, vg, q_positions, kvp, scale=scale,
                           sliding_window=sliding_window, softcap=softcap)

    G = Hq // Hkv
    qg = q[:, 0].reshape(B, Hkv, G, Dh)
    kt = jnp.moveaxis(k, 2, 1)                   # (P, Hkv, page, Dh)
    vt = jnp.moveaxis(v, 2, 1)
    out = paged_decode_attention_fwd(
        qg, kt, vt, block_tables, q_positions, kv_positions, scale=scale,
        sliding_window=sliding_window, softcap=softcap,
        interpret=(impl == "interpret"))
    return out.reshape(B, 1, Hq, Dv)
