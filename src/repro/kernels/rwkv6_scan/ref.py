"""Pure-jnp oracle for the RWKV-6 (Finch) time-mix recurrence.

Per head with state S in R^{K x V}, data-dependent log-decay w_t < 0
[arXiv:2404.05892]:

    y_t = r_t^T S_{t-1} + (r_t . (u o k_t)) v_t
    S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rwkv6_scan(
    r: jnp.ndarray,      # (B, T, H, K) receptance
    k: jnp.ndarray,      # (B, T, H, K)
    v: jnp.ndarray,      # (B, T, H, V)
    w: jnp.ndarray,      # (B, T, H, K) log-decay (negative)
    u: jnp.ndarray,      # (H, K) per-head bonus
    state: Optional[jnp.ndarray] = None,  # (B, H, K, V)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, T, H, K = r.shape
    V = v.shape[-1]
    f32 = jnp.float32
    r_, k_, v_, w_ = (x.astype(f32) for x in (r, k, v, w))
    if state is None:
        state = jnp.zeros((B, H, K, V), f32)

    def step(S, inputs):
        rt, kt, vt, wt = inputs                       # (B,H,K) / (B,H,V)
        bonus = jnp.einsum("bhk,hk,bhk->bh", rt, u.astype(f32), kt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S) + bonus[..., None] * vt
        S = jnp.exp(wt)[..., None] * S + kt[..., None] * vt[..., None, :]
        return S, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r_, k_, v_, w_))
    final, ys = jax.lax.scan(step, state.astype(f32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), final


def rwkv6_chunked(
    r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
    u: jnp.ndarray, state: Optional[jnp.ndarray] = None, *,
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked GLA-form RWKV-6: an UNROLLED python loop over time chunks
    (no lax control flow -> exact dry-run cost accounting). Intra-chunk
    interactions use the stable pairwise-difference tensor
    exp(cw_{t-1} - cw_s) (every retained exponent <= 0), inter-chunk uses
    the carried state. Exact same math as the sequential recurrence."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((B, H, K, V), f32)
    S = state.astype(f32)
    uf = u.astype(f32)
    pad = (-T) % chunk
    if pad:
        zlast = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, w = zlast(r), zlast(k), zlast(v), zlast(w)
    Tp = T + pad
    ys = []
    for s0 in range(0, Tp, chunk):
        rc = r[:, s0:s0 + chunk].astype(f32)     # (B, c, H, K)
        kc = k[:, s0:s0 + chunk].astype(f32)
        vc = v[:, s0:s0 + chunk].astype(f32)
        wc = w[:, s0:s0 + chunk].astype(f32)     # log-decay <= 0
        cw = jnp.cumsum(wc, axis=1)              # inclusive
        cwe = cw - wc                            # exclusive (W_{t-1})
        # intra-chunk: A[t,s] = sum_k r_t[k] k_s[k] exp(cwe_t - cw_s)[k], s<t
        diff = cwe[:, :, None] - cw[:, None, :]  # (B, t, s, H, K)
        tri = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])
        diff = jnp.where(tri[None, :, :, None, None], diff, 0.0)
        A = jnp.einsum("bthk,bshk,btshk->bhts", rc, kc,
                       jnp.exp(jnp.minimum(diff, 0.0)))
        A = jnp.where(tri[None, None], A, 0.0)
        # diagonal bonus
        diag = jnp.einsum("bthk,hk,bthk->bth", rc, uf, kc)
        y = jnp.einsum("bhts,bshv->bthv", A, vc) + diag[..., None] * vc
        # inter-chunk: y_t += (r_t o exp(cwe_t))^T S
        y = y + jnp.einsum("bthk,bhkv->bthv", rc * jnp.exp(cwe), S)
        ys.append(y)
        # state update: S = diag(exp(cw_T)) S + sum_s (exp(cw_T - cw_s) o k_s) v_s
        total = cw[:, -1]                        # (B, H, K)
        wgt = jnp.exp(total[:, None] - cw)       # (B, c, H, K), <= 1
        S = jnp.exp(total)[..., None] * S + jnp.einsum(
            "bshk,bshv->bhkv", kc * wgt, vc)
    y = jnp.concatenate(ys, axis=1)[:, :T]
    return y.astype(r.dtype), S
