"""RWKV-6 recurrence as a chunked Pallas TPU kernel.

Tiling: grid = (B*H, T/chunk); chunks are sequential, carrying the (K, V)
state matrix in VMEM scratch — HBM sees each of r/k/v/w exactly once and the
state never leaves VMEM between chunks (vs. a lax.scan whose carry round-trips
HBM every step). Within a chunk the recurrence is stepped exactly
(rank-1 state updates on the VPU); this is numerically exact for arbitrary
data-dependent decays, unlike the factorized GLA matmul form whose
exp(-cumsum) terms overflow f32 for strong decays. (A sub-chunk-stabilized
matmul intra-chunk path is the known next optimization; see EXPERIMENTS.md
§Perf.)

Head sizes are 64 in RWKV-6, so the state tile is (64, 64) f32 = 16 KiB —
VMEM-resident with room for double-buffered input chunks.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compiler_params


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                  y_ref, sout_ref, s_scr, *,
                  chunk: int, n_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)   # (chunk, K)
    k = k_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)   # (chunk, V)
    u = u_ref[0].astype(jnp.float32)   # (K,)

    def step(t, carry):
        S, y = carry                                    # (K,V), (chunk,V)
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)   # (1, K)
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)   # (1, V)
        bonus = jnp.sum(rt * u[None, :] * kt)           # scalar
        yt = jax.lax.dot_general(rt, S, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32) \
            + bonus * vt                                 # (1, V)
        y = jax.lax.dynamic_update_slice_in_dim(y, yt, t, 0)
        S = jnp.exp(wt).T * S + kt.T * vt               # (K,V)
        return S, y

    S, y = jax.lax.fori_loop(
        0, chunk, step,
        (s_scr[...], jnp.zeros_like(y_ref[0], jnp.float32)))
    s_scr[...] = S
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _finalize():
        sout_ref[0] = s_scr[...]


def rwkv6_fwd(
    r: jnp.ndarray,       # (BH, T, K)
    k: jnp.ndarray,
    v: jnp.ndarray,       # (BH, T, V)
    w: jnp.ndarray,       # (BH, T, K) log-decay
    u: jnp.ndarray,       # (BH, K)
    s0: jnp.ndarray,      # (BH, K, V)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    BH, T, K = r.shape
    V = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk

    seq_spec = lambda last: pl.BlockSpec((1, chunk, last), lambda bh, ic: (bh, ic, 0))
    head_spec = lambda *dims: pl.BlockSpec((1,) + dims, lambda bh, ic: (bh,) + (0,) * len(dims))

    kernel = functools.partial(_rwkv6_kernel, chunk=chunk, n_chunks=n_chunks)
    y, sout = pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            seq_spec(K), seq_spec(K), seq_spec(V), seq_spec(K),
            head_spec(K), head_spec(K, V),
        ],
        out_specs=[seq_spec(V), head_spec(K, V)],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, V), r.dtype),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="sfprompt_rwkv6_scan",
    )(r, k, v, w, u, s0)
    return y, sout
