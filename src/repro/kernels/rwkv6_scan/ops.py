"""Public RWKV-6 scan op with impl dispatch."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan import ref
from repro.kernels.rwkv6_scan.kernel import rwkv6_fwd


@functools.partial(jax.jit, static_argnames=("impl", "chunk"))
def rwkv6_scan(
    r: jnp.ndarray,      # (B, T, H, K)
    k: jnp.ndarray,
    v: jnp.ndarray,      # (B, T, H, V)
    w: jnp.ndarray,      # (B, T, H, K) log-decay (negative)
    u: jnp.ndarray,      # (H, K)
    state: Optional[jnp.ndarray] = None,  # (B, H, K, V)
    *,
    impl: str = "auto",
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl in ("chunked", "analysis"):
        return ref.rwkv6_chunked(r, k, v, w, u, state, chunk=min(chunk, r.shape[1]))
    if impl == "ref":
        return ref.rwkv6_scan(r, k, v, w, u, state)

    B, T, H, K = r.shape
    V = v.shape[-1]
    if state is None:
        state = jnp.zeros((B, H, K, V), jnp.float32)
    c = min(chunk, T)
    pad = (-T) % c
    tohead = lambda x: jnp.moveaxis(x, 2, 1).reshape(B * H, T, x.shape[-1])
    rs, ks, vs, ws = map(tohead, (r, k, v, w))
    if pad:
        widths = ((0, 0), (0, pad), (0, 0))
        rs, ks, vs = (jnp.pad(x, widths) for x in (rs, ks, vs))
        ws = jnp.pad(ws, widths)  # zero log-decay in padding: state unchanged
        # padded k rows are zero => no state pollution
    us = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
    y, sout = rwkv6_fwd(rs, ks, vs, ws, us, state.reshape(B * H, K, V),
                        chunk=c, interpret=(impl == "interpret"))
    y = y[:, :T].reshape(B, H, T, V).swapaxes(1, 2)
    return y.astype(r.dtype), sout.reshape(B, H, K, V)
