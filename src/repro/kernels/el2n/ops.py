"""Public fused EL2N/CE op with impl dispatch.

Impls: "ref" (materialized oracle — builds the full (N, V) probability and
onehot tensors, the ground truth tests compare against), "fused" (one-pass
XLA form of the kernel identity — no onehot, no probability materialization,
the CPU surrogate of the Pallas kernel and the honest bench arm), "pallas" /
"interpret" (the TPU kernel). "auto" picks pallas on TPU, fused elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.el2n import ref
from repro.kernels.el2n.kernel import el2n_fwd


def _fused_scores(logits: jnp.ndarray, labels: jnp.ndarray):
    """One-pass identity (see ref.py's docstring): with m = max logit,
    Z = sum exp(l - m), S2 = sum exp(2(l - m)),
        ||p - y||^2 = S2/Z^2 - 2 exp(l_y - m)/Z + 1,  CE = m + log Z - l_y.
    Only (N,)-sized intermediates beyond exp(l - m) itself — no onehot and
    no (N, V) probability division. Clamped at 0 before the sqrt: near a
    perfectly-confident correct prediction the three terms cancel to
    rounding error, which must not go negative."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1)
    ex = jnp.exp(logits - m[:, None])
    z = jnp.sum(ex, axis=-1)
    s2 = jnp.sum(ex * ex, axis=-1)
    ly = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    py = jnp.exp(ly - m) / z
    el2n = jnp.sqrt(jnp.maximum(s2 / (z * z) - 2.0 * py + 1.0, 0.0))
    ce = m + jnp.log(z) - ly
    return el2n, ce


@functools.partial(jax.jit, static_argnames=("impl", "block_n", "block_v"))
def el2n_scores(logits: jnp.ndarray, labels: jnp.ndarray, *,
                impl: str = "auto", block_n: int = 256, block_v: int = 2048):
    """EL2N score + cross-entropy per row.

    logits: (N, V) float; labels: (N,) int32.
    Returns (el2n (N,), ce (N,)) in float32.
    """
    if impl in ("auto", "analysis"):
        impl = "pallas" if jax.default_backend() == "tpu" else "fused"
    if impl == "ref":
        return ref.el2n_scores(logits, labels)
    if impl == "fused":
        return _fused_scores(logits, labels)

    N, V = logits.shape
    bn = min(block_n, N) if N % min(block_n, N) == 0 else 1
    # pick the largest block_n <= block_n dividing N
    bn = next(b for b in (block_n, 128, 64, 32, 16, 8, 4, 2, 1) if N % b == 0)
    bv = min(block_v, max(128, 1 << (V - 1).bit_length()))
    padv = (-V) % bv
    if padv:
        logits = jnp.pad(logits, ((0, 0), (0, padv)))
    el2n, ce = el2n_fwd(
        logits, labels[:, None].astype(jnp.int32), vocab=V,
        block_n=bn, block_v=bv, interpret=(impl == "interpret"))
    return el2n[:, 0], ce[:, 0]
