"""Public fused EL2N/CE op with impl dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.el2n import ref
from repro.kernels.el2n.kernel import el2n_fwd


@functools.partial(jax.jit, static_argnames=("impl", "block_n", "block_v"))
def el2n_scores(logits: jnp.ndarray, labels: jnp.ndarray, *,
                impl: str = "auto", block_n: int = 256, block_v: int = 2048):
    """EL2N score + cross-entropy per row.

    logits: (N, V) float; labels: (N,) int32.
    Returns (el2n (N,), ce (N,)) in float32.
    """
    if impl in ("auto", "analysis"):
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return ref.el2n_scores(logits, labels)

    N, V = logits.shape
    bn = min(block_n, N) if N % min(block_n, N) == 0 else 1
    # pick the largest block_n <= block_n dividing N
    bn = next(b for b in (block_n, 128, 64, 32, 16, 8, 4, 2, 1) if N % b == 0)
    bv = min(block_v, max(128, 1 << (V - 1).bit_length()))
    padv = (-V) % bv
    if padv:
        logits = jnp.pad(logits, ((0, 0), (0, padv)))
    el2n, ce = el2n_fwd(
        logits, labels[:, None].astype(jnp.int32), vocab=V,
        block_n=bn, block_v=bv, interpret=(impl == "interpret"))
    return el2n[:, 0], ce[:, 0]
