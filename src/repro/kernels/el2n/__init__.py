from repro.kernels.el2n.ops import el2n_scores  # noqa: F401
