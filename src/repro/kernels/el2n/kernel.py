"""Fused EL2N + CE as a Pallas TPU kernel.

The pruning phase of SFPrompt scores every local sample with
||softmax(logits) - onehot||_2. For LM-sized vocabularies (32k..256k) the
naive path materializes an (N, V) probability tensor in HBM. This kernel
streams vocab tiles through VMEM once, maintaining per-row online-softmax
statistics (m, Z, S2 = sum exp(2(l-m)), l_y) in scratch, and emits the score
and CE without ever writing probabilities:

    ||p - y||^2 = S2/Z^2 - 2 exp(l_y - m)/Z + 1
    CE          = m + log Z - l_y

Tiling: grid = (N/block_n, V/block_v); vocab is the inner sequential axis.
Arithmetic intensity: one pass over logits, O(N) outputs — purely
bandwidth-bound, so the win vs the ref path is the removed (N, V) probs
round-trip plus the removed second max/sum pass (~3x HBM traffic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compiler_params

LANES = 128
NEG = -2.0 ** 30


def _el2n_kernel(logits_ref, labels_ref, el2n_ref, ce_ref,
                 m_scr, z_scr, s2_scr, ly_scr, *,
                 block_n: int, block_v: int, n_v_blocks: int, vocab: int):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        z_scr[...] = jnp.zeros_like(z_scr)
        s2_scr[...] = jnp.zeros_like(s2_scr)
        ly_scr[...] = jnp.full_like(ly_scr, NEG)

    l = logits_ref[...].astype(jnp.float32)            # (block_n, block_v)
    cols = iv * block_v + jax.lax.broadcasted_iota(
        jnp.int32, (block_n, block_v), 1)
    valid = cols < vocab
    l = jnp.where(valid, l, NEG)

    labels = labels_ref[...]                            # (block_n, 1) int32
    hit = cols == labels                                # (block_n, block_v)
    ly_tile = jnp.max(jnp.where(hit, l, NEG), axis=-1, keepdims=True)
    ly_scr[...] = jnp.maximum(ly_scr[...], jnp.broadcast_to(ly_tile, ly_scr.shape))

    m_prev = m_scr[:, :1]
    m_cur = jnp.max(l, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    a1 = jnp.exp(m_prev - m_new)                        # rescale for Z
    a2 = jnp.exp(2.0 * (m_prev - m_new))                # rescale for S2
    e = jnp.where(valid, jnp.exp(l - m_new), 0.0)
    z_new = a1 * z_scr[:, :1] + jnp.sum(e, axis=-1, keepdims=True)
    s2_new = a2 * s2_scr[:, :1] + jnp.sum(e * e, axis=-1, keepdims=True)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    z_scr[...] = jnp.broadcast_to(z_new, z_scr.shape)
    s2_scr[...] = jnp.broadcast_to(s2_new, s2_scr.shape)

    @pl.when(iv == n_v_blocks - 1)
    def _finalize():
        m = m_scr[:, :1]
        z = jnp.maximum(z_scr[:, :1], 1e-30)
        s2 = s2_scr[:, :1]
        ly = ly_scr[:, :1]
        py = jnp.exp(ly - m) / z
        sq = jnp.maximum(s2 / (z * z) - 2.0 * py + 1.0, 0.0)
        el2n_ref[...] = jnp.broadcast_to(jnp.sqrt(sq), el2n_ref.shape)
        ce_ref[...] = jnp.broadcast_to(m + jnp.log(z) - ly, ce_ref.shape)


def el2n_fwd(logits: jnp.ndarray, labels: jnp.ndarray, *,
             vocab: int, block_n: int = 256, block_v: int = 2048,
             interpret: bool = False):
    """logits (N, Vp), labels (N, 1) int32; N % block_n == Vp % block_v == 0.
    Returns (el2n (N, 1), ce (N, 1)) — column 0 of LANES-wide outputs."""
    N, Vp = logits.shape
    nv = Vp // block_v
    kernel = functools.partial(
        _el2n_kernel, block_n=block_n, block_v=block_v, n_v_blocks=nv,
        vocab=vocab)
    el2n, ce = pl.pallas_call(
        kernel,
        grid=(N // block_n, nv),
        in_specs=[
            pl.BlockSpec((block_n, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, LANES), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, LANES), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, LANES), jnp.float32),
            jax.ShapeDtypeStruct((N, LANES), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_n, LANES), jnp.float32)] * 4,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="sfprompt_el2n",
    )(logits, labels)
    return el2n[:, :1], ce[:, :1]
