"""Pure-jnp oracle for fused EL2N scoring (+ cross-entropy).

EL2N [Paul et al. 2021, as used by SFPrompt Eq. (2)]:
    score(x, y) = || softmax(f(x)) - onehot(y) ||_2

Identity used by the fused kernel (never materializes the probability
vector): with m = max logit, Z = sum exp(l - m), S2 = sum exp(2(l - m)),
l_y the label logit,
    ||p - y||^2 = sum_i p_i^2 - 2 p_y + 1
               = S2 / Z^2 - 2 exp(l_y - m) / Z + 1
    CE = m + log Z - l_y
"""
from __future__ import annotations

import jax.numpy as jnp


def el2n_scores(logits: jnp.ndarray, labels: jnp.ndarray):
    """logits (N, V) float, labels (N,) int32 -> (el2n (N,), ce (N,))."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    z = jnp.sum(p, axis=-1, keepdims=True)
    probs = p / z
    onehot = jnp.arange(logits.shape[-1])[None, :] == labels[:, None]
    err = probs - onehot.astype(jnp.float32)
    el2n = jnp.sqrt(jnp.sum(err * err, axis=-1))
    ly = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    ce = (m[:, 0] + jnp.log(z[:, 0])) - ly
    return el2n, ce
