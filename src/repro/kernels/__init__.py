"""Pallas TPU kernels for the compute hot-spots of the SFPrompt system.

Each kernel lives in its own subpackage:
  <name>/kernel.py  — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  <name>/ops.py     — jit'd public wrapper with impl dispatch (ref|pallas|interpret)
  <name>/ref.py     — pure-jnp oracle

Kernels:
  flash_attention — blockwise attention: GQA, causal, sliding window, logit softcap
  el2n            — fused EL2N score + CE over vocab tiles (paper's pruning hot-spot)
  rwkv6_scan      — RWKV-6 data-dependent-decay recurrence, chunked (GLA form)
  mamba2_scan     — Mamba-2 SSD chunked scan (matmul form for the MXU)
  quant           — int8 stochastic quantize/dequantize for the wire codecs
  secure_mask     — fused fixed-point encode + pairwise PRG mask-add for
                    masked secure aggregation (privacy engine)
"""
from jax.experimental.pallas import tpu as _pltpu

# The TPU compiler-params dataclass was renamed across JAX releases
# (TPUCompilerParams <-> CompilerParams). Resolve whichever this JAX has.
_COMPILER_PARAMS_CLS = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams")


def compiler_params(**kwargs):
    """Version-compatible constructor for pltpu compiler params."""
    return _COMPILER_PARAMS_CLS(**kwargs)
