"""HLO post-processing for the roofline analysis.

cost_analysis() has no collective statistics, so we parse the (SPMD-
partitioned) HLO text and sum the output-operand bytes of every collective
op. Convention: reported bytes are the op's output tensor size — a uniform,
reproducible proxy; ring-algorithm wire amplification factors (2(n-1)/n for
all-reduce etc.) are applied in the roofline, not here.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  f32[16,4096,128]{2,1,0}   or  bf16[]
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# an HLO instruction line:  %name = <shape(s)> opcode(...)
_INSTR_RE = re.compile(
    r"=\s*(\(?[a-z0-9_\[\],\s{}/<>]*?\)?)\s*"
    r"(" + "|".join(COLLECTIVES) + r")(-start|-done)?\(")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of output bytes per collective kind (plus 'total')."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        shape_text, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # counted at -start
        out[kind] += _shape_bytes(shape_text)
    out["total"] = sum(v for k, v in out.items())
    return dict(out)


def count_ops(hlo_text: str, opcodes=("fusion", "custom-call", "dot",
                                      "convolution")) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        for op in opcodes + COLLECTIVES:
            if f" {op}(" in line:
                counts[op] += 1
    return dict(counts)


# ---------------------------------------------------------------- while-aware
# note: params may be tuple-typed (nested parens) -> greedy .* is required
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?body=\s*%?([\w.\-]+)", re.DOTALL)
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')


def _split_computations(hlo_text: str) -> Dict[str, str]:
    comps: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo_text.splitlines():
        m = _COMP_HEAD_RE.match(line.strip())
        if m and ("{" in line):
            if cur_name:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name, cur_lines = m.group(1), [line]
            if line.strip().startswith("ENTRY"):
                comps.setdefault("__entry_alias__", "")
                comps["__entry_name__"] = m.group(1)
        elif cur_name:
            cur_lines.append(line)
    if cur_name:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def collective_bytes_tripcounted(hlo_text: str) -> Dict[str, int]:
    """Collective output bytes with while-loop bodies multiplied by their
    known_trip_count (scan-over-layers correction). Computations reached
    from multiple while sites accumulate each site's multiplier."""
    comps = _split_computations(hlo_text)
    entry = comps.get("__entry_name__")
    if entry is None:
        return collective_bytes(hlo_text)

    # edges: (parent_comp, child_comp, trip). while bodies carry their
    # known_trip_count; call/conditional targets (to_apply=..., branch
    # computations) carry 1.
    sites = []
    call_re = re.compile(r"to_apply=\s*%?([\w.\-]+)")
    for name, text in comps.items():
        if name.startswith("__"):
            continue
        for line in text.splitlines():
            if " while(" in line:
                mb = _WHILE_RE.search(line)
                if mb:
                    mt = _TRIP_RE.search(line)
                    sites.append((name, mb.group(1),
                                  int(mt.group(1)) if mt else 1))
                continue
            if " call(" in line or " conditional(" in line:
                for child in call_re.findall(line):
                    sites.append((name, child, 1))

    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # propagate multipliers (loop nesting depth is tiny; iterate to fixpoint)
    for _ in range(8):
        changed = False
        for parent, body, trip in sites:
            if mult.get(parent, 0) and mult.get(body, 0) != mult[parent] * trip:
                mult[body] = mult[parent] * trip
                changed = True
        if not changed:
            break

    out: Dict[str, int] = defaultdict(int)
    for name, text in comps.items():
        if name.startswith("__"):
            continue
        per = collective_bytes(text)
        if per.get("total", 0) == 0:
            continue
        # conservative fallback: a computation whose call chain we failed to
        # parse still counts ONCE (never drop collectives silently)
        m = mult.get(name, 0) or 1.0
        for k, v in per.items():
            out[k] += int(v * m)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)
