"""Continuous-batching split-serving launcher.

Runs the `serve.ServeEngine` — slot-based shared KV cache, interleaved
prefill/decode so requests join in-flight batches, per-tenant
(tail, prompt) from a `TenantBank` — against the deterministic synthetic
workload (Poisson arrivals, mixed prompt/output lengths, pure function of
--seed). Reports tokens/s, p50/p99 latency, slot occupancy, and the
measured smashed-tensor wire traffic next to the analytical per-token
model.

Decode fast path knobs: `--decode-block N` steps N tokens per scanned
dispatch (1 = per-token), `--impl` picks the decode-attention kernel
(Pallas on TPU, grouped XLA elsewhere, `ref` = the jnp oracle), and
`--no-donate` disables KV-cache buffer donation into the jitted steps.
`--wire {fp32,bf16,int8}` sets the smashed-tensor codec on both
boundaries. docs/ROUND_LIFECYCLE.md traces one token through the stack.

Paged engine knobs: `--page-size N` (N > 0) swaps in the
`PagedServeEngine` — page-pool KV with per-slot block tables — with
`--n-pages` sizing the pool (default: one full window per slot),
`--shared-prefix K` prepending K deterministic common-prefix tokens to
every request with copy-on-write page sharing across same-tenant
requests, and `--prefill-chunk C` streaming prompts in C-token pieces.
Paging never changes the wire protocol; a prefix HIT honestly meters
fewer prefill bytes, so measured <= analytical when sharing kicks in.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \\
      --requests 16 --slots 8 --tenants 4 --wire int8
  PYTHONPATH=src python -m repro.launch.serve --page-size 16 \\
      --shared-prefix 24 --prefill-chunk 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint
from repro.configs import get_config
from repro.core import SplitConfig, SplitModel
from repro.core.comm import serve_comm_breakdown
from repro.obs import MetricsRegistry, export_all, make_tracer
from repro.obs.trace import LEVELS
from repro.runtime import WireSpec
from repro.runtime.meter import MB
from repro.serve import (PagedServeConfig, PagedServeEngine, ServeConfig,
                         ServeEngine, TenantBank, WorkloadConfig,
                         synthetic_requests)


def personalized_bank(model: SplitModel, params, n_tenants: int,
                      *, jitter: float = 0.05) -> TenantBank:
    """A demo TenantBank: tenant 0 serves the aggregated global
    (tail, prompt); every other tenant gets a deterministically perturbed
    copy, standing in for the per-client tails a federation run stores in
    the Population (see examples/serve_tenants.py for the real flow)."""
    tails, prompts = [], []
    for t in range(n_tenants):
        if t == 0 or jitter == 0.0:
            tails.append(params["tail"])
            prompts.append(params["prompt"])
            continue
        key = jax.random.fold_in(jax.random.PRNGKey(101), t)
        leaves, treedef = jax.tree.flatten(params["tail"])
        ks = jax.random.split(key, len(leaves) + 1)
        tails.append(jax.tree.unflatten(treedef, [
            x + jitter * jax.random.normal(k, x.shape, x.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x
            for x, k in zip(leaves, ks[:-1])]))
        prompts.append(params["prompt"] + jitter * jax.random.normal(
            ks[-1], params["prompt"].shape, params["prompt"].dtype))
    return TenantBank.from_lists(tails, prompts)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="CPU-sized same-family config (on by default)")
    ap.add_argument("--requests", type=int, default=16,
                    help="synthetic workload length")
    ap.add_argument("--slots", type=int, default=8,
                    help="concurrent sequences in the shared KV cache")
    ap.add_argument("--tenants", type=int, default=4,
                    help="distinct (tail, prompt) pairs in the TenantBank")
    ap.add_argument("--max-seq", type=int, default=128,
                    help="KV-cache capacity per slot (prompt + new tokens)")
    ap.add_argument("--mean-interarrival", type=float, default=1.0,
                    help="Poisson arrival gap in engine steps")
    ap.add_argument("--prompt-choices", type=int, nargs="+",
                    default=[8, 16, 32],
                    help="prompt lengths the workload draws from")
    ap.add_argument("--new-token-choices", type=int, nargs="+",
                    default=[4, 8, 16],
                    help="output lengths the workload draws from")
    ap.add_argument("--decode-block", type=int, default=8,
                    help="decode fast path: tokens per scanned dispatch "
                         "(1 = per-token stepping)")
    ap.add_argument("--impl", default="auto",
                    choices=("auto", "ref", "pallas", "interpret"),
                    help="attention impl: auto = the decode-attention "
                         "kernel on TPU, the grouped XLA path elsewhere; "
                         "ref = the jnp oracle")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable KV-cache donation into the jitted steps")
    ap.add_argument("--page-size", type=int, default=0,
                    help="tokens per KV page; > 0 serves with the paged "
                         "engine, 0 (default) keeps the dense slot cache")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="page-pool size incl. the 2 reserved pages "
                         "(default: one full window per slot)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many deterministic common-prefix "
                         "tokens to every request, shared copy-on-write "
                         "across same-tenant requests (paged engine only)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="stream prompts in pieces of this many tokens "
                         "(paged engine only; default: monolithic)")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="serve tensor-parallel over a (data, model) host "
                         "mesh with a 'model' axis of this size (frozen "
                         "body sharded, KV kv-heads sharded; must divide "
                         "the visible device count; 1 = single-device)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--params", default=None,
                    help="checkpoint to serve (e.g. a training run's "
                         "final.npz); default: fresh random init")
    ap.add_argument("--wire", default="fp32", choices=("fp32", "bf16", "int8"),
                    help="codec for the smashed tensors on both boundaries")
    ap.add_argument("--trace-out", default=None,
                    help="flight-recorder export basename: writes "
                         "<base>.jsonl, <base>.trace.json (Chrome/Perfetto) "
                         "and <base>.prom; implies --trace-level round")
    ap.add_argument("--trace-level", default="off", choices=list(LEVELS),
                    help="flight-recorder detail: off = zero-overhead noop, "
                         "round = admission/prefill/retire spans + meter "
                         "bytes, step = decode steps and page churn too")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="print a metrics-registry snapshot every N engine "
                         "steps (0 = only at the end when tracing is on)")
    ap.add_argument("--trace-profiler", action="store_true",
                    help="wrap traced device dispatches in jax.profiler "
                         "TraceAnnotations (visible in a profiler capture)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        # at least 3 layer-pattern cycles so head/body/tail are all non-empty
        cfg = cfg.reduced(n_layers=3 * len(cfg.layer_pattern))
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4)
    wire = WireSpec.make(args.wire)
    model = SplitModel(cfg, split, wire)
    params = model.init(jax.random.PRNGKey(0))
    if args.params:
        loaded = load_checkpoint(args.params)
        params = jax.tree.map(jnp.asarray, loaded)

    trace_level = args.trace_level
    if args.trace_out and trace_level == "off":
        trace_level = "round"
    tracer = make_tracer(trace_level, profiler=args.trace_profiler)

    bank = personalized_bank(model, params, args.tenants)
    mesh = None
    if args.mesh_model > 1:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model=args.mesh_model)
    if args.page_size > 0:
        # deterministic synthetic shared prefix (a pure function of its
        # length), standing in for a common system prompt
        prefix = tuple(int(1 + (i * 13) % (cfg.vocab_size - 1))
                       for i in range(args.shared_prefix))
        engine = PagedServeEngine(
            model, params, bank,
            PagedServeConfig(n_slots=args.slots, max_seq=args.max_seq,
                             decode_block=args.decode_block,
                             donate=not args.no_donate, impl=args.impl,
                             page_size=args.page_size,
                             n_pages=args.n_pages,
                             shared_prefix=prefix or None,
                             prefill_chunk=args.prefill_chunk),
            mesh=mesh, tracer=tracer)
    else:
        if args.shared_prefix or args.prefill_chunk:
            raise SystemExit("--shared-prefix/--prefill-chunk need the "
                             "paged engine: pass --page-size N")
        engine = ServeEngine(model, params, bank,
                             ServeConfig(n_slots=args.slots,
                                         max_seq=args.max_seq,
                                         decode_block=args.decode_block,
                                         donate=not args.no_donate,
                                         impl=args.impl),
                             mesh=mesh, tracer=tracer)
    reqs = synthetic_requests(WorkloadConfig(
        n_requests=args.requests,
        mean_interarrival=args.mean_interarrival,
        prompt_choices=tuple(args.prompt_choices),
        new_token_choices=tuple(args.new_token_choices),
        n_tenants=args.tenants, vocab_size=cfg.vocab_size,
        seed=args.seed))

    registry = MetricsRegistry()
    registry.bind_engine(engine)
    if args.page_size > 0:
        registry.bind_pool(engine.pool_alloc)

    on_step = None
    if args.metrics_every:
        import json as _json

        def on_step(step_idx, _every=args.metrics_every):
            if step_idx % _every == 0:
                print(_json.dumps({"step": step_idx,
                                   "metrics": registry.snapshot()},
                                  sort_keys=True, default=str), flush=True)
    stats = engine.run(reqs, on_step=on_step)

    print(f"{cfg.name}: {stats['n_finished']} requests over "
          f"{args.tenants} tenants | {stats['tokens_out']} tokens in "
          f"{stats['wall_s']:.2f}s = {stats['tok_per_s']:.1f} tok/s "
          f"(incl. compile)")
    print(f"latency p50 {stats['p50_latency_s'] * 1e3:.0f} ms | "
          f"p99 {stats['p99_latency_s'] * 1e3:.0f} ms | "
          f"occupancy {stats['occupancy']:.2f} | "
          f"{stats['prefills']} prefills / {stats['decode_steps']} "
          f"decode steps | rejected {stats['rejected']}")
    measured = stats["wire_bytes"]
    # compare against what was actually SERVED — admission control may
    # have rejected part of the trace, and rejected requests never cross
    # the wire. A shared prefix counts toward every served request's
    # prompt here; prefix HITS skip re-transmitting those activations, so
    # the measured total dips below analytical as the hit ratio climbs.
    prefix_n = args.shared_prefix if args.page_size > 0 else 0
    analytical = serve_comm_breakdown(
        wire, d_model=cfg.d_model, soft_prompt_len=split.prompt_len,
        requests=[(len(f.req.tokens) + prefix_n, f.req.max_new)
                  for f in stats["finished"]])
    print(f"wire [{wire.describe()}]: {measured['total'] / MB:.3f} MB "
          f"measured ({measured['head_body'] / MB:.3f} head_body + "
          f"{measured['body_tail'] / MB:.3f} body_tail) vs "
          f"{sum(analytical.values()) / MB:.3f} MB analytical")
    if args.page_size > 0:
        print(f"pages: {stats['n_pages']} x {stats['page_size']} tok | "
              f"peak {stats['peak_pages']} | "
              f"in use {stats['pages_in_use']} | "
              f"COW copies {stats['page_copies']} | "
              f"prefix hits {stats['prefix_hits']}/"
              f"{stats['prefix_hits'] + stats['prefix_misses']} "
              f"(ratio {stats['prefix_hit_ratio']:.2f}) | "
              f"prefill chunks {stats['prefill_chunks']}")
    if tracer.enabled and args.trace_out:
        paths = export_all(tracer, args.trace_out, meter=engine.meter,
                           registry=registry)
        for fmt, p in sorted(paths.items()):
            print(f"trace [{fmt}]: {p}", flush=True)
    elif tracer.enabled:
        import json as _json
        print(_json.dumps({"metrics": registry.snapshot()}, sort_keys=True,
                          default=str), flush=True)
    return stats


if __name__ == "__main__":
    main()
