"""Batched split-inference serving loop (production shape of the decode
dry-runs): continuous prefill + decode against a shared KV cache, with the
aggregated fine-tuned (tail, prompt).

Serving crosses the same head->body / body->tail wire boundaries as
training: pick the codec with --wire (fp32 | bf16 | int8) and the loop
reports the measured smashed-tensor traffic next to the token rate.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --reduced \\
      --requests 8 --new-tokens 32 --wire int8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint
from repro.configs import get_config
from repro.core import SplitConfig, SplitModel
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.runtime import TrafficMeter, WireSpec
from repro.runtime.meter import MB


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-tokens", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--params", default=None, help="checkpoint to serve")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--wire", default="fp32", choices=("fp32", "bf16", "int8"),
                    help="codec for the smashed tensors on both boundaries")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        # at least 3 layer-pattern cycles so head/body/tail are all non-empty
        cfg = cfg.reduced(n_layers=3 * len(cfg.layer_pattern))
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4)
    wire = WireSpec.make(args.wire)
    model = SplitModel(cfg, split, wire)
    params = model.init(jax.random.PRNGKey(0))
    if args.params:
        loaded = load_checkpoint(args.params)
        params = jax.tree.map(jnp.asarray, loaded)

    prefill = jax.jit(make_prefill_step(model, with_wire_bytes=True))
    decode = jax.jit(make_decode_step(model, with_wire_bytes=True))
    meter = TrafficMeter()
    B = args.requests
    total = args.prompt_tokens + args.new_tokens + split.prompt_len
    cache = model.init_cache(B, seq_len=total, window=args.window)
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (B, args.prompt_tokens), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, 8, cfg.d_model))
    if cfg.arch_type == "audio":
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder.n_frames, cfg.d_model))

    t0 = time.time()
    logits, cache, wb = prefill(params, batch, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t_pre = time.time() - t0
    meter.absorb(wb)
    extra = split.prompt_len + (8 if cfg.arch_type == "vlm" else 0)

    key = jax.random.PRNGKey(7)
    t0 = time.time()
    n_out = 1
    for i in range(args.new_tokens - 1):
        pos = jnp.full((B,), args.prompt_tokens + extra + i, jnp.int32)
        tok, logits, cache, wb = decode(params, {"tokens": tok[:, None],
                                                 "pos": pos}, cache)
        meter.absorb(wb)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature, axis=-1).astype(jnp.int32)
        n_out += 1
    dt = time.time() - t0
    print(f"prefill: {B}x{args.prompt_tokens} in {t_pre:.2f}s | "
          f"decode: {B}x{n_out} in {dt:.2f}s = {B*n_out/dt:.1f} tok/s")
    print(f"wire [{wire.describe()}]: "
          f"{meter.total_bytes() / MB:.3f} MB smashed traffic "
          f"({meter.totals['head_body'] / MB:.3f} head_body + "
          f"{meter.totals['body_tail'] / MB:.3f} body_tail)")


if __name__ == "__main__":
    main()
