"""SFPrompt training launcher (runs the actual protocol end-to-end).

Rounds run through the federated population engine: an N-client
`Population` (N can be >> K), a deterministic `ClientSampler`, and a
`RoundScheduler` that simulates stragglers/dropouts. Runs checkpoint the
full round state (params, meter totals, sampler position) every
`--ckpt-every` rounds; `--resume` restarts a killed run byte-identically
— including, in async mode, the delta buffer and in-flight clients.

Two runtimes (docs/ROUND_LIFECYCLE.md walks both end-to-end):
  * synchronous barrier (default) — `FederatedEngine`: every round waits
    for its whole surviving cohort before aggregating;
  * buffered async (`--async-buffer N`) — `AsyncRoundEngine`: sampled
    clients stream updates on their own simulated clocks, the server
    aggregates every N arrivals with staleness weights
    alpha / (1 + s)^beta (`--staleness-alpha/--staleness-beta`);
    `--async-concurrency` dispatch groups overlap, `--rounds` counts
    FLUSHES. Composes with `--secure-agg` (the flush is the secure-agg
    cohort) and `--dp-epsilon` (noise rides each client's update).

Scale-out and privacy knobs (sfprompt methods only):
  * `--mesh-devices M` shards the cohort round over a host mesh
    (`--fsdp` additionally shards large frozen params over the mesh;
    `--mesh-model T` makes it a 2D (data, model) mesh with the frozen
    body computing tensor-parallel over the T-way 'model' axis);
  * `--edges E` aggregates hierarchically (client -> edge -> global);
  * `--secure-agg` masks uploads (Bonawitz-style, uint32 ring);
  * `--dp-epsilon/--dp-delta/--dp-clip` run DP-SGD on client deltas
    with a zCDP ledger calibrated over `--rounds`.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch vit-base --reduced \\
      --dataset cifar100-syn --rounds 10 --clients 20 --k 5
  PYTHONPATH=src python -m repro.launch.train --arch vit-base --reduced \\
      --clients 1000 --k 16 --sampler weighted --dropout-rate 0.2 \\
      --regime edge_wan --rounds 50 --ckpt-every 5
  # buffered async over consumer WAN links, secure flushes
  PYTHONPATH=src python -m repro.launch.train --arch vit-base --reduced \\
      --clients 100 --k 8 --regime wan --async-buffer 8 \\
      --async-concurrency 2 --secure-agg --rounds 20
  # after a crash / preemption: identical continuation
  PYTHONPATH=src python -m repro.launch.train ... --resume

Methods: sfprompt (default), sfprompt-nolocal (Fig-6 ablation arm),
fl, sfl-ff, sfl-linear (baselines train their cohort synchronously —
the straggler plan and the async runtime only apply to SFPrompt's
partial aggregation).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core import (BaselineConfig, FLTrainer, ProtocolConfig,
                        SFLTrainer, SFPromptTrainer, SplitConfig, SplitModel)
from repro.core.aggregation import get_aggregator
from repro.core.comm import cost_inputs_from, sfprompt_comm, sfprompt_compute
from repro.privacy.dp import calibrate_noise
from repro.data import (DATASETS, synthetic_image_dataset,
                        synthetic_lm_dataset)
from repro.fed import (AsyncConfig, AsyncRoundEngine, ClientSampler,
                       FederatedEngine, Population, RoundScheduler,
                       StragglerConfig)
from repro.fed.scheduler import LINK_REGIMES
from repro.obs import MetricsRegistry, export_all, make_tracer
from repro.obs.trace import LEVELS


def build_data(args, cfg):
    if args.dataset == "lm-syn":
        data = synthetic_lm_dataset(args.samples, args.seq_len,
                                    cfg.vocab_size, seed=args.seed)
        test = synthetic_lm_dataset(max(64, args.samples // 8), args.seq_len,
                                    cfg.vocab_size, seed=args.seed + 1)
    else:
        spec = DATASETS[args.dataset]
        data = synthetic_image_dataset(spec, args.samples, seed=args.seed,
                                       image_hw=args.image_hw)
        test = synthetic_image_dataset(spec, max(128, args.samples // 8),
                                       seed=args.seed + 1,
                                       image_hw=args.image_hw)
    scheme = "dirichlet" if (args.non_iid and "labels" in data) else "iid"
    population = Population.from_partition(data, args.clients, scheme=scheme,
                                           alpha=0.1, seed=args.seed)
    return population, test


def build_mesh(args):
    """Host mesh for sharded-cohort dispatch (--mesh-devices). The K axis
    then shards over the mesh's client plane; 0 keeps single-device vmap.
    --mesh-model M > 1 folds the mesh to 2D (data, model): the frozen body
    runs TENSOR-PARALLEL over 'model' while K shards over 'data'.
    On CPU, XLA_FLAGS=--xla_force_host_platform_device_count=N must be in
    the environment BEFORE jax initializes for N virtual devices."""
    if not args.mesh_devices:
        return None
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(0 if args.mesh_devices < 0 else args.mesh_devices,
                          model=max(1, getattr(args, "mesh_model", 1)))


def build_trainer(args, model, mesh=None, tracer=None):
    if args.method.startswith("sfprompt"):
        dp_noise = 0.0
        if args.dp_epsilon > 0:
            # budget the target (eps, delta) evenly across the full run
            dp_noise = calibrate_noise(args.dp_epsilon, args.dp_delta,
                                       args.rounds)
            print(f"DP: eps={args.dp_epsilon} delta={args.dp_delta} over "
                  f"{args.rounds} round(s) -> noise multiplier "
                  f"z={dp_noise:.3f} at clip C={args.dp_clip}", flush=True)
        pcfg = ProtocolConfig(
            clients_per_round=args.k, local_epochs=args.local_epochs,
            batch_size=args.batch_size, lr_local=args.lr, lr_split=args.lr,
            use_local_loss=(args.method == "sfprompt"),
            # async dispatch aggregates at flush time, from the per-client
            # trees the round returns — same flag personalized tails use
            return_client_trainable=(args.personalize_tails
                                     or args.async_buffer > 0),
            dp_clip=(args.dp_clip if args.dp_epsilon > 0 else 0.0),
            dp_noise_multiplier=dp_noise, dp_delta=args.dp_delta)
        if args.async_buffer > 0:
            # the trainer stays CLEAR under async: the flush, not the
            # dispatch round, is the (possibly secure) aggregation unit
            aggregator = None
        elif args.edges > 0:
            # hierarchical (client -> edge -> global) aggregation; on the
            # secure path each edge runs its own masked aggregator
            kw = {"seed": args.seed} if args.secure_agg else {}
            aggregator = get_aggregator(secure=args.secure_agg,
                                        n_edges=args.edges,
                                        cohort_size=args.k, **kw)
        elif args.secure_agg:
            aggregator = get_aggregator(secure=True, seed=args.seed)
        else:
            aggregator = None
        return SFPromptTrainer(model, pcfg, aggregator, mesh=mesh,
                               fsdp=args.fsdp,
                               donate_cohort=mesh is not None,
                               tracer=tracer)
    if args.method == "fl":
        trainer = FLTrainer(model, BaselineConfig(
            local_epochs=args.local_epochs, batch_size=args.batch_size,
            lr=args.lr))
    else:
        trainer = SFLTrainer(model, BaselineConfig(
            local_epochs=args.local_epochs, batch_size=args.batch_size,
            lr=args.lr), mode=args.method.split("-")[1])
    # baselines have no tracer plumbing, but their meter can still emit
    # exact per-absorb byte events into the flight recorder
    meter = getattr(trainer, "meter", None)
    if meter is not None and tracer is not None:
        meter.attach_tracer(tracer)
    return trainer


def build_scheduler(args, population, cfg, split):
    """Per-client round cost from the Table-1 model bound to THIS
    model/split — the regime's comm-vs-compute mix then decides whether
    slow-link or slow-compute devices miss the deadline (sync) or arrive
    stale (async)."""
    toks = (args.seq_len if args.dataset == "lm-syn"
            else (args.image_hw // 16) ** 2 + 1)
    ci = cost_inputs_from(cfg, split, tokens_per_sample=toks,
                          D=population.n_local, K=args.k,
                          U=args.local_epochs)
    return RoundScheduler(
        StragglerConfig(regime=args.regime,
                        deadline_factor=args.deadline_factor,
                        dropout_rate=args.dropout_rate,
                        late_mode=args.late_mode),
        seed=args.seed,
        round_bytes_per_client=sfprompt_comm(ci) / args.k,
        round_flops_per_client=sfprompt_compute(ci))


def build_engine(args, trainer, population, cfg, split):
    sampler = ClientSampler(
        population.n_clients, args.k, kind=args.sampler, seed=args.seed,
        weights=(population.sizes.astype(float)
                 if args.sampler == "weighted" else None))
    if args.async_buffer > 0:
        # async always needs the latency model — arrival order IS the
        # runtime's semantics, not an optional failure simulation
        acfg = AsyncConfig(buffer_size=args.async_buffer,
                           concurrency=args.async_concurrency,
                           staleness_alpha=args.staleness_alpha,
                           staleness_beta=args.staleness_beta)
        aggregator = (get_aggregator(secure=True, seed=args.seed)
                      if args.secure_agg else None)
        return AsyncRoundEngine(trainer, population, sampler,
                                build_scheduler(args, population, cfg,
                                                split),
                                acfg, aggregator=aggregator)
    scheduler = None
    if args.dropout_rate > 0 or args.straggle:
        scheduler = build_scheduler(args, population, cfg, split)
    return FederatedEngine(trainer, population, sampler, scheduler,
                           personalize_tails=args.personalize_tails)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vit-base")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--layers", type=int, default=4,
                    help="layer count for --reduced (must exceed "
                         "head+tail cycles)")
    ap.add_argument("--method", default="sfprompt",
                    choices=["sfprompt", "sfprompt-nolocal", "fl",
                             "sfl-ff", "sfl-linear"])
    ap.add_argument("--dataset", default="cifar100-syn",
                    choices=list(DATASETS) + ["lm-syn"])
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=50,
                    help="population size N (sampled K per round)")
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--sampler", default="uniform",
                    choices=["uniform", "weighted", "round_robin"])
    ap.add_argument("--straggle", action="store_true",
                    help="simulate stragglers even with --dropout-rate 0")
    ap.add_argument("--dropout-rate", type=float, default=0.0)
    ap.add_argument("--regime", default="fiber", choices=list(LINK_REGIMES))
    ap.add_argument("--deadline-factor", type=float, default=1.5)
    ap.add_argument("--late-mode", default="drop",
                    choices=["drop", "partial"])
    ap.add_argument("--async-buffer", type=int, default=0,
                    help="buffered-async runtime: aggregate every N "
                         "arrivals instead of at a cohort barrier (0 = "
                         "synchronous). --rounds then counts flushes")
    ap.add_argument("--async-concurrency", type=int, default=2,
                    help="dispatch groups in flight at once under "
                         "--async-buffer (>= 2 overlaps client compute)")
    ap.add_argument("--staleness-alpha", type=float, default=1.0,
                    help="async flush weight numerator: alpha/(1+s)^beta")
    ap.add_argument("--staleness-beta", type=float, default=0.5,
                    help="async staleness decay exponent (0 = uniform "
                         "weights regardless of staleness)")
    ap.add_argument("--personalize-tails", action="store_true",
                    help="keep each sampled client's post-round tail in "
                         "the population (sfprompt methods only)")
    ap.add_argument("--mesh-devices", type=int, default=0,
                    help="shard the cohort round over a host mesh of this "
                         "many devices (-1 = all visible; 0 = single-"
                         "device vmap). On CPU export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="tensor-parallel size of the mesh's 'model' axis "
                         "(must divide --mesh-devices): the frozen body "
                         "COMPUTES sharded — attention head-parallel, MLP "
                         "d_ff-parallel — per-device body HBM ~1/M "
                         "(1 = data-only mesh)")
    ap.add_argument("--fsdp", action="store_true",
                    help="FSDP-shard large frozen params over the mesh's "
                         "data axis instead of replicating them")
    ap.add_argument("--edges", type=int, default=0,
                    help="hierarchical aggregation over this many edge "
                         "aggregators (0 = flat); K must divide evenly")
    ap.add_argument("--secure-agg", action="store_true",
                    help="masked secure aggregation: the server sums "
                         "blinded uint32 ring uploads it cannot invert "
                         "(sfprompt methods only)")
    ap.add_argument("--dp-epsilon", type=float, default=0.0,
                    help="target total DP epsilon over --rounds (0 = DP "
                         "off); calibrates the per-round Gaussian noise "
                         "via the zCDP ledger")
    ap.add_argument("--dp-delta", type=float, default=1e-5)
    ap.add_argument("--dp-clip", type=float, default=1.0,
                    help="per-client L2 clip on the round delta (DP-SGD "
                         "sensitivity; used when --dp-epsilon > 0)")
    ap.add_argument("--local-epochs", type=int, default=10)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--samples", type=int, default=2000)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--image-hw", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gamma", type=float, default=0.5)
    ap.add_argument("--head-cycles", type=int, default=1)
    ap.add_argument("--tail-cycles", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint the full run state every N rounds "
                         "(0 = only at the end)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the newest checkpoint under --out")
    ap.add_argument("--init-params", default=None,
                    help="checkpoint to warm-start from (pretrained backbone)")
    ap.add_argument("--trace-out", default=None,
                    help="flight-recorder export basename: writes "
                         "<base>.jsonl, <base>.trace.json (Chrome/Perfetto) "
                         "and <base>.prom; implies --trace-level round")
    ap.add_argument("--trace-level", default="off", choices=list(LEVELS),
                    help="flight-recorder detail: off = zero-overhead noop, "
                         "round = lifecycle spans + meter bytes, step = per-"
                         "dispatch/arrival/buffer events too")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="print a metrics-registry snapshot every N rounds "
                         "(0 = only at the end when tracing is on)")
    ap.add_argument("--trace-profiler", action="store_true",
                    help="wrap traced device dispatches in jax.profiler "
                         "TraceAnnotations (visible in a profiler capture)")
    args = ap.parse_args()
    if args.personalize_tails and not args.method.startswith("sfprompt"):
        ap.error("--personalize-tails needs an sfprompt method")
    if ((args.dropout_rate > 0 or args.straggle)
            and not args.method.startswith("sfprompt")):
        ap.error("straggler simulation (--dropout-rate/--straggle) needs an "
                 "sfprompt method — FL/SFL baselines train their cohort "
                 "synchronously")
    if ((args.secure_agg or args.dp_epsilon > 0)
            and not args.method.startswith("sfprompt")):
        ap.error("--secure-agg/--dp-epsilon need an sfprompt method — the "
                 "privacy engine plugs into the SFPrompt phase-3 "
                 "aggregation path")
    if ((args.mesh_devices or args.edges or args.fsdp)
            and not args.method.startswith("sfprompt")):
        ap.error("--mesh-devices/--edges/--fsdp need an sfprompt method — "
                 "only the SFPrompt trainer dispatches sharded cohorts "
                 "and hierarchical aggregation")
    if args.mesh_model > 1 and not args.mesh_devices:
        ap.error("--mesh-model needs --mesh-devices: the 'model' axis is "
                 "carved out of the host mesh")
    if args.edges > 0 and args.k % args.edges != 0:
        ap.error(f"--k {args.k} must divide evenly into --edges "
                 f"{args.edges} contiguous blocks")
    if args.async_buffer > 0:
        if not args.method.startswith("sfprompt"):
            ap.error("--async-buffer needs an sfprompt method — only the "
                     "SFPrompt trainer exposes per-client updates for "
                     "flush-time aggregation")
        if args.personalize_tails:
            ap.error("--async-buffer and --personalize-tails are mutually "
                     "exclusive (personalized tails ride the synchronous "
                     "engine's cohort write-back)")
        if args.edges > 0:
            ap.error("--async-buffer with --edges is not supported: the "
                     "flush cohort is the buffer, not an edge layout")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=args.layers)
    split = SplitConfig(head_cycles=args.head_cycles,
                        tail_cycles=args.tail_cycles,
                        prompt_len=args.prompt_len, prune_gamma=args.gamma,
                        local_epochs=args.local_epochs)
    model = SplitModel(cfg, split)
    population, test = build_data(args, cfg)
    if population.n_local < args.batch_size:
        ap.error(
            f"--batch-size {args.batch_size} exceeds the per-client shard "
            f"of {population.n_local} samples (= --samples {args.samples} "
            f"// --clients {args.clients}); lower --batch-size or raise "
            f"--samples")

    trace_level = args.trace_level
    if args.trace_out and trace_level == "off":
        trace_level = "round"
    tracer = make_tracer(trace_level, profiler=args.trace_profiler)

    trainer = build_trainer(args, model, build_mesh(args), tracer=tracer)
    engine = build_engine(args, trainer, population, cfg, split)
    ckpt_dir = os.path.join(args.out, "ckpt")

    registry = MetricsRegistry()
    meter = getattr(trainer, "meter", None)
    if meter is not None:
        registry.bind_meter(meter)
    if getattr(engine, "ledger", None) is not None:
        registry.bind_ledger(engine.ledger)

    is_async = args.async_buffer > 0

    def progress():
        return engine.version if is_async else engine.round_idx

    key = jax.random.PRNGKey(args.seed)
    resumed = args.resume and engine.restore(ckpt_dir)
    if resumed:
        print(f"resumed from {'flush' if is_async else 'round'} "
              f"{progress()} ({ckpt_dir})", flush=True)
    else:
        engine.init(key)
        if args.init_params:
            from repro.checkpoint import load_checkpoint
            warm = load_checkpoint(args.init_params)
            params = dict(engine.state["params"])
            for seg in ("head", "body", "tail"):
                if seg in warm:
                    params[seg] = jax.tree.map(jnp.asarray, warm[seg])
            engine.state = dict(engine.state, params=params)

    os.makedirs(args.out, exist_ok=True)
    log_path = os.path.join(
        args.out, f"{args.arch}_{args.method}_{args.dataset}"
        f"{'_noniid' if args.non_iid else ''}.jsonl")
    if resumed and os.path.exists(log_path):
        # drop records from rounds after the restored checkpoint — they are
        # about to be replayed and would otherwise appear twice in the log
        with open(log_path) as f:
            kept = []
            for line in f:
                try:
                    if json.loads(line).get("round", -1) < progress():
                        kept.append(line)
                except json.JSONDecodeError:
                    pass   # torn tail line from the kill
        with open(log_path, "w") as f:
            f.writelines(kept)
    log = open(log_path, "a" if resumed else "w")

    t0 = time.time()
    while progress() < args.rounds:
        r = progress()
        if is_async:
            metrics = engine.run_flushes(1)
            metrics["t_sim"] = engine.t_sim
            rec = {"round": r, "wall_s": round(time.time() - t0, 1),
                   **metrics}
        else:
            plan, metrics = engine.run_round()
            rec = {"round": r, "wall_s": round(time.time() - t0, 1),
                   "cohort": plan.cohort.tolist(), **metrics}
        if hasattr(trainer, "evaluate"):
            ev = trainer.evaluate(engine.params, test,
                                  batch_size=args.batch_size)
            rec.update({f"eval_{k}": v for k, v in ev.items()})
        log.write(json.dumps(rec) + "\n")
        log.flush()
        print(rec, flush=True)
        if args.metrics_every and (r + 1) % args.metrics_every == 0:
            print(json.dumps({"metrics": registry.snapshot()},
                             sort_keys=True), flush=True)
        if args.ckpt_every and (r + 1) % args.ckpt_every == 0:
            engine.save(ckpt_dir)

    engine.save(ckpt_dir)
    save_checkpoint(os.path.join(args.out, "final.npz"), engine.params)
    print("saved", os.path.join(args.out, "final.npz"), "log:", log_path)
    if meter is not None:
        print(meter.report())
    if tracer.enabled and args.trace_out:
        paths = export_all(tracer, args.trace_out, meter=meter,
                           registry=registry)
        for fmt, p in sorted(paths.items()):
            print(f"trace [{fmt}]: {p}", flush=True)
    elif tracer.enabled:
        print(json.dumps({"metrics": registry.snapshot()}, sort_keys=True),
              flush=True)
    if is_async:
        print(f"async: {engine.version} flush(es) over {engine.t_sim:.1f} "
              f"simulated s, staleness mean "
              f"{engine.ledger.mean_staleness():.2f} "
              f"max {engine.ledger.max_staleness}")
    accountant = getattr(trainer, "accountant", None)
    if accountant is not None:
        print(accountant.report())


if __name__ == "__main__":
    main()
