"""SFPrompt training launcher (runs the actual protocol end-to-end).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch vit-base --reduced \\
      --dataset cifar100-syn --rounds 10 --clients 20 --k 5
  PYTHONPATH=src python -m repro.launch.train --arch rwkv6-3b --reduced \\
      --dataset lm-syn --rounds 5 --method sfl-ff

Methods: sfprompt (default), sfprompt-nolocal (Fig-6 ablation arm),
fl, sfl-ff, sfl-linear.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core import (BaselineConfig, FLTrainer, ProtocolConfig,
                        SFLTrainer, SFPromptTrainer, SplitConfig, SplitModel)
from repro.data import (DATASETS, dirichlet_partition, iid_partition,
                        select_clients, stack_clients, synthetic_image_dataset,
                        synthetic_lm_dataset)


def build_data(args, cfg):
    if args.dataset == "lm-syn":
        data = synthetic_lm_dataset(args.samples, args.seq_len,
                                    cfg.vocab_size, seed=args.seed)
        test = synthetic_lm_dataset(max(64, args.samples // 8), args.seq_len,
                                    cfg.vocab_size, seed=args.seed + 1)
    else:
        spec = DATASETS[args.dataset]
        data = synthetic_image_dataset(spec, args.samples, seed=args.seed,
                                       image_hw=args.image_hw)
        test = synthetic_image_dataset(spec, max(128, args.samples // 8),
                                       seed=args.seed + 1,
                                       image_hw=args.image_hw)
    if args.non_iid and "labels" in data:
        clients = dirichlet_partition(data, args.clients, alpha=0.1,
                                      seed=args.seed)
    else:
        clients = iid_partition(data, args.clients, seed=args.seed)
    return clients, test


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vit-base")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--method", default="sfprompt",
                    choices=["sfprompt", "sfprompt-nolocal", "fl",
                             "sfl-ff", "sfl-linear"])
    ap.add_argument("--dataset", default="cifar100-syn",
                    choices=list(DATASETS) + ["lm-syn"])
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--local-epochs", type=int, default=10)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--samples", type=int, default=2000)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--image-hw", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gamma", type=float, default=0.5)
    ap.add_argument("--head-cycles", type=int, default=1)
    ap.add_argument("--tail-cycles", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs")
    ap.add_argument("--init-params", default=None,
                    help="checkpoint to warm-start from (pretrained backbone)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    split = SplitConfig(head_cycles=args.head_cycles,
                        tail_cycles=args.tail_cycles,
                        prompt_len=args.prompt_len, prune_gamma=args.gamma,
                        local_epochs=args.local_epochs)
    model = SplitModel(cfg, split)
    clients, test = build_data(args, cfg)

    key = jax.random.PRNGKey(args.seed)
    if args.method.startswith("sfprompt"):
        pcfg = ProtocolConfig(
            clients_per_round=args.k, local_epochs=args.local_epochs,
            batch_size=args.batch_size, lr_local=args.lr, lr_split=args.lr,
            use_local_loss=(args.method == "sfprompt"))
        trainer = SFPromptTrainer(model, pcfg)
    elif args.method == "fl":
        trainer = FLTrainer(model, BaselineConfig(
            local_epochs=args.local_epochs, batch_size=args.batch_size,
            lr=args.lr))
    else:
        trainer = SFLTrainer(model, BaselineConfig(
            local_epochs=args.local_epochs, batch_size=args.batch_size,
            lr=args.lr), mode=args.method.split("-")[1])

    state = trainer.init(key)
    if args.init_params:
        from repro.checkpoint import load_checkpoint
        warm = load_checkpoint(args.init_params)
        params = dict(state["params"])
        for seg in ("head", "body", "tail"):
            if seg in warm:
                params[seg] = jax.tree.map(jnp.asarray, warm[seg])
        state = dict(state)
        state["params"] = params

    os.makedirs(args.out, exist_ok=True)
    log_path = os.path.join(
        args.out, f"{args.arch}_{args.method}_{args.dataset}"
        f"{'_noniid' if args.non_iid else ''}.jsonl")
    log = open(log_path, "w")

    t0 = time.time()
    for r in range(args.rounds):
        idx = select_clients(args.clients, args.k, seed=args.seed,
                             round_idx=r)
        batch = stack_clients(clients, idx)
        state, metrics = trainer.round(
            state, {k: jnp.asarray(v) for k, v in batch.items()})
        ev = {}
        if hasattr(trainer, "evaluate"):
            ev = trainer.evaluate(state["params"], test,
                                  batch_size=args.batch_size)
        rec = {"round": r, "wall_s": round(time.time() - t0, 1),
               **metrics, **{f"eval_{k}": v for k, v in ev.items()}}
        log.write(json.dumps(rec) + "\n")
        log.flush()
        print(rec, flush=True)

    save_checkpoint(os.path.join(args.out, "final.npz"), state["params"])
    print("saved", os.path.join(args.out, "final.npz"), "log:", log_path)


if __name__ == "__main__":
    main()
