import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede every other import (jax locks device count on first init).

_DOC = """Multi-pod dry-run: prove the distribution config lowers + compiles for
every (architecture x input shape x mesh) combination, and extract the
roofline terms from the compiled artifact.

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  python -m repro.launch.dryrun --sweep                 # all 10 x 4, 1 pod
  python -m repro.launch.dryrun --sweep --multi-pod     # 512-chip mesh
  python -m repro.launch.dryrun --sweep --loss-mode fused ...  # perf variants

Per combination this lowers the SFPrompt step (phase-2 split training step +
phase-3 aggregation for train_4k; split-inference prefill/decode for the
serving shapes), compiles it for the production mesh, prints
memory_analysis()/cost_analysis(), parses collective bytes out of the HLO,
and writes benchmarks/results/dryrun/<arch>__<shape>__<mesh>[__tag].json.
"""
__doc__ = _DOC

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.core.split import SplitConfig, SplitModel
from repro.launch import hlo as hlo_util
from repro.launch import steps as steps_lib
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               data_parallel_size, make_production_mesh,
                               report_sharding_fallbacks)
from repro.launch.specs import (SHAPES, ShapeSpec, batch_specs, cache_specs,
                                param_specs, stack_client_axis)
from repro.sharding.rules import batch_pspec, cache_pspecs, params_pspecs

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

# microbatch count for phase-2 gradient accumulation, by rough model scale
MICROBATCHES = {
    "nemotron-4-340b": 8, "deepseek-v3-671b": 16, "qwen2-vl-72b": 4,
    "phi3.5-moe-42b-a6.6b": 4, "zamba2-2.7b": 4,
}
DEFAULT_SPLIT = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=16,
                            prune_gamma=0.5, local_epochs=10)


def default_split_for(cfg) -> SplitConfig:
    return DEFAULT_SPLIT


def _sharding_tree(mesh, pspec_tree):
    return jax.tree.map(
        lambda p: jax.sharding.NamedSharding(mesh, p), pspec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def _model_flops(cfg, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode: D=new
    tokens only. Forward-only shapes use 2*N*D."""
    n_params = cfg.param_count()
    if cfg.moe is not None:
        e = cfg.moe
        dense_like = cfg.param_count() - cfg.n_cycles * (
            (e.n_experts - e.top_k) * 3 * cfg.d_model * e.d_ff_expert)
        n_params = dense_like
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq
        return 6.0 * n_params * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq
        return 2.0 * n_params * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_params * tokens


FSDP_THRESHOLD_GB = 4.0  # per-device frozen bytes above which the body is
#                           additionally data-sharded (ZeRO-style). Below it
#                           model-only sharding avoids the per-layer
#                           partial-sum activation all-reduces (§Perf pair C).


def _needs_fsdp(model: SplitModel, mesh) -> bool:
    import numpy as _np
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    frozen_bytes = sum(
        int(_np.prod(s.shape)) * 2  # bf16
        for seg in ("head", "body") for s in jax.tree.leaves(shapes[seg]))
    per_device = frozen_bytes / mesh.shape["model"]
    return per_device > FSDP_THRESHOLD_GB * 2**30


def _build_lowered(model: SplitModel, shape: ShapeSpec, mesh, *,
                   loss_mode: str, microbatches: int, remat: bool,
                   unroll: bool, impl: str, fsdp=None):
    cfg = model.cfg
    if fsdp is None:
        fsdp = _needs_fsdp(model, mesh)
    if shape.kind == "train":
        K = data_parallel_size(mesh)
        b = shape.global_batch // K
        mb = min(microbatches, b)
        train_step, opt = steps_lib.make_train_step(
            model, n_clients=K, microbatches=mb, loss_mode=loss_mode,
            remat=remat, unroll=unroll, impl=impl)
        pspecs = param_specs(model)
        frozen = {"head": pspecs["head"], "body": pspecs["body"]}
        trainable = stack_client_axis(
            {"tail": pspecs["tail"], "prompt": pspecs["prompt"]}, K)
        opt_state = jax.eval_shape(lambda t: jax.vmap(opt.init)(t), trainable)
        batch = stack_client_axis(batch_specs(cfg, shape, leading=(b,)), K)
        shardings = (
            _sharding_tree(mesh, params_pspecs(frozen, mesh, fsdp=fsdp)),
            _sharding_tree(mesh, params_pspecs(trainable, mesh,
                                               client_axis=True)),
            _sharding_tree(mesh, params_pspecs(opt_state, mesh,
                                               client_axis=True)),
            _sharding_tree(mesh, batch_pspec(batch, mesh)),
        )
        report_sharding_fallbacks(f"{cfg.name}/{shape.name}")
        fn = jax.jit(train_step, in_shardings=shardings,
                     donate_argnums=(1, 2))
        return fn.lower(frozen, trainable, opt_state, batch)

    params = param_specs(model, trainable_dtype=jnp.bfloat16)
    cache = cache_specs(model, shape)
    batch = batch_specs(cfg, shape, leading=(shape.global_batch,))
    if shape.kind == "prefill":
        step = steps_lib.make_prefill_step(model, impl=impl, unroll=unroll)
    else:
        step = steps_lib.make_decode_step(model, impl=impl, unroll=unroll)
    shardings = (
        _sharding_tree(mesh, params_pspecs(params, mesh, fsdp=fsdp)),
        _sharding_tree(mesh, batch_pspec(batch, mesh)),
        _sharding_tree(mesh, cache_pspecs(cache, mesh)),
    )
    report_sharding_fallbacks(f"{cfg.name}/{shape.name}")
    fn = jax.jit(step, in_shardings=shardings, donate_argnums=(2,))
    return fn.lower(params, batch, cache)


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              loss_mode: str = "logits", microbatches: Optional[int] = None,
              remat: bool = True, tag: str = "",
              analysis: bool = True, fsdp=None) -> Dict[str, Any]:
    """Two passes per combination:
      FULL pass     — production config (layer scans, remat, microbatches):
                      proves lowering+compile, gives memory_analysis().
      ANALYSIS pass — unrolled layer scans, loop-free blocked/chunked ops,
                      microbatches=1: HloCostAnalysis counts while-loop
                      bodies only ONCE (verified empirically), so the
                      unrolled variant is the one whose flops/bytes/
                      collective numbers are exact.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = SplitModel(cfg, default_split_for(cfg))
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    mb = microbatches or MICROBATCHES.get(arch, 1)

    t0 = time.time()
    with mesh:
        lowered = _build_lowered(model, shape, mesh, loss_mode=loss_mode,
                                 microbatches=mb, remat=remat, unroll=False,
                                 impl="ref", fsdp=fsdp)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    n_chips = mesh.size
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "n_chips": n_chips, "loss_mode": loss_mode,
        "microbatches": mb, "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1), "tag": tag,
    }
    try:
        mem = compiled.memory_analysis()
        result["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        args_b = result["memory"].get("argument_size_in_bytes", 0)
        temp_b = result["memory"].get("temp_size_in_bytes", 0)
        result["memory"]["per_device_total_gb"] = round(
            (args_b + temp_b) / n_chips / 2**30, 3)
    except Exception as e:  # pragma: no cover
        result["memory"] = {"error": str(e)}
    full_text = compiled.as_text()
    result["op_counts_full"] = hlo_util.count_ops(full_text)
    # Collectives: from the compiled (SPMD-partitioned) production module,
    # with while-loop bodies multiplied by their known_trip_count — the
    # scan-over-layers correction. Per-device numbers.
    coll = hlo_util.collective_bytes_tripcounted(full_text)
    result["collective_bytes"] = coll
    del compiled, lowered, full_text

    if analysis:
        # FLOPs/bytes: lowered (pre-SPMD, pre-optimization) cost analysis of
        # the UNROLLED loop-free analysis variant at full depth — global,
        # deterministic, and exact for flops (HloCostAnalysis counts while
        # bodies once, so the production scanned module cannot be used).
        # No compile needed. Bytes from unoptimized HLO are an unfused
        # upper bound; the roofline also derives an analytic TPU-fused
        # memory estimate (benchmarks/roofline.py).
        t1 = time.time()
        with mesh:
            lowered_a = _build_lowered(
                model, shape, mesh, loss_mode=loss_mode, microbatches=1,
                remat=False, unroll=True, impl="analysis")
        try:
            cost = lowered_a.cost_analysis()
            cost = cost[0] if isinstance(cost, (list, tuple)) else cost
            result["hlo_flops_global"] = float(cost.get("flops", 0.0))
            result["hlo_bytes_global"] = float(
                cost.get("bytes accessed", 0.0))
        except Exception as e:  # pragma: no cover
            result["hlo_flops_global"] = result["hlo_bytes_global"] = 0.0
            result["cost_error"] = str(e)
        result["hlo_flops"] = result["hlo_flops_global"] / n_chips
        result["hlo_bytes"] = result["hlo_bytes_global"] / n_chips
        result["analysis_lower_s"] = round(time.time() - t1, 1)
        del lowered_a
    else:
        result["hlo_flops"] = result["hlo_bytes"] = 0.0
        result["hlo_flops_global"] = result["hlo_bytes_global"] = 0.0

    # roofline terms (seconds); HLO numbers are per-device under SPMD
    flops, bytes_acc = result["hlo_flops"], result["hlo_bytes"]
    result["roofline"] = {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll.get("total", 0) / ICI_BW,
    }
    terms = result["roofline"]
    result["bottleneck"] = max(terms, key=terms.get)
    mf = _model_flops(cfg, shape)
    result["model_flops"] = mf
    result["useful_flops_frac"] = (
        mf / (flops * n_chips) if flops else 0.0)
    return result


def save_result(res: Dict[str, Any]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = f"__{res['tag']}" if res.get("tag") else ""
    name = f"{res['arch']}__{res['shape']}__{res['mesh']}{tag}.json"
    name = re.sub(r"[^A-Za-z0-9_.\-]", "_", name)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--sweep", action="store_true",
                    help="all assigned archs x shapes")
    ap.add_argument("--loss-mode", default="logits",
                    choices=["logits", "fused"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--force-fsdp", action="store_true",
                    help="paper-faithful baseline layout: always 2D-shard "
                         "the frozen body (pre-§Perf-pair-C behaviour)")
    ap.add_argument("--no-analysis", action="store_true",
                    help="lowering proof only (multi-pod sweep)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED if args.sweep or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.sweep or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                tag = f"__{args.tag}" if args.tag else ""
                out = os.path.join(
                    RESULTS_DIR, re.sub(r"[^A-Za-z0-9_.\-]", "_",
                                        f"{arch}__{shape}__{mesh_name}{tag}.json"))
                if args.skip_existing and os.path.exists(out):
                    print(f"[skip] {arch} x {shape} x {mesh_name}")
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_name} ...",
                      flush=True)
                try:
                    res = lower_one(
                        arch, shape, multi_pod=mp, loss_mode=args.loss_mode,
                        microbatches=args.microbatches,
                        remat=not args.no_remat, tag=args.tag,
                        analysis=not args.no_analysis,
                        fsdp=(True if args.force_fsdp else None))
                    path = save_result(res)
                    r = res["roofline"]
                    print(f"  ok: compile={res['compile_s']}s "
                          f"bottleneck={res['bottleneck']} "
                          f"compute={r['compute_s']:.3e}s "
                          f"mem={r['memory_s']:.3e}s "
                          f"coll={r['collective_s']:.3e}s -> {path}",
                          flush=True)
                except Exception as e:
                    failures.append((arch, shape, mesh_name, repr(e)))
                    print(f"  FAIL: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-runs lowered + compiled OK")


if __name__ == "__main__":
    main()
