"""jit-able production steps for the dry-run and the real launcher.

train_step  — SFPrompt steady state: one phase-2 split minibatch per client
              (vmapped over the client axis, microbatch gradient
              accumulation, frozen head/body, grads only for (tail, prompt))
              followed by the phase-3 FedAvg collective.
serve_step  — split-inference prefill / decode against the KV cache.

Loss modes:
  'logits' — paper-faithful: materialize logits, CE on top (baseline).
  'fused'  — beyond-paper: hidden @ W_head folded into the fused EL2N/CE
             computation per vocab shard (no (B,S,V) f32 tensor).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import losses
from repro.core.aggregation import broadcast_to_clients, fedavg
from repro.models import layers as L
from repro.core.split import SplitModel
from repro.kernels.el2n.ops import el2n_scores
from repro.optim import apply_updates, sgd

ACT_DTYPE = jnp.bfloat16


def _fused_lm_loss(hidden, head_w, tokens, n_prefix, softcap=None):
    """CE without materializing (B, S, V) f32 logits: contract per-position
    in bf16, reduce stats in f32 via the fused EL2N/CE identity."""
    lg = (hidden[:, n_prefix:-1, :] @ head_w.astype(hidden.dtype))
    if softcap:
        lg = softcap * jnp.tanh(lg / softcap)
    V = lg.shape[-1]
    _, ce = el2n_scores(lg.reshape(-1, V).astype(jnp.float32),
                        tokens[:, 1:].reshape(-1))
    return ce.mean()


def make_split_loss(model: SplitModel, *, impl="ref", remat=True,
                    loss_mode="logits", unroll=False):
    """Phase-2 loss with both cut points crossing the model's wire
    boundaries (codec'd activations forward, codec'd gradients backward).
    `wire_key=None` uses deterministic round-to-nearest for stochastic
    codecs — pass a key for unbiased stochastic rounding."""
    cfg = model.cfg

    def split_loss(trainable, frozen, batch, wire_key=None):
        k_hb = k_bt = None
        if wire_key is not None:
            k_hb, k_bt = jax.random.split(wire_key)
        ho = model.head_fwd(frozen["head"], trainable["prompt"], batch,
                            mode="train", impl=impl, dtype=ACT_DTYPE,
                            remat=remat, unroll=unroll)
        x_hb, _ = model.wire.head_body.transmit(ho["smashed"], key=k_hb)
        bo = model.body_fwd(frozen["body"], x_hb, ho)
        x_bt, _ = model.wire.body_tail.transmit(bo["smashed"], key=k_bt)
        if loss_mode == "fused" and not cfg.num_classes:
            x, aux_t, _ = model._seg_fwd(
                trainable["tail"], "tail", model.split.tail_cycles,
                x_bt, model._ctx_from(ho), None)
            hidden = L.apply_norm(trainable["tail"]["final_norm"], x, cfg.norm)
            loss = _fused_lm_loss(hidden, trainable["tail"]["head"]["w"],
                                  batch["tokens"], ho["n_prefix"],
                                  cfg.final_logit_softcap)
            return loss + ho["aux"] + bo["aux"] + aux_t
        to = model.tail_fwd(trainable["tail"], x_bt, ho, batch)
        out = {"logits": to["logits"].astype(jnp.float32),
               "n_prefix": to.get("n_prefix", 0),
               "aux": ho["aux"] + bo["aux"] + to["aux"]}
        loss, _ = losses.task_loss(cfg, out, batch, impl=impl)
        return loss

    return split_loss


def make_train_step(model: SplitModel, *, n_clients: int,
                    microbatches: int = 1, lr: float = 1e-2,
                    impl: str = "ref", loss_mode: str = "logits",
                    remat: bool = True, unroll: bool = False):
    """Returns (train_step, opt). train_step(frozen, trainable_k,
    opt_state_k, batch_k) -> (trainable_k, opt_state_k, loss)."""
    opt = sgd(lr, momentum=0.9)
    split_loss = make_split_loss(model, impl=impl, remat=remat,
                                 loss_mode=loss_mode, unroll=unroll)

    def per_client(frozen, trainable, opt_state, batch):
        b = jax.tree.leaves(batch)[0].shape[0]
        mb = b // microbatches

        mbs = jax.tree.map(
            lambda x: x.reshape((microbatches, mb) + x.shape[1:]), batch)
        grad_fn = jax.value_and_grad(
            lambda tr, bch: split_loss(tr, frozen, bch))

        def one_mb(carry, mbatch):
            loss_acc, g_acc = carry
            loss, g = grad_fn(trainable, mbatch)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, g_acc, g)), None

        zero_g = jax.tree.map(jnp.zeros_like, trainable)
        (loss, grads), _ = jax.lax.scan(one_mb, (jnp.float32(0.0), zero_g), mbs)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        updates, opt_state = opt.update(grads, opt_state, trainable)
        trainable = apply_updates(trainable, updates)
        return trainable, opt_state, loss / microbatches

    def train_step(frozen, trainable_k, opt_state_k, batch_k):
        # broadcast frozen segments over the client axis: ragged_dot (MoE)
        # vmaps only with all operands batched at dim 0; XLA keeps the
        # broadcast unmaterialized per shard.
        frozen_k = broadcast_to_clients(frozen, n_clients)
        trainable_k, opt_state_k, loss_k = jax.vmap(per_client)(
            frozen_k, trainable_k, opt_state_k, batch_k)
        # Phase-3 aggregation: the protocol's signature collective
        agg = fedavg(trainable_k, jnp.ones((n_clients,), jnp.float32))
        trainable_k = broadcast_to_clients(agg, n_clients)
        return trainable_k, opt_state_k, loss_k.mean()

    return train_step, opt


def make_prefill_step(model: SplitModel, *, impl: str = "ref",
                      unroll: bool = False, with_wire_bytes: bool = False,
                      dtype=ACT_DTYPE, donate_cache: bool = False):
    """Prefill crosses both wire boundaries once (forward only); with
    `with_wire_bytes` the step also returns the measured per-link bytes.
    `dtype` is the activation dtype (bf16 production default; the serving
    engine's logit-equivalence tests run fp32). `donate_cache` returns the
    step pre-jitted with the cache argument DONATED — the caller must
    replace its cache with the returned one and never touch the old pytree
    (the serving/decode loops already do); in exchange the KV cache updates
    in place instead of being copied every step."""
    def prefill_step(params, batch, cache):
        out = model.forward(params, batch, route="split", mode="prefill",
                            cache=cache, impl=impl, dtype=dtype,
                            unroll=unroll)
        if with_wire_bytes:
            return out["logits"][:, -1, :], out["cache"], out["wire_bytes"]
        return out["logits"][:, -1, :], out["cache"]
    if donate_cache:
        return jax.jit(prefill_step, donate_argnums=(2,))
    return prefill_step


def make_decode_step(model: SplitModel, *, impl: str = "ref",
                     unroll: bool = False, with_wire_bytes: bool = False,
                     dtype=ACT_DTYPE, donate_cache: bool = False):
    """One greedy decode token against the KV cache; `donate_cache` as in
    `make_prefill_step` (the cache pytree is donated and updated in
    place — the decode hot loop's biggest per-step copy)."""
    def decode_step(params, batch, cache):
        out = model.forward(params, batch, route="split", mode="decode",
                            cache=cache, impl=impl, dtype=dtype,
                            unroll=unroll)
        logits = out["logits"][:, 0, :]
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        if with_wire_bytes:
            return next_tok, logits, out["cache"], out["wire_bytes"]
        return next_tok, logits, out["cache"]
    if donate_cache:
        return jax.jit(decode_step, donate_argnums=(2,))
    return decode_step
