"""Input/parameter ShapeDtypeStruct builders for the multi-pod dry-run.

No device allocation anywhere: params and caches come from jax.eval_shape
over the real init functions, inputs are hand-built ShapeDtypeStructs.

Assigned input shapes:
  train_4k     seq=4096    global_batch=256   (training, SFPrompt phase-2)
  prefill_32k  seq=32768   global_batch=32    (split-inference prefill)
  decode_32k   seq=32768   global_batch=128   (split-inference decode)
  long_500k    seq=524288  global_batch=1     (long-context decode; ring-
               buffer window / native SSM state — DESIGN.md §skips)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.split import SplitModel
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

VLM_PATCH_FRACTION = 4  # 1/4 of the sequence is image patches


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, *,
                leading: Tuple[int, ...], act_dtype=jnp.bfloat16
                ) -> Dict[str, Any]:
    """Model-input ShapeDtypeStructs with the given leading dims
    (e.g. (K, b) for per-client training, (B,) for serving)."""
    S = shape.seq
    mk = lambda tail, dt: SDS(leading + tail, dt)

    if cfg.arch_type == "vit":
        n_patches = 196
        return {"patches": mk((n_patches, 16 * 16 * 3), act_dtype),
                "labels": mk((), jnp.int32)}

    if shape.kind == "decode":
        batch = {"tokens": mk((1,), jnp.int32), "pos": mk((), jnp.int32)}
        return batch

    batch = {}
    if cfg.arch_type == "vlm":
        npz = S // VLM_PATCH_FRACTION
        batch["patch_embeds"] = mk((npz, cfg.d_model), act_dtype)
        batch["mrope_positions"] = mk((3, npz), jnp.int32)  # client-axis first
        batch["tokens"] = mk((S - npz,), jnp.int32)
    elif cfg.arch_type == "audio":
        batch["frames"] = mk((cfg.encoder.n_frames, cfg.d_model), act_dtype)
        batch["tokens"] = mk((S,), jnp.int32)
    else:
        batch["tokens"] = mk((S,), jnp.int32)
    return batch


def cache_specs(model: SplitModel, shape: ShapeSpec, *,
                dtype=jnp.bfloat16) -> Any:
    """Decode-cache ShapeDtypeStructs (eval_shape over the real init).
    long_500k uses the arch's ring-buffer window; decode_32k keeps the full
    cache."""
    window = None
    if shape.name == "long_500k":
        window = model.cfg.long_context_window
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq, dtype=dtype,
                                 window=window))


def param_specs(model: SplitModel, *, frozen_dtype=jnp.bfloat16,
                trainable_dtype=jnp.float32) -> Any:
    """Split-model parameter SDS tree: frozen segments in bf16, trainable
    (tail, prompt) in f32 master precision."""
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))

    def cast(tree, dt):
        return jax.tree.map(lambda s: SDS(s.shape, dt), tree)

    return {
        "head": cast(shapes["head"], frozen_dtype),
        "body": cast(shapes["body"], frozen_dtype),
        "tail": cast(shapes["tail"], trainable_dtype),
        "prompt": cast(shapes["prompt"], trainable_dtype),
    }


def stack_client_axis(tree: Any, k: int) -> Any:
    return jax.tree.map(lambda s: SDS((k,) + s.shape, s.dtype), tree)
