"""Production mesh definitions (TPU v5e target).

Single pod:  (data=16, model=16)            = 256 chips
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips

SFPrompt mapping: the client plane is ('pod', 'data') — each index hosts a
cohort of simulated clients, with per-client parameter copies sharded along
it; the server plane is 'model' — the frozen body is tensor-parallel (and
FSDP-sharded over 'data' for storage). Defined as a FUNCTION so importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax

# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int = 0):
    """1-D ('data',) mesh over this host's visible devices — the off-TPU
    stand-in for the production client plane. With
    XLA_FLAGS=--xla_force_host_platform_device_count=8 (set BEFORE jax
    initializes; see launch/dryrun.py) a CPU host exposes 8 virtual
    devices, so sharded-cohort lowering is testable without silicon.
    n=0 uses every visible device."""
    devices = jax.devices()
    n = len(devices) if n <= 0 else n
    if n > len(devices):
        raise ValueError(
            f"requested a {n}-device host mesh but only {len(devices)} "
            "device(s) are visible — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before jax "
            "initializes")
    return jax.make_mesh((n,), ("data",), devices=devices[:n])


def data_parallel_size(mesh) -> int:
    size = mesh.shape["data"]
    if "pod" in mesh.shape:
        size *= mesh.shape["pod"]
    return size
