"""Production mesh definitions (TPU v5e target).

Single pod:  (data=16, model=16)            = 256 chips
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips

SFPrompt mapping: the client plane is ('pod', 'data') — each index hosts a
cohort of simulated clients, with per-client parameter copies sharded along
it; the server plane is 'model' — the frozen body is tensor-parallel (and
FSDP-sharded over 'data' for storage). Defined as a FUNCTION so importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax

from repro.sharding.rules import report_fallbacks

# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int = 0, *, model: int = 1):
    """Host-device mesh — the off-TPU stand-in for the production mesh.
    model=1 (default): 1-D ('data',) client plane, as before. model>1:
    2-D ('data', 'model') — the client plane shrinks to n // model and the
    'model' axis becomes a real tensor-parallel compute axis (the frozen
    body's params_pspecs 'model' shardings stop being no-ops). With
    XLA_FLAGS=--xla_force_host_platform_device_count=8 (set BEFORE jax
    initializes; see launch/dryrun.py) a CPU host exposes 8 virtual
    devices, so e.g. make_host_mesh(model=4) gives (data=2, model=4).
    n=0 uses every visible device."""
    devices = jax.devices()
    n = len(devices) if n <= 0 else n
    if n > len(devices):
        raise ValueError(
            f"requested a {n}-device host mesh but only {len(devices)} "
            "device(s) are visible — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before jax "
            "initializes")
    if model <= 1:
        return jax.make_mesh((n,), ("data",), devices=devices[:n])
    if n % model != 0:
        raise ValueError(
            f"model={model} does not divide the {n}-device host mesh — "
            "pick a model-axis size that divides the device count")
    return jax.make_mesh((n // model, model), ("data", "model"),
                         devices=devices[:n])


def report_sharding_fallbacks(context: str = "", tracer=None) -> tuple:
    """Drain the divisibility fallbacks recorded while building partition
    specs (sharding.rules.guard_divisibility) and warn ONCE if any rule
    quietly fell back to replication — a mis-sized mesh should be visible,
    not silently slow. With a tracer, the entries additionally land as a
    structured `sharding.fallback` event (sharding.rules.report_fallbacks).
    Returns the drained (path, axis, shape) tuples so launchers can also
    log them."""
    return report_fallbacks(context, tracer)


def data_parallel_size(mesh) -> int:
    size = mesh.shape["data"]
    if "pod" in mesh.shape:
        size *= mesh.shape["pod"]
    return size
