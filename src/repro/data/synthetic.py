"""Synthetic datasets (the container is offline — DESIGN.md §Notes).

Image tasks: class-conditional Gaussian clusters in patch space with
class-dependent spatial structure — learnable by a ViT, so accuracy curves
separate methods the way the paper's CIFAR/SVHN/Flower curves do
(trend-level validation).

LM tasks: a Zipf unigram base with class-style "domain" prefixes and a
deterministic bigram drift per domain — enough structure for a small LM to
reduce CE visibly within a few hundred steps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

PATCH = 16


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_classes: int
    image_hw: int = 224
    difficulty: float = 1.0   # cluster separation divisor (higher = harder)


# stand-ins for the paper's four downstream tasks
DATASETS: Dict[str, DatasetSpec] = {
    "cifar10-syn": DatasetSpec("cifar10-syn", 10, 224, 1.0),
    "cifar100-syn": DatasetSpec("cifar100-syn", 100, 224, 1.2),
    "svhn-syn": DatasetSpec("svhn-syn", 10, 224, 1.6),
    "flower102-syn": DatasetSpec("flower102-syn", 102, 224, 1.4),
}


def synthetic_image_dataset(spec: DatasetSpec, n: int, *, seed: int = 0,
                            image_hw: int | None = None):
    """Returns {'patches': (n, P, PATCH*PATCH*3) f32, 'labels': (n,) i32}.
    Pre-patchified (the ViT patch projection is part of the model head)."""
    rng = np.random.default_rng(seed)
    hw = image_hw or spec.image_hw
    n_patches = (hw // PATCH) ** 2
    pdim = PATCH * PATCH * 3
    labels = rng.integers(0, spec.n_classes, size=n).astype(np.int32)
    # class anchors: low-rank structure + per-patch positional signature
    rank = 8
    class_basis = rng.normal(size=(spec.n_classes, rank)).astype(np.float32)
    mix = rng.normal(size=(rank, n_patches, pdim)).astype(np.float32)
    anchors = np.einsum("cr,rpd->cpd", class_basis, mix) / np.sqrt(rank)
    noise = rng.normal(size=(n, n_patches, pdim)).astype(np.float32)
    patches = anchors[labels] / spec.difficulty + 0.6 * noise
    return {"patches": patches.astype(np.float32), "labels": labels}


def synthetic_lm_dataset(n: int, seq_len: int, vocab: int, *, seed: int = 0,
                         n_domains: int = 8):
    """Returns {'tokens': (n, seq_len) i32} with per-domain bigram drift."""
    rng = np.random.default_rng(seed)
    base = rng.zipf(1.3, size=(n, seq_len)).astype(np.int64)
    dom = rng.integers(0, n_domains, size=(n, 1))
    drift = (np.arange(seq_len)[None, :] * (dom + 1)) % 17
    toks = (base + drift) % vocab
    toks[:, 0] = dom[:, 0] % vocab  # domain marker token
    return {"tokens": toks.astype(np.int32)}
