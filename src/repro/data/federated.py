"""Federated data pipeline: IID / Dirichlet non-IID partitioning (the
paper's Sec. 4.1 setting: 50 clients, Dirichlet alpha=0.1 for non-IID),
client selection, and stacking selected clients into the (K, n, ...) layout
the protocol vmaps/shards over.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


def iid_partition(data: Dict[str, np.ndarray], n_clients: int, *,
                  seed: int = 0) -> List[Dict[str, np.ndarray]]:
    n = len(next(iter(data.values())))
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    per = n // n_clients
    return [
        {k: v[perm[i * per:(i + 1) * per]] for k, v in data.items()}
        for i in range(n_clients)
    ]


def dirichlet_partition(data: Dict[str, np.ndarray], n_clients: int, *,
                        alpha: float = 0.1, seed: int = 0,
                        label_key: str = "labels") -> List[Dict[str, np.ndarray]]:
    """Label-skewed non-IID split [Hsu et al. 2019]. Every client is padded
    (by resampling its own data) to the same size so the client axis stacks."""
    labels = data[label_key]
    n = len(labels)
    classes = np.unique(labels)
    rng = np.random.default_rng(seed)
    per = n // n_clients

    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx_c = np.where(labels == c)[0]
        rng.shuffle(idx_c)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idx_c, cuts)):
            client_idx[cid].extend(part.tolist())

    out = []
    for cid in range(n_clients):
        idx = np.asarray(client_idx[cid], dtype=np.int64)
        if len(idx) == 0:
            idx = rng.integers(0, n, size=per)
        elif len(idx) < per:
            idx = np.concatenate([idx, rng.choice(idx, per - len(idx))])
        else:
            idx = idx[:per]
        rng.shuffle(idx)
        out.append({k: v[idx] for k, v in data.items()})
    return out


def select_clients(n_clients: int, k: int, *, seed: int, round_idx: int):
    rng = np.random.default_rng(seed * 100_003 + round_idx)
    return rng.choice(n_clients, size=k, replace=False)


def stack_clients(clients: Sequence[Dict[str, np.ndarray]],
                  idx: Sequence[int]) -> Dict[str, np.ndarray]:
    """-> pytree with leading (K, n_local, ...) axes."""
    keys = clients[0].keys()
    return {k: np.stack([clients[i][k] for i in idx]) for k in keys}
