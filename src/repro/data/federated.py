"""Federated data pipeline: IID / Dirichlet non-IID partitioning (the
paper's Sec. 4.1 setting: 50 clients, Dirichlet alpha=0.1 for non-IID),
client selection, and stacking selected clients into the (K, n, ...) layout
the protocol vmaps/shards over.

Two layers:

  *_indices   — partition as per-client INDEX arrays into one shared base
                dataset.  This is what `fed.Population` stores: for
                N >> K clients only the sampled cohort is ever
                materialized, so a million-client population costs one
                dataset plus N small int arrays.
  *_partition — the original materialized form (list of per-client dict
                copies), now a thin wrapper over the index layer.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np


def _pad_indices(idx: np.ndarray, per: int, n_total: int,
                 rng: np.random.Generator) -> np.ndarray:
    """Pad/trim a client's index set to exactly `per` samples so the client
    axis stacks. A non-empty client resamples its OWN data; a client the
    Dirichlet draw left EMPTY falls back to `per` uniform draws from the
    whole dataset (an IID stand-in — its `sizes` weight stays 1, so
    weighted sampling and FedAvg barely count it)."""
    if len(idx) == 0:
        idx = rng.integers(0, n_total, size=per)
    elif len(idx) < per:
        idx = np.concatenate([idx, rng.choice(idx, per - len(idx))])
    else:
        idx = idx[:per]
    rng.shuffle(idx)
    return np.asarray(idx, dtype=np.int64)


def iid_indices(n: int, n_clients: int, *,
                seed: int = 0) -> Tuple[List[np.ndarray], np.ndarray]:
    """Uniform shuffle-and-slice. Returns (per-client index arrays,
    true pre-padding sizes)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    per = n // n_clients
    idx = [np.asarray(perm[i * per:(i + 1) * per], dtype=np.int64)
           for i in range(n_clients)]
    return idx, np.full((n_clients,), per, dtype=np.int64)


def dirichlet_indices(labels: np.ndarray, n_clients: int, *,
                      alpha: float = 0.1, seed: int = 0,
                      ) -> Tuple[List[np.ndarray], np.ndarray]:
    """Label-skewed non-IID split [Hsu et al. 2019] as index arrays.
    Every client is padded (by resampling its own data) to the same size so
    the client axis stacks; the returned `sizes` are the TRUE pre-padding
    per-client sample counts — the right FedAvg / weighted-sampling weights.
    """
    n = len(labels)
    classes = np.unique(labels)
    rng = np.random.default_rng(seed)
    per = n // n_clients

    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx_c = np.where(labels == c)[0]
        rng.shuffle(idx_c)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idx_c, cuts)):
            client_idx[cid].extend(part.tolist())

    sizes = np.array([max(1, len(ci)) for ci in client_idx], dtype=np.int64)
    out = [_pad_indices(np.asarray(ci, dtype=np.int64), per, n, rng)
           for ci in client_idx]
    return out, sizes


def iid_partition(data: Dict[str, np.ndarray], n_clients: int, *,
                  seed: int = 0) -> List[Dict[str, np.ndarray]]:
    n = len(next(iter(data.values())))
    idx, _ = iid_indices(n, n_clients, seed=seed)
    return [{k: v[i] for k, v in data.items()} for i in idx]


def dirichlet_partition(data: Dict[str, np.ndarray], n_clients: int, *,
                        alpha: float = 0.1, seed: int = 0,
                        label_key: str = "labels") -> List[Dict[str, np.ndarray]]:
    """Label-skewed non-IID split [Hsu et al. 2019]. Every client is padded
    (by resampling its own data) to the same size so the client axis stacks."""
    idx, _ = dirichlet_indices(data[label_key], n_clients, alpha=alpha,
                               seed=seed)
    return [{k: v[i] for k, v in data.items()} for i in idx]


def select_clients(n_clients: int, k: int, *, seed: int, round_idx: int):
    rng = np.random.default_rng(seed * 100_003 + round_idx)
    return rng.choice(n_clients, size=k, replace=False)


def stack_clients(clients: Sequence[Dict[str, np.ndarray]],
                  idx: Sequence[int]) -> Dict[str, np.ndarray]:
    """-> pytree with leading (K, n_local, ...) axes."""
    keys = clients[0].keys()
    return {k: np.stack([clients[i][k] for i in idx]) for k in keys}
