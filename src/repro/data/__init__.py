from repro.data.federated import (  # noqa: F401
    dirichlet_partition, iid_partition, select_clients, stack_clients)
from repro.data.synthetic import (  # noqa: F401
    DATASETS, synthetic_image_dataset, synthetic_lm_dataset)
