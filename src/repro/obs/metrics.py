"""Unified metrics registry: one `snapshot()` over scattered runtime state.

Before this module, the numbers lived in five places with five shapes:
`TrafficMeter.totals`/`report()`, `ServeEngine.stats()`,
`PagedServeEngine`'s pool/prefix counters, `StalenessLedger.summary()`,
and the sharding-fallback record list. The registry gives them a common
vocabulary — counters, gauges, histograms, all label-capable — plus
lazy *sources*: a registered callable is polled at `snapshot()` time, so
attaching an engine costs nothing per token (the engine keeps mutating
its own counters; the registry reads them on demand).

Values are whatever the owner already computed — the registry never
forces a device sync of its own (`ServeEngine.stats()` keeps its
one-sync-per-call contract; the registry just calls it when *you* ask
for a snapshot).
"""
from __future__ import annotations

import bisect
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

LabelSet = Tuple[Tuple[str, str], ...]


def _labels(labels: Optional[Mapping[str, Any]]) -> LabelSet:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_suffix(ls: LabelSet) -> str:
    if not ls:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in ls) + "}"


class Counter:
    """Monotone sum per label set."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._v: Dict[LabelSet, float] = {}

    def inc(self, amount: float = 1.0,
            labels: Optional[Mapping[str, Any]] = None) -> None:
        ls = _labels(labels)
        self._v[ls] = self._v.get(ls, 0.0) + float(amount)

    def value(self, labels: Optional[Mapping[str, Any]] = None) -> float:
        return self._v.get(_labels(labels), 0.0)

    def collect(self) -> Dict[str, float]:
        return {self.name + _label_suffix(ls): v
                for ls, v in sorted(self._v.items())}


class Gauge:
    """Last-set value per label set; `set_fn` makes it lazy (polled at
    collect time — the idiom for "mirror this live attribute")."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._v: Dict[LabelSet, Any] = {}

    def set(self, value: float,
            labels: Optional[Mapping[str, Any]] = None) -> None:
        self._v[_labels(labels)] = float(value)

    def set_fn(self, fn: Callable[[], float],
               labels: Optional[Mapping[str, Any]] = None) -> None:
        self._v[_labels(labels)] = fn

    def value(self, labels: Optional[Mapping[str, Any]] = None) -> float:
        v = self._v.get(_labels(labels), 0.0)
        return float(v()) if callable(v) else v

    def collect(self) -> Dict[str, float]:
        return {self.name + _label_suffix(ls): (float(v()) if callable(v)
                                                else v)
                for ls, v in sorted(self._v.items())}


class Histogram:
    """Fixed-bucket histogram (cumulative counts, Prometheus-style) with
    sum/count — enough for latency/size distributions without keeping
    every observation."""

    DEFAULT_BUCKETS = (1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0, 100.0, 1e3,
                       1e4, 1e5, 1e6)

    def __init__(self, name: str, help: str = "",
                 buckets: Optional[Tuple[float, ...]] = None):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(buckets or self.DEFAULT_BUCKETS))
        self._counts: Dict[LabelSet, List[int]] = {}
        self._sum: Dict[LabelSet, float] = {}
        self._n: Dict[LabelSet, int] = {}

    def observe(self, value: float,
                labels: Optional[Mapping[str, Any]] = None) -> None:
        ls = _labels(labels)
        if ls not in self._counts:
            self._counts[ls] = [0] * (len(self.buckets) + 1)
            self._sum[ls] = 0.0
            self._n[ls] = 0
        v = float(value)
        self._counts[ls][bisect.bisect_left(self.buckets, v)] += 1
        self._sum[ls] += v
        self._n[ls] += 1

    def collect(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for ls in sorted(self._counts):
            cum = 0
            for edge, c in zip(self.buckets, self._counts[ls]):
                cum += c
                le = _labels(dict(dict(ls), le=repr(edge)))
                out[f"{self.name}_bucket" + _label_suffix(le)] = cum
            inf = _labels(dict(dict(ls), le="+Inf"))
            out[f"{self.name}_bucket" + _label_suffix(inf)] = self._n[ls]
            out[f"{self.name}_sum" + _label_suffix(ls)] = self._sum[ls]
            out[f"{self.name}_count" + _label_suffix(ls)] = self._n[ls]
        return out


class MetricsRegistry:
    """Namespace of instruments + lazy snapshot sources.

    `register_source(name, fn)` hooks a zero-arg callable returning a
    flat `{metric: value}` mapping; `snapshot()` merges every
    instrument's `collect()` with every source's poll, prefixing source
    keys with `<name>/`. Sources are how existing state joins without
    migrating: `bind_*` helpers below wrap a TrafficMeter, serve engine,
    page pool, or staleness ledger as a source in one line.
    """

    def __init__(self):
        self._instruments: Dict[str, Any] = {}
        self._sources: Dict[str, Callable[[], Mapping[str, Any]]] = {}

    def _get(self, name: str, kind):
        """Idempotent by (name, kind): re-registering the same name
        returns the live instrument; a cross-kind clash is a bug."""
        got = self._instruments.get(name)
        if got is not None and not isinstance(got, kind):
            raise ValueError(
                f"metric {name!r} already registered as "
                f"{type(got).__name__}, not {kind.__name__}")
        return got

    def counter(self, name: str, help: str = "") -> Counter:
        got = self._get(name, Counter)
        if got is None:
            got = self._instruments[name] = Counter(name, help)
        return got

    def gauge(self, name: str, help: str = "") -> Gauge:
        got = self._get(name, Gauge)
        if got is None:
            got = self._instruments[name] = Gauge(name, help)
        return got

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Tuple[float, ...]] = None) -> Histogram:
        got = self._get(name, Histogram)
        if got is None:
            got = self._instruments[name] = Histogram(name, help, buckets)
        return got

    def register_source(self, name: str,
                        fn: Callable[[], Mapping[str, Any]]) -> None:
        self._sources[name] = fn

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for inst in self._instruments.values():
            out.update(inst.collect())
        for name, fn in self._sources.items():
            for k, v in dict(fn()).items():
                out[f"{name}/{k}"] = v
        return out

    # ------------------------------------------------------ source binders
    def bind_meter(self, meter, name: str = "meter") -> None:
        """TrafficMeter totals + wall streams + round counts."""
        def poll():
            out = dict(meter.state_dict())
            out["total_bytes"] = meter.total_bytes()
            return out
        self.register_source(name, poll)

    def bind_engine(self, engine, name: str = "serve") -> None:
        """ServeEngine/PagedServeEngine `live_stats()` — token/step
        counters, last-flush wire bytes, and (paged) pool/prefix counters
        — flattened one level."""
        def poll():
            out: Dict[str, Any] = {}
            for k, v in engine.live_stats().items():
                if isinstance(v, Mapping):
                    for kk, vv in v.items():
                        out[f"{k}/{kk}"] = vv
                else:
                    out[k] = v
            return out
        self.register_source(name, poll)

    def bind_ledger(self, ledger, name: str = "staleness") -> None:
        """Async runtime's StalenessLedger: applied count, mean/max."""
        def poll():
            return {"applied": ledger.applied,
                    "mean": ledger.mean_staleness(),
                    "max": ledger.max_staleness}
        self.register_source(name, poll)

    def bind_pool(self, pool, name: str = "pages") -> None:
        """PagePool occupancy: total/free/used pages (used excludes the
        two reserved ids)."""
        def poll():
            return {"n_pages": pool.n_pages, "page_size": pool.page_size,
                    "n_free": pool.n_free, "n_used": pool.n_used}
        self.register_source(name, poll)
