"""Exporters: JSONL event log, Chrome-trace JSON, Prometheus text.

All three consume the flight recorder's raw records (`Tracer.records()`)
— the JSONL file is the ground truth `tools/trace_check.py` validates,
the Chrome trace is the same data laid out for `chrome://tracing` /
Perfetto ("Open trace file"), and the Prometheus dump renders a
`MetricsRegistry.snapshot()` for scrape-style ingestion.

Clock layout in the Chrome trace: host-clock records render under
``pid 0`` ("host"), simulated-clock records (async runtime: `t_sim` /
`dur_sim` in seconds) under ``pid 1`` ("sim") with one tid per `lane`
(client id), so overlapping in-flight clients stack as parallel tracks
instead of overwriting each other.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Mapping, Optional

PID_HOST = 0
PID_SIM = 1


def meter_final_record(meter, seq: int) -> Dict[str, Any]:
    """The closing `meter.final` record: authoritative per-stream totals
    at export time. trace_check verifies the running `meter.absorb` sums
    equal these floats EXACTLY (same left-to-right addition order)."""
    return {"seq": seq, "kind": "event", "name": "meter.final", "depth": 0,
            "attrs": {**{k: float(v) for k, v in meter.totals.items()},
                      "rounds": meter.rounds}}


def _finalize(records: Iterable[Mapping[str, Any]],
              meter=None) -> List[Dict[str, Any]]:
    recs = [dict(r) for r in records]
    if meter is not None:
        recs.append(meter_final_record(
            meter, recs[-1]["seq"] + 1 if recs else 0))
    return recs


def write_jsonl(path: str, records: Iterable[Mapping[str, Any]],
                meter=None) -> int:
    """One record per line, sorted keys (deterministic bytes modulo the
    wall-time fields). Appends the `meter.final` record when a meter is
    given. Returns the number of records written."""
    recs = _finalize(records, meter)
    with open(path, "w") as f:
        for rec in recs:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
    return len(recs)


def chrome_trace(records: Iterable[Mapping[str, Any]],
                 meter=None) -> Dict[str, Any]:
    """Records → Chrome trace-event JSON (the `traceEvents` envelope).

    Host spans become complete ("X") events with ts/dur in µs from
    `t_ns`; sim-clock spans use `t_sim` seconds → µs on the sim process.
    Events become instants ("i"). Metadata ("M") events name the two
    processes and the sim lanes.
    """
    recs = _finalize(records, meter)
    events: List[Dict[str, Any]] = [
        {"ph": "M", "pid": PID_HOST, "name": "process_name",
         "args": {"name": "host"}},
        {"ph": "M", "pid": PID_SIM, "name": "process_name",
         "args": {"name": "sim"}},
    ]
    named_lanes = set()
    t0 = min((r["t_ns"] for r in recs if "t_ns" in r), default=0)
    for rec in recs:
        args = dict(rec.get("attrs", {}))
        if "t_sim" in rec:
            pid, tid = PID_SIM, rec.get("lane", 0)
            ts = rec["t_sim"] * 1e6
            dur = rec.get("dur_sim", 0.0) * 1e6
            if tid not in named_lanes:
                named_lanes.add(tid)
                events.append({"ph": "M", "pid": PID_SIM, "tid": tid,
                               "name": "thread_name",
                               "args": {"name": f"lane {tid}"}})
        else:
            pid, tid = PID_HOST, 0
            ts = (rec.get("t_ns", t0) - t0) / 1e3
            dur = rec.get("dur_ns", 0) / 1e3
        if rec.get("kind") == "span":
            events.append({"ph": "X", "pid": pid, "tid": tid,
                           "name": rec["name"], "ts": ts, "dur": dur,
                           "args": args})
        else:
            events.append({"ph": "i", "pid": pid, "tid": tid, "s": "t",
                           "name": rec["name"], "ts": ts, "args": args})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, records: Iterable[Mapping[str, Any]],
                       meter=None) -> int:
    doc = chrome_trace(records, meter)
    with open(path, "w") as f:
        json.dump(doc, f, sort_keys=True)
    return len(doc["traceEvents"])


def prometheus_text(snapshot: Mapping[str, Any]) -> str:
    """A `MetricsRegistry.snapshot()` as Prometheus text exposition.
    Metric names are sanitized (`/`, `-`, `.` → `_`); label suffixes
    produced by the registry pass through untouched. Non-numeric values
    are skipped (exposition is numbers-only)."""
    lines = []
    for key in sorted(snapshot):
        v = snapshot[key]
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            continue
        name, brace, labels = key.partition("{")
        name = (name.replace("/", "_").replace("-", "_")
                .replace(".", "_"))
        lines.append(f"{name}{brace}{labels} {float(v)}")
    return "\n".join(lines) + "\n"


def write_prometheus(path: str, snapshot: Mapping[str, Any]) -> None:
    with open(path, "w") as f:
        f.write(prometheus_text(snapshot))


def export_all(tracer, base: str, *, meter=None,
               registry=None) -> Dict[str, str]:
    """Write every applicable format next to `base` (a path prefix):
    `<base>.jsonl`, `<base>.trace.json`, and `<base>.prom` when a
    registry is supplied. Returns {format: path} for logging."""
    recs = tracer.records()
    out: Dict[str, str] = {}
    jsonl = base + ".jsonl"
    write_jsonl(jsonl, recs, meter)
    out["jsonl"] = jsonl
    chrome = base + ".trace.json"
    write_chrome_trace(chrome, recs, meter)
    out["chrome"] = chrome
    if registry is not None:
        prom = base + ".prom"
        write_prometheus(prom, registry.snapshot())
        out["prom"] = prom
    return out
