"""Observability: flight recorder, metrics registry, exporters.

`repro.obs` is pure observation — attaching it changes no engine output,
no metered byte, no RNG draw (tests/test_obs.py pins bit-identity with
tracing off and exact byte accounting with tracing on). See
ARCHITECTURE.md §Observability for the span taxonomy.
"""
from repro.obs.trace import (            # noqa: F401
    LEVEL_OFF, LEVEL_ROUND, LEVEL_STEP, LEVELS, NOOP,
    NoopTracer, Tracer, make_tracer, span_tree, strip_times, sum_stream,
    to_jsonl,
)
from repro.obs.metrics import (          # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry,
)
from repro.obs.export import (           # noqa: F401
    chrome_trace, export_all, meter_final_record, prometheus_text,
    write_chrome_trace, write_jsonl, write_prometheus,
)
