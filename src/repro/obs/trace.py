"""Flight recorder: nested host-side spans + structured events.

The tracer is a RING BUFFER of structured records — "what just happened,
in order, with the numbers attached" — threaded through the protocol
round, the async runtime, and the serve engines. Design contract (the
hard part, pinned by tests/test_obs.py):

* **No-op when disabled.** Every instrumented component defaults to the
  shared `NOOP` tracer whose methods do nothing and whose `enabled` is
  False; hot loops guard attribute construction behind `tracer.enabled`.
  With tracing off, engine outputs, round params, and metered bytes are
  BIT-IDENTICAL to an un-instrumented build — tracing is observation,
  never participation (it forces no extra device syncs: byte attributes
  are recorded at the points the host already materializes them).
* **Exact byte accounting.** `TrafficMeter.absorb` emits one
  `meter.absorb` event per fold with the SAME host floats it adds to its
  totals, so summing the events per stream in record order reproduces
  the meter totals float-exactly (tools/trace_check.py verifies this
  against the `meter.final` record the exporters append).
* **Deterministic modulo wall time.** Record order, names, depths, and
  attribute values are pure functions of the run's seed/config; only
  `t_ns`/`dur_ns` carry host wall time. Strip those and two same-seed
  traces compare equal (`strip_times`).

Two clocks coexist: host spans stamp `time.perf_counter_ns()`; the async
runtime's records instead carry the engine's SIMULATED clock (`t_sim` /
`dur_sim`, seconds) via `event_at`/`span_at` — the Chrome-trace exporter
lays them out as a separate process track.

Levels: ``off`` (0) records nothing, ``round`` (1) the lifecycle
(rounds, flushes, admissions, retirements, meter folds), ``step`` (2)
adds per-dispatch detail (decode steps, page-pool churn, buffer traffic).
"""
from __future__ import annotations

import itertools
import json
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Tuple

LEVEL_OFF = 0
LEVEL_ROUND = 1
LEVEL_STEP = 2
LEVELS = {"off": LEVEL_OFF, "round": LEVEL_ROUND, "step": LEVEL_STEP}

# record keys that carry host wall time — the only nondeterminism a
# same-seed trace is allowed (strip them before comparing traces)
TIME_KEYS = ("t_ns", "dur_ns")


class _NoopSpan:
    """Reusable null context: `with NOOP.span(...) as sp: sp.set(...)`
    costs two attribute lookups and nothing else."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> None:
        pass


_NOOP_SPAN = _NoopSpan()


class NoopTracer:
    """The disabled tracer: every hook is a no-op, `enabled` is False so
    hot paths can skip attribute construction entirely."""
    __slots__ = ()
    enabled = False
    level = LEVEL_OFF

    def span(self, name: str, level: int = LEVEL_ROUND, **attrs):
        return _NOOP_SPAN

    def event(self, name: str, level: int = LEVEL_ROUND, **attrs) -> None:
        pass

    def event_at(self, name: str, t_sim: float,
                 level: int = LEVEL_ROUND, **attrs) -> None:
        pass

    def span_at(self, name: str, t0_sim: float, t1_sim: float,
                level: int = LEVEL_ROUND, lane: int = 0, **attrs) -> None:
        pass

    def records(self) -> Tuple:
        return ()

    def annotate(self, name: str):
        from contextlib import nullcontext
        return nullcontext()


NOOP = NoopTracer()


class _Span:
    """Open span handle; records one complete record at exit."""
    __slots__ = ("_tracer", "name", "level", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, level: int,
                 attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.level = level
        self.attrs = attrs

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        self._tracer._depth += 1
        return self

    def set(self, **attrs) -> None:
        """Attach attributes discovered while the span is open (byte
        counters, cohort sizes) — they land on the closing record."""
        self.attrs.update(attrs)

    def __exit__(self, *exc):
        tr = self._tracer
        tr._depth -= 1
        t1 = time.perf_counter_ns()
        tr._push({"seq": next(tr._seq), "kind": "span", "name": self.name,
                  "depth": tr._depth, "t_ns": self._t0,
                  "dur_ns": t1 - self._t0, "attrs": self.attrs})
        return False


class Tracer:
    """Span/event flight recorder over a bounded ring buffer.

    `capacity` bounds host memory: the buffer keeps the NEWEST records
    (old ones fall off the front), so a long run's tail is always
    exportable. `records()` returns the live contents in seq order;
    `drain()` additionally empties the buffer.
    """

    def __init__(self, level: int = LEVEL_ROUND, *,
                 capacity: int = 1 << 16, profiler: bool = False):
        if isinstance(level, str):
            level = LEVELS[level]
        self.level = int(level)
        self.profiler = profiler
        self._buf: deque = deque(maxlen=capacity)
        self._seq = itertools.count()
        self._depth = 0
        self.dropped = 0   # records that fell off the ring

    @property
    def enabled(self) -> bool:
        return self.level > LEVEL_OFF

    def _push(self, rec: Dict[str, Any]) -> None:
        if len(self._buf) == self._buf.maxlen:
            self.dropped += 1
        self._buf.append(rec)

    # ------------------------------------------------------------ recording
    def span(self, name: str, level: int = LEVEL_ROUND, **attrs):
        """Nested host-clock span (context manager). The record is pushed
        at EXIT, so a child's record precedes its parent's; `depth` (the
        nesting depth at entry) recovers the tree."""
        if level > self.level:
            return _NOOP_SPAN
        return _Span(self, name, level, attrs)

    def event(self, name: str, level: int = LEVEL_ROUND, **attrs) -> None:
        """Instant host-clock event."""
        if level > self.level:
            return
        self._push({"seq": next(self._seq), "kind": "event", "name": name,
                    "depth": self._depth, "t_ns": time.perf_counter_ns(),
                    "attrs": attrs})

    def event_at(self, name: str, t_sim: float,
                 level: int = LEVEL_ROUND, **attrs) -> None:
        """Instant event on a SIMULATED clock (async runtime seconds)."""
        if level > self.level:
            return
        self._push({"seq": next(self._seq), "kind": "event", "name": name,
                    "depth": self._depth, "t_ns": time.perf_counter_ns(),
                    "t_sim": float(t_sim), "attrs": attrs})

    def span_at(self, name: str, t0_sim: float, t1_sim: float,
                level: int = LEVEL_ROUND, lane: int = 0, **attrs) -> None:
        """Complete span on the simulated clock — e.g. one async client's
        compute+wire interval [dispatch, arrival]. `lane` keys the
        Chrome-trace track (overlapping sim spans need distinct lanes)."""
        if level > self.level:
            return
        self._push({"seq": next(self._seq), "kind": "span", "name": name,
                    "depth": self._depth, "t_ns": time.perf_counter_ns(),
                    "t_sim": float(t0_sim),
                    "dur_sim": float(t1_sim) - float(t0_sim),
                    "lane": int(lane), "attrs": attrs})

    def annotate(self, name: str):
        """Opt-in `jax.profiler.TraceAnnotation` around a jitted step —
        shows up in XLA profiler timelines; a no-op nullcontext unless
        the tracer was built with profiler=True."""
        if not self.profiler:
            from contextlib import nullcontext
            return nullcontext()
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)

    # ------------------------------------------------------------- reading
    def records(self) -> List[Dict[str, Any]]:
        return list(self._buf)

    def drain(self) -> List[Dict[str, Any]]:
        out = list(self._buf)
        self._buf.clear()
        return out


def make_tracer(level: Any = "off", *, capacity: int = 1 << 16,
                profiler: bool = False):
    """`NOOP` for "off"/0/None, a live `Tracer` otherwise — the one
    constructor launchers need."""
    if level in (None, "off", LEVEL_OFF, False):
        return NOOP
    return Tracer(level, capacity=capacity, profiler=profiler)


# ----------------------------------------------------------------- helpers
def strip_times(records: Iterable[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Records minus the host wall-time keys — the determinism view two
    same-seed runs must agree on exactly."""
    return [{k: v for k, v in rec.items() if k not in TIME_KEYS}
            for rec in records]


def sum_stream(records: Iterable[Dict[str, Any]], name: str,
               stream: str) -> float:
    """Fold one byte stream over the named records IN ORDER — the same
    left-to-right float addition `TrafficMeter` performs, so the result
    is comparable to the meter total with ==, not allclose."""
    total = 0.0
    for rec in records:
        if rec.get("name") == name:
            v = rec.get("attrs", {}).get(stream)
            if v is not None:
                total += float(v)
    return total


def to_jsonl(records: Iterable[Dict[str, Any]]) -> str:
    return "".join(json.dumps(rec, sort_keys=True) + "\n"
                   for rec in records)


def span_tree(records: Iterable[Dict[str, Any]]
              ) -> List[Tuple[int, str, Optional[float]]]:
    """(depth, name, dur_ns) per span record, in record order — a cheap
    textual view of the nesting for summaries and tests."""
    return [(rec.get("depth", 0), rec["name"], rec.get("dur_ns"))
            for rec in records if rec.get("kind") == "span"]
