"""Name-based partition rules for the SFPrompt mesh mapping.

Client plane: tensors with a leading client axis (trainable tail/prompt
copies, per-client batches) shard that axis over ('pod', 'data').
Server plane: the frozen body is tensor-parallel over 'model' — attention
projections by heads, MLP by d_ff, MoE by experts, embeddings/LM head by
vocab.

Rules are right-aligned to trailing dims, so the same rule covers a bare
(D, F) leaf and its scan-stacked (n_layers, D, F) form. Every assignment is
divisibility-guarded: a dim that does not divide its mesh axis is replicated
on that axis instead — lowering is correct-by-construction for e.g.
kv_heads=8 on model=16.
"""
from __future__ import annotations

import re
from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

# (path regex, trailing-dims spec). First match wins.
_PARAM_RULES: Tuple[Tuple[str, Tuple], ...] = (
    # --- embeddings / output head: vocab-parallel
    (r"embed/tok$", ("model", None)),
    (r"embed/(patch)/w$", (None, None)),
    (r"embed/(cls|pos)$", (None, None)),
    (r"(^|/)head/w$", (None, "model")),
    # --- MoE experts: expert-parallel
    (r"experts/(up|gate|down)$", ("model", None, None)),
    (r"router/w$", (None, None)),
    # --- attention projections: head-parallel (output dim)
    (r"(q|k|v|q_a|q_b|kv_a|kv_b|cq|ck|cv|g|r)/w$", (None, "model")),
    (r"(o|co)/w$", ("model", None)),
    # --- MLP: d_ff-parallel
    (r"(up|gate|ck)/w$", (None, "model")),
    (r"(down|cv)/w$", ("model", None)),
    # --- mamba2 / rwkv6 projections
    (r"in_proj/w$", (None, "model")),
    (r"out_proj/w$", ("model", None)),
    (r"(w_lora_a|w_lora_b)$", (None, None)),
    # --- everything else (norms, biases, scalars): replicated
)


def _rule_for(path: str) -> Tuple:
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            return spec
    return ()


# Divisibility fallbacks recorded by guard_divisibility: a rule WANTED to
# shard a dim over a mesh axis that exists, but the dim does not divide the
# axis size, so the leaf silently replicated on that axis. Quietly slow on
# a mis-sized mesh — launchers drain this via pop_sharding_fallbacks() and
# report once (launch/mesh.report_sharding_fallbacks).
_SHARDING_FALLBACKS: list = []


def pop_sharding_fallbacks() -> Tuple[Tuple[str, Any, Tuple[int, ...]], ...]:
    """Drain the recorded (path, dropped_axis, shape) divisibility
    fallbacks accumulated by guard_divisibility since the last drain.
    Deduplicated, insertion-ordered. Mesh-absent axis drops (e.g. 'model'
    rules on a data-only host mesh) are intentional and never recorded."""
    seen, out = set(), []
    for entry in _SHARDING_FALLBACKS:
        if entry not in seen:
            seen.add(entry)
            out.append(entry)
    _SHARDING_FALLBACKS.clear()
    return tuple(out)


def format_sharding_fallbacks(entries) -> str:
    """One human-readable line per fallback, for warnings/logs."""
    lines = [f"  {path or '<unnamed>'}: shape {shape} does not divide "
             f"mesh axis {axis!r} — replicated on it instead"
             for path, axis, shape in entries]
    return ("sharding rules fell back to replication on "
            f"{len(entries)} leaf dim(s):\n" + "\n".join(lines))


def report_fallbacks(context: str = "", tracer=None) -> tuple:
    """Drain + surface the recorded fallbacks at one build site.

    The structured path: when a tracer is attached, emit ONE
    `sharding.fallback` event carrying every drained entry (the drain
    dedups, so each build site produces its event exactly once per
    build — pinned by tests/test_obs.py). The `warnings` path stays as
    the always-on fallback so mis-sized meshes are loud even untraced.
    Returns the drained (path, axis, shape) tuples."""
    entries = pop_sharding_fallbacks()
    if entries:
        if tracer is not None and tracer.enabled:
            tracer.event(
                "sharding.fallback", context=context, n=len(entries),
                entries=[[path, str(axis), list(shape)]
                         for path, axis, shape in entries])
        import warnings
        prefix = f"[{context}] " if context else ""
        warnings.warn(prefix + format_sharding_fallbacks(entries),
                      stacklevel=2)
    return entries


def guard_divisibility(spec: Tuple, shape: Tuple[int, ...],
                       mesh: Mesh, *, path: str = None) -> P:
    """Drop axis assignments whose dim is not divisible by the axis size.
    Axes the mesh does not have at all (e.g. 'model' rules on a data-only
    host mesh) are dropped the same way — the rule tables stay mesh-shape
    agnostic and lowering is correct-by-construction. Divisibility drops
    (axis present, dim indivisible) are recorded when `path` is given so
    launchers can surface them (pop_sharding_fallbacks)."""
    out = []
    for dim, axis in zip(shape, spec):
        if axis is None:
            out.append(None)
            continue
        axes = tuple(a for a in
                     (axis if isinstance(axis, tuple) else (axis,))
                     if a in mesh.shape)
        if not axes:
            out.append(None)
            continue
        axis = axes if len(axes) > 1 else axes[0]
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % size == 0 and dim > 0:
            out.append(axis)
        else:
            # dim <= 1 carries nothing to shard — replication is free,
            # not a fallback worth surfacing
            if path is not None and dim > 1:
                _SHARDING_FALLBACKS.append((path, axis, tuple(shape)))
            out.append(None)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def params_pspecs(params_shape: Any, mesh: Mesh, *,
                  client_axis: bool = False, fsdp: bool = False,
                  fsdp_threshold: int = 1 << 21) -> Any:
    """Pytree of PartitionSpec for a (possibly ShapeDtypeStruct) params tree.

    client_axis=True: leaves carry a leading client axis K sharded over
    ('pod', 'data') (whichever exist in the mesh).
    fsdp=True: large leaves additionally shard their biggest still-
    replicated dim over 'data' — 2D weight sharding for the frozen server
    body (FSDP-style storage; XLA chooses gather-weights vs partial-sum
    activations per op)."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    data_axes = data_axes if len(data_axes) > 1 else (
        data_axes[0] if data_axes else None)

    def leaf_spec(path, leaf):
        shape = tuple(leaf.shape)
        spec = tuple(_rule_for(_path_str(path)))
        lead = 1 if client_axis else 0
        # right-align the rule to the trailing dims
        n_lead = len(shape) - len(spec) - lead
        if n_lead < 0:
            spec = spec[-len(shape) + lead:] if len(shape) > lead else ()
            n_lead = len(shape) - len(spec) - lead
        full = ((data_axes,) if client_axis else ()) + \
            (None,) * n_lead + spec
        guarded = list(guard_divisibility(full, shape, mesh,
                                          path=_path_str(path)))
        guarded += [None] * (len(shape) - len(guarded))

        if (fsdp and not client_axis and "data" in mesh.shape
                and int(np.prod(shape, dtype=np.int64)) >= fsdp_threshold):
            dsize = mesh.shape["data"]
            for i in sorted(range(len(shape)), key=lambda i: -shape[i]):
                if guarded[i] is None and shape[i] % dsize == 0 \
                        and shape[i] >= dsize:
                    guarded[i] = "data"
                    break
        return P(*guarded)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def cohort_pspecs(cohort_shape: Any, mesh: Mesh) -> Any:
    """Pytree of PartitionSpec for COHORT tensors — anything carrying a
    leading client axis K (stacked per-client trainables/opt state, the
    gathered (K, n_local, ...) client data, (K,) participation vectors).
    The K axis shards over the client plane ('pod','data' — whichever the
    mesh has); every other dim is replicated. Divisibility-guarded, so a
    K that does not divide the plane falls back to replication instead of
    failing to lower."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    data_axes = data_axes if len(data_axes) > 1 else (
        data_axes[0] if data_axes else None)

    def leaf_spec(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        spec = (data_axes,) + (None,) * (len(shape) - 1)
        return guard_divisibility(spec, shape, mesh)

    return jax.tree.map(leaf_spec, cohort_shape)


def batch_pspec(batch_shape: Any, mesh: Mesh, *,
                client_axis: bool = False) -> Any:
    """Batch tensors: leading (K?) and batch dims shard over ('pod','data');
    everything else replicated."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    data_axes = data_axes if len(data_axes) > 1 else (
        data_axes[0] if data_axes else None)

    def leaf_spec(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        spec = (data_axes,) + (None,) * (len(shape) - 1)
        return guard_divisibility(spec, shape, mesh)

    return jax.tree.map(leaf_spec, batch_shape)


def cache_pspecs(cache_shape: Any, mesh: Mesh, *,
                 paged: bool = False) -> Any:
    """KV/state caches: (n_layers, B, W, heads, dh)-style leaves — batch dim
    (axis 1) over ('pod','data'); the heads/latent dim over 'model' when
    divisible.

    paged=True: the leaves are a PAGE POOL — (n_layers, n_pages, page_size,
    heads, dh). Axis 1 is pages, not batch, and must stay REPLICATED over
    the client plane: any slot's block table may point at any page (COW
    shared prefixes make pages genuinely global), so there is no stable
    page->device mapping. Only the kv-heads dim shards (over 'model'), so
    paged decode attention runs head-parallel exactly like dense."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    data_axes = data_axes if len(data_axes) > 1 else (
        data_axes[0] if data_axes else None)

    def leaf_spec(path, leaf):
        shape = tuple(leaf.shape)
        name = _path_str(path)
        if len(shape) < 2:
            return P(*([None] * len(shape)))
        spec = [None] * len(shape)
        if not paged:
            spec[1] = data_axes                  # batch (slot) dim
        if re.search(r"(^|/)(k|v)$", name) and len(shape) == 5:
            spec[3] = "model"                    # kv heads
        if not paged:
            if re.search(r"(^|/)ssm$", name) and len(shape) == 5:
                spec[2] = "model"                # mamba heads
            if re.search(r"(^|/)state$", name) and len(shape) == 5:
                spec[2] = "model"                # rwkv heads
        return guard_divisibility(tuple(spec), shape, mesh, path=name)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)
