from repro.sharding.rules import (  # noqa: F401
    batch_pspec, cache_pspecs, cohort_pspecs, params_pspecs,
    guard_divisibility, format_sharding_fallbacks, pop_sharding_fallbacks)
