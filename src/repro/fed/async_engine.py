"""AsyncRoundEngine: buffered asynchronous federated runtime.

The synchronous `FederatedEngine` runs a barrier per round: the server
waits for the whole cohort (minus dropouts) before aggregating, so one
slow WAN client stalls everyone. This engine removes the barrier,
FedBuff-style (Nguyen et al., AISTATS'22):

    dispatch groups of clients on their own simulated clocks
    ->  each finishes its phase-2/3 update after its own latency
        (wire + compute, from the SAME per-client persistent factors the
        RoundScheduler uses)
    ->  its (tail, prompt) delta lands in a bounded `DeltaBuffer`
    ->  every `buffer_size` arrivals the server FLUSHES: one
        staleness-weighted `fedavg_partial` (or secure-agg cohort) over
        the buffered contributions, producing the next model version.

Staleness: a contribution computed against version v and applied at
version V has staleness s = V - v, weighted alpha / (1 + s)^beta
(`fed.buffer.staleness_weight`). The flush is the aggregation unit —
secure aggregation, DP metering, and FedAvg weighting all see one flush
exactly as they would see one synchronous round.

Bit-identity contract (test-pinned): with `buffer_size == K` (one
dispatch group fills the buffer), `concurrency=1` and `staleness_beta=0`
every contribution has staleness 0 and the flush reproduces the
synchronous round's aggregated params AND metered bytes bit-exactly.
This works because dispatch runs the SAME compiled `SFPromptTrainer`
round (`client_updates` — all-zero aggregate weights), the flush drains
in dispatch order (not arrival order, so the float-sum order matches the
synchronous vmap), and the flush weight `keep * size * 1.0` equals the
synchronous `float32(keep) * aggregate` weight vector element-for-element.

Resume: `save()`/`restore()` checkpoint the buffer contents, every
in-flight client's computed contribution and absolute finish time, the
staleness ledger, and the simulated clock — a killed-and-restarted run
replays every subsequent arrival, flush, and metered byte byte-identically
(contributions are stored, not recomputed, so no RNG replay is needed).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load_latest, save_checkpoint
from repro.core.aggregation import get_aggregator
from repro.fed.buffer import (BufferEntry, DeltaBuffer, StalenessLedger,
                              flush_weights)
from repro.fed.population import Population
from repro.fed.sampler import ClientSampler
from repro.fed.scheduler import (LINK_REGIMES, FullParticipationScheduler,
                                 RoundScheduler)
from repro.obs.trace import NOOP
from repro.runtime.meter import EDGE, PARAMS, SECURE, TrafficMeter

# RNG domain tag for async dispatch jitter/dropout draws — disjoint from
# the sampler's (3, 5) and the scheduler's (7, 11); see fed/sampler.py on
# SeedSequence trailing-zero dropping (tags must be non-zero).
ASYNC_TAG = 13


@dataclass(frozen=True)
class AsyncConfig:
    """Knobs of the buffered async runtime.

    buffer_size        — arrivals per flush (the aggregation cohort K').
    concurrency        — dispatch groups in flight at once; 1 degenerates
                         to "one group computes while none queue", >= 2
                         overlaps client compute across groups (the
                         async win).
    group_size         — clients per dispatch group; defaults to the
                         sampler's cohort size when 0.
    staleness_alpha/beta — flush weight alpha / (1+s)^beta. beta=0 turns
                         staleness discounting off (pure FedBuff-with-
                         uniform-weights; required for the bit-identity
                         test).
    server_flops_per_param — aggregation cost model for the meter's
                         server_busy_s stream: flushing E entries over
                         n_trainable params costs E * n * this / P_S
                         seconds at the regime's server FLOP rate.
    """
    buffer_size: int = 5
    concurrency: int = 2
    group_size: int = 0
    staleness_alpha: float = 1.0
    staleness_beta: float = 0.5
    server_flops_per_param: float = 6.0

    def __post_init__(self):
        if self.buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got "
                             f"{self.buffer_size}")
        if self.concurrency < 1:
            raise ValueError(f"concurrency must be >= 1, got "
                             f"{self.concurrency}")
        if self.group_size < 0:
            raise ValueError(f"group_size must be >= 0, got "
                             f"{self.group_size}")
        if self.staleness_alpha <= 0:
            raise ValueError("staleness_alpha must be > 0")
        if self.staleness_beta < 0:
            raise ValueError("staleness_beta must be >= 0")


@dataclass
class _InFlight:
    """One dispatched client whose (already computed) contribution has not
    yet arrived at the server, keyed by its simulated finish time."""
    client_id: int
    dispatch_idx: int
    position: int          # slot within the dispatch group's K-axis
    version: int           # model version the contribution was computed on
    finish_t: float        # absolute simulated arrival time
    dropped: bool          # died mid-round -> zero-weight passenger row
    transmit_frac: float   # fraction of its uplink bytes that made it
    size: float            # true local sample count (FedAvg weight)
    keep: int              # post-pruning trained sample count
    contribution: Any      # host-numpy {"tail","prompt"} pytree

    def order_key(self) -> Tuple[int, int]:
        return (self.dispatch_idx, self.position)


def trainer_fingerprint(trainer) -> np.int64:
    """CRC of the trainer's hyperparameter dataclasses (ProtocolConfig /
    BaselineConfig, SplitConfig, ModelConfig reprs, wire + aggregator
    descriptors) — checkpointed so a resume with changed flags fails
    loudly. Shared by FederatedEngine and AsyncRoundEngine so the two
    runtimes reject each other's checkpoints only on REAL config drift."""
    parts = []
    for attr in ("pcfg", "bcfg"):
        if hasattr(trainer, attr):
            parts.append(repr(getattr(trainer, attr)))
    model = getattr(trainer, "model", None)
    if model is not None:
        parts.append(repr(getattr(model, "split", None)))
        parts.append(repr(getattr(model, "cfg", None)))
        parts.append(model.wire.describe())
    aggregator = getattr(trainer, "aggregator", None)
    if aggregator is not None:
        parts.append(aggregator.describe())
    return np.int64(zlib.crc32("|".join(parts).encode()))


class AsyncRoundEngine:
    """Event-driven buffered-async driver. See module docstring.

    `trainer=None` enables CLOCK-ONLY mode: no model, no contributions —
    dispatch/arrival/flush advance the simulated clock and the meter's
    wall streams only. `benchmarks/async_rounds.py` uses it to measure
    round-throughput against the synchronous barrier without paying for
    actual training steps.
    """

    def __init__(self, trainer, population: Optional[Population],
                 sampler: ClientSampler,
                 scheduler: Optional[RoundScheduler] = None,
                 acfg: AsyncConfig = AsyncConfig(), *,
                 aggregator=None, tracer=None):
        self.trainer = trainer
        self.population = population
        self.sampler = sampler
        self.acfg = acfg
        self.scheduler = scheduler or FullParticipationScheduler(
            seed=sampler.seed)
        if population is not None and (
                sampler.n_clients != population.n_clients):
            raise ValueError(
                f"sampler over {sampler.n_clients} clients but population "
                f"has {population.n_clients}")
        if acfg.group_size > sampler.k:
            raise ValueError(
                f"group_size={acfg.group_size} exceeds the sampler's "
                f"cohort size k={sampler.k}")
        if trainer is not None:
            if not getattr(trainer, "supports_partial", False):
                raise ValueError(
                    f"{type(trainer).__name__} cannot run async dispatch — "
                    "it has no participation-weight path (FL/SFL baselines "
                    "are synchronous by construction)")
            if not getattr(trainer.pcfg, "return_client_trainable", False):
                raise ValueError(
                    "async dispatch needs ProtocolConfig("
                    "return_client_trainable=True): the engine aggregates "
                    "at flush time, from per-client (tail, prompt) updates")
            if population is None:
                raise ValueError("a trainer needs a population to gather "
                                 "client data from")
            inner = getattr(trainer, "aggregator", None)
            if inner is not None and inner.name != "clear":
                raise ValueError(
                    "build the trainer with the CLEAR aggregator and pass "
                    "secure aggregation to AsyncRoundEngine(aggregator=...) "
                    "— the flush, not the dispatch round, is the secure-agg "
                    "cohort")
        # flush-time aggregator; the cohort is the buffer's entry count
        self.aggregator = aggregator or get_aggregator(
            cohort_size=acfg.buffer_size)
        # a metered flush aggregator (secure/hierarchical) bills its own
        # uplink (masked ring tensors) at flush time; the clear path bills
        # plain f32 bytes at each arrival — mirrors the sync protocol's
        # agg_wire branch
        self._flush_metered = self.aggregator.name != "clear"
        # clock-only mode owns its meter; otherwise bill the trainer's
        self.meter = (getattr(trainer, "meter", None)
                      or TrafficMeter()) if trainer is not None \
            else TrafficMeter()
        # flight recorder: async records ride the SIMULATED clock
        # (event_at/span_at with t_sim) — a wall-clock trace of a
        # simulation would be meaningless. Inherit the trainer's tracer
        # unless one is passed explicitly.
        if tracer is None and trainer is not None:
            tracer = getattr(trainer, "tracer", None)
        self.tracer = tracer if tracer is not None else NOOP
        self.meter.attach_tracer(self.tracer)

        self.state: Optional[Dict[str, Any]] = None
        self.version = 0           # flush count == model version
        self.dispatch_idx = 0      # dispatch groups launched so far
        self.t_sim = 0.0           # simulated wall clock (seconds)
        self.arrivals = 0          # live contributions received, ever
        self.buffer = DeltaBuffer(buffer_size=acfg.buffer_size,
                                  tracer=self.tracer)
        self.in_flight: List[_InFlight] = []
        n = sampler.n_clients
        self.ledger = StalenessLedger(n)
        self.flush_history: list = []   # (version, n_live, mean_staleness)
        self._span_mark = 0.0      # t_sim at last wall absorb

    # --------------------------------------------------------------- state
    def init(self, key) -> None:
        if self.trainer is not None:
            self.state = self.trainer.init(key)
        else:
            self.state = {"round": jnp.int32(0)}
        self.version = 0
        self.dispatch_idx = 0
        self.t_sim = 0.0
        self.arrivals = 0
        self._span_mark = 0.0

    @property
    def params(self):
        return self.state["params"] if self.trainer is not None else None

    def _group_size(self) -> int:
        return self.acfg.group_size or self.sampler.k

    # ------------------------------------------------------------ dispatch
    def _dispatch_rng(self, d: int) -> np.random.Generator:
        return np.random.default_rng(np.random.SeedSequence(
            (self.sampler.seed & 0xFFFFFFFF, ASYNC_TAG, d)))

    def dispatch_group(self) -> None:
        """Launch one group: sample clients, run their phase-2/3 updates
        against the CURRENT params (version v), and queue the resulting
        contributions with per-client simulated finish times. The server
        does not wait — the group's arrivals interleave with other
        groups' and with flushes."""
        d = self.dispatch_idx
        cohort = np.asarray(self.sampler.sample(d), dtype=np.int64)
        # group_size < sampler.k: dispatch a prefix of the sampled cohort
        # (benchmarks use small groups to decouple flush cadence from K)
        cohort = cohort[:self._group_size()]
        k = len(cohort)
        rng = self._dispatch_rng(d)
        cfg = self.scheduler.cfg

        jitter = np.exp(rng.normal(0.0, cfg.jitter_sigma, size=k))
        wire, comp = self.scheduler.client_latency_parts(cohort)
        latency = (wire + comp) * jitter
        dropped = rng.random(k) < cfg.dropout_rate
        died_frac = rng.random(k)     # where in its round a dying client is
        # min_survivors: the fastest clients always deliver (mirrors
        # RoundScheduler.plan — keeps every flush non-degenerate)
        need = max(0, min(cfg.min_survivors, k) - int((~dropped).sum()))
        if need > 0:
            for idx in np.argsort(latency):
                if not dropped[idx]:
                    continue
                dropped[idx] = False
                need -= 1
                if need == 0:
                    break
        transmit = np.ones(k, dtype=np.float32)
        transmit[dropped] = np.clip(died_frac[dropped], 0.0, 1.0)
        finish = self.t_sim + np.where(dropped, died_frac * latency, latency)

        sizes = (self.population.cohort_sizes(cohort).astype(np.float64)
                 if self.population is not None
                 else np.ones(k, dtype=np.float64))

        contributions = [None] * k
        keep = 0
        if self.trainer is not None:
            data = {kk: jnp.asarray(v) for kk, v in
                    self.population.gather(cohort).items()}
            n_local = jax.tree.leaves(data)[0].shape[1]
            keep = self.trainer.phase2_keep(n_local)
            # the dispatch group reuses the synchronous trainer's compiled
            # round with all-zero AGGREGATE weights: params stay untouched
            # (fedavg_partial falls back bit-exactly), per-client updates
            # come back on the K-axis, and only the downlink is billed
            # (transmit carries the straggler-scaled phase-2 bytes)
            per_client, metrics = self.trainer.client_updates(
                dict(self.state, round=jnp.int32(d)),
                data, jnp.asarray(transmit))
            self.last_dispatch_metrics = metrics
            host = jax.tree.map(np.asarray, per_client)
            contributions = [
                jax.tree.map(lambda x: x[i], host) for i in range(k)]
        else:
            # clock-only: bill the downlink the protocol would have
            self.meter.absorb({PARAMS: k * self._param_bytes()},
                              clients=0)

        tracer = self.tracer
        if tracer.enabled:
            tracer.event_at("async.dispatch", self.t_sim, group=d,
                            cohort=k, version=self.version)
        for i in range(k):
            self.in_flight.append(_InFlight(
                client_id=int(cohort[i]), dispatch_idx=d, position=i,
                version=self.version, finish_t=float(finish[i]),
                dropped=bool(dropped[i]),
                transmit_frac=float(transmit[i]),
                size=float(sizes[i]), keep=int(keep),
                contribution=contributions[i]))
            if tracer.enabled:
                # one sim-clock span per in-flight client, laned by client
                # id so overlapping flights stack in the Chrome trace
                tracer.span_at("async.client", self.t_sim,
                               float(finish[i]), level=2,
                               lane=int(cohort[i]), group=d,
                               version=self.version,
                               dropped=bool(dropped[i]))
        # wall accounting: the group's client compute and wire time happen
        # regardless of when the server looks at the results; dying
        # clients only burn their fraction
        frac = np.where(dropped, died_frac, 1.0)
        self.meter.absorb_wall(
            client_compute_s=float((comp * jitter * frac).sum()),
            wire_s=float((wire * jitter * frac).sum()))
        self.dispatch_idx = d + 1

    def _param_bytes(self) -> float:
        """Downlink/uplink bytes of one (tail, prompt) transfer. With a
        trainer this is metered by the protocol itself; clock-only mode
        approximates it from the scheduler's per-client round bytes."""
        return float(self.scheduler.round_bytes)

    # --------------------------------------------------------------- event
    def _pump(self) -> None:
        """Keep `concurrency` dispatch groups in flight."""
        while True:
            groups = {f.dispatch_idx for f in self.in_flight}
            if len(groups) >= self.acfg.concurrency:
                return
            self.dispatch_group()

    def step_event(self) -> bool:
        """Advance the simulated clock to the next arrival, move that
        contribution into the buffer (dropped clients become zero-weight
        passenger rows), flush if full. Returns True when a flush
        happened."""
        self._pump()
        # earliest finish; ties broken by dispatch order for determinism
        nxt = min(self.in_flight,
                  key=lambda f: (f.finish_t,) + f.order_key())
        self.in_flight.remove(nxt)
        self.t_sim = max(self.t_sim, nxt.finish_t)
        if self.tracer.enabled:
            self.tracer.event_at(
                "async.arrival", self.t_sim, client=nxt.client_id,
                group=nxt.dispatch_idx, version=nxt.version,
                staleness=self.version - nxt.version, dropped=nxt.dropped)
        self.buffer.append(BufferEntry(
            client_id=nxt.client_id, dispatch_idx=nxt.dispatch_idx,
            position=nxt.position, version=nxt.version, size=nxt.size,
            keep=nxt.keep, contribution=nxt.contribution,
            arrival_t=self.t_sim, dropped=nxt.dropped))
        if not nxt.dropped:
            self.arrivals += 1
            if not self._flush_metered:
                # uplink lands NOW — the dispatch round billed downlink
                # only (aggregate weights were all zero), so sync and
                # async meter identical `params` totals: (K + n_up) * pb
                self.meter.absorb(
                    {PARAMS: nxt.transmit_frac * self._up_bytes()},
                    clients=1)
            else:
                # secure/hierarchical flushes meter their own uplink
                # (masked ring tensors) in _flush; only count the client
                self.meter.absorb({}, clients=1)
        if self.buffer.full:
            self._flush()
            return True
        return False

    def _up_bytes(self) -> float:
        """One client's phase-3 uplink as the sync protocol meters it:
        the byte size of the (tail, prompt) globals."""
        if self.trainer is None:
            return self._param_bytes()
        return float(sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(
                {"tail": self.state["params"]["tail"],
                 "prompt": self.state["params"]["prompt"]})))

    # --------------------------------------------------------------- flush
    def _flush(self) -> None:
        acfg = self.acfg
        entries = self.buffer.drain()    # dispatch order, NOT arrival order
        live = [e for e in entries if not e.dropped]
        weights = flush_weights(entries, alpha=acfg.staleness_alpha,
                                beta=acfg.staleness_beta,
                                version=self.version)
        if self.trainer is not None:
            stacked = DeltaBuffer.stacked(entries)
            stacked = jax.tree.map(jnp.asarray, stacked)
            fallback = {k: self.state["params"][k] for k in stacked}
            new_globals, wire = self.aggregator.aggregate(
                stacked, jnp.asarray(weights), fallback, self.version)
            params = dict(self.state["params"])
            params.update(jax.tree.map(jnp.asarray, new_globals))
            self.state = dict(self.state, params=params)
            if wire:
                # metered aggregator: the masked/hierarchical uplink plus
                # key-agreement / escrow-reveal overhead (arrivals did not
                # bill params when _flush_metered — see step_event)
                counts = {PARAMS: float(wire.get("params_up", 0.0))}
                for stream in (SECURE, EDGE):
                    if stream in wire:
                        counts[stream] = float(wire[stream])
                self.meter.absorb(counts, clients=0)
            if self.population is not None:
                ids = np.asarray([e.client_id for e in live],
                                 dtype=np.int64)
                self.population.record_participation(ids, self.version)
        # staleness bookkeeping + server busy time
        for e in live:
            self.ledger.record(e.client_id, self.version - e.version)
        stale = [self.version - e.version for e in live]
        self.flush_history.append(
            (self.version, len(live),
             float(np.mean(stale)) if stale else 0.0))
        regime = LINK_REGIMES[self.scheduler.cfg.regime]
        n_param = (self._up_bytes() / 4.0 if self.trainer is not None
                   else self._param_bytes() / 4.0)
        busy = (acfg.server_flops_per_param * n_param * len(entries)
                / regime["P_S"])
        span = self.t_sim - self._span_mark
        self._span_mark = self.t_sim
        self.meter.absorb_wall(server_busy_s=busy, span_s=span)
        if self.tracer.enabled:
            self.tracer.event_at(
                "async.flush", self.t_sim, version=self.version,
                n_entries=len(entries), n_live=len(live),
                mean_staleness=float(np.mean(stale)) if stale else 0.0,
                server_busy_s=busy)
        self.version += 1

    def run_flushes(self, n_flushes: int) -> Dict[str, float]:
        """Advance the event loop until `n_flushes` more flushes land.
        Returns summary metrics of the span just simulated."""
        if self.state is None:
            raise RuntimeError("call init(key) or restore(ckpt_dir) first")
        t0, v0, a0 = self.t_sim, self.version, self.arrivals
        while self.version < v0 + n_flushes:
            self.step_event()
        dt = max(self.t_sim - t0, 1e-12)
        return {"flushes": float(self.version - v0),
                "arrivals": float(self.arrivals - a0),
                "sim_seconds": self.t_sim - t0,
                "flushes_per_s": (self.version - v0) / dt,
                "mean_staleness": self.ledger.mean_staleness(),
                "max_staleness": float(self.ledger.max_staleness)}

    # ------------------------------------------------------------- resume
    def _pack_flight(self, recs: Sequence[Any]) -> Dict[str, Any]:
        """BufferEntry/_InFlight lists -> nested npz-able dict. Keys are
        zero-padded indices so checkpoint.io's sorted '/'-flattening
        restores the original order."""
        out: Dict[str, Any] = {}
        for i, r in enumerate(recs):
            rec: Dict[str, Any] = {
                "client_id": np.int64(r.client_id),
                "dispatch_idx": np.int64(r.dispatch_idx),
                "position": np.int64(r.position),
                "version": np.int64(r.version),
                "size": np.float64(r.size),
                "keep": np.int64(r.keep),
                "dropped": np.int64(int(r.dropped)),
            }
            if isinstance(r, BufferEntry):
                rec["arrival_t"] = np.float64(r.arrival_t)
            else:
                rec["finish_t"] = np.float64(r.finish_t)
                rec["transmit_frac"] = np.float64(r.transmit_frac)
            if r.contribution is not None:
                rec["contribution"] = jax.tree.map(np.asarray,
                                                   r.contribution)
            out[f"{i:05d}"] = rec
        return out

    def _acfg_state(self) -> Dict[str, np.float64]:
        return {"buffer_size": np.float64(self.acfg.buffer_size),
                "concurrency": np.float64(self.acfg.concurrency),
                "group_size": np.float64(self.acfg.group_size),
                "staleness_alpha": np.float64(self.acfg.staleness_alpha),
                "staleness_beta": np.float64(self.acfg.staleness_beta),
                "server_flops_per_param":
                    np.float64(self.acfg.server_flops_per_param)}

    def _run_state(self) -> Dict[str, Any]:
        state: Dict[str, Any] = {
            "trainer": self.state,
            "version": np.int64(self.version),
            "dispatch_idx": np.int64(self.dispatch_idx),
            "t_sim": np.float64(self.t_sim),
            "arrivals": np.int64(self.arrivals),
            "span_mark": np.float64(self._span_mark),
            "acfg": self._acfg_state(),
            "sampler": self.sampler.state_dict(),
            "scheduler": {k: np.float64(v) for k, v in
                          self.scheduler.state_dict().items()},
            "ledger": self.ledger.state_dict(),
            "meter": self.meter.state_dict(),
            "buffer": self._pack_flight(self.buffer.entries),
            "in_flight": self._pack_flight(
                sorted(self.in_flight, key=_InFlight.order_key)),
            "agg_crc": np.int64(zlib.crc32(
                self.aggregator.describe().encode())),
        }
        if self.trainer is not None:
            state["trainer_fingerprint"] = trainer_fingerprint(self.trainer)
        if self.population is not None:
            state["population"] = self.population.state_dict()
        return state

    def save(self, ckpt_dir: str, *, keep_last: Optional[int] = 3) -> str:
        """Atomic full-run checkpoint INCLUDING the buffer and in-flight
        clients — resume replays arrivals/flushes byte-identically."""
        return save_checkpoint(ckpt_dir, self._run_state(),
                               step=self.version, keep_last=keep_last)

    def restore(self, ckpt_dir: str) -> bool:
        run = load_latest(ckpt_dir)
        if run is None:
            return False
        saved_acfg = {k: float(np.asarray(v))
                      for k, v in run["acfg"].items()}
        diff = {k: (saved_acfg.get(k), float(v))
                for k, v in self._acfg_state().items()
                if saved_acfg.get(k) != float(v)}
        if diff:
            raise ValueError(
                f"async config mismatch on resume: checkpoint vs engine "
                f"differ on {diff} — rebuild with the original async flags")
        if "trainer_fingerprint" in run:
            if self.trainer is None:
                raise ValueError("checkpoint was written with a trainer; "
                                 "this engine is clock-only")
            if int(run["trainer_fingerprint"]) != int(
                    trainer_fingerprint(self.trainer)):
                raise ValueError(
                    "trainer mismatch on resume: the checkpoint was "
                    "written with different hyperparameters — rebuild the "
                    "trainer with the original flags")
        elif self.trainer is not None:
            raise ValueError("clock-only checkpoint resumed with a "
                             "trainer — params would be uninitialized")
        if int(run["agg_crc"]) != zlib.crc32(
                self.aggregator.describe().encode()):
            raise ValueError(
                "flush aggregator mismatch on resume (clear vs secure, or "
                "different masking params) — replayed flushes would "
                "diverge")
        self.state = jax.tree.map(jnp.asarray, run["trainer"])
        self.version = int(run["version"])
        self.dispatch_idx = int(run["dispatch_idx"])
        self.t_sim = float(run["t_sim"])
        self.arrivals = int(run["arrivals"])
        self._span_mark = float(run["span_mark"])
        self.sampler.load_state_dict(run["sampler"])
        self.scheduler.load_state_dict(run["scheduler"])
        self.ledger.load_state_dict(run["ledger"])
        from repro.fed.engine import _flatten_numeric
        self.meter.load_state_dict(_flatten_numeric(run["meter"]))
        if self.population is not None and "population" in run:
            self.population.load_state_dict(run["population"])

        def _unpack(packed, cls):
            recs = []
            # empty dicts vanish in npz flattening: absent key == empty
            for _, rec in sorted((packed or {}).items()):
                contrib = rec.get("contribution")
                if contrib is not None:
                    contrib = jax.tree.map(np.asarray, contrib)
                common = dict(
                    client_id=int(rec["client_id"]),
                    dispatch_idx=int(rec["dispatch_idx"]),
                    position=int(rec["position"]),
                    version=int(rec["version"]),
                    size=float(rec["size"]), keep=int(rec["keep"]),
                    dropped=bool(int(rec["dropped"])),
                    contribution=contrib)
                if cls is BufferEntry:
                    recs.append(BufferEntry(
                        arrival_t=float(rec["arrival_t"]), **common))
                else:
                    recs.append(_InFlight(
                        finish_t=float(rec["finish_t"]),
                        transmit_frac=float(rec["transmit_frac"]),
                        **common))
            return recs

        self.buffer = DeltaBuffer(buffer_size=self.acfg.buffer_size,
                                  entries=_unpack(run.get("buffer"),
                                                  BufferEntry),
                                  tracer=self.tracer)
        self.in_flight = _unpack(run.get("in_flight"), _InFlight)
        self.flush_history = []
        return True
