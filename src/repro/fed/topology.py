"""Edge topology for hierarchical (client -> edge -> global) aggregation.

A mega-cohort of K clients does not report to one server: clients attach to
E edge aggregators (cell towers, campus gateways), each edge reduces its
own sub-cohort first, and only the E edge means cross the backhaul to the
global server. Per-edge reduction composes with the privacy engine — each
edge runs its OWN masked `SecureAggregator` instance (pairwise masks only
among that edge's clients, so key agreement costs sum(k_e^2) pubkeys
instead of K^2) — and with `fedavg_partial`'s survivor renormalization: an
edge whose clients all dropped contributes weight 0 and is excluded at the
global tier; when every edge drops, the round falls back to the pre-round
globals exactly like the flat path.

Trust boundary: the edge sees its sub-cohort's AGGREGATE (never an
individual client's update under secure aggregation — masks cancel only in
the sum), and the global server sees only edge means. Edge means travel
the backhaul in clear fp32 — the `edge_global` TrafficMeter stream meters
exactly (E + live_edges) * param_bytes per round, the analytical
counterpart being `core.comm.hierarchical_edge_breakdown`.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import fedavg_partial
from repro.runtime.boundary import Boundary
from repro.runtime.codec import get_codec
from repro.runtime.meter import EDGE, SECURE


class EdgeTopology:
    """Static position -> edge assignment for a K-cohort.

    Edges are contiguous equal-size blocks of the cohort axis (K % E == 0),
    so every per-edge slice is static under jit and shards cleanly on the
    client plane of the device mesh."""

    def __init__(self, k: int, n_edges: int):
        if k <= 0 or n_edges <= 0:
            raise ValueError(
                f"EdgeTopology needs positive sizes, got K={k}, "
                f"n_edges={n_edges}")
        if n_edges > k:
            raise ValueError(
                f"more edges ({n_edges}) than clients (K={k}) — every edge "
                "needs at least one client")
        if k % n_edges != 0:
            raise ValueError(
                f"K={k} not divisible by n_edges={n_edges}: edges are "
                "contiguous equal blocks so per-edge slices stay static "
                "under jit")
        self.k = k
        self.n_edges = n_edges
        self.edge_size = k // n_edges
        self.assignment = np.repeat(np.arange(n_edges), self.edge_size)

    def members(self, e: int) -> slice:
        """Cohort-position slice of edge e (contiguous by construction)."""
        return slice(e * self.edge_size, (e + 1) * self.edge_size)

    def describe(self) -> str:
        return f"edges={self.n_edges}x{self.edge_size}"


class HierarchicalAggregator:
    """Two-tier aggregation behind the pluggable phase-3 contract.

    Tier 1: each edge reduces its sub-cohort through its own inner
    aggregator (clear `fedavg_partial` or a per-edge `SecureAggregator`
    seeded seed+e so no two edges share a mask stream). Tier 2: the E edge
    means FedAvg with weights W_e = the edge's surviving weight mass —
    algebraically the flat survivor-weighted mean, so the flat and
    hierarchical rounds agree up to float reassociation.

    Wire dict: `params_up` sums the per-edge client uplinks (secure path
    only — the clear path keeps the protocol's seed-exact accounting),
    `secure` sums per-edge key agreement + escrow reveals, and
    `edge_global` meters the backhaul: each LIVE edge uploads its fp32
    mean, and the new globals broadcast down to all E edges."""

    name = "hierarchical"

    def __init__(self, topology: EdgeTopology, *, secure: bool = False,
                 **kw):
        from repro.privacy.secure_agg import ClearAggregator, SecureAggregator
        self.topology = topology
        self.secure = secure
        if secure:
            seed = kw.pop("seed", 0)
            self.edge_aggs = [SecureAggregator(seed=seed + e, **kw)
                              for e in range(topology.n_edges)]
        else:
            if kw:
                raise ValueError(
                    f"clear hierarchical aggregation takes no options "
                    f"beyond the topology, got {kw}")
            self.edge_aggs = [ClearAggregator()
                              for _ in range(topology.n_edges)]
        self.edge_boundary = Boundary(EDGE, get_codec("raw"))

    def describe(self) -> str:
        return (f"hier({self.topology.describe()}; "
                f"edge={self.edge_aggs[0].describe()})")

    def aggregate(self, client_trees, weights: jnp.ndarray, fallback,
                  round_idx) -> Tuple[Any, Dict[str, jnp.ndarray]]:
        topo = self.topology
        k = jax.tree.leaves(client_trees)[0].shape[0]
        if k != topo.k:
            raise ValueError(
                f"cohort of {k} clients under a {topo.describe()} topology "
                f"laid out for K={topo.k}")
        w = weights.astype(jnp.float32)

        edge_means, edge_weights = [], []
        wire: Dict[str, jnp.ndarray] = {}
        for e, agg in enumerate(self.edge_aggs):
            sl = topo.members(e)
            sub = jax.tree.map(lambda x: x[sl], client_trees)
            mean_e, wire_e = agg.aggregate(sub, w[sl], fallback, round_idx)
            edge_means.append(mean_e)
            edge_weights.append(w[sl].sum())
            for name, b in wire_e.items():
                wire[name] = wire.get(name, jnp.float32(0.0)) + b

        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *edge_means)
        w_edge = jnp.stack(edge_weights)
        out = fedavg_partial(stacked, w_edge, fallback)

        # ---- backhaul metering: live edges upload their fp32 mean, the
        # new globals go down to every edge (live or not — an edge must
        # serve next round's cohort either way)
        live = (w_edge > 0).sum().astype(jnp.float32)
        flat_mean = jnp.concatenate(
            [x.reshape(-1).astype(jnp.float32)
             for x in jax.tree.leaves(edge_means[0])])
        _, b_up_one = self.edge_boundary.transmit(
            flat_mean[None, :], train=False)
        wire[EDGE] = (live + topo.n_edges) * b_up_one
        return out, wire
