"""Federated population engine: N >> K clients, sampled cohorts,
stragglers, and resumable rounds.

  population.py — `Population`: the base dataset once + per-client index
                  arrays (Dirichlet / IID from data/federated.py) and
                  per-client persistent state, incl. personalized tails.
  sampler.py    — `ClientSampler`: uniform / weighted / round-robin cohort
                  draws, pure functions of (seed, round) => trivially
                  checkpointable.
  scheduler.py  — `RoundScheduler` + `StragglerConfig`: per-client latency
                  (LINK_REGIMES, shared with benchmarks/latency_model.py),
                  deadlines, dropouts; emits the participation arrays the
                  protocol's partial FedAvg and wire metering consume.
  engine.py     — `FederatedEngine`: the sample -> gather -> schedule ->
                  train -> checkpoint loop, resumable byte-identically.
  topology.py   — `EdgeTopology` + `HierarchicalAggregator`: two-tier
                  (client -> edge -> global) aggregation with per-edge
                  secure-agg instances and metered backhaul bytes.
  buffer.py     — `DeltaBuffer` + `StalenessLedger` + staleness weights:
                  the bounded arrival buffer the async runtime flushes.
  async_engine.py — `AsyncRoundEngine` + `AsyncConfig`: barrier-free
                  buffered-async driver (FedBuff-style), clients on their
                  own simulated clocks, staleness-weighted flushes.
"""
from repro.fed.async_engine import (  # noqa: F401
    AsyncConfig, AsyncRoundEngine)
from repro.fed.buffer import (  # noqa: F401
    BufferEntry, DeltaBuffer, StalenessLedger, staleness_weight)
from repro.fed.engine import FederatedEngine  # noqa: F401
from repro.fed.population import Population  # noqa: F401
from repro.fed.sampler import SAMPLER_KINDS, ClientSampler  # noqa: F401
from repro.fed.scheduler import (  # noqa: F401
    LINK_REGIMES, FullParticipationScheduler, RoundPlan, RoundScheduler,
    StragglerConfig)
from repro.fed.topology import (  # noqa: F401
    EdgeTopology, HierarchicalAggregator)
