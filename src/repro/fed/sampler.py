"""Cohort sampling: which K of the N-client population train this round.

Every strategy is a pure function of (seed, round_idx) — there is no hidden
mutable PRNG. That is the property that makes a killed run resumable
byte-identically: the sampler's entire "position" is the integer round
counter the engine checkpoints, and replaying round r after a restart
re-derives exactly the cohort the uninterrupted run would have drawn.

Strategies:
  uniform     — without-replacement uniform draw per round.
  weighted    — without-replacement draw proportional to per-client weights
                (typically the TRUE pre-padding sample counts from the
                Dirichlet partition, so data-rich clients are seen more).
  round_robin — a fixed seed-derived permutation walked K clients at a
                time; every client is visited once per N/K rounds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

SAMPLER_KINDS = ("uniform", "weighted", "round_robin")


# RNG domain tags. SeedSequence drops trailing zero entropy words, so a
# bare (seed, round) stream would COLLIDE with the scheduler's
# (seed, 7, cid=0) / (seed, 11, round=0) streams at round 7 / 11 — every
# fed RNG domain therefore gets its own non-zero tag in the SECOND word:
# sampler rounds = 3, round-robin permutation = 5, scheduler client
# factors = 7, scheduler round stream = 11.
_DOMAIN_ROUND = 3
_DOMAIN_PERM = 5


def _round_rng(seed: int, round_idx: int) -> np.random.Generator:
    """Independent stream per (seed, round): SeedSequence hashes the
    tagged tuple, so nearby rounds — and the scheduler's streams — are
    uncorrelated."""
    return np.random.default_rng(
        np.random.SeedSequence((seed & 0xFFFFFFFF, _DOMAIN_ROUND,
                                round_idx)))


@dataclass
class ClientSampler:
    n_clients: int
    k: int
    kind: str = "uniform"
    seed: int = 0
    weights: Optional[np.ndarray] = None   # (N,) for kind="weighted"

    def __post_init__(self):
        if self.kind not in SAMPLER_KINDS:
            raise ValueError(f"unknown sampler kind {self.kind!r}; "
                             f"expected one of {SAMPLER_KINDS}")
        if self.k > self.n_clients:
            raise ValueError(f"k={self.k} > population {self.n_clients}")
        if self.kind == "weighted":
            if self.weights is None:
                raise ValueError("kind='weighted' needs per-client weights")
            w = np.asarray(self.weights, dtype=np.float64)
            if w.shape != (self.n_clients,) or (w < 0).any() or w.sum() <= 0:
                raise ValueError("weights must be (N,) non-negative with "
                                 "positive sum")
            self.weights = w
        if self.kind == "round_robin":
            # one fixed shuffle of the population; the cursor is derived
            # from round_idx so it needs no state of its own
            self._order = np.random.default_rng(
                np.random.SeedSequence(
                    (self.seed & 0xFFFFFFFF, _DOMAIN_PERM))).permutation(
                    self.n_clients)

    # ----------------------------------------------------------- sampling
    def sample(self, round_idx: int) -> np.ndarray:
        """-> (K,) distinct client ids for this round."""
        if self.kind == "round_robin":
            start = (round_idx * self.k) % self.n_clients
            pos = (start + np.arange(self.k)) % self.n_clients
            return np.asarray(self._order[pos], dtype=np.int64)
        rng = _round_rng(self.seed, round_idx)
        if self.kind == "weighted":
            p = self.weights / self.weights.sum()
            return np.asarray(
                rng.choice(self.n_clients, size=self.k, replace=False, p=p),
                dtype=np.int64)
        return np.asarray(
            rng.choice(self.n_clients, size=self.k, replace=False),
            dtype=np.int64)

    # ------------------------------------------------------------- resume
    def state_dict(self) -> Dict[str, np.ndarray]:
        """Everything needed to re-derive every future draw. The engine
        checkpoints this next to params and meter totals."""
        return {"seed": np.int64(self.seed),
                "n_clients": np.int64(self.n_clients),
                "k": np.int64(self.k),
                "kind_id": np.int64(SAMPLER_KINDS.index(self.kind))}

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> None:
        got = (int(state["n_clients"]), int(state["k"]),
               SAMPLER_KINDS[int(state["kind_id"])])
        want = (self.n_clients, self.k, self.kind)
        if got != want:
            raise ValueError(
                f"sampler mismatch: checkpoint has (N, K, kind)={got}, "
                f"engine was built with {want}")
        self.seed = int(state["seed"])
        if self.kind == "round_robin":
            self.__post_init__()   # rebuild the seed-derived order
