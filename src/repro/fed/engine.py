"""FederatedEngine: population-scale driver for the SFPrompt protocol.

One object owns the full loop the launcher used to hand-roll:

    sample cohort (ClientSampler)  ->  gather data (Population)
    ->  simulate stragglers (RoundScheduler)  ->  train the cohort
    (SFPromptTrainer._round, vmapped K-axis intact)  ->  write back
    per-client state  ->  checkpoint.

and makes the whole thing RESUMABLE: `save()` writes params, the round
counter, the TrafficMeter totals, the sampler position, and the
population's per-client state into one atomic npz; `restore()` brings a
killed run back to a state from which every subsequent round — sampled
cohort, straggler plan, parameter update, metered bytes — is byte-identical
to the uninterrupted run (samplers and schedulers are pure functions of
(seed, round), so the round counter IS their PRNG position).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load_latest, save_checkpoint
from repro.fed.population import Population
from repro.fed.sampler import ClientSampler
from repro.fed.scheduler import (FullParticipationScheduler, RoundPlan,
                                 RoundScheduler)


class FederatedEngine:
    def __init__(self, trainer, population: Population,
                 sampler: ClientSampler,
                 scheduler: Optional[RoundScheduler] = None, *,
                 personalize_tails: bool = False):
        if sampler.n_clients != population.n_clients:
            raise ValueError(
                f"sampler over {sampler.n_clients} clients but population "
                f"has {population.n_clients}")
        self.trainer = trainer
        self.population = population
        self.sampler = sampler
        if scheduler is not None and not getattr(
                trainer, "supports_partial", False):
            raise ValueError(
                f"{type(trainer).__name__} trains its cohort synchronously "
                "and cannot honor a straggler plan — omit the scheduler "
                "(FL/SFL baselines always run full participation)")
        self.scheduler = scheduler or FullParticipationScheduler(
            seed=sampler.seed)
        if personalize_tails and not getattr(
                getattr(trainer, "pcfg", None), "return_client_trainable",
                False):
            raise ValueError(
                "personalize_tails=True needs a trainer built with "
                "ProtocolConfig(return_client_trainable=True) so per-client "
                "tails survive the round")
        self.personalize_tails = personalize_tails
        self.round_idx = 0
        self.state: Optional[Dict[str, Any]] = None
        self.cohort_history: list = []   # per-round sampled ids (this run)

    # --------------------------------------------------------------- state
    def init(self, key) -> None:
        self.state = self.trainer.init(key)
        self.round_idx = 0

    @property
    def params(self):
        return self.state["params"]

    # --------------------------------------------------------------- round
    def run_round(self) -> Tuple[RoundPlan, Dict[str, float]]:
        """Sample -> gather -> schedule -> train -> write back. Returns the
        straggler plan and the trainer metrics for the round."""
        if self.state is None:
            raise RuntimeError("call init(key) or restore(ckpt_dir) first")
        r = self.round_idx
        cohort = self.sampler.sample(r)
        plan = self.scheduler.plan(cohort, r)
        data = {k: jnp.asarray(v) for k, v in
                self.population.gather(cohort).items()}

        if getattr(self.trainer, "supports_partial", False):
            part = plan.participation()
            # paper Eq. 3: FedAvg weighted by TRUE per-client sample counts
            # (pre-padding Dirichlet sizes), folded into the participation
            # weight — fedavg_partial normalizes, so only ratios matter
            part["aggregate"] = (part["aggregate"] *
                                 self.population.cohort_sizes(cohort)
                                 .astype(np.float32))
            part = {k: jnp.asarray(v) for k, v in part.items()}
            init_tails = None
            if self.personalize_tails:
                # each sampled client resumes from its OWN last tail
                # (global tail for the never-sampled); FedAvg still feeds
                # the shared global tail every round
                per_client = self.population.get_tails(
                    cohort, self.state["params"]["tail"])
                if per_client is not None:
                    init_tails = jax.tree.map(
                        lambda *xs: jnp.stack(
                            [jnp.asarray(x) for x in xs]), *per_client)
            self.state, metrics = self.trainer.round(self.state, data, part,
                                                     init_tails)
        else:
            # baselines (FL / SFL) predate partial participation: they run
            # the cohort synchronously and ignore the straggler plan
            self.state, metrics = self.trainer.round(self.state, data)

        if self.personalize_tails:
            per_client = getattr(self.trainer, "last_client_trainable", None)
            if per_client is not None:
                # survivors keep their own post-round tail (pre-FedAvg) —
                # the personalized-tail regime of the hetero plans
                active_ids = cohort[plan.aggregate > 0]
                pos = np.flatnonzero(plan.aggregate > 0)
                tails = jax.tree.map(lambda x: np.asarray(x)[pos],
                                     per_client["tail"])
                self.population.set_tails(active_ids, tails)

        active = plan.aggregate > 0
        self.population.record_participation(cohort[active], r)
        metrics["cohort/sampled"] = float(len(cohort))
        metrics["cohort/dropped"] = float(plan.dropped.sum())
        metrics["cohort/late"] = float(plan.late.sum())
        self.cohort_history.append(np.asarray(cohort))
        self.round_idx = r + 1
        return plan, metrics

    # ------------------------------------------------------------- resume
    def _trainer_fingerprint(self) -> np.int64:
        """CRC of the trainer's hyperparameter dataclasses — checkpointed
        so a resume with changed --lr/--gamma/--prompt-len/... fails
        loudly like the sampler/scheduler/population mismatches do.
        Shared with the async runtime: `fed.async_engine
        .trainer_fingerprint` is the single definition."""
        from repro.fed.async_engine import trainer_fingerprint
        return trainer_fingerprint(self.trainer)

    def _run_state(self) -> Dict[str, Any]:
        state: Dict[str, Any] = {
            "trainer": self.state,
            "round_idx": np.int64(self.round_idx),
            "sampler": self.sampler.state_dict(),
            "scheduler": {k: np.float64(v) for k, v in
                          self.scheduler.state_dict().items()},
            "personalize_tails": np.int64(int(self.personalize_tails)),
            "trainer_fingerprint": self._trainer_fingerprint(),
            "population": self.population.state_dict(),
        }
        meter = getattr(self.trainer, "meter", None)
        if meter is not None:
            state["meter"] = meter.state_dict()
        accountant = getattr(self.trainer, "accountant", None)
        if accountant is not None:
            # the zCDP ledger rides the checkpoint as float64 scalars —
            # npz round-trips them byte-identically, so the resumed run's
            # epsilon is exactly the uninterrupted run's
            state["privacy"] = accountant.state_dict()
        return state

    def save(self, ckpt_dir: str, *, keep_last: Optional[int] = 3) -> str:
        """Atomic full-run checkpoint; safe to call every round."""
        return save_checkpoint(ckpt_dir, self._run_state(),
                               step=self.round_idx, keep_last=keep_last)

    def restore(self, ckpt_dir: str) -> bool:
        """Resume from the newest checkpoint in `ckpt_dir`. Returns False
        (engine untouched) when the directory holds none."""
        run = load_latest(ckpt_dir)
        if run is None:
            return False
        trainer_state = jax.tree.map(jnp.asarray, run["trainer"])
        # round rides in the trainer state as int32; npz round-trips dtypes
        # exactly, so the restored pytree is bit-identical to the saved one
        self.state = trainer_state
        self.round_idx = int(run["round_idx"])
        self.sampler.load_state_dict(run["sampler"])
        if "scheduler" in run:
            self.scheduler.load_state_dict(run["scheduler"])
        if "personalize_tails" in run:
            saved = bool(int(run["personalize_tails"]))
            if saved != self.personalize_tails:
                raise ValueError(
                    f"personalize_tails mismatch on resume: checkpoint was "
                    f"written with {saved}, engine built with "
                    f"{self.personalize_tails} — the replayed rounds would "
                    f"silently diverge")
        if "trainer_fingerprint" in run:
            saved_fp = int(run["trainer_fingerprint"])
            if saved_fp != int(self._trainer_fingerprint()):
                raise ValueError(
                    "trainer mismatch on resume: the checkpoint was "
                    "written with different hyperparameters (protocol / "
                    "split / model config or wire codec) — rebuild the "
                    "trainer with the original flags")
        self.population.load_state_dict(run["population"])
        if self.personalize_tails and "params" in trainer_state:
            self.population.restore_tails(trainer_state["params"]["tail"])
        meter = getattr(self.trainer, "meter", None)
        if meter is not None and "meter" in run:
            meter.load_state_dict(_flatten_numeric(run["meter"]))
        accountant = getattr(self.trainer, "accountant", None)
        if accountant is not None:
            if "privacy" not in run:
                raise ValueError(
                    "DP trainer resumed from a checkpoint with no privacy "
                    "ledger — the pre-checkpoint releases would be "
                    "unaccounted; resume with the original DP flags")
            accountant.load_state_dict(run["privacy"])
        self.cohort_history = []
        return True


def _flatten_numeric(tree: Dict[str, Any], prefix: str = "") -> Dict[str, float]:
    """checkpoint.io round-trips nested dicts; the meter's state_dict is
    flat with '/'-keys — re-flatten what load produced."""
    out: Dict[str, float] = {}
    for k, v in tree.items():
        if isinstance(v, dict):
            out.update(_flatten_numeric(v, f"{prefix}{k}/"))
        else:
            out[f"{prefix}{k}"] = float(np.asarray(v))
    return out
