"""RoundScheduler: stragglers, dropouts, and deadline-bounded rounds.

Real federated cohorts are not synchronous: devices differ in compute and
link rate by orders of magnitude (persistent heterogeneity), each round adds
transient jitter, and some devices die mid-round. The scheduler turns a
sampled cohort into a `RoundPlan` the protocol can consume without leaving
its jitted, vmapped K-axis:

  transmit[k]  in [0, 1] — fraction of client k's phase-2 wire traffic that
               actually crossed the boundaries before it finished, dropped,
               or hit the deadline.  The protocol scales measured per-client
               bytes by this, so the TrafficMeter absorbs exactly the
               partial-cohort traffic.
  aggregate[k] >= 0      — client k's inclusion weight in phase-3 FedAvg
               (1 on-time, 0 dropped, `partial_weight` for late clients
               under late_mode="partial" — paper-Table-1 FedAvg corrected
               for partial participation in `core/aggregation.py`).

Latencies come from the same per-round cost model as the Table-1 analysis
(`core/comm.py`): comm at the regime link rate + client compute at the
regime FLOP rate, scaled by a per-client persistent speed factor and
per-round lognormal jitter. `LINK_REGIMES` is the single source of truth
for the regime constants; `benchmarks/latency_model.py` imports it.

Everything is a pure function of (seed, round_idx, cohort) — resumable runs
replay identical plans. RNG domain tags 7 (per-client factors) and 11
(per-round stream) keep these streams disjoint from the sampler's
(tags 3/5 — see fed/sampler.py on SeedSequence trailing-zero dropping).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence

import numpy as np

# Link-rate / compute regimes (bytes/s, FLOP/s) used across the Table-1
# latency analysis and the straggler simulation.  R is the shared uplink;
# P_C / P_S are client / server compute rates.  NOTE: resume encodes the
# regime as an index into sorted(LINK_REGIMES), so new regimes must sort
# AFTER the existing three (datacenter, edge_wan, fiber) or old
# checkpoints mis-map — "wan" does.
LINK_REGIMES: Dict[str, Dict[str, float]] = {
    "edge_wan": dict(R=12.5e6, P_C=5e12, P_S=500e12),      # 100 Mbps
    "fiber": dict(R=125e6, P_C=5e12, P_S=500e12),          # 1 Gbps
    "datacenter": dict(R=12.5e9, P_C=50e12, P_S=5000e12),
    "wan": dict(R=3.125e6, P_C=5e12, P_S=500e12),          # 25 Mbps consumer
}

LATE_MODES = ("drop", "partial")


@dataclass(frozen=True)
class StragglerConfig:
    regime: str = "fiber"          # key into LINK_REGIMES
    deadline_factor: float = 1.5   # deadline = factor * cohort median latency
    dropout_rate: float = 0.0      # P(device dies mid-round), iid per client
    speed_sigma: float = 0.4       # lognormal sigma of PERSISTENT compute speed
    link_sigma: float = 0.8        # lognormal sigma of PERSISTENT link rate
    #   (links vary more than silicon: the same fleet spans fiber and 3G)
    jitter_sigma: float = 0.15     # lognormal sigma of per-ROUND jitter
    late_mode: str = "drop"        # what happens past the deadline
    partial_weight: float = 0.5    # FedAvg weight of late clients (partial)
    min_survivors: int = 1         # fastest clients forced on-time if needed

    def __post_init__(self):
        if self.regime not in LINK_REGIMES:
            raise ValueError(f"unknown regime {self.regime!r}; expected one "
                             f"of {sorted(LINK_REGIMES)}")
        if self.late_mode not in LATE_MODES:
            raise ValueError(f"unknown late_mode {self.late_mode!r}")


@dataclass
class RoundPlan:
    cohort: np.ndarray       # (K,) client ids
    latency_s: np.ndarray    # (K,) simulated wall time to finish the round
    deadline_s: float
    transmit: np.ndarray     # (K,) float32, fraction of wire bytes sent
    aggregate: np.ndarray    # (K,) float32, FedAvg inclusion weight
    dropped: np.ndarray      # (K,) bool — died mid-round
    late: np.ndarray         # (K,) bool — finished after the deadline

    @property
    def n_active(self) -> int:
        return int((self.aggregate > 0).sum())

    def participation(self) -> Dict[str, np.ndarray]:
        """The two arrays `SFPromptTrainer.round` consumes."""
        return {"transmit": self.transmit.astype(np.float32),
                "aggregate": self.aggregate.astype(np.float32)}


class RoundScheduler:
    """Simulates one deadline-bounded round over a sampled cohort."""

    def __init__(self, cfg: StragglerConfig = StragglerConfig(), *,
                 seed: int = 0,
                 round_bytes_per_client: float = 1e6,
                 round_flops_per_client: float = 1e12):
        self.cfg = cfg
        self.seed = seed
        self.round_bytes = float(round_bytes_per_client)
        self.round_flops = float(round_flops_per_client)

    # ------------------------------------------------------------ latency
    def client_factors(self, client_ids: np.ndarray):
        """Persistent per-client (link_slowdown, compute_slowdown) — median
        1, the same device is slow in every round it is sampled. Link and
        compute draw INDEPENDENTLY, so which devices straggle depends on
        the regime's comm-vs-compute mix: on edge_wan the slow-link devices
        miss deadlines, in a datacenter the slow-compute ones do."""
        link = np.empty(len(client_ids), dtype=np.float64)
        comp = np.empty(len(client_ids), dtype=np.float64)
        for i, cid in enumerate(np.asarray(client_ids, dtype=np.int64)):
            rng = np.random.default_rng(
                np.random.SeedSequence(
                    (self.seed & 0xFFFFFFFF, 7, int(cid))))
            link[i] = np.exp(rng.normal(0.0, self.cfg.link_sigma))
            comp[i] = np.exp(rng.normal(0.0, self.cfg.speed_sigma))
        return link, comp

    def client_latency_parts(self, client_ids: np.ndarray):
        """(wire_s, compute_s) per client BEFORE jitter — the two addends
        of `client_latency`, kept separate so the async runtime can bill
        wire time and client compute time into the TrafficMeter's
        wall-clock overlap streams independently."""
        regime = LINK_REGIMES[self.cfg.regime]
        t_comm = self.round_bytes / regime["R"]
        t_comp = self.round_flops / regime["P_C"]
        link, comp = self.client_factors(client_ids)
        return t_comm * link, t_comp * comp

    def client_latency(self, client_ids: np.ndarray) -> np.ndarray:
        """Expected round latency per client (no jitter): the Table-1 cost
        split — bytes over the regime link rate plus FLOPs over the regime
        client compute — scaled by that client's persistent factors."""
        wire, comp = self.client_latency_parts(client_ids)
        return wire + comp

    # --------------------------------------------------------------- plan
    def plan(self, cohort: Sequence[int], round_idx: int) -> RoundPlan:
        cfg = self.cfg
        cohort = np.asarray(cohort, dtype=np.int64)
        k = len(cohort)
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed & 0xFFFFFFFF, 11, round_idx)))

        jitter = np.exp(rng.normal(0.0, cfg.jitter_sigma, size=k))
        latency = self.client_latency(cohort) * jitter
        deadline = cfg.deadline_factor * float(np.median(latency))

        dropped = rng.random(k) < cfg.dropout_rate
        # a dying device stops at a uniform point of its own round
        died_at = rng.random(k) * latency
        late = (~dropped) & (latency > deadline)

        # min_survivors: force the fastest clients through (re-transmission
        # in a real system; keeps FedAvg well-defined here)
        ok = (~dropped) & (~late)
        need = max(0, min(cfg.min_survivors, k) - int(ok.sum()))
        if need > 0:
            for idx in np.argsort(latency):
                if ok[idx]:
                    continue
                dropped[idx] = late[idx] = False
                ok[idx] = True
                need -= 1
                if need == 0:
                    break

        transmit = np.ones(k)
        aggregate = np.ones(k)
        # dropped: sent the fraction of phase-2 traffic reached when it died
        transmit[dropped] = np.clip(died_at[dropped] / latency[dropped],
                                    0.0, 1.0)
        aggregate[dropped] = 0.0
        if cfg.late_mode == "drop":
            # late clients finished transmitting up to the deadline cut-off
            transmit[late] = np.clip(deadline / latency[late], 0.0, 1.0)
            aggregate[late] = 0.0
        else:
            aggregate[late] = cfg.partial_weight   # sent everything, late
        return RoundPlan(cohort=cohort, latency_s=latency,
                         deadline_s=deadline,
                         transmit=transmit.astype(np.float32),
                         aggregate=aggregate.astype(np.float32),
                         dropped=dropped, late=late)

    # ------------------------------------------------------------- resume
    def state_dict(self) -> Dict[str, float]:
        """Everything a replayed plan depends on. Checkpointed so a resume
        with different straggler flags fails loudly instead of silently
        diverging from the uninterrupted run."""
        cfg = self.cfg
        return {"seed": float(self.seed),
                "regime_id": float(sorted(LINK_REGIMES).index(cfg.regime)),
                "deadline_factor": cfg.deadline_factor,
                "dropout_rate": cfg.dropout_rate,
                "speed_sigma": cfg.speed_sigma,
                "link_sigma": cfg.link_sigma,
                "jitter_sigma": cfg.jitter_sigma,
                "late_mode_id": float(LATE_MODES.index(cfg.late_mode)),
                "partial_weight": cfg.partial_weight,
                "min_survivors": float(cfg.min_survivors),
                "round_bytes": self.round_bytes,
                "round_flops": self.round_flops}

    def load_state_dict(self, state: Dict[str, float]) -> None:
        got = {k: float(np.asarray(v)) for k, v in state.items()}
        want = self.state_dict()
        diff = {k: (got.get(k), want[k]) for k in want
                if got.get(k) != want[k]}
        if diff:
            raise ValueError(
                f"scheduler mismatch on resume: checkpoint vs engine "
                f"differ on {diff} — rebuild the engine with the original "
                f"straggler flags")


class FullParticipationScheduler(RoundScheduler):
    """Every client on time — the seed repo's implicit assumption."""

    def __init__(self, *, seed: int = 0):
        super().__init__(StragglerConfig(dropout_rate=0.0,
                                         deadline_factor=1e9), seed=seed)

    def plan(self, cohort: Sequence[int], round_idx: int) -> RoundPlan:
        cohort = np.asarray(cohort, dtype=np.int64)
        k = len(cohort)
        ones = np.ones(k, dtype=np.float32)
        return RoundPlan(cohort=cohort, latency_s=np.zeros(k),
                         deadline_s=float("inf"), transmit=ones.copy(),
                         aggregate=ones.copy(),
                         dropped=np.zeros(k, dtype=bool),
                         late=np.zeros(k, dtype=bool))
