"""Bounded delta buffer + staleness ledger for the async federated runtime.

FedBuff-style buffered aggregation decouples the server's update cadence
from the slowest client: sampled clients stream their (tail, prompt)
contributions as their own simulated clocks finish, the server appends
each arrival to a bounded `DeltaBuffer`, and every `buffer_size` arrivals
the buffer FLUSHES — one staleness-weighted aggregation over exactly the
buffered cohort. The flush is the aggregation unit: it is what the
pluggable aggregators (clear / masked secure / hierarchical) see, what
the params wire stream bills, and what the checkpoint serializes.

Staleness of a contribution is the number of flushes the server applied
between the client's dispatch and its arrival; the weight

    staleness_weight(s) = alpha / (1 + s) ** beta

down-weights stale contributions smoothly (s = 0 => alpha, so with the
default alpha = 1 a zero-staleness flush is weight-identical to the
synchronous round — the normalized aggregation cancels alpha, which is
kept for FedBuff-compatibility of the config surface).

Ordering invariant: `stacked()` sorts entries by dispatch order
(dispatch_idx, position-in-group), NOT arrival order, so the flushed
float sum is invariant to how arrivals interleaved — and bit-identical
to the synchronous vmapped round when the buffer holds exactly one
zero-staleness dispatch group.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def staleness_weight(staleness, *, alpha: float = 1.0,
                     beta: float = 0.5):
    """alpha / (1 + s)^beta — monotonically non-increasing in s for
    beta >= 0, strictly decreasing for beta > 0. Accepts scalars or
    arrays; s must be >= 0 (a contribution cannot arrive before its own
    dispatch)."""
    s = np.asarray(staleness, dtype=np.float64)
    if (s < 0).any():
        raise ValueError(f"staleness must be >= 0, got {staleness}")
    return alpha / np.power(1.0 + s, beta)


@dataclass
class BufferEntry:
    """One streamed client contribution awaiting the next flush."""
    client_id: int
    dispatch_idx: int        # which dispatch group produced it
    position: int            # row within that group's vmapped cohort
    version: int             # server version (flush count) at dispatch
    size: int                # true pre-padding sample count (FedAvg n_k)
    keep: int                # post-pruning samples that trained phase 2
    contribution: Any        # (tail, prompt) pytree, host numpy leaves
    arrival_t: float = 0.0   # simulated wall clock of the arrival
    dropped: bool = False    # died after upload: weight 0, mask recovery

    def order_key(self):
        return (self.dispatch_idx, self.position)


@dataclass
class DeltaBuffer:
    """Bounded arrival buffer; `full` triggers the engine's flush.

    `tracer` (optional, a repro.obs Tracer) records append/drain as
    step-level events on the SIMULATED clock (each entry's arrival_t) —
    set by the owning engine, never checkpointed."""
    buffer_size: int
    entries: List[BufferEntry] = field(default_factory=list)
    tracer: Any = None

    def __post_init__(self):
        if self.buffer_size < 1:
            raise ValueError(
                f"buffer_size must be >= 1, got {self.buffer_size}")

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def full(self) -> bool:
        # dropped rows ride along for mask recovery but do not count
        # toward the flush trigger — only genuine arrivals fill the buffer
        return self.n_live >= self.buffer_size

    @property
    def n_live(self) -> int:
        return sum(not e.dropped for e in self.entries)

    def append(self, entry: BufferEntry) -> None:
        self.entries.append(entry)
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.event_at(
                "buffer.append", entry.arrival_t, level=2,
                client=entry.client_id, group=entry.dispatch_idx,
                version=entry.version, dropped=entry.dropped,
                fill=self.n_live)

    def drain(self) -> List[BufferEntry]:
        """Pop every entry in DISPATCH order (see module docstring)."""
        out = sorted(self.entries, key=BufferEntry.order_key)
        self.entries = []
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.event("buffer.drain", level=2, n=len(out),
                              n_live=sum(not e.dropped for e in out))
        return out

    @staticmethod
    def stacked(entries: List[BufferEntry]):
        """Stack the drained entries' contributions into one tree with a
        leading cohort axis — the exact layout `fedavg_partial` and the
        secure aggregator consume."""
        if not entries:
            raise ValueError("cannot stack an empty flush cohort")
        return jax.tree.map(lambda *xs: np.stack(xs),
                            *[e.contribution for e in entries])


class StalenessLedger:
    """Per-run staleness bookkeeping, checkpointed with the engine.

    Tracks how many contributions were applied at each staleness, the
    running staleness sum (for the mean), and each client's last applied
    staleness — the observability surface the async docs and benchmarks
    report from, and part of the byte-identical resume contract (a
    restored run's ledger continues exactly where the killed run's was).
    """

    def __init__(self, n_clients: int):
        self.n_clients = int(n_clients)
        self.applied = 0
        self.staleness_sum = 0.0
        self.max_staleness = 0
        self.last_staleness = np.full((self.n_clients,), -1, dtype=np.int64)

    def record(self, client_id: int, staleness: int) -> None:
        self.applied += 1
        self.staleness_sum += float(staleness)
        self.max_staleness = max(self.max_staleness, int(staleness))
        self.last_staleness[int(client_id)] = int(staleness)

    def mean_staleness(self) -> float:
        return self.staleness_sum / max(1, self.applied)

    # ------------------------------------------------------------- resume
    def state_dict(self) -> Dict[str, np.ndarray]:
        return {"n_clients": np.int64(self.n_clients),
                "applied": np.int64(self.applied),
                "staleness_sum": np.float64(self.staleness_sum),
                "max_staleness": np.int64(self.max_staleness),
                "last_staleness": self.last_staleness.copy()}

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> None:
        if int(state["n_clients"]) != self.n_clients:
            raise ValueError(
                f"staleness ledger mismatch on resume: checkpoint covers "
                f"{int(state['n_clients'])} clients, engine has "
                f"{self.n_clients}")
        self.applied = int(state["applied"])
        self.staleness_sum = float(state["staleness_sum"])
        self.max_staleness = int(state["max_staleness"])
        self.last_staleness = np.asarray(state["last_staleness"],
                                         dtype=np.int64).copy()


def flush_weights(entries: List[BufferEntry], *, alpha: float,
                  beta: float, version: int) -> np.ndarray:
    """The (B,) aggregation weight vector of one flush cohort:

        w_i = keep_i * size_i * staleness_weight(version - version_i)

    `keep * size` mirrors the synchronous round's weighting exactly (the
    engine folds true sample counts into `aggregate`, the protocol
    multiplies by the post-pruning keep count), so a zero-staleness flush
    at alpha = 1 hands `fedavg_partial` the SAME weight vector as the
    synchronous barrier — bit-identical aggregation, not just allclose.
    Dropped rows (mask-recovery passengers) are forced to 0."""
    s = np.array([version - e.version for e in entries], dtype=np.float64)
    w = np.array([e.keep * e.size for e in entries], dtype=np.float64)
    w = w * staleness_weight(s, alpha=alpha, beta=beta)
    w[[e.dropped for e in entries]] = 0.0
    return w.astype(np.float32)
