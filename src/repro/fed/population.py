"""Population: the full N-client federation, of which only K train per round.

The seed repo stacked ALL clients into one (K, n, ...) tensor — fine for the
paper's K=5 reproduction, a dead end at population scale. A `Population`
instead holds the base dataset ONCE plus per-client index arrays (from
`data/federated.py`'s `iid_indices` / `dirichlet_indices`), and materializes
only the sampled cohort via `gather()`. Memory is O(dataset + N) instead of
O(N * dataset); the cohort tensor stays exactly the (K, n_local, ...) layout
`SFPromptTrainer._round` vmaps over.

Per-client PERSISTENT state rides along:
  * `sizes`        — true pre-padding sample counts (FedAvg / weighted
                     sampling weights),
  * `times_sampled`, `last_round` — participation bookkeeping,
  * optional personalized tails (`set_tails`/`get_tails`): the post-round,
    pre-aggregation tail of each sampled client, in the style of the hetero
    plans' personalized tails (flexible personalized split FL,
    arXiv:2508.10349) — clients keep a private tail while the prompt and
    the aggregated global tail stay shared.
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.data.federated import dirichlet_indices, iid_indices


class Population:
    def __init__(self, data: Dict[str, np.ndarray],
                 client_indices: Sequence[np.ndarray],
                 sizes: Optional[np.ndarray] = None):
        lens = {len(idx) for idx in client_indices}
        if len(lens) != 1:
            raise ValueError(f"client index arrays must share one length "
                             f"for stacking; got {sorted(lens)}")
        self.data = data
        self.client_indices = [np.asarray(i, dtype=np.int64)
                               for i in client_indices]
        self.n_clients = len(client_indices)
        self.n_local = lens.pop()
        self.sizes = (np.asarray(sizes, dtype=np.int64) if sizes is not None
                      else np.full((self.n_clients,), self.n_local,
                                   dtype=np.int64))
        self.times_sampled = np.zeros((self.n_clients,), dtype=np.int64)
        self.last_round = np.full((self.n_clients,), -1, dtype=np.int64)
        self._tails: Dict[int, Dict] = {}   # cid -> personalized tail pytree

    # ------------------------------------------------------- construction
    @classmethod
    def from_partition(cls, data: Dict[str, np.ndarray], n_clients: int, *,
                       scheme: str = "iid", alpha: float = 0.1,
                       seed: int = 0, label_key: str = "labels",
                       ) -> "Population":
        n = len(next(iter(data.values())))
        if n // n_clients < 1:
            raise ValueError(
                f"population of {n_clients} clients needs at least one "
                f"sample per client; dataset has only {n}")
        if scheme == "dirichlet":
            idx, sizes = dirichlet_indices(data[label_key], n_clients,
                                           alpha=alpha, seed=seed)
        elif scheme == "iid":
            idx, sizes = iid_indices(n, n_clients, seed=seed)
        else:
            raise ValueError(f"unknown scheme {scheme!r}")
        return cls(data, idx, sizes)

    @classmethod
    def from_client_list(cls, clients: Sequence[Dict[str, np.ndarray]],
                         ) -> "Population":
        """Adapt the legacy materialized form (list of per-client dicts)."""
        data = {k: np.concatenate([c[k] for c in clients])
                for k in clients[0]}
        sizes = [len(next(iter(c.values()))) for c in clients]
        offsets = np.concatenate([[0], np.cumsum(sizes)])
        idx = [np.arange(offsets[i], offsets[i + 1], dtype=np.int64)
               for i in range(len(clients))]
        return cls(data, idx, np.asarray(sizes))

    # ------------------------------------------------------------- cohort
    def gather(self, cohort: Sequence[int]) -> Dict[str, np.ndarray]:
        """Materialize the sampled cohort: (K, n_local, ...) per key."""
        rows = np.stack([self.client_indices[int(c)] for c in cohort])
        return {k: v[rows] for k, v in self.data.items()}

    def cohort_sizes(self, cohort: Sequence[int]) -> np.ndarray:
        return self.sizes[np.asarray(cohort, dtype=np.int64)]

    def record_participation(self, cohort: Sequence[int],
                             round_idx: int) -> None:
        ids = np.asarray(cohort, dtype=np.int64)
        self.times_sampled[ids] += 1
        self.last_round[ids] = round_idx

    # ------------------------------------------------- personalized tails
    def set_tails(self, cohort: Sequence[int], stacked_tail) -> None:
        """Store each sampled client's post-training tail (leading K axis
        on every leaf of `stacked_tail`)."""
        for pos, cid in enumerate(cohort):
            self._tails[int(cid)] = jax.tree.map(
                lambda x: np.asarray(x[pos]), stacked_tail)

    def get_tails(self, cohort: Sequence[int], default_tail,
                  *, always: bool = False) -> Optional[List]:
        """Per-client tails for a cohort (global tail for never-sampled
        clients); None if no client has a personalized tail yet.
        `always=True` returns the default-filled list even when nothing is
        personalized — the serving TenantBank wants one entry per tenant
        regardless."""
        if not self._tails and not always:
            return None
        return [self._tails.get(int(c), default_tail) for c in cohort]

    # ------------------------------------------------------------- resume
    def fingerprint(self) -> Dict[str, np.ndarray]:
        """Cheap identity of the partition a run was trained on: client
        count, shard size, and CRCs of the index arrays / true sizes.
        Checkpointed so a resume against a REBUILT population with
        different data flags fails loudly instead of silently replaying
        rounds on different client data."""
        idx_crc = 0
        for idx in self.client_indices:
            idx_crc = zlib.crc32(idx.tobytes(), idx_crc)
        shape_crc = 0
        for k in sorted(self.data):
            v = self.data[k]
            shape_crc = zlib.crc32(
                f"{k}:{v.shape}:{v.dtype}".encode(), shape_crc)
        return {"n_clients": np.int64(self.n_clients),
                "n_local": np.int64(self.n_local),
                "sizes_crc": np.int64(zlib.crc32(self.sizes.tobytes())),
                "indices_crc": np.int64(idx_crc),
                "data_shape_crc": np.int64(shape_crc)}

    def state_dict(self) -> Dict:
        """Nested dict of arrays — round-trips through checkpoint/io.py
        verbatim. Personalized tails are stored as leaf lists per client id
        (`restore_tails` rebuilds the pytree structure from a template)."""
        state: Dict = {
            "times_sampled": self.times_sampled.copy(),
            "last_round": self.last_round.copy(),
            "fingerprint": self.fingerprint(),
        }
        if self._tails:
            state["tails"] = {
                f"{cid:08d}": {str(i): np.asarray(leaf) for i, leaf in
                               enumerate(jax.tree.leaves(tail))}
                for cid, tail in sorted(self._tails.items())}
        return state

    def load_state_dict(self, state: Dict) -> None:
        if "fingerprint" in state:
            got = {k: int(v) for k, v in state["fingerprint"].items()}
            want = {k: int(v) for k, v in self.fingerprint().items()}
            if got != want:
                diff = {k: (got[k], want[k]) for k in want
                        if got.get(k) != want[k]}
                raise ValueError(
                    f"population mismatch on resume: checkpoint vs rebuilt "
                    f"partition differ on {diff} — rebuild with the "
                    f"original data flags (samples/clients/scheme/seed)")
        self.times_sampled = np.asarray(state["times_sampled"],
                                        dtype=np.int64).copy()
        self.last_round = np.asarray(state["last_round"],
                                     dtype=np.int64).copy()
        # drop tails from any rounds past the checkpoint — a resumed run
        # must replay from exactly the checkpointed per-client state
        self._tails = {}
        # structure-free leaves; `restore_tails(template)` rebuilds pytrees
        self._tail_leaves = state.get("tails", {})

    def restore_tails(self, template) -> None:
        """Rebuild personalized tails from a loaded state, using `template`
        (any tail pytree, e.g. the global tail) for structure."""
        treedef = jax.tree.structure(template)
        for cid, leaves in getattr(self, "_tail_leaves", {}).items():
            self._tails[int(cid)] = jax.tree.unflatten(
                treedef, [leaves[str(i)] for i in range(len(leaves))])
