"""Pluggable phase-3 aggregators: clear FedAvg vs masked secure aggregation.

Both implement one contract the protocol jits over:

    aggregate(client_trees, weights, fallback, round_idx)
        -> (aggregated tree, wire bytes dict)

`ClearAggregator` is bit-identical to the seed repo's `fedavg_partial` path
(the default — existing runs, checkpoints, and golden tests are unchanged).

`SecureAggregator` makes the same round cryptographically blind:

  1. each client pre-scales its contribution by w_k / W (the public weight
     metadata — W cancels between encode and decode, so the simulation
     folds the survivor-renormalization of `fedavg_partial` straight in),
  2. fixed-point encodes into the uint32 ring and adds its pairwise PRG
     masks in one fused pass (kernels/secure_mask — Pallas on TPU, XLA ref
     on CPU CI),
  3. the server ring-sums the surviving uploads — pair masks between two
     survivors cancel mod 2^32,
  4. masks dangling toward clients the RoundScheduler dropped are
     regenerated from the escrowed pair seeds and subtracted (Bonawitz
     dropout recovery), composing with `fedavg_partial`'s survivor
     renormalization: the decoded sum IS the survivor-weighted mean,
  5. an all-dropped round falls back to the pre-round globals, exactly
     like the clear path.

Every byte of the exchange crosses a runtime Boundary (RawCodec), so the
TrafficMeter and `comm.secure_agg_breakdown` meter the same payloads:
simulated DH pubkeys (PK_BYTES per client per peer), the uint32 uploads
(RING_BYTES per padded element, survivors only), and the per-dropout seed
reveals (SEED_BYTES per survivor x dropped pair).

Async composition (fed/async_engine.py): under the buffered runtime the
aggregation unit is the buffer FLUSH, not the dispatch round — the engine
hands `aggregate` the flush cohort (live arrivals plus zero-weight rows
for clients that died in the same dispatch groups) with `round_idx` set
to the server VERSION. The zero-weight rows exercise exactly the dropout
path above: their dangling masks are recovered from escrowed seeds, and
the decoded sum equals the staleness-weighted clear flush. Build the
TRAINER with ClearAggregator and pass the SecureAggregator to
`AsyncRoundEngine(aggregator=...)`.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import fedavg_partial
from repro.kernels.secure_mask.ops import (FRAC_BITS, decode, masked_encode,
                                           summed_mask)
from repro.privacy.fixed_point import flatten_tree, unflatten_tree
from repro.privacy.masking import (PK_BYTES, SEED_BYTES, client_pairs,
                                   pair_seeds, recovery_pairs, round_key)
from repro.runtime.boundary import Boundary
from repro.runtime.codec import get_codec
from repro.runtime.meter import SECURE


class ClearAggregator:
    """`fedavg_partial` behind the pluggable-aggregator contract. The
    empty wire dict tells the protocol to keep its seed-exact
    (K + n_up) * param_bytes accounting."""

    name = "clear"

    def describe(self) -> str:
        return "clear"

    def aggregate(self, client_trees, weights: jnp.ndarray, fallback,
                  round_idx) -> Tuple[Any, Dict[str, jnp.ndarray]]:
        return fedavg_partial(client_trees, weights, fallback), {}


class SecureAggregator:
    """Masked secure aggregation over the fixed-point uint32 ring."""

    name = "secure"

    def __init__(self, *, frac_bits: int = FRAC_BITS, impl: str = "auto",
                 seed: int = 0):
        self.frac_bits = frac_bits
        self.impl = impl
        self.seed = seed
        raw = get_codec("raw")
        self.params_boundary = Boundary("params", raw)
        self.secure_boundary = Boundary(SECURE, raw)

    def describe(self) -> str:
        return f"secure(frac_bits={self.frac_bits}, seed={self.seed})"

    def aggregate(self, client_trees, weights: jnp.ndarray, fallback,
                  round_idx) -> Tuple[Any, Dict[str, jnp.ndarray]]:
        flat, treedef, shapes, n_real = flatten_tree(client_trees)
        k, n_pad = flat.shape
        w = weights.astype(jnp.float32)
        total = w.sum()
        alive = (w > 0)
        n_up = alive.sum().astype(jnp.float32)
        # survivor-renormalized weights; W cancels encode->decode so using
        # the survivor total directly reproduces fedavg_partial's mean
        wn = w / jnp.maximum(total, 1e-9)
        scaled = flat * wn[:, None]

        rk = round_key(self.seed, round_idx)
        seeds = pair_seeds(rk, k)

        # ---- client side: fused encode + pairwise mask, survivors upload
        ring_sum = jnp.zeros((n_pad,), jnp.uint32)
        for c in range(k):
            peers, signs = client_pairs(k, c)
            enc = masked_encode(scaled[c], seeds[c, peers],
                                jnp.asarray(signs), frac_bits=self.frac_bits,
                                impl=self.impl)
            ring_sum = ring_sum + jnp.where(alive[c], enc, jnp.uint32(0))

        # ---- server side: regenerate masks dangling toward dropped
        # clients from the escrowed seeds and subtract the residue. Gated
        # on an actual dropout — the common full-participation round must
        # not pay a second K*(K-1) pass of PRG generation over zeros.
        ri, rj = recovery_pairs(k)
        eff_signs = (jnp.sign(jnp.asarray(rj - ri)).astype(jnp.int32)
                     * alive[ri].astype(jnp.int32)
                     * (1 - alive[rj].astype(jnp.int32)))
        residue = jax.lax.cond(
            jnp.any(~alive),
            lambda: summed_mask(seeds[ri, rj], eff_signs, n_pad,
                                frac_bits=self.frac_bits, impl=self.impl),
            lambda: jnp.zeros((n_pad,), jnp.uint32))
        corrected = ring_sum - residue

        mean_flat = decode(corrected, self.frac_bits)
        agg = unflatten_tree(mean_flat, treedef, shapes, n_real, fallback)
        agg = jax.tree.map(
            lambda x, fb: jnp.where(total > 0, x, fb), agg, fallback)

        # ---- wire: pubkey exchange (all K set up before dropouts), masked
        # uploads (survivors only), escrow reveals (survivor x dropped)
        pubkeys = jax.random.bits(rk, (k * k, PK_BYTES // 4), jnp.uint32)
        _, b_pk = self.secure_boundary.transmit(pubkeys, train=False)
        _, b_up = self.params_boundary.transmit(
            jnp.broadcast_to(corrected[None], (k, n_pad)), train=False,
            rows=n_up)
        n_dropped = k - n_up
        reveal_payload = seeds[ri, rj].reshape(-1, 1)
        assert SEED_BYTES == 4  # one uint32 per revealed pair seed
        _, b_reveal = self.secure_boundary.transmit(
            reveal_payload, train=False, rows=n_up * n_dropped)
        return agg, {"params_up": b_up, SECURE: b_pk + b_reveal}
