"""Pairwise PRG masking (Bonawitz et al., CCS'17 — simplified).

Client k's upload is blinded with one PRG stream per cohort peer:

    upload_k = encode(x_k) + sum_{j != k} sign(k, j) * PRG(s_kj)

with sign(k, j) = +1 for j > k and -1 for j < k and s_kj = s_jk, so in the
cohort SUM every pair contributes +PRG(s_kj) - PRG(s_kj) = 0: the server
sees uniform-looking ring noise per client yet decodes the exact sum.

Key agreement is SIMULATED: pair seeds derive from a per-round key
(round-keyed fold_in, symmetrized), standing in for the DH exchange whose
pubkey traffic the wire model meters (PK_BYTES per client per peer). Seeds
are ESCROWED in the Bonawitz sense: when the RoundScheduler drops client j
mid-round, each survivor i reveals s_ij (SEED_BYTES each on the wire) and
the server regenerates sum_i sign(i, j) * PRG(s_ij) — the residue the
dead client's missing upload left in the sum — and subtracts it. Recovery
MUST run the same impl (same PRG family) as the uploads; ops.summed_mask
pins that contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

MASK_SEED = 41   # base PRNG domain for per-round pairwise seeds
PK_BYTES = 32    # simulated DH public key size (key-agreement traffic)
SEED_BYTES = 4   # one uint32 pair seed (escrow-reveal traffic)


def pair_seeds(round_key, k: int) -> jnp.ndarray:
    """(K, K) uint32 symmetric pair-seed matrix, zero diagonal, derived
    from the round key — the simulation's stand-in for key agreement."""
    raw = jax.random.bits(round_key, (k, k), jnp.uint32)
    i = jnp.arange(k)[:, None]
    j = jnp.arange(k)[None, :]
    sym = jnp.where(i < j, raw, raw.T)
    return jnp.where(i == j, jnp.uint32(0), sym)


def round_key(seed: int, round_idx) -> jax.Array:
    """Per-round masking key; `round_idx` may be traced (it rides in the
    trainer state)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed ^ MASK_SEED),
                              round_idx)


def pair_signs(k: int) -> np.ndarray:
    """(K, K) int32 antisymmetric sign matrix: +1 above the diagonal."""
    i = np.arange(k)[:, None]
    j = np.arange(k)[None, :]
    return np.sign(j - i).astype(np.int32)


def client_pairs(k: int, client: int):
    """Static (peers, signs) for one client's K-1 mask streams."""
    peers = np.array([j for j in range(k) if j != client], dtype=np.int64)
    signs = pair_signs(k)[client, peers]
    return peers, signs


def recovery_pairs(k: int):
    """All (i, j) ordered pairs as index arrays for the server's dropout
    correction: residue = sum_{i,j} alive_i * (1-alive_j) * sign(i,j)
    * PRG(s_ij). Static in K; the alive vector gates it at runtime."""
    i = np.repeat(np.arange(k), k)
    j = np.tile(np.arange(k), k)
    keep = i != j
    return i[keep], j[keep]
