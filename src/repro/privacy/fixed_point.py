"""Fixed-point uint32 ring codec for secure aggregation.

Secure aggregation sums ciphertexts, so the plaintext arithmetic must be
EXACT and closed under addition — floats are neither. Client deltas are
therefore carried as two's-complement fixed point in the uint32 ring
(`frac_bits` fractional bits, saturating encode), where pairwise masks add
and cancel mod 2^32 with no rounding anywhere.

Range discipline: the server decodes only SUMS of client values, so every
client pre-scales its contribution by w_k / W_ref (W_ref = the cohort's
total weight, public metadata) — the ring then only ever holds values
bounded by max|x|, and the headroom to the 2^31 edge is 2^(31 - frac_bits)
in float units (~32768 at the default 16 bits). Crossing it saturates per
client and WRAPS on the summed ring — the property tests pin both edges.

Tree <-> ring plumbing (`flatten_tree` / `unflatten_tree`) fixes the leaf
order via jax.tree, pads to the kernel lane multiple (the pad is masked
and counted on the wire like real payload), and is shared by the
aggregator, the meter cross-check, and the analytical cost model so the
three can never disagree about payload sizes.
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.secure_mask.ops import (FRAC_BITS, decode,  # noqa: F401
                                           encode, ring_size)
from repro.kernels.secure_mask.ref import SAT

RING_BYTES = 4   # one uint32 per encoded element on the wire


def resolution(frac_bits: int = FRAC_BITS) -> float:
    """Smallest representable increment, in float units."""
    return 2.0 ** -frac_bits


def headroom(frac_bits: int = FRAC_BITS) -> float:
    """Largest encodable magnitude before saturation, in float units
    (the ring's SAT bound — see kernels/secure_mask/ref.py)."""
    return SAT * resolution(frac_bits)


def roundtrip_tol(n_clients: int, frac_bits: int = FRAC_BITS) -> float:
    """Worst-case absolute error of a decoded n-client fixed-point sum vs
    the float computation: half an ulp of encode rounding per client plus
    one f32 conversion ulp each."""
    return (n_clients + 1) * (0.5 + 2.0 ** -7) * resolution(frac_bits)


def flatten_tree(tree: Any) -> Tuple[jnp.ndarray, List, List, int]:
    """K-leading-axis pytree -> (K, n_padded) f32 matrix + recovery info.
    Returns (flat, treedef, shapes, n_real)."""
    leaves, treedef = jax.tree.flatten(tree)
    k = leaves[0].shape[0]
    shapes = [leaf.shape[1:] for leaf in leaves]
    flat = jnp.concatenate(
        [leaf.reshape(k, -1).astype(jnp.float32) for leaf in leaves], axis=1)
    n_real = flat.shape[1]
    pad = ring_size(n_real) - n_real
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat, treedef, shapes, n_real


def unflatten_tree(flat: jnp.ndarray, treedef, shapes, n_real: int,
                   like: Any) -> Any:
    """(n_padded,) vector -> pytree shaped/dtyped like `like` (no K axis)."""
    flat = flat[:n_real]
    leaves, pos = [], 0
    like_leaves = jax.tree.leaves(like)
    for shape, ref_leaf in zip(shapes, like_leaves):
        size = 1
        for s in shape:
            size *= s
        leaves.append(flat[pos: pos + size].reshape(shape)
                      .astype(ref_leaf.dtype))
        pos += size
    return jax.tree.unflatten(treedef, leaves)
