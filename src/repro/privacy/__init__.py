"""Privacy engine: masked secure aggregation + DP-metered rounds.

  fixed_point.py — uint32-ring fixed-point codec + tree<->ring plumbing
  masking.py     — pairwise PRG seeds/signs, simulated key agreement,
                   escrowed-seed dropout recovery (Bonawitz-style)
  secure_agg.py  — ClearAggregator / SecureAggregator: the pluggable
                   phase-3 aggregation the protocol jits over
  dp.py          — DP-SGD clip + Gaussian noise on client deltas, zCDP
                   PrivacyAccountant checkpointed through the engine

Threat model and what is (not) protected: ARCHITECTURE.md §Privacy engine.
"""
from repro.privacy.dp import (PrivacyAccountant, calibrate_noise,  # noqa: F401
                              clip_tree, gaussian_noise_tree)
from repro.privacy.secure_agg import (SECURE, ClearAggregator,  # noqa: F401
                                      SecureAggregator)
