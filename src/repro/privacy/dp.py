"""Differential privacy for client updates: DP-SGD clip + noise, zCDP ledger.

The client-side mechanism (applied in core/local_update.py to the per-round
(tail, prompt) delta, BEFORE masking/upload):

    delta <- delta * min(1, C / ||delta||_2)          # global L2 clip
    delta <- delta + N(0, (z * C)^2 I)                # calibrated Gaussian

One release of that mechanism is rho = 1 / (2 z^2) zero-concentrated DP
(zCDP); zCDP composes ADDITIVELY across rounds, and converts to the usual
(eps, delta) ledger via

    eps(delta) = rho + 2 * sqrt(rho * ln(1 / delta))      (Bun-Steinke'16)

This is the per-client (local-model) guarantee against the honest-but-
curious server; we deliberately do NOT claim subsampling amplification
(the cohort sampler is not a secret), so the ledger is conservative.

`PrivacyAccountant` is the cross-round ledger. Its state is two float64
scalars checkpointed through FederatedEngine save/restore — npz round-trips
them byte-identically, so a killed-and-resumed run reports the exact eps
of the uninterrupted one. Mechanism hyperparameters are validated on
restore like every other config fingerprint: a resume that silently changed
z or C would invalidate the ledger.
"""
from __future__ import annotations

import math
from typing import Dict, Mapping

import jax
import jax.numpy as jnp
import numpy as np

DP_SEED = 97   # base PRNG domain for DP noise (disjoint from WIRE/MASK)


# ------------------------------------------------------------- mechanism
def clip_tree(tree, l2_clip: float):
    """Scale `tree` to global L2 norm <= l2_clip (no-op when under)."""
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    norm = jnp.sqrt(jnp.maximum(sq, 1e-24))
    factor = jnp.minimum(1.0, l2_clip / norm)
    return jax.tree.map(lambda x: (x * factor).astype(x.dtype), tree), norm


def gaussian_noise_tree(key, tree, stddev: float):
    """iid N(0, stddev^2) shaped like `tree` (per-leaf folded keys)."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noised = [stddev * jax.random.normal(k, x.shape, jnp.float32)
              for k, x in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, noised)


# ------------------------------------------------------------- accounting
def rho_per_release(noise_multiplier: float) -> float:
    """zCDP cost of one Gaussian release at noise z * sensitivity."""
    if noise_multiplier <= 0:
        return math.inf
    return 1.0 / (2.0 * noise_multiplier ** 2)


def epsilon_from_rho(rho: float, delta: float) -> float:
    """Bun-Steinke zCDP -> (eps, delta) conversion."""
    if rho == 0:
        return 0.0
    return rho + 2.0 * math.sqrt(rho * math.log(1.0 / delta))


def calibrate_noise(epsilon: float, delta: float, rounds: int) -> float:
    """Noise multiplier z so `rounds` composed releases land at a total
    (epsilon, delta). Inverts eps = rho + 2 sqrt(rho L): sqrt(rho_total)
    = sqrt(L + eps) - sqrt(L), split evenly across rounds."""
    if epsilon <= 0:
        raise ValueError(f"target epsilon must be > 0, got {epsilon}")
    L = math.log(1.0 / delta)
    rho_total = (math.sqrt(L + epsilon) - math.sqrt(L)) ** 2
    rho_round = rho_total / max(1, rounds)
    return math.sqrt(1.0 / (2.0 * rho_round))


class PrivacyAccountant:
    """Additive zCDP ledger across rounds, checkpoint-exact."""

    def __init__(self, *, noise_multiplier: float, l2_clip: float,
                 delta: float = 1e-5):
        if noise_multiplier <= 0:
            raise ValueError("DP accounting needs noise_multiplier > 0 "
                             f"(got {noise_multiplier}); without noise no "
                             "finite epsilon exists")
        if l2_clip <= 0:
            raise ValueError(f"l2_clip must be > 0, got {l2_clip}")
        self.noise_multiplier = float(noise_multiplier)
        self.l2_clip = float(l2_clip)
        self.delta = float(delta)
        self.rho = 0.0
        self.releases = 0

    def spend(self, n_releases: int = 1) -> None:
        self.rho += n_releases * rho_per_release(self.noise_multiplier)
        self.releases += n_releases

    def epsilon(self, delta: float = None) -> float:
        return epsilon_from_rho(self.rho,
                                self.delta if delta is None else delta)

    def report(self) -> str:
        return (f"zCDP rho={self.rho:.6f} over {self.releases} release(s) "
                f"-> eps={self.epsilon():.3f} at delta={self.delta:g} "
                f"(z={self.noise_multiplier:g}, C={self.l2_clip:g})")

    # ------------------------------------------------------------ resume
    def state_dict(self) -> Dict[str, np.float64]:
        """Ledger state + mechanism params. rho/releases restore the
        ledger; the params are fingerprints validated on load."""
        return {"rho": np.float64(self.rho),
                "releases": np.float64(self.releases),
                "noise_multiplier": np.float64(self.noise_multiplier),
                "l2_clip": np.float64(self.l2_clip),
                "delta": np.float64(self.delta)}

    def load_state_dict(self, state: Mapping[str, np.ndarray]) -> None:
        for name in ("noise_multiplier", "l2_clip", "delta"):
            saved = float(np.asarray(state[name]))
            if saved != getattr(self, name):
                raise ValueError(
                    f"DP mechanism mismatch on resume: checkpoint "
                    f"{name}={saved} vs engine {getattr(self, name)} — the "
                    f"epsilon ledger would be invalid; rebuild with the "
                    f"original DP flags")
        self.rho = float(np.asarray(state["rho"]))
        self.releases = int(np.asarray(state["releases"]))
