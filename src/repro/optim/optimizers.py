"""Pure-JAX optimizers (optax-style (init, update) pairs, pytree-generic).

Used for both the client-side (tail, prompt) updates and the full-model
baselines. States are pytrees, so they vmap over the client axis for
per-client optimizer state in the federated phases.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]  # (grads, state, params) -> (updates, state)


def sgd(lr: Schedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        mom = (jax.tree.map(jnp.zeros_like, params) if momentum else None)
        return {"step": jnp.zeros((), jnp.int32), "mom": mom}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g,
                               state["mom"], grads)
            updates = jax.tree.map(lambda m: -lr_t * m, mom)
            return updates, {"step": step, "mom": mom}
        updates = jax.tree.map(lambda g: -lr_t * g, grads)
        return updates, {"step": step, "mom": None}

    return Optimizer(init, update)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree.map(jnp.zeros_like, params)
        return {"step": jnp.zeros((), jnp.int32), "mu": z,
                "nu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state["nu"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            mhat = m / c1
            vhat = v / c2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p
            return -lr_t * u

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm
