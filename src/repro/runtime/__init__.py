"""Transport-aware segment pipeline: the split's wire boundaries as
first-class objects.

  codec.py    — WireCodec (fp32 | bf16 | int8-stochastic) + the custom-VJP
                roundtrip that quantizes backward gradients too
  boundary.py — Boundary / WireSpec: the head->body and body->tail links
  meter.py    — TrafficMeter: measured bytes per boundary per round
  hetero.py   — per-client SplitConfig groups (import directly to avoid a
                core<->runtime import cycle at package load)

This is the seam between the model segments (core/split.py) and everything
that moves tensors between machines: phase-2 training (core/protocol.py),
serving (launch/serve.py, launch/steps.py), and the analytical cost model
cross-check (core/comm.py, benchmarks/comm_cost.py).
"""
from repro.runtime.boundary import (BOUNDARY_NAMES, Boundary,  # noqa: F401
                                    WireSpec)
from repro.runtime.codec import (CODECS, Bf16Codec, Fp32Codec,  # noqa: F401
                                 Int8Codec, WireCodec, get_codec)
from repro.runtime.meter import TrafficMeter  # noqa: F401
