"""TrafficMeter: measured bytes per boundary per round.

Byte counts originate in `Boundary.transmit` as traced f32 scalars (from
the actual payload shapes that crossed the wire) and ride through the
protocol's jit/scan carries; `absorb()` folds a round's counters into
host-side Python floats, and `report()`/`as_dict()` pretty-print them —
benchmarks/comm_cost.py compares them against the analytical model.

Under partial participation (fed.RoundScheduler) a round's counters are
already straggler-scaled by the protocol; `absorb(counts, clients=k)`
additionally records how many clients actually aggregated, so
`per_client_round()` normalizes by ACTIVE client-rounds, not by cohort
size — the honest per-device cost under dropouts.

The meter is part of the resumable run state: `state_dict()` /
`load_state_dict()` round-trip its totals exactly (floats, no re-metering),
so a killed-and-restarted run reports the same cumulative traffic as an
uninterrupted one.
"""
from __future__ import annotations

from typing import Dict, Iterable, Mapping, Optional

from repro.runtime.boundary import BOUNDARY_NAMES

PARAMS = "params"       # phase-3 (tail, prompt) up+down traffic
SECURE = "secure"       # secure-agg key agreement + escrow-reveal traffic
EDGE = "edge_global"    # hierarchical tier-2: edge-mean up + global down
MB = 2 ** 20


class TrafficMeter:
    def __init__(self,
                 names: Iterable[str] = BOUNDARY_NAMES + (PARAMS, SECURE,
                                                          EDGE)):
        self.names = tuple(names)
        self.totals: Dict[str, float] = {n: 0.0 for n in self.names}
        self.rounds = 0
        self.client_rounds = 0.0   # sum over rounds of active clients

    def absorb(self, counts: Mapping[str, float], *,
               clients: Optional[float] = None) -> None:
        """Fold one round's counters (traced scalars or floats) in.
        `clients`: how many clients' traffic the round actually carried
        (active cohort under dropouts); defaults to unknown -> 0 added."""
        for name, v in counts.items():
            if name in self.totals:
                self.totals[name] += float(v)
        self.rounds += 1
        if clients is not None:
            self.client_rounds += float(clients)

    def total_bytes(self) -> float:
        return sum(self.totals.values())

    def as_dict(self) -> Dict[str, float]:
        return dict(self.totals, total=self.total_bytes())

    def per_round(self) -> Dict[str, float]:
        r = max(1, self.rounds)
        return {n: v / r for n, v in self.as_dict().items()}

    def per_client_round(self) -> Dict[str, float]:
        """Bytes per ACTIVE client-round — the per-device cost a real
        deployment bills, unchanged by how many stragglers were dropped."""
        cr = max(1.0, self.client_rounds)
        return {n: v / cr for n, v in self.as_dict().items()}

    def per_token(self, n_tokens: float) -> Dict[str, float]:
        """Bytes per generated token — the serving analogue of
        `per_client_round`; `n_tokens` comes from the engine's counter
        (the meter itself has no notion of tokens)."""
        t = max(1.0, float(n_tokens))
        return {n: v / t for n, v in self.as_dict().items()}

    # ------------------------------------------------------------- resume
    def state_dict(self) -> Dict[str, float]:
        state = {f"totals/{n}": v for n, v in self.totals.items()}
        state["rounds"] = float(self.rounds)
        state["client_rounds"] = self.client_rounds
        return state

    def load_state_dict(self, state: Mapping[str, float]) -> None:
        for n in self.totals:
            key = f"totals/{n}"
            if key in state:
                self.totals[n] = float(state[key])
        self.rounds = int(state["rounds"])
        self.client_rounds = float(state["client_rounds"])

    def report(self) -> str:
        lines = [f"wire traffic over {self.rounds} round(s):"]
        for n, v in self.as_dict().items():
            lines.append(f"  {n:>10}: {v / MB:10.3f} MB")
        if self.client_rounds > 0:
            per = self.per_client_round()["total"]
            lines.append(f"  ({self.client_rounds:.0f} active "
                         f"client-rounds, {per / MB:.3f} MB each)")
        return "\n".join(lines)
