"""TrafficMeter: measured bytes per boundary per round.

Byte counts originate in `Boundary.transmit` as traced f32 scalars (from
the actual payload shapes that crossed the wire) and ride through the
protocol's jit/scan carries; `absorb()` folds a round's counters into
host-side Python floats, and `report()`/`as_dict()` pretty-print them —
benchmarks/comm_cost.py compares them against the analytical model.
"""
from __future__ import annotations

from typing import Dict, Iterable, Mapping

from repro.runtime.boundary import BOUNDARY_NAMES

PARAMS = "params"   # phase-3 (tail, prompt) up+down traffic
MB = 2 ** 20


class TrafficMeter:
    def __init__(self, names: Iterable[str] = BOUNDARY_NAMES + (PARAMS,)):
        self.names = tuple(names)
        self.totals: Dict[str, float] = {n: 0.0 for n in self.names}
        self.rounds = 0

    def absorb(self, counts: Mapping[str, float]) -> None:
        """Fold one round's counters (traced scalars or floats) in."""
        for name, v in counts.items():
            if name in self.totals:
                self.totals[name] += float(v)
        self.rounds += 1

    def total_bytes(self) -> float:
        return sum(self.totals.values())

    def as_dict(self) -> Dict[str, float]:
        return dict(self.totals, total=self.total_bytes())

    def per_round(self) -> Dict[str, float]:
        r = max(1, self.rounds)
        return {n: v / r for n, v in self.as_dict().items()}

    def report(self) -> str:
        lines = [f"wire traffic over {self.rounds} round(s):"]
        for n, v in self.as_dict().items():
            lines.append(f"  {n:>10}: {v / MB:10.3f} MB")
        return "\n".join(lines)
