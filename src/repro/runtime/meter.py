"""TrafficMeter: measured bytes per boundary per round.

Byte counts originate in `Boundary.transmit` as traced f32 scalars (from
the actual payload shapes that crossed the wire) and ride through the
protocol's jit/scan carries; `absorb()` folds a round's counters into
host-side Python floats, and `report()`/`as_dict()` pretty-print them —
benchmarks/comm_cost.py compares them against the analytical model.

Under partial participation (fed.RoundScheduler) a round's counters are
already straggler-scaled by the protocol; `absorb(counts, clients=k)`
additionally records how many clients actually aggregated, so
`per_client_round()` normalizes by ACTIVE client-rounds, not by cohort
size — the honest per-device cost under dropouts.

The meter is part of the resumable run state: `state_dict()` /
`load_state_dict()` round-trip its totals exactly (floats, no re-metering),
so a killed-and-restarted run reports the same cumulative traffic as an
uninterrupted one.
"""
from __future__ import annotations

from typing import Dict, Iterable, Mapping, Optional

from repro.runtime.boundary import BOUNDARY_NAMES

PARAMS = "params"       # phase-3 (tail, prompt) up+down traffic
SECURE = "secure"       # secure-agg key agreement + escrow-reveal traffic
EDGE = "edge_global"    # hierarchical tier-2: edge-mean up + global down
MB = 2 ** 20

# wall-clock overlap streams (simulated seconds, not bytes): how much
# server aggregation work, client compute, and wire time the run
# accumulated vs the simulated span it all fit into.  Under a synchronous
# barrier span ~= sum of per-round maxima; under the async buffered
# runtime client/wire time OVERLAPS, so their sums exceed the span — the
# overlap() ratios make that win measurable (analytical twin:
# core/comm.py async_vs_sync_round_time).
WALL_STREAMS = ("server_busy_s", "client_compute_s", "wire_s", "span_s")


class TrafficMeter:
    def __init__(self,
                 names: Iterable[str] = BOUNDARY_NAMES + (PARAMS, SECURE,
                                                          EDGE)):
        self.names = tuple(names)
        self.totals: Dict[str, float] = {n: 0.0 for n in self.names}
        self.rounds = 0
        self.client_rounds = 0.0   # sum over rounds of active clients
        self.wall: Dict[str, float] = {n: 0.0 for n in WALL_STREAMS}
        # flight-recorder hook (repro.obs): when attached, every absorb
        # emits a `meter.absorb` event carrying the SAME host floats it
        # adds to `totals`, so a trace's per-stream event sums equal the
        # meter totals float-exactly (tools/trace_check.py enforces it).
        # None (the default) keeps the meter observation-free.
        self.tracer = None

    def attach_tracer(self, tracer) -> None:
        self.tracer = tracer if (tracer is not None
                                 and tracer.enabled) else None

    def absorb(self, counts: Mapping[str, float], *,
               clients: Optional[float] = None) -> None:
        """Fold one round's counters (traced scalars or floats) in.
        `clients`: how many clients' traffic the round actually carried
        (active cohort under dropouts); defaults to unknown -> 0 added."""
        folded: Dict[str, float] = {}
        for name, v in counts.items():
            if name in self.totals:
                fv = float(v)
                self.totals[name] += fv
                folded[name] = fv
        self.rounds += 1
        if clients is not None:
            self.client_rounds += float(clients)
        if self.tracer is not None:
            self.tracer.event("meter.absorb", round=self.rounds, **folded)

    def absorb_wall(self, *, server_busy_s: float = 0.0,
                    client_compute_s: float = 0.0, wire_s: float = 0.0,
                    span_s: float = 0.0) -> None:
        """Fold simulated wall-clock increments in. `span_s` is the
        advance of the run's single simulated clock; the other three are
        work sums that may legitimately exceed it (overlap)."""
        self.wall["server_busy_s"] += float(server_busy_s)
        self.wall["client_compute_s"] += float(client_compute_s)
        self.wall["wire_s"] += float(wire_s)
        self.wall["span_s"] += float(span_s)
        if self.tracer is not None:
            self.tracer.event("meter.wall", level=2,
                              server_busy_s=float(server_busy_s),
                              client_compute_s=float(client_compute_s),
                              wire_s=float(wire_s), span_s=float(span_s))

    def overlap(self) -> Dict[str, float]:
        """Wall-clock utilization ratios: work-seconds per span-second
        for each stream, plus their sum (`parallelism` — 1.0 means the
        run was fully serial, > 1 means client compute and wire time
        overlapped across clients / with the server)."""
        span = max(self.wall["span_s"], 1e-12)
        out = {k: v / span for k, v in self.wall.items() if k != "span_s"}
        out["parallelism"] = sum(out.values())
        return out

    def total_bytes(self) -> float:
        return sum(self.totals.values())

    def as_dict(self) -> Dict[str, float]:
        return dict(self.totals, total=self.total_bytes())

    def per_round(self) -> Dict[str, float]:
        r = max(1, self.rounds)
        return {n: v / r for n, v in self.as_dict().items()}

    def per_client_round(self) -> Dict[str, float]:
        """Bytes per ACTIVE client-round — the per-device cost a real
        deployment bills, unchanged by how many stragglers were dropped."""
        cr = max(1.0, self.client_rounds)
        return {n: v / cr for n, v in self.as_dict().items()}

    def per_token(self, n_tokens: float) -> Dict[str, float]:
        """Bytes per generated token — the serving analogue of
        `per_client_round`; `n_tokens` comes from the engine's counter
        (the meter itself has no notion of tokens)."""
        t = max(1.0, float(n_tokens))
        return {n: v / t for n, v in self.as_dict().items()}

    # ------------------------------------------------------------- resume
    def state_dict(self) -> Dict[str, float]:
        state = {f"totals/{n}": v for n, v in self.totals.items()}
        state["rounds"] = float(self.rounds)
        state["client_rounds"] = self.client_rounds
        for n, v in self.wall.items():
            state[f"wall/{n}"] = v
        return state

    def load_state_dict(self, state: Mapping[str, float]) -> None:
        for n in self.totals:
            key = f"totals/{n}"
            if key in state:
                self.totals[n] = float(state[key])
        self.rounds = int(state["rounds"])
        self.client_rounds = float(state["client_rounds"])
        for n in self.wall:
            # absent in pre-async checkpoints: zero, not an error
            self.wall[n] = float(state.get(f"wall/{n}", 0.0))

    def report(self) -> str:
        lines = [f"wire traffic over {self.rounds} round(s):"]
        for n, v in self.as_dict().items():
            lines.append(f"  {n:>10}: {v / MB:10.3f} MB")
        if self.client_rounds > 0:
            per = self.per_client_round()["total"]
            lines.append(f"  ({self.client_rounds:.0f} active "
                         f"client-rounds, {per / MB:.3f} MB each)")
        if self.wall["span_s"] > 0:
            ov = self.overlap()
            lines.append(
                f"wall clock over {self.wall['span_s']:.1f} simulated s: "
                f"server {self.wall['server_busy_s']:.1f}s, client "
                f"compute {self.wall['client_compute_s']:.1f}s, wire "
                f"{self.wall['wire_s']:.1f}s "
                f"(parallelism {ov['parallelism']:.2f}x)")
        return "\n".join(lines)
