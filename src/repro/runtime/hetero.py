"""Per-client split heterogeneity: different cut points in one round.

Resource-limited fleets are not uniform — a phone holds one transformer
cycle per client segment, a workstation three. `ClientPlan` groups clients
by their `SplitConfig`; each group trains through its own `SplitModel`
(same backbone config, different head/tail cycle counts, same wire codecs),
and the round ends with a cross-group FedAvg of the soft prompt — the one
trainable tensor whose shape is split-invariant. Tails stay personalized
per group (their layer counts differ), in the style of flexible
personalized split FL (Yuan et al., arXiv:2508.10349).

Wire traffic from every group lands in one shared `TrafficMeter`, so the
comm accounting stays honest under heterogeneity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.protocol import ProtocolConfig, SFPromptTrainer
from repro.core.split import SplitConfig, SplitModel
from repro.models.config import ModelConfig
from repro.runtime.boundary import WireSpec
from repro.runtime.meter import TrafficMeter


@dataclass(frozen=True)
class ClientPlan:
    """One homogeneous group: `n_clients` devices sharing a cut point."""
    split: SplitConfig
    n_clients: int
    name: str = ""


class HeteroSFPromptTrainer:
    """Runs one SFPrompt round across groups with different cut points."""

    def __init__(self, cfg: ModelConfig, plans: Sequence[ClientPlan],
                 pcfg: ProtocolConfig, wire: Optional[WireSpec] = None):
        if not plans:
            raise ValueError("need at least one ClientPlan")
        p_lens = {p.split.prompt_len for p in plans}
        if len(p_lens) != 1:
            raise ValueError(
                f"prompt_len must match across plans for cross-group "
                f"aggregation; got {sorted(p_lens)}")
        self.cfg = cfg
        self.plans = list(plans)
        self.trainers: List[SFPromptTrainer] = [
            SFPromptTrainer(SplitModel(cfg, p.split, wire), pcfg)
            for p in plans]
        self.meter = TrafficMeter()

    # ------------------------------------------------------------- state
    def init(self, key) -> List[Dict]:
        return [t.init(jax.random.fold_in(key, i))
                for i, t in enumerate(self.trainers)]

    # ------------------------------------------------------------- round
    def round(self, states: List[Dict],
              group_data: Sequence) -> Tuple[List[Dict], Dict]:
        """group_data[i]: pytree with leading (plans[i].n_clients, n, ...)
        axes. Returns (new per-group states with the globally-averaged
        prompt written back, merged metrics)."""
        new_states, metrics = [], {}
        wire_totals: Dict[str, float] = {}
        for i, (tr, st, data) in enumerate(
                zip(self.trainers, states, group_data)):
            st, m = tr.round(st, data)
            new_states.append(st)
            tag = self.plans[i].name or f"g{i}"
            for k, v in m.items():
                if k.startswith("wire/"):
                    wire_totals[k] = wire_totals.get(k, 0.0) + v
                metrics[f"{tag}/{k}"] = v

        # cross-group prompt FedAvg (client-count weighted); tails stay
        # personalized per group — their shapes differ across cut points
        w = jnp.asarray([p.n_clients for p in self.plans], jnp.float32)
        w = w / w.sum()
        prompt = sum(wi * st["params"]["prompt"]
                     for wi, st in zip(w, new_states))
        for st in new_states:
            st["params"] = dict(st["params"], prompt=prompt)

        metrics.update(wire_totals)
        self.meter.absorb({k.removeprefix("wire/").removesuffix("_bytes"): v
                           for k, v in wire_totals.items()})
        return new_states, metrics

    # ------------------------------------------------------------- eval
    def evaluate(self, states: List[Dict], data, *,
                 batch_size: int = 32) -> Dict:
        per_group = [t.evaluate(s["params"], data, batch_size=batch_size)
                     for t, s in zip(self.trainers, states)]
        w = [p.n_clients for p in self.plans]
        tot = sum(w)
        out = {k: sum(wi * g[k] for wi, g in zip(w, per_group)) / tot
               for k in per_group[0]}
        out["per_group"] = per_group
        return out
