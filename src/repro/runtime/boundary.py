"""Wire boundaries: the two physical links of the three-way split.

    client ──(head_body)──> server ──(body_tail)──> client

`Boundary.transmit` is THE function every smashed tensor crosses on its way
between segments. It applies the codec roundtrip (with the custom VJP that
also quantizes the backward gradient) and returns the exact byte count that
hit the wire, as a traced scalar the protocol accumulates per round.

`WireSpec` bundles the two boundaries; `SplitModel` owns one and routes
`forward()` / phase-2 losses / serving through it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.runtime.codec import WireCodec, get_codec

HEAD_BODY = "head_body"
BODY_TAIL = "body_tail"
BOUNDARY_NAMES = (HEAD_BODY, BODY_TAIL)


@dataclass(frozen=True)
class Boundary:
    name: str
    codec: WireCodec

    def _noise(self, key, shape):
        if key is None or not self.codec.stochastic:
            # round-to-nearest: unbiased only in expectation per element,
            # but deterministic — the eval/serving mode
            half = jnp.full((), 0.5, jnp.float32)
            return half, half
        kf, kb = jax.random.split(key)
        return (jax.random.uniform(kf, shape, jnp.float32),
                jax.random.uniform(kb, shape, jnp.float32))

    def transmit(self, x: jnp.ndarray, *, key=None, train: bool = True,
                 rows=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Push `x` across this boundary. Returns (received tensor,
        wire bytes as a traced f32 scalar). `train=True` counts the backward
        gradient crossing too (same shape, same codec, opposite direction).

        `rows` (optional, traced): number of leading-axis rows that actually
        cross the wire. A continuous-batching decode step runs all cache
        slots but only transmits the occupied ones — bytes then count
        `rows * payload_nbytes(one row)` instead of the full tensor."""
        u_fwd, u_bwd = self._noise(key, x.shape)
        y = self.codec.roundtrip(x, u_fwd, u_bwd)
        direction = 2 if train else 1
        if rows is None:
            nbytes = jnp.float32(self.codec.payload_nbytes(x.shape)
                                 * direction)
        else:
            per_row = self.codec.payload_nbytes((1,) + tuple(x.shape[1:]))
            nbytes = (jnp.asarray(rows, jnp.float32)
                      * jnp.float32(per_row * direction))
        return y, nbytes

    def payload_nbytes(self, shape) -> int:
        return self.codec.payload_nbytes(shape)


@dataclass(frozen=True)
class WireSpec:
    """The split's two cut points with their codecs."""
    head_body: Boundary
    body_tail: Boundary

    @classmethod
    def make(cls, codec: str = "fp32", *, impl: str = "auto",
             body_tail_codec: Optional[str] = None) -> "WireSpec":
        c_hb = get_codec(codec, impl=impl)
        c_bt = get_codec(body_tail_codec or codec, impl=impl)
        return cls(head_body=Boundary(HEAD_BODY, c_hb),
                   body_tail=Boundary(BODY_TAIL, c_bt))

    @property
    def boundaries(self) -> Tuple[Boundary, Boundary]:
        return (self.head_body, self.body_tail)

    def describe(self) -> str:
        return (f"{HEAD_BODY}:{self.head_body.codec.name} "
                f"{BODY_TAIL}:{self.body_tail.codec.name}")
