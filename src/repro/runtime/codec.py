"""Wire codecs: what a smashed tensor looks like as bytes on the link.

A `WireCodec` maps an activation (or cut-layer gradient) to the payload that
actually crosses the client<->server boundary and back:

    payload = encode(x, u)        # the bytes on the wire
    y       = decode(payload, dt) # what the receiving segment computes on

`payload_nbytes(shape)` is the exact serialized size of that payload — the
TrafficMeter counts it, and benchmarks/comm_cost.py cross-checks it against
the analytical Table-1 model.

`roundtrip(x, u_fwd, u_bwd)` is the autodiff-correct wire crossing: the
forward value goes through encode/decode, and the custom VJP pushes the
backward gradient through the SAME codec (with independent noise), so
phase-2 training sees exactly the int8 wire a physical deployment would —
quantized activations forward, quantized gradients backward (FedPrompt-style
payload quantization, arXiv:2208.12268).

Stochastic rounding noise `u` is uniform in [0, 1); `u = 0.5` degenerates to
round-to-nearest (the deterministic eval/serving mode).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quant.ops import dequantize_int8, quantize_int8

Payload = Any


class WireCodec:
    """Base contract. Codecs are stateless and hashable (static under jit)."""

    name: str = "identity"
    stochastic: bool = False   # does encode consume rounding noise?

    def __init__(self, impl: str = "auto"):
        self.impl = impl       # ref | pallas | interpret | auto (codecs
                               # without a kernel ignore it)

    def encode(self, x: jnp.ndarray, u) -> Payload:
        raise NotImplementedError

    def decode(self, payload: Payload, dtype) -> jnp.ndarray:
        raise NotImplementedError

    def payload_nbytes(self, shape: Tuple[int, ...]) -> int:
        """Exact wire bytes for one tensor of `shape`."""
        raise NotImplementedError

    def bytes_per_float(self, shape: Tuple[int, ...]) -> float:
        """Effective bytes per element incl. side-channel (scales) overhead —
        plugs straight into comm.CostInputs.bytes_smashed."""
        return self.payload_nbytes(shape) / max(1, math.prod(shape))

    def roundtrip(self, x: jnp.ndarray, u_fwd, u_bwd) -> jnp.ndarray:
        return _wire_roundtrip(self, x, jnp.asarray(u_fwd, jnp.float32),
                               jnp.asarray(u_bwd, jnp.float32))

    def __repr__(self):
        return f"{type(self).__name__}({self.name})"

    # static-hashability so codecs can ride in jit-static args
    def __hash__(self):
        return hash((type(self), self.name))

    def __eq__(self, other):
        return type(self) is type(other)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _wire_roundtrip(codec: WireCodec, x, u_fwd, u_bwd):
    return codec.decode(codec.encode(x, u_fwd), x.dtype)


def _wire_roundtrip_fwd(codec, x, u_fwd, u_bwd):
    y = codec.decode(codec.encode(x, u_fwd), x.dtype)
    return y, (u_fwd, u_bwd)


def _wire_roundtrip_bwd(codec, res, g):
    u_fwd, u_bwd = res
    # the gradient crosses the same physical link: encode/decode it too
    gq = codec.decode(codec.encode(g, u_bwd), g.dtype)
    return gq, jnp.zeros_like(u_fwd), jnp.zeros_like(u_bwd)


_wire_roundtrip.defvjp(_wire_roundtrip_fwd, _wire_roundtrip_bwd)


class Fp32Codec(WireCodec):
    """Raw fp32 on the wire — the paper-naive baseline."""

    name = "fp32"

    def encode(self, x, u):
        return x.astype(jnp.float32)

    def decode(self, payload, dtype):
        return payload.astype(dtype)

    def payload_nbytes(self, shape):
        return 4 * math.prod(shape)


class Bf16Codec(WireCodec):
    """bf16 truncation: 2 bytes/float, exact exponent, 8-bit mantissa."""

    name = "bf16"

    def encode(self, x, u):
        return x.astype(jnp.bfloat16)

    def decode(self, payload, dtype):
        return payload.astype(dtype)

    def payload_nbytes(self, shape):
        return 2 * math.prod(shape)


class Int8Codec(WireCodec):
    """Per-token-row symmetric int8 with stochastic rounding.

    Payload = int8 values (1 B/elem) + one fp32 scale per row of the last
    axis. The quantize/dequantize pair runs as a Pallas kernel on TPU
    (kernels/quant/) with the pure-jnp ref elsewhere.
    """

    name = "int8"
    stochastic = True

    def encode(self, x, u):
        D = x.shape[-1]
        x2 = x.reshape(-1, D)
        u2 = jnp.broadcast_to(jnp.asarray(u, jnp.float32), x.shape
                              ).reshape(-1, D)
        values, scales = quantize_int8(x2, u2, impl=self.impl)
        return values.reshape(x.shape), scales.reshape(x.shape[:-1] + (1,))

    def decode(self, payload, dtype):
        values, scales = payload
        D = values.shape[-1]
        out = dequantize_int8(values.reshape(-1, D),
                              scales.reshape(-1, 1), dtype=dtype,
                              impl=self.impl)
        return out.reshape(values.shape)

    def payload_nbytes(self, shape):
        n_rows = math.prod(shape[:-1]) if len(shape) > 1 else 1
        return math.prod(shape) + 4 * n_rows

    def __hash__(self):
        return hash((type(self), self.name, self.impl))

    def __eq__(self, other):
        return type(self) is type(other) and self.impl == other.impl


class RawCodec(WireCodec):
    """Verbatim 4-byte words on the wire — no cast, no quantization. The
    secure-aggregation path uses it for uint32 ring uploads and seed/pubkey
    exchange, where a float cast would corrupt the payload (f32 holds only
    24 bits of a uint32) and the bytes must be counted exactly."""

    name = "raw"

    def encode(self, x, u):
        return x

    def decode(self, payload, dtype):
        return payload.astype(dtype)

    def payload_nbytes(self, shape):
        return 4 * math.prod(shape)


CODECS = {"fp32": Fp32Codec, "bf16": Bf16Codec, "int8": Int8Codec,
          "raw": RawCodec}


def get_codec(name: str, **kw) -> WireCodec:
    if name not in CODECS:
        raise ValueError(f"unknown wire codec {name!r}; have {list(CODECS)}")
    return CODECS[name](**kw)
