from repro.checkpoint.io import (  # noqa: F401
    all_checkpoints, latest_checkpoint, load_checkpoint, load_latest,
    save_checkpoint)
