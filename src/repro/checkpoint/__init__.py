from repro.checkpoint.io import (  # noqa: F401
    latest_checkpoint, load_checkpoint, save_checkpoint)
