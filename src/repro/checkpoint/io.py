"""Checkpointing: nested-dict pytrees <-> npz files.

Paths are flattened with '/' separators; arrays are gathered to host before
saving (call inside jax.experimental.multihost_utils barriers on real
multi-host — on this single-process simulator a plain device_get suffices).
"""
from __future__ import annotations

import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif tree is None:
        out[prefix[:-1] + "~none"] = np.zeros((0,))
    else:
        out[prefix[:-1]] = np.asarray(jax.device_get(tree))
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    tree: Dict[str, Any] = {}
    for path, v in flat.items():
        if path.endswith("~none"):
            path, v = path[: -len("~none")].rstrip("/"), None
        node = tree
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save_checkpoint(path: str, tree: Any, *, step: Optional[int] = None,
                    keep_last: Optional[int] = None) -> str:
    """Write `tree` to npz. With `step`, writes ckpt_<step>.npz under
    `path` ATOMICALLY (tmp + rename, so a kill mid-write never leaves a
    truncated checkpoint for resume to trip on) and, with `keep_last`,
    prunes all but the newest `keep_last` step files."""
    if keep_last is not None:
        if step is None:
            raise ValueError("keep_last only applies to stepped "
                             "checkpoints (pass step=)")
        if keep_last < 1:
            raise ValueError(f"keep_last must be >= 1, got {keep_last} "
                             "(the checkpoint being written always stays)")
    if step is not None:
        ckpt_dir, path = path, os.path.join(path, f"ckpt_{step:08d}.npz")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **_flatten(tree))
    os.replace(tmp, path)
    if step is not None and keep_last is not None:
        for old in all_checkpoints(ckpt_dir)[:-keep_last]:
            os.remove(old)
    return path


def load_checkpoint(path: str) -> Any:
    with np.load(path, allow_pickle=False) as z:
        return _unflatten({k: z[k] for k in z.files})


def all_checkpoints(ckpt_dir: str) -> list:
    """Step-ordered list of checkpoint paths in `ckpt_dir`."""
    if not os.path.isdir(ckpt_dir):
        return []
    pat = re.compile(r"ckpt_(\d+)\.npz$")
    found = [(int(m.group(1)), os.path.join(ckpt_dir, f))
             for f in os.listdir(ckpt_dir) if (m := pat.match(f))]
    return [p for _, p in sorted(found)]


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    ckpts = all_checkpoints(ckpt_dir)
    return ckpts[-1] if ckpts else None


def load_latest(ckpt_dir: str) -> Optional[Any]:
    """Load the newest INTACT checkpoint in `ckpt_dir`, or None when the
    directory holds none. The resumable-training entry point: engines call
    this on restart.

    Writes are atomic (tmp + rename), so a torn tail file should never
    exist — but a copied-in or disk-damaged npz can still fail to parse,
    and dying on it would leave the run unresumable even though older
    intact checkpoints sit right next to it. A corrupt tail is therefore
    skipped with a warning and the next-newest checkpoint loads instead
    (the engine then replays the lost rounds deterministically)."""
    import sys
    import zipfile
    for path in reversed(all_checkpoints(ckpt_dir)):
        try:
            return load_checkpoint(path)
        except (zipfile.BadZipFile, ValueError, EOFError, OSError) as e:
            print(f"warning: skipping corrupt checkpoint {path}: {e}",
                  file=sys.stderr)
    return None
