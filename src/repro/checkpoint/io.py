"""Checkpointing: nested-dict pytrees <-> npz files.

Paths are flattened with '/' separators; arrays are gathered to host before
saving (call inside jax.experimental.multihost_utils barriers on real
multi-host — on this single-process simulator a plain device_get suffices).
"""
from __future__ import annotations

import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif tree is None:
        out[prefix[:-1] + "~none"] = np.zeros((0,))
    else:
        out[prefix[:-1]] = np.asarray(jax.device_get(tree))
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    tree: Dict[str, Any] = {}
    for path, v in flat.items():
        if path.endswith("~none"):
            path, v = path[: -len("~none")].rstrip("/"), None
        node = tree
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save_checkpoint(path: str, tree: Any, *, step: Optional[int] = None) -> str:
    if step is not None:
        path = os.path.join(path, f"ckpt_{step:08d}.npz")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))
    return path


def load_checkpoint(path: str) -> Any:
    with np.load(path, allow_pickle=False) as z:
        return _unflatten({k: z[k] for k in z.files})


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    pat = re.compile(r"ckpt_(\d+)\.npz$")
    best, best_step = None, -1
    for f in os.listdir(ckpt_dir):
        m = pat.match(f)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(ckpt_dir, f), int(m.group(1))
    return best
