"""Layer library: every block kind used by the assigned architectures.

Functional style: each block kind has ``init_<kind>(key, cfg) -> params`` and
``apply_<kind>(params, cfg, x, ctx) -> (x, new_cache)``. Params are plain
dict pytrees so they stack cleanly for lax.scan over layers and shard with
simple name-based partition rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.decode import (decode_attention,
                                                  paged_decode_attention)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mamba2_scan.ops import mamba2_scan
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.models.config import AttentionConfig, ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------- helpers
def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: float = 0.02) -> Params:
    p = {"w": scale * jax.random.normal(key, (d_in, d_out), jnp.float32)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def norm_init(d: int, kind: str) -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (y * p["scale"]).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------- RoPE
def rope_cos_sin(positions: jnp.ndarray, rot_dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, rot_dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                             / rot_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x (B, S, H, D); cos/sin (B, S, D/2) — rotate-half convention."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def mrope_cos_sin(positions: jnp.ndarray, sections: Tuple[int, int, int],
                  rot_dim: int, theta: float):
    """Qwen2-VL M-RoPE [arXiv:2409.12191]: positions (3, B, S) for
    (temporal, height, width); frequency bands are split across the three
    position streams by `sections` (in half-dim units, sum = rot_dim/2)."""
    assert sum(sections) == rot_dim // 2, (sections, rot_dim)
    cos3, sin3 = rope_cos_sin(positions, rot_dim, theta)  # (3, B, S, rot/2)
    chunks_c, chunks_s = [], []
    start = 0
    for i, sec in enumerate(sections):
        chunks_c.append(cos3[i, :, :, start:start + sec])
        chunks_s.append(sin3[i, :, :, start:start + sec])
        start += sec
    return jnp.concatenate(chunks_c, -1), jnp.concatenate(chunks_s, -1)


def sinusoidal_embedding(positions: jnp.ndarray, d: int):
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------- context
@dataclasses.dataclass
class Ctx:
    """Per-call context threaded through blocks."""
    mode: str                                   # train | prefill | decode
    positions: jnp.ndarray                      # RoPE positions: (B, S) or
    #                                             (3, B, S) for M-RoPE
    seq_pos: Optional[jnp.ndarray] = None       # (B, S) sequence indices for
    #                                             masking & cache slots (only
    #                                             differs from positions for
    #                                             M-RoPE sequences)
    impl: str = "ref"                           # attention/scan impl
    causal: bool = True                         # False: ViT / whisper encoder
    encoder_out: Optional[jnp.ndarray] = None   # (B, F, D) for cross-attn
    remat: bool = False
    unroll: bool = False                        # unroll layer scans (dry-run
    #                                             analysis: exact HLO costs)
    has_context: bool = False                   # prefill continuation: the
    #                                             cache already holds earlier
    #                                             chunks, attend over it
    #                                             (write-then-attend) instead
    #                                             of chunk-local causal

    @property
    def decoding(self) -> bool:
        return self.mode == "decode"


def _pos2d(ctx: Ctx) -> jnp.ndarray:
    return ctx.positions[0] if ctx.positions.ndim == 3 else ctx.positions


def _seq_pos(ctx: Ctx) -> jnp.ndarray:
    return ctx.seq_pos if ctx.seq_pos is not None else _pos2d(ctx)


# ---------------------------------------------------------------- attention
def init_attention(key, cfg: ModelConfig, *, cross: bool = False) -> Params:
    att = cfg.attention
    D = cfg.d_model
    ks = jax.random.split(key, 8)
    p: Params = {"ln": norm_init(D, cfg.norm)}
    if att.mla is not None:
        m = att.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        p["q_a"] = dense_init(ks[0], D, m.q_lora_rank)
        p["q_a_ln"] = norm_init(m.q_lora_rank, "rmsnorm")
        p["q_b"] = dense_init(ks[1], m.q_lora_rank, att.n_heads * qk)
        p["kv_a"] = dense_init(ks[2], D, m.kv_lora_rank + m.qk_rope_head_dim)
        p["kv_a_ln"] = norm_init(m.kv_lora_rank, "rmsnorm")
        p["kv_b"] = dense_init(
            ks[3], m.kv_lora_rank,
            att.n_heads * (m.qk_nope_head_dim + m.v_head_dim))
        p["o"] = dense_init(ks[4], att.n_heads * m.v_head_dim, D)
    else:
        p["q"] = dense_init(ks[0], D, att.n_heads * att.head_dim, bias=att.qkv_bias)
        p["k"] = dense_init(ks[1], D, att.n_kv_heads * att.head_dim, bias=att.qkv_bias)
        p["v"] = dense_init(ks[2], D, att.n_kv_heads * att.head_dim, bias=att.qkv_bias)
        p["o"] = dense_init(ks[3], att.n_heads * att.head_dim, D)
    if cross:
        p["ln_cross"] = norm_init(D, cfg.norm)
    return p


def init_attn_cache(cfg: ModelConfig, batch: int, window: int,
                    dtype=jnp.float32) -> Params:
    att = cfg.attention
    if att.mla is not None:
        m = att.mla
        return {
            "ckv": jnp.zeros((batch, window, m.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, window, m.qk_rope_head_dim), dtype),
            "positions": jnp.full((batch, window), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, window, att.n_kv_heads, att.head_dim), dtype),
        "v": jnp.zeros((batch, window, att.n_kv_heads, att.head_dim), dtype),
        "positions": jnp.full((batch, window), -1, jnp.int32),
    }


def _cache_write(cache: Params, names: Tuple[str, ...], values, pos: jnp.ndarray):
    """Ring-buffer write of one decode step at absolute position `pos` (B,)."""
    window = cache["positions"].shape[1]
    slot = pos % window                                  # (B,)
    out = dict(cache)
    for name, val in zip(names, values):
        # val (B, 1, ...) -> write into slot per batch row
        b_idx = jnp.arange(val.shape[0])
        out[name] = cache[name].at[b_idx, slot].set(val[:, 0])
    out["positions"] = cache["positions"].at[jnp.arange(pos.shape[0]), slot].set(pos)
    return out


def _paged_cache_write(cache: Params, names: Tuple[str, ...], values,
                       pos: jnp.ndarray):
    """One decode token into a paged pool: slot b's token at absolute
    position `pos[b]` lands at offset pos % page_size of physical page
    block_tables[b, pos // page_size]. Idle slots' tables point every block
    at the scratch page, so their (discarded) writes never touch a live
    page; duplicate scratch writes are fine because scratch is never read."""
    bt = cache["block_tables"]                           # (S, n_blocks)
    page_len = cache["positions"].shape[1]
    b_idx = jnp.arange(pos.shape[0])
    page = bt[b_idx, pos // page_len]                    # (B,)
    off = pos % page_len
    out = dict(cache)
    for name, val in zip(names, values):
        out[name] = cache[name].at[page, off].set(val[:, 0])
    out["positions"] = cache["positions"].at[page, off].set(pos)
    return out


def _gqa_attend(q, k, v, ctx: Ctx, att: AttentionConfig, *, window, softcap,
                kv_positions=None, q_offset=None, causal=True, scale=None):
    return flash_attention(
        q, k, v, causal=causal, q_offset=q_offset, kv_positions=kv_positions,
        sliding_window=window, softcap=softcap, scale=scale, impl=ctx.impl)


def apply_attention(p: Params, cfg: ModelConfig, x: jnp.ndarray, ctx: Ctx,
                    cache: Optional[Params], *, kind: str = "attn"):
    """Self-attention block half (pre-norm). Returns (residual_delta, cache)."""
    att = cfg.attention
    B, S, D = x.shape
    h = apply_norm(p["ln"], x, cfg.norm)
    window = att.sliding_window if kind == "attn_local" else None
    pos2d = _pos2d(ctx)
    sp = _seq_pos(ctx)

    if att.mla is not None:
        return _apply_mla(p, cfg, x, h, ctx, cache, window)

    q = dense(p["q"], h).reshape(B, S, att.n_heads, att.head_dim)
    k = dense(p["k"], h).reshape(B, S, att.n_kv_heads, att.head_dim)
    v = dense(p["v"], h).reshape(B, S, att.n_kv_heads, att.head_dim)

    if att.use_rope:
        if att.mrope_sections is not None and ctx.positions.ndim == 3:
            cos, sin = mrope_cos_sin(ctx.positions, att.mrope_sections,
                                     att.head_dim, att.rope_theta)
        else:
            cos, sin = rope_cos_sin(pos2d, att.head_dim, att.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = cache
    if ctx.mode == "decode":
        if cache is not None and "block_tables" in cache:
            # paged decode: write through the block table, then attend the
            # slot's pages (gather on XLA, scalar-prefetch on TPU Pallas)
            new_cache = _paged_cache_write(cache, ("k", "v"), (k, v),
                                           sp[:, 0])
            out = paged_decode_attention(
                q, new_cache["k"], new_cache["v"],
                block_tables=cache["block_tables"], q_positions=sp[:, 0],
                kv_positions=new_cache["positions"], sliding_window=window,
                softcap=att.attn_logit_softcap, impl=ctx.impl)
        else:
            # decode fast path: single-query cache-read kernel, never the
            # full flash machinery (see kernels/flash_attention/decode.py)
            new_cache = _cache_write(cache, ("k", "v"), (k, v), sp[:, 0])
            out = decode_attention(
                q, new_cache["k"], new_cache["v"], q_positions=sp[:, 0],
                kv_positions=new_cache["positions"], sliding_window=window,
                softcap=att.attn_logit_softcap, impl=ctx.impl)
    elif ctx.has_context and cache is not None:
        # chunked-prefill continuation: land this chunk's K/V in the cache
        # first, then attend over everything cached so far (earlier chunks
        # + this one) with absolute query positions
        w = cache["positions"].shape[1]
        slot = sp % w
        b_idx = jnp.arange(B)[:, None]
        new_cache = dict(cache)
        new_cache["k"] = cache["k"].at[b_idx, slot].set(k)
        new_cache["v"] = cache["v"].at[b_idx, slot].set(v)
        new_cache["positions"] = cache["positions"].at[b_idx, slot].set(sp)
        out = _gqa_attend(q, new_cache["k"], new_cache["v"], ctx, att,
                          window=window, softcap=att.attn_logit_softcap,
                          kv_positions=new_cache["positions"],
                          q_offset=sp[:, 0])
    else:
        out = _gqa_attend(q, k, v, ctx, att, window=window,
                          softcap=att.attn_logit_softcap, causal=ctx.causal)
        if ctx.mode == "prefill" and cache is not None:
            w = cache["positions"].shape[1]
            keep = min(w, S)
            new_cache = dict(cache)
            # store last `keep` tokens at slots pos % w (ring layout)
            tail_pos = sp[:, S - keep:]
            slot = tail_pos % w
            b_idx = jnp.arange(B)[:, None]
            new_cache["k"] = cache["k"].at[b_idx, slot].set(k[:, S - keep:])
            new_cache["v"] = cache["v"].at[b_idx, slot].set(v[:, S - keep:])
            new_cache["positions"] = cache["positions"].at[b_idx, slot].set(tail_pos)

    out = out.reshape(B, S, att.n_heads * att.head_dim)
    return dense(p["o"], out), new_cache


def _apply_mla(p: Params, cfg: ModelConfig, x, h, ctx: Ctx, cache, window):
    """DeepSeek-V3 Multi-head Latent Attention. The decode cache holds only
    the compressed latent (kv_lora + rope dims) — the memory win that makes
    long decode caches cheap."""
    att = cfg.attention
    m = att.mla
    if cache is not None and "block_tables" in cache:
        raise NotImplementedError(
            "paged KV cache does not support MLA latent caches")
    B, S, D = x.shape
    pos2d = _pos2d(ctx)
    sp = _seq_pos(ctx)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim

    q = dense(p["q_b"], apply_norm(p["q_a_ln"], dense(p["q_a"], h), "rmsnorm"))
    q = q.reshape(B, S, att.n_heads, qk)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]

    kv_a = dense(p["kv_a"], h)
    ckv = apply_norm(p["kv_a_ln"], kv_a[..., :m.kv_lora_rank], "rmsnorm")
    k_rope = kv_a[..., m.kv_lora_rank:]                     # (B, S, rope)

    cos, sin = rope_cos_sin(pos2d, m.qk_rope_head_dim, att.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)    # (B, S, 1, rope)

    def decompress(ckv_seq):
        kv = dense(p["kv_b"], ckv_seq)
        kv = kv.reshape(*ckv_seq.shape[:-1], att.n_heads,
                        m.qk_nope_head_dim + m.v_head_dim)
        return kv[..., :m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]

    new_cache = cache
    scale = qk ** -0.5
    if ctx.mode == "decode":
        new_cache = _cache_write(cache, ("ckv", "kr"),
                                 (ckv, k_rope[:, :, 0]), sp[:, 0])
        k_nope, v = decompress(new_cache["ckv"])            # (B, W, H, ·)
        kr = jnp.broadcast_to(
            new_cache["kr"][:, :, None, :],
            (*new_cache["kr"].shape[:2], att.n_heads, m.qk_rope_head_dim))
        k = jnp.concatenate([k_nope, kr], -1)
        qfull = jnp.concatenate([q_nope, q_rope], -1)
        out = decode_attention(
            qfull, k, v, q_positions=sp[:, 0],
            kv_positions=new_cache["positions"], sliding_window=window,
            softcap=att.attn_logit_softcap, scale=scale, impl=ctx.impl)
    else:
        k_nope, v = decompress(ckv)
        kr = jnp.broadcast_to(k_rope, (B, S, att.n_heads, m.qk_rope_head_dim))
        k = jnp.concatenate([k_nope, kr], -1)
        qfull = jnp.concatenate([q_nope, q_rope], -1)
        out = flash_attention(qfull, k, v, causal=True, sliding_window=window,
                              softcap=att.attn_logit_softcap, scale=scale,
                              impl=ctx.impl)
        if ctx.mode == "prefill" and cache is not None:
            w = cache["positions"].shape[1]
            keep = min(w, S)
            tail_pos = sp[:, S - keep:]
            slot = tail_pos % w
            b_idx = jnp.arange(B)[:, None]
            new_cache = dict(cache)
            new_cache["ckv"] = cache["ckv"].at[b_idx, slot].set(ckv[:, S - keep:])
            new_cache["kr"] = cache["kr"].at[b_idx, slot].set(
                k_rope[:, S - keep:, 0])
            new_cache["positions"] = cache["positions"].at[b_idx, slot].set(tail_pos)

    out = out.reshape(B, S, att.n_heads * m.v_head_dim)
    return dense(p["o"], out), new_cache


def apply_cross_attention(p: Params, cfg: ModelConfig, x, ctx: Ctx):
    """Cross-attention to ctx.encoder_out (whisper decoder)."""
    att = cfg.attention
    B, S, D = x.shape
    h = apply_norm(p["ln_cross"], x, cfg.norm)
    enc = ctx.encoder_out
    q = dense(p["cq"], h).reshape(B, S, att.n_heads, att.head_dim)
    k = dense(p["ck"], enc).reshape(B, enc.shape[1], att.n_kv_heads, att.head_dim)
    v = dense(p["cv"], enc).reshape(B, enc.shape[1], att.n_kv_heads, att.head_dim)
    out = flash_attention(q, k, v, causal=False, impl=ctx.impl)
    return dense(p["co"], out.reshape(B, S, -1))


# ---------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"ln": norm_init(D, cfg.norm)}
    if cfg.mlp_activation.endswith("_glu"):
        p["up"] = dense_init(ks[0], D, F)
        p["gate"] = dense_init(ks[1], D, F)
    else:
        p["up"] = dense_init(ks[0], D, F)
    p["down"] = dense_init(ks[2], F, D)
    return p


def _act(x, kind: str):
    if kind.startswith("gelu"):
        return jax.nn.gelu(x)
    if kind.startswith("silu"):
        return jax.nn.silu(x)
    if kind == "relu2":  # nemotron-4 squared ReLU [arXiv:2402.16819]
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def apply_mlp(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    h = apply_norm(p["ln"], x, cfg.norm)
    if cfg.mlp_activation.endswith("_glu"):
        h = _act(dense(p["gate"], h), cfg.mlp_activation) * dense(p["up"], h)
    else:
        h = _act(dense(p["up"], h), cfg.mlp_activation)
    return dense(p["down"], h)


# ---------------------------------------------------------------- MoE
@jax.custom_vjp
def _ragged_dot(lhs, rhs, group_sizes):
    """ragged_dot with a custom VJP: the built-in transpose rule produces a
    ragged op whose vmap rule is NYI (breaks grad-under-client-vmap).
    dlhs is another dim-0 ragged_dot (vmap-safe); drhs is a segment
    scatter-add — only materialized when expert weights are actually being
    differentiated (full-FT baselines; DCE'd for SFPrompt's frozen body)."""
    return jax.lax.ragged_dot(lhs, rhs, group_sizes)


def _ragged_dot_fwd(lhs, rhs, group_sizes):
    return jax.lax.ragged_dot(lhs, rhs, group_sizes), (lhs, rhs, group_sizes)


def _ragged_dot_bwd(res, dout):
    lhs, rhs, gs = res
    M = lhs.shape[0]
    G = rhs.shape[0]
    dlhs = jax.lax.ragged_dot(dout, jnp.swapaxes(rhs, 1, 2), gs)
    ids = jnp.repeat(jnp.arange(G), gs, total_repeat_length=M)
    drhs = jnp.zeros_like(rhs).at[ids].add(
        lhs[:, :, None] * dout[:, None, :])
    dgs = jnp.zeros(gs.shape, dtype=jax.dtypes.float0)
    return dlhs.astype(lhs.dtype), drhs, dgs


_ragged_dot.defvjp(_ragged_dot_fwd, _ragged_dot_bwd)


def init_moe(key, cfg: ModelConfig) -> Params:
    e = cfg.moe
    D, F, E = cfg.d_model, e.d_ff_expert, e.n_experts
    ks = jax.random.split(key, 6)
    s = 0.02
    p = {
        "ln": norm_init(D, cfg.norm),
        "router": dense_init(ks[0], D, E),
        "experts": {
            "up": s * jax.random.normal(ks[1], (E, D, F), jnp.float32),
            "gate": s * jax.random.normal(ks[2], (E, D, F), jnp.float32),
            "down": s * jax.random.normal(ks[3], (E, F, D), jnp.float32),
        },
    }
    if e.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=F * e.n_shared_experts)
        del p["shared"]["ln"]  # share the block norm
    return p


def apply_moe(p: Params, cfg: ModelConfig, x: jnp.ndarray):
    """Token-choice top-k MoE with DROPLESS sort-based dispatch.

    Tokens are sorted by expert assignment and pushed through
    jax.lax.ragged_dot (grouped GEMM — the megablocks pattern, MXU-native):
    FLOPs scale with *activated* expert paths (N*top_k), not E, keeping
    dry-run cost_analysis honest for 256-expert stacks, and no token is ever
    dropped, so decode and train routing agree exactly.
    Returns (delta, aux) where aux carries the load-balance loss.
    """
    e = cfg.moe
    B, S, D = x.shape
    N = B * S
    h = apply_norm(p["ln"], x, cfg.norm)
    flat = h.reshape(N, D)

    logits = dense(p["router"], flat).astype(jnp.float32)     # (N, E)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, e.top_k)              # (N, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], e.n_experts), 0)
    router_mean = jnp.mean(probs, 0)
    aux = e.load_balance_coef * e.n_experts * jnp.sum(density * router_mean)

    flat_e = top_e.reshape(-1)                                 # (N*k,)
    order = jnp.argsort(flat_e)                                # stable
    tok = order // e.top_k                                     # token per slot
    group_sizes = jnp.bincount(flat_e, length=e.n_experts).astype(jnp.int32)

    xs = flat[tok]                                             # (N*k, D) sorted
    # keep the dispatched tokens in the residual-stream layout (hidden dim
    # over 'model'): without this SPMD flip-flops between layouts around the
    # gather and inserts an involuntary full all-gather per MoE layer
    # (EXPERIMENTS.md #Perf pair B, iteration 2).
    try:
        from jax.sharding import PartitionSpec as _P
        mesh = jax.sharding.get_abstract_mesh()
        if (mesh is not None and "model" in dict(getattr(mesh, "shape", {}))
                and D % dict(mesh.shape)["model"] == 0):
            xs = jax.lax.with_sharding_constraint(xs, _P(None, "model"))
    except Exception:
        pass  # no mesh context (single-device CPU tests)
    we = p["experts"]
    gate = _ragged_dot(xs, we["gate"].astype(h.dtype), group_sizes)
    up = _ragged_dot(xs, we["up"].astype(h.dtype), group_sizes)
    hid = _act(gate, "silu_glu") * up
    ys = _ragged_dot(hid, we["down"].astype(h.dtype), group_sizes)

    gathered = ys * top_p.reshape(-1)[order][:, None].astype(h.dtype)
    y = jnp.zeros((N, D), h.dtype).at[tok].add(gathered)

    if "shared" in p:
        sh = p["shared"]
        hshared = _act(dense(sh["gate"], h), "silu_glu") * dense(sh["up"], h)
        y = y.reshape(B, S, D) + dense(sh["down"], hshared)
        return y, aux
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------- Mamba-2
def init_mamba2(key, cfg: ModelConfig) -> Params:
    m = cfg.mamba2
    D = cfg.d_model
    di = m.d_inner(D)
    H = m.n_heads(D)
    G = 1
    conv_dim = di + 2 * G * m.d_state
    ks = jax.random.split(key, 4)
    return {
        "ln": norm_init(D, cfg.norm),
        "in_proj": dense_init(ks[0], D, 2 * di + 2 * G * m.d_state + H),
        "conv_w": 0.02 * jax.random.normal(ks[1], (m.d_conv, conv_dim), jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(0.01 * jnp.ones((H,), jnp.float32))),
        "norm": norm_init(di, "rmsnorm"),
        "out_proj": dense_init(ks[2], di, D),
    }


def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    m = cfg.mamba2
    di = m.d_inner(cfg.d_model)
    H = m.n_heads(cfg.d_model)
    conv_dim = di + 2 * m.d_state
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, H, m.head_dim, m.d_state), jnp.float32),
    }


def _causal_conv1d(x, w, b, prev=None):
    """x (B, T, C); w (K, C) depthwise; prev (B, K-1, C) carried state."""
    K = w.shape[0]
    B, T, C = x.shape
    if prev is None:
        prev = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(xp[:, i:i + T] * w[i].astype(x.dtype) for i in range(K))
    new_prev = xp[:, T:]
    return out + b.astype(x.dtype), new_prev


def apply_mamba2(p: Params, cfg: ModelConfig, x: jnp.ndarray, ctx: Ctx,
                 cache: Optional[Params]):
    m = cfg.mamba2
    B, S, D = x.shape
    di = m.d_inner(D)
    H = m.n_heads(D)
    G, N = 1, m.d_state
    h = apply_norm(p["ln"], x, cfg.norm)
    zxbcdt = dense(p["in_proj"], h)
    z, xin, BC, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * G * N], -1)
    conv_in = jnp.concatenate([xin, BC], -1)
    prev = cache["conv"] if cache is not None else None
    conv_out, new_prev = _causal_conv1d(conv_in, p["conv_w"], p["conv_b"], prev)
    conv_out = jax.nn.silu(conv_out)
    xin, Bm, Cm = jnp.split(conv_out, [di, di + G * N], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,S,H)
    A = -jnp.exp(p["A_log"])

    xh = xin.reshape(B, S, H, m.head_dim)
    ssm_state = cache["ssm"] if cache is not None else None
    y, new_ssm = mamba2_scan(
        xh, dt, A, Bm.reshape(B, S, G, N), Cm.reshape(B, S, G, N),
        ssm_state, impl=ctx.impl)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    y = apply_norm(p["norm"], y, "rmsnorm")
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_prev, "ssm": new_ssm}
    return dense(p["out_proj"], y), new_cache


# ---------------------------------------------------------------- RWKV-6
def init_rwkv6(key, cfg: ModelConfig) -> Params:
    r6 = cfg.rwkv6
    D = cfg.d_model
    H = D // r6.head_size
    ks = jax.random.split(key, 10)
    s = 0.02
    return {
        "ln_t": norm_init(D, "layernorm"),
        "mu": s * jax.random.normal(ks[0], (5, D), jnp.float32),  # r,k,v,w,g lerps
        "w_lora_a": s * jax.random.normal(ks[1], (D, r6.decay_lora_rank), jnp.float32),
        "w_lora_b": s * jax.random.normal(ks[2], (r6.decay_lora_rank, D), jnp.float32),
        "w0": jnp.zeros((D,), jnp.float32),
        "r": dense_init(ks[3], D, D),
        "k": dense_init(ks[4], D, D),
        "v": dense_init(ks[5], D, D),
        "g": dense_init(ks[6], D, D),
        "u": s * jax.random.normal(ks[7], (H, r6.head_size), jnp.float32),
        "gn": {"scale": jnp.ones((D,), jnp.float32),
               "bias": jnp.zeros((D,), jnp.float32)},
        "o": dense_init(ks[8], D, D),
        # channel mix
        "ln_c": norm_init(D, "layernorm"),
        "mu_c": s * jax.random.normal(ks[9], (2, D), jnp.float32),
        "ck": dense_init(jax.random.fold_in(key, 101), D, cfg.d_ff),
        "cv": dense_init(jax.random.fold_in(key, 102), cfg.d_ff, D),
        "cr": dense_init(jax.random.fold_in(key, 103), D, D),
    }


def init_rwkv6_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    r6 = cfg.rwkv6
    D = cfg.d_model
    H = D // r6.head_size
    return {
        "shift_t": jnp.zeros((batch, D), dtype),
        "shift_c": jnp.zeros((batch, D), dtype),
        "state": jnp.zeros((batch, H, r6.head_size, r6.head_size), jnp.float32),
    }


def _token_shift(x, prev):
    """xx_t = x_{t-1}; first position uses carried `prev` (B, D) or zeros."""
    B, S, D = x.shape
    if prev is None:
        prev = jnp.zeros((B, D), x.dtype)
    return jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1)


def apply_rwkv6(p: Params, cfg: ModelConfig, x: jnp.ndarray, ctx: Ctx,
                cache: Optional[Params]):
    """RWKV-6 block = time-mix (data-dependent decay recurrence) +
    channel-mix, each with token-shift. [arXiv:2404.05892]"""
    r6 = cfg.rwkv6
    B, S, D = x.shape
    H, K = D // r6.head_size, r6.head_size

    # ---- time mix
    h = apply_norm(p["ln_t"], x, "layernorm")
    xx = _token_shift(h, cache["shift_t"] if cache else None)
    mix = lambda i: h + (xx - h) * p["mu"][i].astype(h.dtype)
    mr, mk, mv, mw, mg = (mix(i) for i in range(5))
    r = dense(p["r"], mr).reshape(B, S, H, K)
    k = dense(p["k"], mk).reshape(B, S, H, K)
    v = dense(p["v"], mv).reshape(B, S, H, K)
    g = jax.nn.silu(dense(p["g"], mg))
    # data-dependent decay (Finch): w = w0 + tanh(mw A) B, log-decay -exp(w)
    wdd = p["w0"] + jnp.tanh(mw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    w = -jnp.exp(wdd).reshape(B, S, H, K)

    state = cache["state"] if cache else None
    y, new_state = rwkv6_scan(r, k, v, w, p["u"], state, impl=ctx.impl)
    y = y.reshape(B, S, D)
    # per-head groupnorm
    yg = y.reshape(B, S, H, K).astype(jnp.float32)
    mu = yg.mean(-1, keepdims=True)
    var = ((yg - mu) ** 2).mean(-1, keepdims=True)
    yg = ((yg - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, D)
    y = (yg * p["gn"]["scale"] + p["gn"]["bias"]).astype(x.dtype) * g
    tdelta = dense(p["o"], y)
    x = x + tdelta

    # ---- channel mix
    hc = apply_norm(p["ln_c"], x, "layernorm")
    xxc = _token_shift(hc, cache["shift_c"] if cache else None)
    mkc = hc + (xxc - hc) * p["mu_c"][0].astype(hc.dtype)
    mrc = hc + (xxc - hc) * p["mu_c"][1].astype(hc.dtype)
    kk = jax.nn.relu(dense(p["ck"], mkc))
    cdelta = jax.nn.sigmoid(dense(p["cr"], mrc)) * dense(p["cv"], kk * kk)

    new_cache = None
    if cache is not None:
        new_cache = {"shift_t": h[:, -1], "shift_c": hc[:, -1],
                     "state": new_state}
    return tdelta + cdelta, new_cache  # caller adds to the residual stream


def init_cross_attention_extra(key, cfg: ModelConfig) -> Params:
    """Extra q/k/v/o for the cross-attention half of a decoder block."""
    att = cfg.attention
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "cq": dense_init(ks[0], D, att.n_heads * att.head_dim),
        "ck": dense_init(ks[1], D, att.n_kv_heads * att.head_dim),
        "cv": dense_init(ks[2], D, att.n_kv_heads * att.head_dim),
        "co": dense_init(ks[3], att.n_heads * att.head_dim, D),
    }
