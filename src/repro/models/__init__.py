from repro.models.config import (  # noqa: F401
    AttentionConfig, EncoderConfig, Mamba2Config, MLAConfig, ModelConfig,
    MoEConfig, RWKV6Config)
from repro.models.transformer import Transformer  # noqa: F401
