"""Model configuration schema.

A single declarative config drives every assigned architecture: the layer
stack is a repeating *cycle* of block types (e.g. gemma2 alternates
local/global attention; zamba2 interleaves one shared-weight attention block
into runs of mamba2 blocks). The transformer assembles the stack by scanning
over stacked per-cycle parameters, which keeps HLO size independent of depth.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# Block kinds understood by repro.models.transformer
ATTN_KINDS = ("attn", "attn_local", "attn_global", "shared_attn", "cross_attn")
SSM_KINDS = ("mamba2", "rwkv6")


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention [arXiv:2412.19437]."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    use_rope: bool = True               # False: whisper (abs-pos instead)
    qkv_bias: bool = False
    attn_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None          # used by 'attn_local' blocks
    mrope_sections: Optional[Tuple[int, int, int]] = None  # Qwen2-VL M-RoPE
    mla: Optional[MLAConfig] = None

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    router_noise: float = 0.0
    load_balance_coef: float = 0.01


@dataclass(frozen=True)
class Mamba2Config:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RWKV6Config:
    head_size: int = 64
    decay_lora_rank: int = 64


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). The modality frontend
    (mel + conv) is a stub: input_specs supplies frame embeddings."""
    n_layers: int = 6
    n_frames: int = 1500


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio | vit
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    layer_pattern: Tuple[str, ...] = ("attn",)
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    mamba2: Optional[Mamba2Config] = None
    rwkv6: Optional[RWKV6Config] = None
    mlp_activation: str = "silu_glu"    # gelu | gelu_glu | silu_glu | relu2
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    final_logit_softcap: Optional[float] = None
    tie_embeddings: bool = False
    num_classes: Optional[int] = None   # ViT-style classifier head
    encoder: Optional[EncoderConfig] = None
    n_dense_layers: int = 0             # leading dense layers in MoE stacks
    mtp: bool = False                   # DeepSeek multi-token-prediction head
    max_seq_len: int = 8192
    # Ring-buffer sliding-window decode cache used for long_500k on attention
    # archs without native sub-quadratic structure (beyond-paper feature).
    long_context_window: Optional[int] = None
    source: str = ""                    # citation

    def __post_init__(self):
        cyc = len(self.layer_pattern)
        n_patterned = self.n_layers - self.n_dense_layers
        if n_patterned % cyc != 0:
            raise ValueError(
                f"{self.name}: {n_patterned} patterned layers not divisible "
                f"by cycle length {cyc}")
        if any(k in ATTN_KINDS for k in self.layer_pattern) and self.attention is None:
            raise ValueError(f"{self.name}: attention blocks need AttentionConfig")
        if "moe" in self.layer_pattern and self.moe is None:
            raise ValueError(f"{self.name}: moe blocks need MoEConfig")

    @property
    def n_cycles(self) -> int:
        return (self.n_layers - self.n_dense_layers) // len(self.layer_pattern)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder is not None

    def reduced(self, *, n_layers: int = 2, d_model: int = 256,
                d_ff: int = 512, vocab_size: int = 512,
                max_experts: int = 4, max_seq_len: int = 256) -> "ModelConfig":
        """A small same-family variant for CPU smoke tests."""
        att = self.attention
        if att is not None:
            head_dim = 32
            n_heads = max(2, min(4, d_model // head_dim))
            n_kv = min(att.n_kv_heads, n_heads)
            while n_heads % n_kv:
                n_kv -= 1
            mla = MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=16,
                            qk_rope_head_dim=16, v_head_dim=32) if att.mla else None
            mrope = None
            if att.mrope_sections is not None:
                half = head_dim // 2
                mrope = (half - 2 * (half * 3 // 8), half * 3 // 8, half * 3 // 8)
            att = dataclasses.replace(
                att, n_heads=n_heads, n_kv_heads=n_kv, head_dim=head_dim,
                sliding_window=(64 if att.sliding_window else None), mla=mla,
                mrope_sections=mrope)
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe, n_experts=min(moe.n_experts, max_experts),
                top_k=min(moe.top_k, 2), d_ff_expert=d_ff // 2)
        mamba2 = Mamba2Config(d_state=16, d_conv=4, expand=2, head_dim=32) \
            if self.mamba2 else None
        rwkv6 = RWKV6Config(head_size=32, decay_lora_rank=16) if self.rwkv6 else None
        enc = EncoderConfig(n_layers=1, n_frames=16) if self.encoder else None
        cyc = len(self.layer_pattern)
        n_dense = min(self.n_dense_layers, 1)
        # keep at least one full pattern cycle
        n_layers = max(n_layers, cyc) + n_dense
        if (n_layers - n_dense) % cyc:
            n_layers = cyc + n_dense
        return dataclasses.replace(
            self, name=self.name + "-reduced", n_layers=n_layers,
            d_model=d_model, d_ff=d_ff, vocab_size=vocab_size,
            attention=att, moe=moe, mamba2=mamba2, rwkv6=rwkv6,
            encoder=enc, n_dense_layers=n_dense, max_seq_len=max_seq_len,
            num_classes=(min(self.num_classes, 10) if self.num_classes else None),
            long_context_window=(128 if self.long_context_window else None))

    def param_count(self) -> int:
        """Analytic parameter count (used by the cost model)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        total = V * D  # embeddings
        if not self.tie_embeddings:
            total += D * (self.num_classes or V)
        per_kind = {}
        att = self.attention
        if att is not None:
            if att.mla is not None:
                m = att.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                a = (D * m.q_lora_rank + m.q_lora_rank * att.n_heads * qk
                     + D * (m.kv_lora_rank + m.qk_rope_head_dim)
                     + m.kv_lora_rank * att.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                     + att.n_heads * m.v_head_dim * D)
            else:
                a = (D * att.n_heads * att.head_dim
                     + 2 * D * att.n_kv_heads * att.head_dim
                     + att.n_heads * att.head_dim * D)
            mlp_mult = 3 if self.mlp_activation.endswith("_glu") else 2
            per_kind.update({k: a + mlp_mult * D * F for k in
                             ("attn", "attn_local", "attn_global", "shared_attn")})
            per_kind["cross_attn"] = 2 * a + mlp_mult * D * F
        if self.moe is not None:
            e = self.moe
            per_expert = 3 * D * e.d_ff_expert
            per_kind["moe"] = (a + D * e.n_experts
                               + (e.n_experts + e.n_shared_experts) * per_expert)
        if self.mamba2 is not None:
            m = self.mamba2
            di = m.d_inner(D)
            per_kind["mamba2"] = (D * (2 * di + 2 * m.d_state + m.n_heads(D))
                                  + di * D + m.d_conv * (di + 2 * m.d_state))
        if self.rwkv6 is not None:
            r6 = self.rwkv6
            per_kind["rwkv6"] = (6 * D * D + 2 * D * F
                                 + 2 * D * r6.decay_lora_rank + 12 * D)
        shared_seen = False
        for i in range(self.n_dense_layers):
            total += per_kind.get("attn", 0)
        for _ in range(self.n_cycles):
            for kind in self.layer_pattern:
                if kind == "shared_attn":
                    if not shared_seen:
                        total += per_kind[kind]
                        shared_seen = True
                else:
                    total += per_kind.get(kind, 0)
        if self.encoder is not None:
            total += self.encoder.n_layers * per_kind.get("attn", 0)
        return int(total)
