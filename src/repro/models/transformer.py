"""Transformer assembly: config-driven stacks for all assigned architectures.

The layer stack is grouped by the config's layer-pattern cycle and executed
with lax.scan over stacked per-cycle parameters, so HLO size (and CPU
dry-run compile time) is independent of depth. Decode caches are stacked the
same way and threaded through the scan as xs/ys.

Modes:
  train   — full-sequence forward, no cache
  prefill — full-sequence forward, fills a (possibly ring-buffer) cache
  decode  — one token per call against the cache
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ATTN_KINDS, ModelConfig

Params = Dict[str, Any]


def _is_attn(kind: str) -> bool:
    return kind in ATTN_KINDS


# ------------------------------------------------------------------ blocks
def init_block(key, cfg: ModelConfig, kind: str) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("attn", "attn_local", "attn_global", "shared_attn"):
        return {"attn": L.init_attention(k1, cfg), "mlp": L.init_mlp(k2, cfg)}
    if kind == "cross_attn":
        p = {"attn": L.init_attention(k1, cfg, cross=True),
             "mlp": L.init_mlp(k2, cfg)}
        p["attn"].update(L.init_cross_attention_extra(k3, cfg))
        return p
    if kind == "moe":
        return {"attn": L.init_attention(k1, cfg), "moe": L.init_moe(k2, cfg)}
    if kind == "mamba2":
        return {"mamba": L.init_mamba2(k1, cfg)}
    if kind == "rwkv6":
        return {"rwkv": L.init_rwkv6(k1, cfg)}
    raise ValueError(kind)


def apply_block(params: Params, cfg: ModelConfig, kind: str, x, ctx: L.Ctx,
                cache):
    """-> (x, new_cache, aux_loss)"""
    aux = jnp.float32(0.0)
    if kind in ("attn", "attn_local", "attn_global", "shared_attn",
                "cross_attn", "moe"):
        delta, new_cache = L.apply_attention(
            params["attn"], cfg, x, ctx, cache, kind=kind)
        x = x + delta
        if kind == "cross_attn":
            x = x + L.apply_cross_attention(params["attn"], cfg, x, ctx)
        if kind == "moe":
            delta, aux = L.apply_moe(params["moe"], cfg, x)
            x = x + delta
        else:
            x = x + L.apply_mlp(params["mlp"], cfg, x)
        return x, new_cache, aux
    if kind == "mamba2":
        delta, new_cache = L.apply_mamba2(params["mamba"], cfg, x, ctx, cache)
        return x + delta, new_cache, aux
    if kind == "rwkv6":
        delta, new_cache = L.apply_rwkv6(params["rwkv"], cfg, x, ctx, cache)
        return x + delta, new_cache, aux
    raise ValueError(kind)


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                     dtype=jnp.float32, window=None):
    """window: optional ring-buffer cap (long_500k passes
    cfg.long_context_window); local-attention layers additionally cap at
    their sliding window — their cache never needs to be larger."""
    if _is_attn(kind) or kind == "moe":
        att = cfg.attention
        eff = seq_len if window is None else min(seq_len, window)
        if kind == "attn_local" and att.sliding_window:
            eff = min(eff, att.sliding_window)
        return L.init_attn_cache(cfg, batch, max(eff, 1), dtype)
    if kind == "mamba2":
        return L.init_mamba2_cache(cfg, batch, dtype)
    if kind == "rwkv6":
        return L.init_rwkv6_cache(cfg, batch, dtype)
    raise ValueError(kind)


# ---------------------------------------------------- reusable stack runner
def init_stack(key, cfg: ModelConfig, kind: str, n: int) -> Params:
    """Stacked params for n layers of one kind (leading dim n)."""
    ks = jax.random.split(key, n)
    per = [init_block(k, cfg, kind) for k in ks]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


def run_stack(cfg: ModelConfig, stacked: Params, kinds, x, ctx: L.Ctx,
              caches=None, shared: Optional[Params] = None):
    """Scan a stacked layer group. `stacked` maps 'pos{i}' -> stacked params
    for cycle position i; `caches` mirrors that layout (or None).
    Returns (x, aux_loss, new_caches)."""
    has_cache = caches is not None

    def body(carry, xs):
        x, aux = carry
        p_cyc, c_cyc = xs if has_cache else (xs, None)
        new_caches = {}
        for i, kind in enumerate(kinds):
            p = shared if kind == "shared_attn" else p_cyc[f"pos{i}"]
            c = c_cyc[f"pos{i}"] if has_cache else None
            x, nc, a = apply_block(p, cfg, kind, x, ctx, c)
            aux = aux + a
            if has_cache:
                new_caches[f"pos{i}"] = nc
        return (x, aux), (new_caches if has_cache else None)

    if ctx.remat:
        body = jax.checkpoint(body)
    xs = (stacked, caches) if has_cache else stacked
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.float32(0.0)), xs, unroll=True if ctx.unroll else 1)
    return x, aux, new_caches


def stack_cache(cfg: ModelConfig, kind: str, n: int, batch: int,
                seq_len: int, dtype=jnp.float32, window=None):
    one = init_block_cache(cfg, kind, batch, seq_len, dtype, window=window)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), one)


# ------------------------------------------------------------------ model
class Transformer:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------- init
    def init(self, key) -> Params:
        cfg = self.cfg
        keys = iter(jax.random.split(key, 64))
        params: Params = {}
        if cfg.arch_type == "vit":
            patch_dim = 16 * 16 * 3
            params["embed"] = {
                "patch": L.dense_init(next(keys), patch_dim, cfg.d_model),
                "cls": 0.02 * jax.random.normal(next(keys), (1, cfg.d_model)),
                "pos": 0.02 * jax.random.normal(
                    next(keys), (cfg.max_seq_len, cfg.d_model)),
            }
        else:
            params["embed"] = {
                "tok": 0.02 * jax.random.normal(
                    next(keys), (cfg.vocab_size, cfg.d_model), jnp.float32)}

        if cfg.n_dense_layers:
            params["dense_stack"] = {"pos0": self._init_stack(
                next(keys), ("attn",), cfg.n_dense_layers)}

        # one stacked group per cycle position
        cyc = {}
        for i, kind in enumerate(cfg.layer_pattern):
            if kind == "shared_attn":
                continue  # weights shared, initialized once below
            cyc[f"pos{i}"] = self._init_stack(
                next(keys), (kind,), cfg.n_cycles)
        params["cycle"] = cyc
        if "shared_attn" in cfg.layer_pattern:
            params["shared_attn"] = init_block(next(keys), cfg, "shared_attn")

        params["final_norm"] = L.norm_init(cfg.d_model, cfg.norm)
        out_dim = cfg.num_classes or cfg.vocab_size
        if not cfg.tie_embeddings or cfg.num_classes:
            params["head"] = L.dense_init(next(keys), cfg.d_model, out_dim)

        if cfg.encoder is not None:
            enc = {"cycle": {"pos0": self._init_stack(
                next(keys), ("attn",), cfg.encoder.n_layers)},
                "final_norm": L.norm_init(cfg.d_model, cfg.norm)}
            params["encoder"] = enc
        if cfg.mtp:
            params["mtp"] = {
                "proj": L.dense_init(next(keys), 2 * cfg.d_model, cfg.d_model),
                "block": init_block(next(keys), cfg, "attn"),
                "norm": L.norm_init(cfg.d_model, cfg.norm),
            }
        return params

    def _init_stack(self, key, kinds, n: int) -> Params:
        return init_stack(key, self.cfg, kinds[0], n)

    # ---------------- caches
    def init_cache(self, batch: int, seq_len: int, dtype=jnp.float32,
                   window=None) -> Params:
        cfg = self.cfg
        cache: Params = {"cycle": {}}

        def stack(kind, n):
            one = init_block_cache(cfg, kind, batch, seq_len, dtype,
                                   window=window)
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), one)

        if cfg.n_dense_layers:
            cache["dense_stack"] = {"pos0": stack("attn", cfg.n_dense_layers)}
        for i, kind in enumerate(cfg.layer_pattern):
            cache["cycle"][f"pos{i}"] = stack(kind, cfg.n_cycles)
        if cfg.encoder is not None:
            cache["encoder_out"] = jnp.zeros(
                (batch, cfg.encoder.n_frames, cfg.d_model), dtype)
        return cache

    # ---------------- scan over a homogeneous stacked group
    def _run_stack(self, stacked: Params, kinds, x, ctx: L.Ctx, caches,
                   shared: Optional[Params] = None):
        return run_stack(self.cfg, stacked, kinds, x, ctx, caches,
                         shared=shared)

    # ---------------- embedding frontends
    def _embed(self, params, batch: Dict[str, jnp.ndarray], ctx_mode: str,
               prompts: Optional[jnp.ndarray], dtype):
        """Returns (x, positions, n_prefix) — n_prefix = prompt+patch tokens."""
        cfg = self.cfg
        emb = params["embed"]

        if cfg.arch_type == "vit":
            patches = batch["patches"]                         # (B, N, ppc)
            B = patches.shape[0]
            x = L.dense(emb["patch"], patches)
            cls = jnp.broadcast_to(emb["cls"][None], (B, 1, cfg.d_model))
            x = jnp.concatenate([cls.astype(x.dtype), x], 1)
            if prompts is not None:
                pr = jnp.broadcast_to(prompts[None], (B,) + prompts.shape)
                x = jnp.concatenate([x[:, :1], pr.astype(x.dtype), x[:, 1:]], 1)
            x = x + emb["pos"][: x.shape[1]].astype(x.dtype)
            pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None],
                                   x.shape[:2])
            return x, pos, pos, 0

        toks = batch["tokens"]
        B, S = toks.shape
        x = jnp.take(emb["tok"].astype(dtype), toks, axis=0)
        n_prefix = 0
        if cfg.arch_type == "audio":
            # whisper decoder: absolute positions, no RoPE
            if ctx_mode == "decode":
                apos = batch["pos"][:, None]
            else:
                apos = jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            x = x + L.sinusoidal_embedding(apos, cfg.d_model).astype(dtype)

        if cfg.arch_type == "vlm" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(dtype)           # (B, Np, D)
            x = jnp.concatenate([pe, x], axis=1)
            n_prefix += pe.shape[1]

        if prompts is not None and ctx_mode != "decode":
            pr = jnp.broadcast_to(prompts[None], (B,) + prompts.shape)
            x = jnp.concatenate([pr.astype(dtype), x], axis=1)
            n_prefix += prompts.shape[0]

        T = x.shape[1]
        if ctx_mode == "decode":
            base = batch["pos"][:, None]                       # (B, 1)
        else:
            base = jnp.broadcast_to(
                jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        base = base.astype(jnp.int32)

        att = cfg.attention
        if att is not None and att.mrope_sections is not None:
            # M-RoPE: layout is [prompt | patches | text]. Patches carry
            # (t, h, w) grid positions from the frontend stub (offset past
            # the prompts); prompt/text stream positions are the sequence
            # index on all three channels. Masking & cache slots use `base`.
            if ctx_mode != "decode" and "mrope_positions" in batch:
                # stored client-axis-first as (B, 3, Np); model wants (3, B, Np)
                grid = jnp.moveaxis(
                    batch["mrope_positions"], 1, 0).astype(jnp.int32)
                npz = grid.shape[-1]
                npr = n_prefix - npz
                b3 = jnp.broadcast_to(base[None], (3, B, T))
                pos = jnp.concatenate(
                    [b3[:, :, :npr], grid + npr, b3[:, :, npr + npz:]], -1)
            else:
                pos = jnp.broadcast_to(base[None], (3,) + base.shape)
            return x, pos, base, n_prefix
        return x, base, base, n_prefix

    # ---------------- public apply
    def apply(self, params: Params, batch: Dict[str, jnp.ndarray], *,
              mode: str = "train", cache: Optional[Params] = None,
              prompts: Optional[jnp.ndarray] = None, impl: str = "ref",
              remat: bool = False, dtype=jnp.float32) -> Dict[str, Any]:
        cfg = self.cfg
        out: Dict[str, Any] = {}

        # ----- encoder (whisper): frames -> encoder_out
        encoder_out = None
        if cfg.encoder is not None:
            if mode == "decode":
                encoder_out = cache["encoder_out"]
            else:
                frames = batch["frames"].astype(dtype)         # (B, F, D)
                Bf, F, _ = frames.shape
                fpos = jnp.broadcast_to(
                    jnp.arange(F, dtype=jnp.int32)[None], (Bf, F))
                h = frames + L.sinusoidal_embedding(
                    fpos, cfg.d_model).astype(dtype)
                ectx = L.Ctx(mode="train", positions=fpos, impl=impl,
                             remat=remat, causal=False)
                h, _, _ = self._run_stack(
                    params["encoder"]["cycle"], ("attn",), h, ectx, None)
                encoder_out = L.apply_norm(
                    params["encoder"]["final_norm"], h, cfg.norm)

        x, positions, seq_pos, n_prefix = self._embed(
            params, batch, mode, prompts, dtype)
        ctx = L.Ctx(mode=mode, positions=positions, seq_pos=seq_pos,
                    impl=impl, remat=remat, encoder_out=encoder_out,
                    causal=(cfg.arch_type != "vit"))

        new_cache = dict(cache) if cache is not None else None
        aux_total = jnp.float32(0.0)

        if cfg.n_dense_layers:
            c = new_cache.get("dense_stack") if new_cache else None
            x, aux, nc = self._run_stack(
                params["dense_stack"], ("attn",), x, ctx, c)
            aux_total += aux
            if new_cache is not None:
                new_cache["dense_stack"] = nc

        cyc_cache = new_cache["cycle"] if new_cache else None
        shared = params.get("shared_attn")
        stacked = params["cycle"].copy()
        if "shared_attn" in cfg.layer_pattern:
            # scanning needs an entry per position; shared weights come from
            # the closure, so feed an empty pytree at those positions.
            for i, kind in enumerate(cfg.layer_pattern):
                if kind == "shared_attn":
                    stacked[f"pos{i}"] = {"_": jnp.zeros((cfg.n_cycles,))}
        x, aux, nc = self._run_stack(
            stacked, cfg.layer_pattern, x, ctx, cyc_cache, shared=shared)
        aux_total += aux
        if new_cache is not None:
            new_cache["cycle"] = nc
            if cfg.encoder is not None and mode == "prefill":
                new_cache["encoder_out"] = encoder_out

        x = L.apply_norm(params["final_norm"], x, cfg.norm)

        if cfg.arch_type == "vit":
            logits = L.dense(params["head"], x[:, 0])          # cls token
            out.update(logits=logits, hidden=x, aux_loss=aux_total)
            return out

        out["hidden"] = x
        out["n_prefix"] = n_prefix
        head_w = (params["head"]["w"] if "head" in params
                  else params["embed"]["tok"].T)
        logits = x @ head_w.astype(x.dtype)
        if cfg.final_logit_softcap:
            c = cfg.final_logit_softcap
            logits = c * jnp.tanh(logits / c)
        out["logits"] = logits
        out["aux_loss"] = aux_total

        if cfg.mtp and mode == "train":
            # DeepSeek-V3 MTP: predict token t+2 from (h_t, emb(tok_{t+1}))
            toks = batch["tokens"]
            emb_next = jnp.take(params["embed"]["tok"].astype(x.dtype),
                                toks[:, 1:], axis=0)
            h_txt = x[:, n_prefix:, :]
            hcat = jnp.concatenate([h_txt[:, :-1], emb_next], -1)
            hm = L.dense(params["mtp"]["proj"], hcat)
            mpos = (positions[:, n_prefix:-1] if positions.ndim == 2
                    else positions[:, :, n_prefix:-1])
            mctx = L.Ctx(mode="train", positions=mpos, impl=impl)
            hm, _, _ = apply_block(params["mtp"]["block"], cfg, "attn", hm,
                                   mctx, None)
            hm = L.apply_norm(params["mtp"]["norm"], hm, cfg.norm)
            out["mtp_logits"] = hm @ head_w.astype(hm.dtype)

        if cache is not None:
            out["cache"] = new_cache
        return out
