"""ViT-L/16 [arXiv:2010.11929] — the paper's larger backbone for the
ViT-Large rows of Table 2. 24L, d_model=1024, 16 heads, d_ff=4096."""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="vit-large",
    arch_type="vit",
    n_layers=24,
    d_model=1024,
    d_ff=4096,
    vocab_size=1,
    layer_pattern=("attn",),
    attention=AttentionConfig(n_heads=16, n_kv_heads=16, head_dim=64,
                              use_rope=False),
    mlp_activation="gelu",
    norm="layernorm",
    num_classes=100,
    max_seq_len=512,
    source="arXiv:2010.11929 (SFPrompt Sec. 4.1)",
)
