"""DeepSeek-V3 671B [arXiv:2412.19437] — 61L, d_model=7168, MLA with 128
heads (q_lora 1536, kv_lora 512, nope/rope head dims 128/64, v 128); first 3
layers dense (d_ff=18432), remaining 58 MoE with 1 shared + 256 routed
experts top-8 (expert d_ff=2048); multi-token-prediction head; vocab 129280.
MLA's compressed decode cache (576 floats/token/layer) is what makes the
decode_32k/long_500k shapes cheap."""
from repro.models.config import (AttentionConfig, MLAConfig, ModelConfig,
                                 MoEConfig)

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    d_ff=18432,                       # dense (first-3) layers
    vocab_size=129_280,
    layer_pattern=("moe",),
    n_dense_layers=3,
    attention=AttentionConfig(
        n_heads=128, n_kv_heads=128, head_dim=192, rope_theta=10_000.0,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128)),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1),
    mtp=True,
    mlp_activation="silu_glu",
    norm="rmsnorm",
    max_seq_len=131_072,
    long_context_window=8192,
    source="arXiv:2412.19437",
)
