"""Gemma 2 9B [arXiv:2408.00118] — 42L, d_model=3584, 16 heads (GQA kv=8,
head_dim=256), d_ff=14336, vocab 256000; local(4096-window)/global
alternating attention; attention and final-logit softcapping; tied embeddings.
long_500k decode is natively sub-quadratic on local layers; global layers use
the ring-buffer window."""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    arch_type="dense",
    n_layers=42,
    d_model=3584,
    d_ff=14336,
    vocab_size=256_000,
    layer_pattern=("attn_local", "attn_global"),
    attention=AttentionConfig(n_heads=16, n_kv_heads=8, head_dim=256,
                              rope_theta=10_000.0, sliding_window=4096,
                              attn_logit_softcap=50.0),
    mlp_activation="gelu_glu",
    norm="rmsnorm",
    final_logit_softcap=30.0,
    tie_embeddings=True,
    max_seq_len=8192,
    long_context_window=8192,
    source="arXiv:2408.00118",
)
