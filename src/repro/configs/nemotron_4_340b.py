"""Nemotron-4 340B [arXiv:2402.16819] — 96L, d_model=18432, 96 heads
(GQA kv=8, head_dim=192), d_ff=73728, vocab 256000, squared-ReLU MLP.
The motivating regime for SFPrompt: no client could ever hold W_b."""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    n_layers=96,
    d_model=18432,
    d_ff=73728,
    vocab_size=256_000,
    layer_pattern=("attn",),
    attention=AttentionConfig(n_heads=96, n_kv_heads=8, head_dim=192,
                              rope_theta=10_000.0),
    mlp_activation="relu2",
    norm="layernorm",
    max_seq_len=4096,
    long_context_window=8192,
    source="arXiv:2402.16819",
)
