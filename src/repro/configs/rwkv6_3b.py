"""RWKV-6 (Finch) 3B [arXiv:2404.05892] — 32L, d_model=2560, attention-free
time-mix with data-dependent decay (head_size 64 -> 40 heads), channel-mix
d_ff=8960, vocab 65536. Decode state is O(1) in sequence length, so
long_500k runs natively."""
from repro.models.config import ModelConfig, RWKV6Config

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    n_layers=32,
    d_model=2560,
    d_ff=8960,
    vocab_size=65_536,
    layer_pattern=("rwkv6",),
    rwkv6=RWKV6Config(head_size=64, decay_lora_rank=64),
    norm="layernorm",
    max_seq_len=1_048_576,
    source="arXiv:2404.05892",
)
