"""Qwen2.5-14B [hf:Qwen/Qwen2.5-0.5B family] — 48L, d_model=5120, 40 heads
(GQA kv=8, head_dim=128), d_ff=13824, vocab 152064, QKV bias."""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    arch_type="dense",
    n_layers=48,
    d_model=5120,
    d_ff=13824,
    vocab_size=152_064,
    layer_pattern=("attn",),
    attention=AttentionConfig(n_heads=40, n_kv_heads=8, head_dim=128,
                              rope_theta=1_000_000.0, qkv_bias=True),
    mlp_activation="silu_glu",
    norm="rmsnorm",
    max_seq_len=32_768,
    long_context_window=8192,
    source="hf:Qwen/Qwen2.5-0.5B",
)
