"""Whisper base [arXiv:2212.04356] — enc-dec, 6+6L, d_model=512, 8 heads,
d_ff=2048, vocab 51865. The mel-spectrogram + conv frontend is a STUB per
the assignment carve-out: input_specs supplies 1500 frame embeddings.
Decoder layers = self-attn + cross-attn + MLP; absolute (sinusoidal)
positions, no RoPE."""
from repro.models.config import AttentionConfig, EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    n_layers=6,
    d_model=512,
    d_ff=2048,
    vocab_size=51_865,
    layer_pattern=("cross_attn",),
    attention=AttentionConfig(n_heads=8, n_kv_heads=8, head_dim=64,
                              use_rope=False),
    encoder=EncoderConfig(n_layers=6, n_frames=1500),
    mlp_activation="gelu",
    norm="layernorm",
    max_seq_len=1_048_576,   # structurally exercised; real model caps at 448
    long_context_window=8192,
    source="arXiv:2212.04356",
)
