"""StableLM 2 12B [hf:stabilityai/stablelm-2-1_6b family] — 40L,
d_model=5120, 32 heads (GQA kv=8, head_dim=160), d_ff=13824, vocab 100352."""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch_type="dense",
    n_layers=40,
    d_model=5120,
    d_ff=13824,
    vocab_size=100_352,
    layer_pattern=("attn",),
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=160,
                              rope_theta=10_000.0),
    mlp_activation="silu_glu",
    norm="layernorm",
    max_seq_len=4096,
    long_context_window=8192,
    source="hf:stabilityai/stablelm-2-1_6b",
)
