"""Architecture config registry.

Every assigned architecture is a selectable config (``--arch <id>``); the
paper's own ViT-Base/Large are included for the faithful reproduction of its
tables. IDs are the exact assignment strings.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES = {
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "gemma2-9b": "gemma2_9b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "stablelm-12b": "stablelm_12b",
    "qwen2.5-14b": "qwen2_5_14b",
    "rwkv6-3b": "rwkv6_3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "whisper-base": "whisper_base",
    "nemotron-4-340b": "nemotron_4_340b",
    "vit-base": "vit_base",
    "vit-large": "vit_large",
}

ASSIGNED: List[str] = [k for k in _MODULES if not k.startswith("vit-")]


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {k: get_config(k) for k in _MODULES}
