"""Phi-3.5-MoE-instruct: 42B total / 6.6B active.
[hf:microsoft/Phi-3.5-MoE-instruct] — 32L, d_model=4096, 32 heads (GQA kv=8),
16 experts top-2 with expert d_ff=6400, vocab 32064."""
from repro.models.config import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    d_ff=6400,
    vocab_size=32064,
    layer_pattern=("moe",),
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                              rope_theta=10_000.0),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400),
    mlp_activation="silu_glu",
    norm="layernorm",
    max_seq_len=131_072,
    long_context_window=8192,   # ring-buffer window for long_500k decode
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
