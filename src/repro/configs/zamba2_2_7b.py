"""Zamba2-2.7B [arXiv:2411.15242] — 54L, d_model=2560: Mamba-2 backbone
(ssm_state=64) with a single SHARED-WEIGHT attention block (32 heads,
d_ff=10240 MLP) applied every 6th layer (weight sharing is honored: one
parameter set, 9 cache sites). Hybrid state decode -> long_500k native."""
from repro.models.config import AttentionConfig, Mamba2Config, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    n_layers=54,
    d_model=2560,
    d_ff=10240,
    vocab_size=32_000,
    layer_pattern=("mamba2",) * 5 + ("shared_attn",),
    attention=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=80,
                              rope_theta=10_000.0),
    mamba2=Mamba2Config(d_state=64, d_conv=4, expand=2, head_dim=64),
    mlp_activation="gelu_glu",
    norm="rmsnorm",
    max_seq_len=1_048_576,
    long_context_window=8192,   # for the shared attention block's cache
    source="arXiv:2411.15242",
)
