"""ViT-B/16 [arXiv:2010.11929] — the paper's own backbone (pre-trained on
ImageNet-21k in the paper; randomly initialized here). 12L, d_model=768,
12 heads, d_ff=3072; 224x224 images -> 196 patches + CLS + prompts."""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="vit-base",
    arch_type="vit",
    n_layers=12,
    d_model=768,
    d_ff=3072,
    vocab_size=1,                 # unused for ViT
    layer_pattern=("attn",),
    attention=AttentionConfig(n_heads=12, n_kv_heads=12, head_dim=64,
                              use_rope=False),
    mlp_activation="gelu",
    norm="layernorm",
    num_classes=100,
    max_seq_len=512,              # 196 patches + cls + up to ~300 prompts
    source="arXiv:2010.11929 (SFPrompt Sec. 4.1)",
)
