"""Qwen2-VL-72B [arXiv:2409.12191] — 80L, d_model=8192, 64 heads (GQA kv=8),
d_ff=29568, vocab 152064; M-RoPE (temporal/height/width sections 16/24/24 of
the 64 half-dims); QKV bias. The ViT vision encoder is a STUB per the
assignment carve-out: input_specs supplies pre-projected patch embeddings and
their M-RoPE grid positions."""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    n_layers=80,
    d_model=8192,
    d_ff=29568,
    vocab_size=152_064,
    layer_pattern=("attn",),
    attention=AttentionConfig(n_heads=64, n_kv_heads=8, head_dim=128,
                              rope_theta=1_000_000.0, qkv_bias=True,
                              mrope_sections=(16, 24, 24)),
    mlp_activation="silu_glu",
    norm="rmsnorm",
    max_seq_len=32_768,
    long_context_window=8192,
    source="arXiv:2409.12191",
)
