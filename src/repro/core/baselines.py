"""Baselines the paper compares against (Sec. 4.1).

  FL          — FedAvg full fine-tuning: every client trains the ENTIRE
                model locally for U epochs; all parameters aggregate.
  SFL+FF      — SplitFed [Thapa et al. 2022] with full fine-tuning: client
                parts (head+tail) train per-client, the server body trains
                on the mean gradient across the parallel clients.
  SFL+Linear  — SplitFed, only the final linear (task head) trains.

(SFPrompt-without-local-loss — the Fig. 6 ablation — is ProtocolConfig
(use_local_loss=False); SFPrompt-without-pruning is use_pruning=False.)

All baselines reuse the SplitModel forward; they differ only in which
subtrees receive gradients and in the cost-model entries (core/comm.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import losses
from repro.core.aggregation import broadcast_to_clients, fedavg
from repro.core.split import SplitModel
from repro.optim import apply_updates, sgd

Params = Dict[str, Any]


@dataclass(frozen=True)
class BaselineConfig:
    local_epochs: int = 10       # U (FL); SFL interacts per batch anyway
    batch_size: int = 16
    lr: float = 1e-2
    momentum: float = 0.9
    impl: str = "ref"


def _batched(data, batch_size):
    n = jax.tree.leaves(data)[0].shape[0]
    nb = max(1, n // batch_size)
    return jax.tree.map(
        lambda x: x[: nb * batch_size].reshape(
            (nb, batch_size) + x.shape[1:]), data), nb


def _full_loss(model: SplitModel, params, batch, *, impl, prompt=None):
    out = model.forward(params, batch, route="split", mode="train",
                        impl=impl, prompt=(prompt if prompt is not None
                                           else jnp.zeros((0, model.cfg.d_model))))
    return losses.task_loss(model.cfg, out, batch, impl=impl)


class FLTrainer:
    """FedAvg full fine-tuning (no prompts, no split execution benefit)."""

    def __init__(self, model: SplitModel, bcfg: BaselineConfig):
        self.model, self.bcfg = model, bcfg
        self.opt = sgd(bcfg.lr, momentum=bcfg.momentum)
        self._round_jit = jax.jit(self._round)

    def init(self, key) -> Params:
        p = self.model.init(key)
        return {"params": p, "round": jnp.zeros((), jnp.int32)}

    def _local(self, trainable, opt_state, data):
        bcfg = self.bcfg
        batched, nb = _batched(data, bcfg.batch_size)
        grad_fn = jax.value_and_grad(
            lambda tr, b: _full_loss(self.model, tr, b, impl=bcfg.impl)[0])

        def one_batch(carry, batch):
            tr, os, acc = carry
            loss, g = grad_fn(tr, batch)
            upd, os = self.opt.update(g, os, tr)
            return (apply_updates(tr, upd), os, acc + loss), None

        def one_epoch(carry, _):
            carry, _ = jax.lax.scan(one_batch, carry, batched)
            return carry, None

        (trainable, opt_state, acc), _ = jax.lax.scan(
            one_epoch, (trainable, opt_state, jnp.float32(0.0)), None,
            length=bcfg.local_epochs)
        return trainable, acc / (bcfg.local_epochs * nb)

    def _round(self, state, client_data):
        params = state["params"]
        K = jax.tree.leaves(client_data)[0].shape[0]
        full = {"head": params["head"], "body": params["body"],
                "tail": params["tail"]}  # FL has no prompts
        per_client = broadcast_to_clients(full, K)
        opt_state = jax.vmap(self.opt.init)(per_client)
        trained, loss = jax.vmap(
            lambda tr, os, d: self._local(tr, os, d))(
                per_client, opt_state, client_data)
        n = jax.tree.leaves(client_data)[0].shape[1]
        agg = fedavg(trained, jnp.full((K,), n, jnp.float32))
        new = dict(params)
        new.update({k: agg[k] for k in ("head", "body", "tail")})
        return ({"params": new, "round": state["round"] + 1},
                {"train_loss": loss.mean()})

    def round(self, state, client_data):
        state, m = self._round_jit(state, client_data)
        return state, {k: float(v) for k, v in m.items()}


class SFLTrainer:
    """SplitFed [Thapa et al. 2022]. mode='ff' trains head+tail (per-client)
    + body (server, mean gradient); mode='linear' trains only the task head."""

    def __init__(self, model: SplitModel, bcfg: BaselineConfig,
                 mode: str = "ff"):
        assert mode in ("ff", "linear")
        self.model, self.bcfg, self.mode = model, bcfg, mode
        self.opt_client = sgd(bcfg.lr, momentum=bcfg.momentum)
        self.opt_server = sgd(bcfg.lr, momentum=bcfg.momentum)
        self._round_jit = jax.jit(self._round)

    def init(self, key) -> Params:
        p = self.model.init(key)
        return {"params": p, "round": jnp.zeros((), jnp.int32)}

    def _client_trainable(self, params):
        if self.mode == "linear":
            return {"tail": {"head": params["tail"]["head"]}}
        return {"head": params["head"], "tail": params["tail"]}

    def _merge(self, params, client_tr):
        new = dict(params)
        if self.mode == "linear":
            tail = dict(params["tail"])
            tail["head"] = client_tr["tail"]["head"]
            new["tail"] = tail
        else:
            new["head"] = client_tr["head"]
            new["tail"] = client_tr["tail"]
        return new

    def _loss(self, body, client_tr, params, batch):
        merged = self._merge(params, client_tr)
        merged["body"] = body
        return _full_loss(self.model, merged, batch, impl=self.bcfg.impl)[0]

    def _round(self, state, client_data):
        model, bcfg = self.model, self.bcfg
        params = state["params"]
        K = jax.tree.leaves(client_data)[0].shape[0]
        n = jax.tree.leaves(client_data)[0].shape[1]

        client_tr = broadcast_to_clients(self._client_trainable(params), K)
        client_os = jax.vmap(self.opt_client.init)(client_tr)
        body = params["body"]
        train_body = self.mode == "ff"
        body_os = self.opt_server.init(body) if train_body else None

        batched, nb = _batched(
            jax.tree.map(lambda x: x.swapaxes(0, 1), client_data),
            bcfg.batch_size)
        # batched leaves: (nb, batch, K, ...) -> per-step (batch, K, ...)

        grad_fn = jax.value_and_grad(self._loss, argnums=(0, 1))

        def one_batch(carry, batch_k):
            body, body_os, ctr, cos, acc = carry
            # per-client grads: vmap over K (body broadcast)
            batch_by_client = jax.tree.map(
                lambda x: x.swapaxes(0, 1), batch_k)   # (K, batch, ...)
            (loss, (gb, gc)) = jax.vmap(
                lambda tr, b: grad_fn(body, tr, params, b),
                in_axes=(0, 0))(ctr, batch_by_client)
            upd, cos = jax.vmap(self.opt_client.update)(gc, cos, ctr)
            ctr = apply_updates(ctr, upd)
            if train_body:
                gb_mean = jax.tree.map(lambda g: g.mean(0), gb)
                bupd, body_os = self.opt_server.update(gb_mean, body_os, body)
                body = apply_updates(body, bupd)
            return (body, body_os, ctr, cos, acc + loss.mean()), None

        def one_epoch(carry, _):
            carry, _ = jax.lax.scan(one_batch, carry, batched)
            return carry, None

        (body, body_os, client_tr, client_os, acc), _ = jax.lax.scan(
            one_epoch, (body, body_os, client_tr, client_os,
                        jnp.float32(0.0)), None, length=bcfg.local_epochs)

        agg = fedavg(client_tr, jnp.full((K,), n, jnp.float32))
        new = self._merge(params, agg)
        new["body"] = body
        return ({"params": new, "round": state["round"] + 1},
                {"train_loss": acc / (bcfg.local_epochs * nb)})

    def round(self, state, client_data):
        state, m = self._round_jit(state, client_data)
        return state, {k: float(v) for k, v in m.items()}
