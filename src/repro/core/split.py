"""Three-way model split W = [W_h | W_b | W_t] (SFPrompt Sec. 3.1).

The head (embedding frontend + the first layers) and the tail (last layers +
final norm + task head) live on the CLIENT; the body (everything between)
lives on the SERVER. Split points land on layer-pattern cycle boundaries so
every segment scans homogeneously. Per the paper the split is dynamic —
`SplitConfig.head_cycles/tail_cycles` choose it per deployment.

The head->body and body->tail cut points are real wire boundaries: a
`runtime.boundary.WireSpec` (default raw fp32) owns a codec per link, and
`forward(route="split")` pushes every smashed activation — and, via the
codec's custom VJP, every cut-layer gradient — through it, reporting the
measured bytes in `out["wire_bytes"]`. See ARCHITECTURE.md §Segment
pipeline.

Segment placement notes (DESIGN.md §Arch-applicability):
  - deepseek-v3: the 3 dense prefix layers belong to the head.
  - whisper: the (stubbed-frontend) encoder is client-side feature
    extraction, so it lives in the head segment.
  - zamba2: the shared attention block's weights are *replicated* into every
    segment that contains one of its sites; only the tail's copy is
    trainable, mirroring what a physical split forces.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.transformer import (init_block,
                                      init_stack, run_stack,
                                      stack_cache)
from repro.runtime.boundary import WireSpec

Params = Dict[str, Any]


@dataclass(frozen=True)
class SplitConfig:
    head_cycles: int = 1          # cycles of the layer pattern in W_h
    tail_cycles: int = 1          # cycles in W_t
    prompt_len: int = 16          # p — soft prompt tokens (VPT-style)
    prune_gamma: float = 0.5      # fraction of local data PRUNED away
    local_epochs: int = 10        # U — phase-1 self-update epochs
    capacity_note: str = ""


class SplitModel:
    def __init__(self, cfg: ModelConfig, split: SplitConfig,
                 wire: Optional[WireSpec] = None):
        if split.head_cycles + split.tail_cycles >= cfg.n_cycles:
            raise ValueError(
                f"{cfg.name}: head({split.head_cycles}) + tail"
                f"({split.tail_cycles}) cycles must leave a non-empty body"
                f" out of {cfg.n_cycles}")
        self.cfg = cfg
        self.split = split
        # The two physical links of the split; route="split" traffic always
        # crosses them (route="local" is client-only, zero wire traffic).
        self.wire = wire if wire is not None else WireSpec.make("fp32")
        self.body_cycles = cfg.n_cycles - split.head_cycles - split.tail_cycles
        cyc = len(cfg.layer_pattern)
        self.n_head_layers = cfg.n_dense_layers + split.head_cycles * cyc
        self.n_tail_layers = split.tail_cycles * cyc
        self.n_body_layers = self.body_cycles * cyc
        self._has_shared = "shared_attn" in cfg.layer_pattern

    # -------------------------------------------------------------- sizes
    def segment_fractions(self):
        """(alpha, tau) parameter fractions of |W| in head and body — feeds
        the Table-1 cost model."""
        total = self.cfg.param_count()
        h = self._segment_params_count("head")
        b = self._segment_params_count("body")
        return h / total, b / total

    def _segment_params_count(self, seg: str) -> int:
        import numpy as _np
        shapes = jax.eval_shape(lambda k: self.init(k)[seg],
                                jax.random.PRNGKey(0))
        return sum(int(_np.prod(s.shape)) for s in jax.tree.leaves(shapes))

    # -------------------------------------------------------------- init
    def init(self, key) -> Params:
        cfg = self.cfg
        keys = iter(jax.random.split(key, 32))
        head: Params = {}
        # embedding frontend
        if cfg.arch_type == "vit":
            patch_dim = 16 * 16 * 3
            head["embed"] = {
                "patch": L.dense_init(next(keys), patch_dim, cfg.d_model),
                "cls": 0.02 * jax.random.normal(next(keys), (1, cfg.d_model)),
                "pos": 0.02 * jax.random.normal(
                    next(keys), (cfg.max_seq_len, cfg.d_model)),
            }
        else:
            head["embed"] = {"tok": 0.02 * jax.random.normal(
                next(keys), (cfg.vocab_size, cfg.d_model), jnp.float32)}
        if cfg.encoder is not None:
            head["encoder"] = {
                "cycle": {"pos0": init_stack(next(keys), cfg, "attn",
                                             cfg.encoder.n_layers)},
                "final_norm": L.norm_init(cfg.d_model, cfg.norm)}
        if cfg.n_dense_layers:
            head["dense_stack"] = {"pos0": init_stack(
                next(keys), cfg, "attn", cfg.n_dense_layers)}
        head["stack"] = self._init_cycles(next(keys), self.split.head_cycles)

        body: Params = {"stack": self._init_cycles(next(keys), self.body_cycles)}

        tail: Params = {"stack": self._init_cycles(next(keys),
                                                   self.split.tail_cycles)}
        tail["final_norm"] = L.norm_init(cfg.d_model, cfg.norm)
        out_dim = cfg.num_classes or cfg.vocab_size
        tail["head"] = L.dense_init(next(keys), cfg.d_model, out_dim)
        if cfg.mtp:
            tail["mtp"] = {
                "proj": L.dense_init(next(keys), 2 * cfg.d_model, cfg.d_model),
                "block": init_block(next(keys), cfg, "attn"),
                "norm": L.norm_init(cfg.d_model, cfg.norm),
            }

        if self._has_shared:
            sh = init_block(next(keys), cfg, "shared_attn")
            for seg in (head, body, tail):
                seg["shared_attn"] = jax.tree.map(jnp.copy, sh)

        prompt = 0.02 * jax.random.normal(
            next(keys), (self.split.prompt_len, cfg.d_model), jnp.float32)
        return {"head": head, "body": body, "tail": tail, "prompt": prompt}

    def _init_cycles(self, key, n_cycles: int) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, len(cfg.layer_pattern))
        out = {}
        for i, kind in enumerate(cfg.layer_pattern):
            if kind == "shared_attn":
                out[f"pos{i}"] = {"_": jnp.zeros((n_cycles,))}
            else:
                out[f"pos{i}"] = init_stack(ks[i], cfg, kind, n_cycles)
        return out

    # -------------------------------------------------------------- caches
    def init_cache(self, batch: int, seq_len: int, dtype=jnp.float32,
                   window=None) -> Params:
        cfg = self.cfg

        def seg_cache(n_cycles):
            return {f"pos{i}": stack_cache(cfg, kind, n_cycles, batch,
                                           seq_len, dtype, window=window)
                    for i, kind in enumerate(cfg.layer_pattern)}

        cache: Params = {
            "head": {"stack": seg_cache(self.split.head_cycles)},
            "body": {"stack": seg_cache(self.body_cycles)},
            "tail": {"stack": seg_cache(self.split.tail_cycles)},
        }
        if cfg.n_dense_layers:
            cache["head"]["dense_stack"] = {"pos0": stack_cache(
                cfg, "attn", cfg.n_dense_layers, batch, seq_len, dtype,
                window=window)}
        if cfg.encoder is not None:
            cache["head"]["encoder_out"] = jnp.zeros(
                (batch, cfg.encoder.n_frames, cfg.d_model), dtype)
        return cache

    # ------------------------------------------------- slotted allocation
    # A serving engine's shared KV cache is `init_cache(n_slots, ...)`:
    # every batch row is a SLOT that one in-flight request owns. The
    # helpers below move whole slots between a fresh single-request cache
    # and the shared one, so a prefill computed at batch=1 can join an
    # in-flight decode batch without draining it (serve/engine.py).

    def blank_slot_cache(self, seq_len: int, dtype=jnp.float32,
                         window=None) -> Params:
        """A fresh batch=1 cache — the state of one unoccupied slot."""
        return self.init_cache(1, seq_len, dtype, window=window)

    @staticmethod
    def _slot_axis(path) -> int:
        # every cache leaf carries the batch (=slot) axis at 1, after the
        # stacked-layer axis — except the head's encoder_out at axis 0
        return 0 if any(getattr(p, "key", None) == "encoder_out"
                        for p in path) else 1

    def cache_write_slot(self, shared: Params, single: Params,
                         slot) -> Params:
        """Scatter a batch=1 cache pytree into slot `slot` (traced int) of
        the shared n-slot cache. Overwrites every leaf of that slot, so a
        newly allocated slot never sees a previous tenant's KV state."""
        def wr(path, s, one):
            ax = self._slot_axis(path)
            return jax.lax.dynamic_update_index_in_dim(
                s, jnp.take(one, 0, axis=ax).astype(s.dtype), slot, ax)
        return jax.tree_util.tree_map_with_path(wr, shared, single)

    def cache_read_slot(self, shared: Params, slot) -> Params:
        """Gather slot `slot` of the shared cache as a batch=1 cache."""
        def rd(path, s):
            ax = self._slot_axis(path)
            return jnp.expand_dims(
                jax.lax.dynamic_index_in_dim(s, slot, ax, keepdims=False),
                ax)
        return jax.tree_util.tree_map_with_path(rd, shared)

    def jit_slot_writer(self, *, donate: bool = True):
        """Jitted `cache_write_slot` for serving engines. With `donate` the
        SHARED cache argument of the scatter is donated, so a slot join
        updates the n-slot pytree in place instead of copying every cache
        leaf per admission (decode fast path — backends without donation
        silently fall back to the copy)."""
        return jax.jit(self.cache_write_slot,
                       donate_argnums=(0,) if donate else ())

    # ------------------------------------------------- paged allocation
    # The paged serving cache replaces the dense (slot, window) pair with a
    # PAGE POOL: every attention-cache leaf becomes (n_cycles, n_pages,
    # page_size, ...) and a per-slot BLOCK TABLE of physical page ids maps a
    # slot's logical blocks onto pool pages (serve/paged_engine.py owns the
    # host-side allocator). A pool is literally `init_cache(n_pages,
    # page_size)` — batch axis = page axis — so the page axis is uniformly
    # axis 1 of every leaf, after the stacked-layer axis.

    def paged_cache_unsupported(self) -> Optional[str]:
        """None when this model can serve from a paged pool; otherwise the
        reason it cannot. Paging assumes every cached layer is a uniform
        full-window attention cache (one ring layout shared by all leaves);
        recurrent state (mamba/rwkv), MLA latents, local-attention windows,
        encoder outputs and dense prefix stacks keep per-slot state the
        block tables cannot express yet."""
        cfg = self.cfg
        if cfg.arch_type in ("vit", "audio", "vlm"):
            return f"arch_type {cfg.arch_type!r} has no token decode loop"
        if any(kind != "attn" for kind in cfg.layer_pattern):
            return (f"layer pattern {cfg.layer_pattern} has non-'attn' "
                    f"layers")
        if cfg.attention is not None and cfg.attention.mla is not None:
            return "MLA latent caches are not paged yet"
        if cfg.n_dense_layers:
            return f"{cfg.n_dense_layers} dense prefix layers are not paged"
        if cfg.encoder is not None:
            return "encoder models have no token decode loop"
        return None

    def init_paged_cache(self, n_pages: int, page_size: int,
                         dtype=jnp.float32) -> Params:
        """The device-side page pool: one page axis shared by head, body
        and tail stacks (a page id is valid in every layer's pool)."""
        reason = self.paged_cache_unsupported()
        if reason is not None:
            raise ValueError(f"{self.cfg.name}: paged cache unsupported — "
                             f"{reason}")
        return self.init_cache(n_pages, page_size, dtype)

    @staticmethod
    def paged_seg_view(seg_cache: Params, tables) -> Params:
        """Inject the (S, n_blocks) block tables into every stacked layer
        group of one segment's pool (broadcast over the cycle axis so they
        ride the layer scan); `apply_attention` detects the key and takes
        the paged decode path."""
        stacks = {}
        for name, stack in seg_cache["stack"].items():
            n = stack["positions"].shape[0]
            stacks[name] = dict(stack, block_tables=jnp.broadcast_to(
                tables[None], (n,) + tables.shape))
        return {"stack": stacks}

    @staticmethod
    def strip_paged_view(seg_cache: Params) -> Params:
        """Drop the injected block tables, leaving the bare pool pytree."""
        return {"stack": {name: {k: v for k, v in stack.items()
                                if k != "block_tables"}
                          for name, stack in seg_cache["stack"].items()}}

    @staticmethod
    def paged_gather(pool: Params, tables) -> Params:
        """Gather per-slot dense cache views out of a pool: leaf
        (n, P, page, ...) + tables (S, nb) -> (n, S, nb*page, ...), laid out
        exactly like a dense `init_cache(S, nb*page)` slot cache (block j
        covers width indices [j*page, (j+1)*page))."""
        S, nb = tables.shape

        def g(leaf):
            out = leaf[:, tables]                    # (n, S, nb, page, ...)
            return out.reshape(leaf.shape[0], S, nb * leaf.shape[2],
                               *leaf.shape[3:])
        return jax.tree.map(g, pool)

    @staticmethod
    def paged_scatter_token(pool: Params, dense: Params, tables,
                            pos) -> Params:
        """Write back the single token each slot just wrote at width index
        `pos` (S,) of its dense view (the decode-step inverse of
        `paged_gather` — everything else in the dense view is unchanged
        pool content)."""
        S, nb = tables.shape
        s_idx = jnp.arange(S)

        def sc(pool_leaf, dense_leaf):
            page_len = pool_leaf.shape[2]
            page = tables[s_idx, pos // page_len]    # (S,)
            off = pos % page_len
            vals = dense_leaf[:, s_idx, pos]         # (n, S, ...)
            return pool_leaf.at[:, page, off].set(
                vals.astype(pool_leaf.dtype))
        return jax.tree.map(sc, pool, dense)

    @staticmethod
    def paged_scatter_slot(pool: Params, single: Params, table_row,
                           write_mask, scratch_page) -> Params:
        """Scatter one slot's batch=1 dense cache (width nb*page) into its
        pages. `write_mask` (nb,) bool selects the blocks to land; masked
        blocks (shared prefix pages, unallocated entries) are redirected to
        the scratch page so the op stays shape-stable without touching
        live pages."""
        nb = table_row.shape[0]
        dest = jnp.where(write_mask, table_row, scratch_page)

        def sc(pool_leaf, dense_leaf):
            page_len = pool_leaf.shape[2]
            r = dense_leaf[:, 0].reshape(dense_leaf.shape[0], nb, page_len,
                                         *dense_leaf.shape[3:])
            return pool_leaf.at[:, dest].set(r.astype(pool_leaf.dtype))
        return jax.tree.map(sc, pool, single)

    @staticmethod
    def paged_copy_page(pool: Params, src, dst) -> Params:
        """Copy one physical page across every layer's pool — the COW
        divergence copy for a shared boundary page."""
        return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), pool)

    # -------------------------------------------------------------- embed
    def _embed(self, head_p, batch, mode, prompt, dtype, chunk_start=None):
        cfg = self.cfg
        emb = head_p["embed"]
        if cfg.arch_type == "vit":
            patches = batch["patches"]
            B = patches.shape[0]
            x = L.dense(emb["patch"], patches.astype(dtype))
            cls = jnp.broadcast_to(emb["cls"][None], (B, 1, cfg.d_model))
            x = jnp.concatenate([cls.astype(x.dtype), x], 1)
            if prompt is not None:
                pr = jnp.broadcast_to(prompt[None], (B,) + prompt.shape)
                x = jnp.concatenate([x[:, :1], pr.astype(x.dtype), x[:, 1:]], 1)
            x = x + emb["pos"][: x.shape[1]].astype(x.dtype)
            pos = jnp.broadcast_to(
                jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
            return x, pos, pos, 0

        toks = batch["tokens"]
        B, S = toks.shape
        x = jnp.take(emb["tok"].astype(dtype), toks, axis=0)
        n_prefix = 0
        if cfg.arch_type == "audio":
            if mode == "decode":
                apos = batch["pos"][:, None]
            else:
                apos = jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            x = x + L.sinusoidal_embedding(apos, cfg.d_model).astype(dtype)
        if cfg.arch_type == "vlm" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(dtype)
            x = jnp.concatenate([pe, x], axis=1)
            n_prefix += pe.shape[1]
        if prompt is not None and mode != "decode" and chunk_start is None:
            pr = jnp.broadcast_to(prompt[None], (B,) + prompt.shape)
            x = jnp.concatenate([pr.astype(dtype), x], axis=1)
            n_prefix += prompt.shape[0]

        T = x.shape[1]
        if mode == "decode":
            base = batch["pos"][:, None]
        elif chunk_start is not None:
            # chunked-prefill continuation: this chunk's tokens sit at
            # positions [chunk_start, chunk_start + T) of an already
            # partially-filled cache; no soft prompt is prepended (it went
            # in with the first chunk).
            base = chunk_start[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        else:
            base = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        base = base.astype(jnp.int32)
        att = cfg.attention
        if att is not None and att.mrope_sections is not None:
            # M-RoPE: layout is [prompt | patches | text]; patch grid
            # positions come from the frontend stub, offset past the prompts;
            # masking & cache slots always use the sequence index `base`.
            if mode != "decode" and "mrope_positions" in batch:
                # stored client-axis-first as (B, 3, Np); model wants (3, B, Np)
                grid = jnp.moveaxis(
                    batch["mrope_positions"], 1, 0).astype(jnp.int32)
                npz = grid.shape[-1]
                npr = n_prefix - npz
                b3 = jnp.broadcast_to(base[None], (3, B, T))
                pos = jnp.concatenate(
                    [b3[:, :, :npr], grid + npr, b3[:, :, npr + npz:]], -1)
            else:
                pos = jnp.broadcast_to(base[None], (3,) + base.shape)
            return x, pos, base, n_prefix
        return x, base, base, n_prefix

    # -------------------------------------------------------------- segments
    def _seg_fwd(self, seg_p, seg_name, n_cycles, x, ctx, cache):
        cfg = self.cfg
        caches = cache["stack"] if cache is not None else None
        x, aux, new_stack = run_stack(
            cfg, seg_p["stack"], cfg.layer_pattern, x, ctx, caches,
            shared=seg_p.get("shared_attn"))
        new_cache = None
        if cache is not None:
            new_cache = dict(cache)
            new_cache["stack"] = new_stack
        return x, aux, new_cache

    def head_fwd(self, head_p, prompt, batch, *, mode="train", cache=None,
                 impl="ref", dtype=jnp.float32, remat=False,
                 unroll=False, chunk_start=None) -> Dict[str, Any]:
        """Client-side: embed (+prompts, + whisper encoder) -> head layers.
        Output `smashed` is the cut-layer activation sent to the server.
        `chunk_start` (B,) marks a chunked-prefill continuation: the batch's
        tokens extend a partially-filled prefill cache starting at those
        positions (attention then runs write-then-attend over the full
        cache, like decode, instead of chunk-local causal)."""
        cfg = self.cfg
        encoder_out = None
        new_cache = dict(cache) if cache is not None else None
        if cfg.encoder is not None:
            if mode == "decode":
                encoder_out = cache["encoder_out"]
            else:
                frames = batch["frames"].astype(dtype)
                Bf, F, _ = frames.shape
                fpos = jnp.broadcast_to(
                    jnp.arange(F, dtype=jnp.int32)[None], (Bf, F))
                h = frames + L.sinusoidal_embedding(fpos, cfg.d_model).astype(dtype)
                ectx = L.Ctx(mode="train", positions=fpos, impl=impl,
                             causal=False, remat=remat, unroll=unroll)
                h, _, _ = run_stack(cfg, head_p["encoder"]["cycle"], ("attn",),
                                    h, ectx, None)
                encoder_out = L.apply_norm(
                    head_p["encoder"]["final_norm"], h, cfg.norm)
                if new_cache is not None:
                    new_cache["encoder_out"] = encoder_out

        x, positions, seq_pos, n_prefix = self._embed(
            head_p, batch, mode, prompt, dtype, chunk_start)
        ctx = L.Ctx(mode=mode, positions=positions, seq_pos=seq_pos,
                    impl=impl, remat=remat, unroll=unroll,
                    causal=(cfg.arch_type != "vit"), encoder_out=encoder_out,
                    has_context=(chunk_start is not None))
        aux = jnp.float32(0.0)
        if cfg.n_dense_layers:
            c = cache.get("dense_stack") if cache is not None else None
            x, a, nc = run_stack(cfg, head_p["dense_stack"], ("attn",), x,
                                 ctx, c)
            aux += a
            if new_cache is not None:
                new_cache["dense_stack"] = nc
        seg_cache = {"stack": cache["stack"]} if cache is not None else None
        x, a, nc = self._seg_fwd(head_p, "head", self.split.head_cycles, x,
                                 ctx, seg_cache)
        aux += a
        if new_cache is not None:
            new_cache["stack"] = nc["stack"]
        return {"smashed": x, "positions": positions, "seq_pos": seq_pos,
                "n_prefix": n_prefix, "encoder_out": encoder_out, "aux": aux,
                "cache": new_cache, "mode": mode, "impl": impl,
                "remat": remat, "unroll": unroll,
                "has_context": chunk_start is not None}

    def _ctx_from(self, head_out) -> L.Ctx:
        return L.Ctx(mode=head_out["mode"], positions=head_out["positions"],
                     seq_pos=head_out["seq_pos"], impl=head_out["impl"],
                     remat=head_out.get("remat", False),
                     unroll=head_out.get("unroll", False),
                     causal=(self.cfg.arch_type != "vit"),
                     encoder_out=head_out["encoder_out"],
                     has_context=head_out.get("has_context", False))

    def body_fwd(self, body_p, smashed, head_out, *, cache=None):
        """Server-side: frozen body over the smashed activations."""
        ctx = self._ctx_from(head_out)
        x, aux, new_cache = self._seg_fwd(
            body_p, "body", self.body_cycles, smashed, ctx, cache)
        return {"smashed": x, "aux": aux, "cache": new_cache}

    def tail_fwd(self, tail_p, x, head_out, batch=None, *, cache=None,
                 last_only: bool = False):
        """Client-side: tail layers -> final norm -> task head.
        last_only=True computes logits for the final position only — the
        production prefill semantics (avoids materializing/reducing the
        (B, S, V) logits tensor; see EXPERIMENTS.md §Perf pair A)."""
        cfg = self.cfg
        ctx = self._ctx_from(head_out)
        x, aux, new_cache = self._seg_fwd(
            tail_p, "tail", self.split.tail_cycles, x, ctx, cache)
        hidden = L.apply_norm(tail_p["final_norm"], x, cfg.norm)
        out: Dict[str, Any] = {"aux": aux, "cache": new_cache, "hidden": hidden}
        if cfg.arch_type == "vit":
            out["logits"] = L.dense(tail_p["head"], hidden[:, 0])
            return out
        if last_only:
            hidden = hidden[:, -1:, :]
        logits = hidden @ tail_p["head"]["w"].astype(hidden.dtype)
        if cfg.final_logit_softcap:
            c = cfg.final_logit_softcap
            logits = c * jnp.tanh(logits / c)
        out["logits"] = logits
        out["n_prefix"] = head_out["n_prefix"]
        if cfg.mtp and head_out["mode"] == "train" and batch is not None:
            toks = batch["tokens"]
            # embedding lives in the head segment; MTP needs it — the client
            # holds both, so this is local (no extra communication).
            out["mtp_hidden_ready"] = True
        return out

    # -------------------------------------------------------------- routes
    def forward(self, params, batch, *, route="split", mode="train",
                cache=None, impl="ref", dtype=jnp.float32, remat=False,
                unroll=False, prompt=None, last_only=True, wire_key=None,
                chunk_start=None):
        """route='split': head -> body -> tail (phase 2), every smashed
        tensor crossing the head_body / body_tail wire boundaries through
        their codecs; out['wire_bytes'] holds the measured bytes per link.
        route='local': head -> tail directly (phase 1 local-loss update and
        EL2N scoring — the body is skipped, zero server communication).
        `chunk_start` (B,) runs a chunked-prefill continuation (see
        `head_fwd`); the soft prompt went in with the first chunk, so none
        is prepended here."""
        if chunk_start is not None:
            prompt = None
        else:
            prompt = params["prompt"] if prompt is None else prompt
        hc = cache["head"] if cache is not None else None
        ho = self.head_fwd(params["head"], prompt, batch, mode=mode,
                           cache=hc, impl=impl, dtype=dtype, remat=remat,
                           unroll=unroll, chunk_start=chunk_start)
        x, aux = ho["smashed"], ho["aux"]
        new_cache = {"head": ho["cache"]} if cache is not None else None
        wire_bytes = {}
        train = mode == "train"
        if route == "split":
            k_hb = k_bt = None
            if wire_key is not None:
                k_hb, k_bt = jax.random.split(wire_key)
            x, wire_bytes["head_body"] = self.wire.head_body.transmit(
                x, key=k_hb, train=train)
            bo = self.body_fwd(params["body"], x, ho,
                               cache=cache["body"] if cache else None)
            x = bo["smashed"]
            aux += bo["aux"]
            if cache is not None:
                new_cache["body"] = bo["cache"]
            x, wire_bytes["body_tail"] = self.wire.body_tail.transmit(
                x, key=k_bt, train=train)
        to = self.tail_fwd(params["tail"], x, ho, batch,
                           cache=cache["tail"] if cache else None,
                           last_only=(mode == "prefill" and last_only))
        out = dict(to)
        out["aux"] = aux + to["aux"]
        out["wire_bytes"] = wire_bytes
        if cache is not None:
            new_cache["tail"] = to["cache"]
            out["cache"] = new_cache
        return out
