"""Phase-1 client self-update: local-loss updates (SFPrompt Sec. 3.2, Eq. (1)).

The client connects W_h directly to W_t (body skipped), and runs U local
epochs updating only (W_t, prompt) — the head stays frozen. This phase costs
ZERO server communication; it substitutes for the per-epoch smashed-data
round trips that make naive SFL expensive.

All functions operate on ONE client and are vmapped over the client axis by
the protocol (head params broadcast, tail/prompt/opt-state per-client).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import losses
from repro.core.split import SplitModel
from repro.optim import Optimizer, apply_updates


def local_loss_fn(model: SplitModel, head_p, trainable, batch, *,
                  impl: str = "ref"):
    """L_C(x; (W_h, W_t); p): loss of the head->tail local model."""
    tail_p, prompt = trainable["tail"], trainable["prompt"]
    ho = model.head_fwd(head_p, prompt, batch, mode="train", impl=impl)
    to = model.tail_fwd(tail_p, ho["smashed"], ho, batch)
    out = {"logits": to["logits"], "n_prefix": to.get("n_prefix", 0),
           "aux": ho["aux"] + to["aux"]}
    return losses.task_loss(model.cfg, out, batch, impl=impl)


def local_epochs(model: SplitModel, head_p, trainable, opt: Optimizer,
                 opt_state, data: Dict[str, jnp.ndarray], *,
                 batch_size: int, n_epochs: int, impl: str = "ref"):
    """U epochs of local-loss SGD over one client's full dataset.
    Returns (trainable, opt_state, mean_loss)."""
    n = jax.tree.leaves(data)[0].shape[0]
    nb = max(1, n // batch_size)
    batched = jax.tree.map(
        lambda x: x[: nb * batch_size].reshape((nb, batch_size) + x.shape[1:]),
        data)
    grad_fn = jax.grad(
        lambda tr, b: local_loss_fn(model, head_p, tr, b, impl=impl)[0])

    def one_batch(carry, batch):
        trainable, opt_state, acc = carry
        loss, _ = local_loss_fn(model, head_p, trainable, batch, impl=impl)
        grads = grad_fn(trainable, batch)
        updates, opt_state = opt.update(grads, opt_state, trainable)
        trainable = apply_updates(trainable, updates)
        return (trainable, opt_state, acc + loss), None

    def one_epoch(carry, _):
        carry, _ = jax.lax.scan(one_batch, carry, batched)
        return carry, None

    (trainable, opt_state, acc), _ = jax.lax.scan(
        one_epoch, (trainable, opt_state, jnp.float32(0.0)),
        None, length=n_epochs)
    return trainable, opt_state, acc / (n_epochs * nb)


def dp_clip_and_noise(trainable, reference, key, *, l2_clip: float,
                      noise_multiplier: float):
    """DP-SGD on ONE client's round update (vmapped over clients by the
    protocol, like everything above).

    The privatized quantity is the client's DELTA against the broadcast
    pre-round globals — clipping absolute params would destroy them, and
    the delta is what the server aggregates. Per DP-SGD: scale the delta to
    global L2 norm <= l2_clip, add N(0, (noise_multiplier * l2_clip)^2)
    per coordinate, and rebuild the params the client uploads. Returns
    (privatized trainable, pre-clip delta norm for diagnostics)."""
    # lazy like aggregation.get_aggregator: the core layer only touches
    # the privacy subsystem when the DP path is actually taken
    from repro.privacy.dp import clip_tree, gaussian_noise_tree
    delta = jax.tree.map(
        lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
        trainable, reference)
    delta, norm = clip_tree(delta, l2_clip)
    noise = gaussian_noise_tree(key, delta, noise_multiplier * l2_clip)
    return jax.tree.map(
        lambda ref, d, n: (ref.astype(jnp.float32) + d + n)
        .astype(ref.dtype), reference, delta, noise), norm
