"""Analytical cost model — SFPrompt Table 1 (Sec. 3.5).

Symbols (paper's):
  |W|   total parameters;  alpha = |W_h|/|W|;  tau = |W_b|/|W|
  |D|   local samples per client;  gamma_keep = kept fraction after pruning
  q     cut-layer size (floats per SAMPLE per direction)
  p     prompt parameters;  U local epochs;  K selected clients
  R     link rate (bytes/s, shared: R/K effective per client)
  P_C / P_S  client / server FLOP rates;  beta = forward fraction of a step

Conventions (calibrated against the paper's Table 2 in
benchmarks/comm_cost.py; deviations recorded in EXPERIMENTS.md):
  * FL transmits the model twice per round per client: 2|W|K.
  * SFL transmits smashed data + gradients for every sample of every local
    epoch (4q|D|U: fwd activation + bwd grad at the cut, both directions of
    the two cut points), plus the client submodel twice: 2(1-tau)|W|K.
  * SFPrompt transmits smashed traffic only for the pruned subset and only
    for the split_epochs (E) phase-2 passes — local-loss epochs are free —
    plus only (tail + prompt) twice: (4q*gamma_keep*|D|*E + 2((1-a-t)|W|+p))K.
  * 2 cut points exist (head->body and body->tail), hence 4q per sample
    per pass (2 activations forward + 2 gradients backward).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.split import SplitConfig, SplitModel
from repro.models.config import ModelConfig

BYTES = 4  # fp32 for parameters on the wire

# Calibration (EXPERIMENTS.md §Comm-cost): the paper's Table-2 numbers are
# reproduced to ~5% iff smashed activations/gradients travel INT8-quantized
# (1 byte/float) while parameters travel fp32, gamma_keep = 0.6, E = 1, and
# |W| includes the pre-trained checkpoint's 21k-class head. That implicit
# int8 wire format is now RUNNABLE, not just assumed: runtime/codec.py's
# Int8Codec carries smashed activations and cut-layer gradients as actual
# int8 payloads, the protocol's TrafficMeter counts the real bytes, and
# benchmarks/comm_cost.py (--check) validates measured-vs-analytical to
# within 5%. Set bytes_smashed from codec.bytes_per_float(shape) to make
# this model the exact cross-check of a measured run.


@dataclass
class CostInputs:
    W: float                  # total params
    alpha: float              # head fraction
    tau: float                # body fraction
    q: float                  # cut width: floats per sample per direction
    D: int                    # local samples per client
    U: int = 10               # local epochs
    E: int = 1                # split-training passes (SFPrompt phase 2)
    K: int = 5                # clients per round
    p: float = 0.0            # prompt params
    gamma_keep: float = 1.0   # kept fraction after pruning
    R: float = 100e6          # link bytes/s
    P_C: float = 1e12         # client FLOP/s
    P_S: float = 100e12       # server FLOP/s
    beta: float = 1.0 / 3.0   # forward fraction of one training step
    bytes_smashed: float = 4  # bytes/float for cut-layer traffic (1 = int8)
    bytes_param: float = 4

    @property
    def Wc(self) -> float:     # client submodel (head + tail)
        return (1 - self.tau) * self.W

    @property
    def Wt(self) -> float:     # tail only
        return (1 - self.alpha - self.tau) * self.W


# --------------------------------------------------------- communication
def fl_comm(c: CostInputs) -> float:
    """Bytes per global round."""
    return 2 * c.W * c.K * c.bytes_param


def sfl_comm(c: CostInputs) -> float:
    smashed = 4 * c.q * c.D * c.U          # per client, all epochs interact
    return (smashed * c.bytes_smashed + 2 * c.Wc * c.bytes_param) * c.K


def sfprompt_comm(c: CostInputs) -> float:
    smashed = 4 * c.q * c.gamma_keep * c.D * c.E
    return (smashed * c.bytes_smashed
            + 2 * (c.Wt + c.p) * c.bytes_param) * c.K


def sfprompt_comm_breakdown(c: CostInputs) -> Dict[str, float]:
    """sfprompt_comm split by physical link, keyed like the TrafficMeter:
    each cut point carries q floats forward + q backward per sample per
    phase-2 pass; (tail + prompt) travel up + down once per round."""
    per_boundary = 2 * c.q * c.gamma_keep * c.D * c.E * c.bytes_smashed * c.K
    return {"head_body": per_boundary, "body_tail": per_boundary,
            "params": 2 * (c.Wt + c.p) * c.bytes_param * c.K}


def sfprompt_comm_breakdown_partial(c: CostInputs, *, transmit_sum: float,
                                    n_uploads: float,
                                    k_down: Optional[float] = None,
                                    ) -> Dict[str, float]:
    """`sfprompt_comm_breakdown` corrected for a partially-participating
    cohort (fed.RoundPlan): each boundary carries the per-client full
    traffic times the sum of transmit fractions; (tail + prompt) go DOWN to
    all `k_down` sampled clients but UP only from the `n_uploads` clients
    that survived to aggregate. With transmit_sum = n_uploads = k_down = K
    this reduces exactly to the synchronous breakdown."""
    per_boundary_client = 2 * c.q * c.gamma_keep * c.D * c.E * c.bytes_smashed
    params_each = (c.Wt + c.p) * c.bytes_param
    k_down = c.K if k_down is None else k_down
    return {"head_body": per_boundary_client * transmit_sum,
            "body_tail": per_boundary_client * transmit_sum,
            "params": params_each * (k_down + n_uploads)}


def secure_agg_breakdown(*, n_trainable: int, param_nbytes: float, K: int,
                         n_uploads: float,
                         n_dropped: Optional[float] = None,
                         ) -> Dict[str, float]:
    """Analytical wire bytes of one masked-secure-aggregation round, keyed
    like the TrafficMeter — the exact counterpart of what
    `privacy.SecureAggregator` pushes through its runtime Boundaries
    (tests pin measured vs this to <= 5%; exact in practice).

      params: the fp32 (tail + prompt) broadcast DOWN to all K sampled
              clients, plus each SURVIVOR's uint32 ring upload — the
              flattened trainable count padded to the mask kernel's lane
              multiple (`ring_size`), 4 bytes per ring element.
      secure: simulated-DH key agreement (each of the K clients sends its
              pubkey and receives the K-1 others: K^2 * PK_BYTES total)
              plus dropout recovery (each survivor reveals its escrowed
              pair seed with each dropped client: n_up * n_drop seeds).

    `n_trainable` is the UNPADDED flattened (tail + prompt) element count;
    `param_nbytes` the fp32 byte size of that tree (the downlink payload).
    """
    from repro.kernels.secure_mask.ops import ring_size
    from repro.privacy.fixed_point import RING_BYTES
    from repro.privacy.masking import PK_BYTES, SEED_BYTES
    n_pad = ring_size(n_trainable)
    if n_dropped is None:
        n_dropped = K - n_uploads
    return {
        "params": K * param_nbytes + n_uploads * n_pad * RING_BYTES,
        "secure": (K * K * PK_BYTES
                   + n_uploads * n_dropped * SEED_BYTES),
    }


def hierarchical_edge_breakdown(*, param_nbytes: float, n_edges: int,
                                live_edges: float) -> Dict[str, float]:
    """Analytical backhaul bytes of one hierarchical round's tier 2, keyed
    like the TrafficMeter's `edge_global` stream: each LIVE edge (one with
    at least one surviving client) uploads its fp32 edge mean, and the new
    globals broadcast down to all `n_edges` edges."""
    return {"edge_global": (n_edges + live_edges) * param_nbytes}


def hierarchical_secure_agg_breakdown(*, n_trainable: int,
                                      param_nbytes: float,
                                      edge_sizes, edge_uploads,
                                      ) -> Dict[str, float]:
    """Analytical wire bytes of one hierarchical SECURE round — the
    per-edge sum of `secure_agg_breakdown` plus the tier-2 backhaul.

    edge_sizes: per-edge sub-cohort sizes k_e (sum = K); edge_uploads: how
    many of each edge's clients survived to upload. Key agreement costs
    sum(k_e^2) pubkeys — the hierarchical win over the flat K^2 — and
    escrow reveals pair each edge's survivors with ITS dropped clients
    only. `params` keeps the flat shape: the fp32 downlink reaches all K
    clients and every survivor uploads one padded ring tensor; `edge_global`
    follows `hierarchical_edge_breakdown` with an all-dropped edge not
    uploading its mean."""
    totals = {"params": 0.0, "secure": 0.0}
    live = 0.0
    for k_e, up_e in zip(edge_sizes, edge_uploads):
        part = secure_agg_breakdown(
            n_trainable=n_trainable, param_nbytes=param_nbytes, K=int(k_e),
            n_uploads=float(up_e))
        totals["params"] += part["params"]
        totals["secure"] += part["secure"]
        live += float(up_e > 0)
    totals.update(hierarchical_edge_breakdown(
        param_nbytes=param_nbytes, n_edges=len(list(edge_sizes)),
        live_edges=live))
    return totals


def serve_comm_breakdown(wire, *, d_model: int, soft_prompt_len: int,
                         requests) -> Dict[str, float]:
    """Analytical SERVING wire bytes per boundary for a request trace.

    `requests` is a sequence of (prompt_tokens, new_tokens) pairs. Each
    request crosses each boundary once at prefill with its full
    (prompt + soft prompt) smashed tensor, then once per additional decode
    step with a single token's activation — the first generated token
    comes out of the prefill itself, so a request generating m tokens pays
    m - 1 decode crossings. Byte sizes come from the boundary codec's
    `payload_nbytes` of the REAL payload shapes (per-row int8 scales
    included), making this the exact counterpart of the ServeEngine's
    TrafficMeter; tests/test_serve.py pins measured-vs-analytical <= 5%.
    Serving is forward-only: no gradient crossings, 1x per direction.

    The PAGED engine changes none of this: paging is a memory-layout
    optimization, so this model covers both engines verbatim
    (tests/test_serve_paged.py pins paged == dense metered bytes).
    Count a shared prefix as part of each request's prompt here; prefix
    HITS then meter measured <= analytical, since the prefix activations
    cross once per tenant instead of once per request.
    """
    out: Dict[str, float] = {}
    for b in wire.boundaries:
        total = 0.0
        for prompt_tokens, new_tokens in requests:
            total += b.codec.payload_nbytes(
                (1, prompt_tokens + soft_prompt_len, d_model))
            total += max(0, new_tokens - 1) * b.codec.payload_nbytes(
                (1, 1, d_model))
        out[b.name] = float(total)
    return out


def crosscheck(measured: Dict[str, float], c: CostInputs,
               analytical: Optional[Dict[str, float]] = None,
               ) -> Dict[str, Dict]:
    """Measured TrafficMeter bytes vs the analytical model, per link.
    Returns {link: {measured, analytical, err_pct}}. Pass `analytical`
    explicitly (e.g. `sfprompt_comm_breakdown_partial`) to check a
    partial-participation round; default is the synchronous breakdown."""
    if analytical is None:
        analytical = sfprompt_comm_breakdown(c)
    out = {}
    for name, ref in analytical.items():
        if name not in measured:
            continue
        got = measured[name]
        out[name] = {"measured": got, "analytical": ref,
                     "err_pct": 100.0 * (got - ref) / max(ref, 1e-12)}
    return out


# --------------------------------------------------------- client compute
def fl_compute(c: CostInputs) -> float:
    """FLOPs per client per round (6 * params * tokens convention folded
    into |D||W| as in the paper: one epoch touches |D||W| work units)."""
    return 6 * c.D * c.W * c.U


def sfl_compute(c: CostInputs) -> float:
    return 6 * (1 - c.tau) * c.D * c.W * c.U


def sfprompt_compute(c: CostInputs) -> float:
    # U local-loss epochs over (head+tail), E split passes over the pruned
    # subset for the client share (head fwd + tail fwd/bwd).
    local = 6 * (1 - c.tau) * c.D * c.W * c.U
    split = 6 * (1 - c.tau) * c.gamma_keep * c.D * c.W * c.E
    return local + split


def sfprompt_compute_paper(c: CostInputs) -> float:
    """The paper's Table-1 entry (1-tau)*gamma*|D||W| — phase-2 only."""
    return 6 * (1 - c.tau) * c.gamma_keep * c.D * c.W * c.E


# --------------------------------------------------------- latency
def fl_latency(c: CostInputs) -> float:
    comm = fl_comm(c) / c.R
    comp = fl_compute(c) / c.P_C
    return comm + comp


def sfl_latency(c: CostInputs) -> float:
    comm = sfl_comm(c) / c.R
    client = 6 * (1 - c.tau) * c.D * c.W * c.U / c.P_C
    server = 6 * c.tau * c.D * c.W * c.U * c.K / c.P_S
    return comm + client + server


def sfprompt_latency(c: CostInputs) -> float:
    comm = sfprompt_comm(c) / c.R
    # phase 1 (client only, parallel across clients)
    phase1 = 6 * (1 - c.tau) * c.D * c.W * c.U / c.P_C
    # phase 2: client head fwd + tail, server body — pipelined; take max
    client2 = 6 * (1 - c.tau) * c.gamma_keep * c.D * c.W * c.E / c.P_C
    server2 = 6 * c.tau * c.gamma_keep * c.D * c.W * c.E * c.K / c.P_S
    return comm + phase1 + max(client2, server2)


# ------------------------------------------- async round-time twin
def _lognormal_moments(t_comm: float, t_comp: float, link_sigma: float,
                       speed_sigma: float, jitter_sigma: float):
    """Fenton-Wilkinson fit of one client's round latency to a single
    lognormal(mu, sigma). The simulated latency (fed/scheduler.py) is
    (t_comm * L + t_comp * C) * J with L, C, J independent lognormals of
    median 1 — moment-match the sum, then fold the jitter in exactly
    (products of lognormals add mus and sigma^2s)."""
    import math

    def mv(scale, s):
        mean = scale * math.exp(s * s / 2.0)
        var = scale * scale * (math.exp(s * s) - 1.0) * math.exp(s * s)
        return mean, var

    m_l, v_l = mv(t_comm, link_sigma)
    m_c, v_c = mv(t_comp, speed_sigma)
    mean, var = m_l + m_c, v_l + v_c
    sigma2 = math.log(1.0 + var / (mean * mean))
    mu = math.log(mean) - sigma2 / 2.0
    return mu, math.sqrt(sigma2 + jitter_sigma * jitter_sigma)


def _expected_max_lognormal(n: int, mu: float, sigma: float) -> float:
    """E[max of n iid lognormal(mu, sigma)] via the order-statistic
    quantile approximation exp(mu + sigma * Phi^-1(n/(n+1))) — stdlib
    only (statistics.NormalDist), no scipy in the image."""
    import math
    from statistics import NormalDist

    if n <= 1:
        return math.exp(mu + sigma * sigma / 2.0)
    return math.exp(mu + sigma * NormalDist().inv_cdf(n / (n + 1.0)))


def async_vs_sync_round_time(*, t_comm: float, t_comp: float, K: int,
                             buffer_size: int, concurrency: int,
                             group_size: int = 0,
                             link_sigma: float = 0.8,
                             speed_sigma: float = 0.4,
                             jitter_sigma: float = 0.15,
                             ) -> Dict[str, float]:
    """Analytical twin of `benchmarks/async_rounds.py`: contributions/s of
    the synchronous barrier vs the buffered-async runtime, from the same
    latency distribution the simulated engines draw from.

    Sync: every round waits for the slowest of its K sampled clients, so
    it lands K contributions per E[max_K T] seconds. Async: `concurrency`
    dispatch groups of `group_size` clients run independently; each group
    cycles in E[max_g T] (the engine refills a group when its last member
    lands), so arrivals stream at concurrency * g / E[max_g] per second —
    the straggler tail is paid per GROUP, not per cohort, and groups
    overlap. The ratio is the throughput speedup the benchmark gates;
    `benchmarks/async_rounds.py --check` crosschecks simulated vs this."""
    g = group_size or K
    mu, sigma = _lognormal_moments(t_comm, t_comp, link_sigma,
                                   speed_sigma, jitter_sigma)
    t_sync = _expected_max_lognormal(K, mu, sigma)
    t_group = _expected_max_lognormal(g, mu, sigma)
    sync_rate = K / t_sync
    async_rate = concurrency * g / t_group
    return {"sync_round_s": t_sync, "async_group_s": t_group,
            "sync_contrib_per_s": sync_rate,
            "async_contrib_per_s": async_rate,
            "async_flush_interval_s": buffer_size / async_rate,
            "throughput_speedup": async_rate / sync_rate}


def summarize(c: CostInputs) -> Dict[str, Dict[str, float]]:
    return {
        "FL": {"comm_bytes": fl_comm(c), "client_flops": fl_compute(c),
               "latency_s": fl_latency(c)},
        "SFL": {"comm_bytes": sfl_comm(c), "client_flops": sfl_compute(c),
                "latency_s": sfl_latency(c)},
        "SFPrompt": {"comm_bytes": sfprompt_comm(c),
                     "client_flops": sfprompt_compute_paper(c),
                     "latency_s": sfprompt_latency(c)},
    }


# --------------------------------------------------------- model binding
def measured_cost_inputs(model: SplitModel, *, tokens_per_sample: int,
                         n_local: int, batch_size: int, K: int,
                         U: int = 1, E: int = 1) -> CostInputs:
    """CostInputs matched to what an ACTUAL round of `model` runs, for
    crosschecking a TrafficMeter: segment sizes from the real init (the
    analytic `cfg.param_count()` is the full-architecture closed form, not
    the reduced instance), pruning `keep` mirroring the protocol's
    batch-multiple rounding, and bytes_smashed from the wire codec's real
    payload. Shared by benchmarks/comm_cost.py --check and
    tests/test_population.py so the two gates cannot drift apart."""
    from repro.core.pruning import pruned_keep_count
    split, cfg = model.split, model.cfg
    keep = pruned_keep_count(n_local, split.prune_gamma, batch_size)
    h, b, t = (model._segment_params_count(s)
               for s in ("head", "body", "tail"))
    W = h + b + t
    ci = CostInputs(W=W, alpha=h / W, tau=b / W,
                    q=(tokens_per_sample + split.prompt_len) * cfg.d_model,
                    D=n_local, U=U, E=E, K=K,
                    p=split.prompt_len * cfg.d_model,
                    gamma_keep=keep / n_local)
    ci.bytes_smashed = model.wire.head_body.codec.bytes_per_float(
        (batch_size, tokens_per_sample + split.prompt_len, cfg.d_model))
    return ci


def cost_inputs_from(cfg: ModelConfig, split: SplitConfig, *,
                     tokens_per_sample: int, D: int, K: int = 5,
                     U: int = 10, E: int = 1, model: Optional[SplitModel] = None,
                     **kw) -> CostInputs:
    """Derive (alpha, tau, q, p) from an actual split model instance."""
    model = model or SplitModel(cfg, split)
    alpha, tau = model.segment_fractions()
    q = cfg.d_model * (tokens_per_sample + split.prompt_len)
    return CostInputs(
        W=cfg.param_count(), alpha=alpha, tau=tau, q=q, D=D, K=K, U=U, E=E,
        p=split.prompt_len * cfg.d_model,
        gamma_keep=1.0 - split.prune_gamma, **kw)
