"""SFPrompt core: the paper's contribution as composable JAX modules.

  split.py        — three-way model partition W = [W_h | W_b | W_t]
  protocol.py     — the three-phase training round (self-update, split
                    training, aggregation) with first-class clients
  local_update.py — phase-1 local-loss updates (Eq. 1)
  pruning.py      — phase-1 EL2N dataset pruning (Eq. 2)
  aggregation.py  — phase-3 weighted FedAvg (Eq. 3)
  losses.py       — task losses + per-sample EL2N glue
  comm.py         — the Table-1 analytical cost model
  baselines.py    — FL, SFL+FF, SFL+Linear comparison trainers
"""
from repro.core.split import SplitConfig, SplitModel  # noqa: F401
from repro.core.protocol import ProtocolConfig, SFPromptTrainer  # noqa: F401
from repro.core.baselines import BaselineConfig, FLTrainer, SFLTrainer  # noqa: F401
