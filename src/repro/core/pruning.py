"""Phase-1 local dataset pruning via EL2N (SFPrompt Sec. 3.2, Eq. (2)).

The client links W_h -> W_t (the body is skipped — no server traffic),
scores every local sample with the error-vector L2 norm, and keeps the
highest-scoring (1 - gamma) fraction. Only surviving samples ever produce
smashed-data traffic in phase 2.

NOTE: the paper's Algorithm 1 box writes the kept subset as
{z_i | i > gamma*n} after a *descending* sort, which would keep the LOWEST
scores — contradicting both the surrounding text ("retain the examples with
higher EL2N scores") and the EL2N literature. We follow the text: keep the
top (1-gamma) by score. (Recorded in EXPERIMENTS.md §Deviations.)
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import losses
from repro.core.split import SplitModel


def pruned_keep_count(n_local: int, prune_gamma: float,
                      batch_size: int) -> int:
    """How many of a client's `n_local` samples survive phase-1 pruning
    AND actually train in phase 2: the protocol keeps
    max(batch_size, n - floor(gamma * n)) rounded DOWN to a batch
    multiple (the phase-2 scan consumes full batches only). One shared
    definition for the protocol (`SFPromptTrainer._round`), the
    analytical cost model (`comm.measured_cost_inputs`), and the async
    runtime's flush weights — three copies of this rounding had already
    appeared and must never drift."""
    keep = max(batch_size, n_local - int(prune_gamma * n_local))
    return keep - keep % batch_size


def score_client_data(model: SplitModel, head_p, tail_p, prompt,
                      data: Dict[str, jnp.ndarray], *, batch_size: int,
                      impl: str = "ref") -> jnp.ndarray:
    """EL2N score for EVERY sample of one client's dataset (n, ...).
    Runs the LOCAL route (head -> tail), batched. When n is not a multiple
    of batch_size the final batch is padded by wrapping to the dataset's
    start and the padding's scores are masked off, so `prune_indices` ranks
    all n samples instead of silently never scoring the last
    n % batch_size of them."""
    n = jax.tree.leaves(data)[0].shape[0]
    nb = -(-n // batch_size)            # ceil: the last batch may be padded
    if nb * batch_size != n:
        # wrap-pad with real samples (scores of the padding are discarded
        # below); modular indexing also covers batch_size > n
        idx = jnp.arange(nb * batch_size) % n
        data = jax.tree.map(lambda x: jnp.take(x, idx, axis=0), data)
    batched = jax.tree.map(
        lambda x: x.reshape((nb, batch_size) + x.shape[1:]), data)

    def score_batch(_, batch):
        ho = model.head_fwd(head_p, prompt, batch, mode="train", impl=impl)
        to = model.tail_fwd(tail_p, ho["smashed"], ho, batch)
        out = {"logits": to["logits"], "n_prefix": to.get("n_prefix", 0)}
        return None, losses.task_el2n(model.cfg, out, batch, impl=impl)

    _, scores = jax.lax.scan(score_batch, None, batched)
    return scores.reshape(-1)[:n]


def prune_indices(scores: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """Indices of the kept subset (static size): top (1-gamma) by EL2N."""
    n = scores.shape[0]
    keep = max(1, n - int(gamma * n))
    order = jnp.argsort(-scores)      # descending
    return order[:keep]


def prune_client_data(data: Dict[str, jnp.ndarray], scores: jnp.ndarray,
                      gamma: float) -> Tuple[Dict[str, jnp.ndarray], int]:
    idx = prune_indices(scores, gamma)
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), data), idx.shape[0]
