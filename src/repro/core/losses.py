"""Task losses + EL2N scoring glue.

For LM-style archs the loss region excludes the prompt/patch prefix
(``n_prefix``); EL2N for a sequence is the mean over next-token positions of
||softmax(logits) - onehot||_2 (the classifier Eq. (2) applied per position —
DESIGN.md §Arch-applicability). The fused el2n kernel computes both the CE
and the EL2N statistics in one pass over the vocab.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from repro.kernels.el2n.ops import el2n_scores


def lm_loss(logits: jnp.ndarray, tokens: jnp.ndarray, n_prefix: int,
            *, impl: str = "auto") -> Tuple[jnp.ndarray, Dict]:
    """Next-token CE on the text region. logits (B, T, V); tokens (B, S)."""
    B, T, V = logits.shape
    lg = logits[:, n_prefix:-1, :]                    # predicts tokens[1:]
    tg = tokens[:, 1:]
    # differentiated -> ref path (the fused kernel is for scoring; its
    # custom-VJP variant is a perf-pass item)
    _, ce = el2n_scores(lg.reshape(-1, V), tg.reshape(-1), impl="ref")
    loss = ce.mean()
    acc = jnp.mean((jnp.argmax(lg, -1) == tg).astype(jnp.float32))
    return loss, {"ce": loss, "acc": acc}


def lm_el2n(logits: jnp.ndarray, tokens: jnp.ndarray, n_prefix: int,
            *, impl: str = "auto") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-sequence EL2N score + CE. Returns (el2n (B,), ce (B,))."""
    B, T, V = logits.shape
    lg = logits[:, n_prefix:-1, :]
    tg = tokens[:, 1:]
    n = tg.shape[1]
    el2n, ce = el2n_scores(lg.reshape(-1, V), tg.reshape(-1), impl=impl)
    return el2n.reshape(B, n).mean(-1), ce.reshape(B, n).mean(-1)


def classifier_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                    *, impl: str = "auto") -> Tuple[jnp.ndarray, Dict]:
    """logits (B, C), integer labels (B,)."""
    _, ce = el2n_scores(logits, labels, impl="ref")
    loss = ce.mean()
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"ce": loss, "acc": acc}


def classifier_el2n(logits: jnp.ndarray, labels: jnp.ndarray,
                    *, impl: str = "auto"):
    """SFPrompt Eq. (2) exactly: per-sample ||softmax - onehot||_2."""
    el2n, ce = el2n_scores(logits, labels, impl=impl)
    return el2n, ce


def task_loss(cfg, out: Dict, batch: Dict, *, impl: str = "auto",
              mtp_weight: float = 0.3):
    """Dispatch on arch type; adds MoE aux loss and the DeepSeek MTP term."""
    if cfg.num_classes:
        loss, metrics = classifier_loss(out["logits"], batch["labels"],
                                        impl=impl)
    else:
        loss, metrics = lm_loss(out["logits"], batch["tokens"],
                                out.get("n_prefix", 0), impl=impl)
        if "mtp_logits" in out:
            # MTP predicts token t+2 from position t
            mlg = out["mtp_logits"][:, :-1, :]
            mtg = batch["tokens"][:, 2:]
            V = mlg.shape[-1]
            _, mce = el2n_scores(mlg.reshape(-1, V), mtg.reshape(-1),
                                 impl="ref")
            metrics["mtp_ce"] = mce.mean()
            loss = loss + mtp_weight * mce.mean()
    loss = loss + out.get("aux", out.get("aux_loss", 0.0))
    metrics["loss"] = loss
    return loss, metrics


def task_el2n(cfg, out: Dict, batch: Dict, *, impl: str = "auto"):
    if cfg.num_classes:
        return classifier_el2n(out["logits"], batch["labels"], impl=impl)[0]
    return lm_el2n(out["logits"], batch["tokens"], out.get("n_prefix", 0),
                   impl=impl)[0]
