"""The SFPrompt three-phase protocol (Sec. 3, Fig. 3, Algorithms 1-2).

Per global round r:
  Phase 1 — client self-update: U local-loss epochs on (W_t, p) with the
            body skipped (zero server traffic), then EL2N dataset pruning.
  Phase 2 — split training over the pruned subset: head (client, frozen) ->
            body (server, frozen) -> tail (client, trainable); prompt grads
            flow back through the frozen body exactly as the paper's relayed
            backward signals — jax.grad through the chain is byte-identical
            mathematics.
  Phase 3 — sample-count-weighted FedAvg of (W_t, p).

Clients are FIRST-CLASS: every client-side tensor carries a leading client
axis K, all client math is vmapped over it (true per-client divergence), and
on a mesh that axis shards over ('pod','data') — see launch/dryrun.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import losses, pruning
from repro.core.aggregation import broadcast_to_clients, get_aggregator
from repro.core.local_update import dp_clip_and_noise, local_epochs
from repro.core.split import SplitModel
from repro.obs.trace import NOOP
from repro.optim import Optimizer, adamw, apply_updates, sgd
from repro.privacy.dp import DP_SEED, PrivacyAccountant
from repro.runtime.meter import EDGE, SECURE, TrafficMeter
from repro.sharding.rules import (cohort_pspecs, params_pspecs,
                                  report_fallbacks)

Params = Dict[str, Any]

WIRE_SEED = 23   # base PRNG stream for stochastic wire rounding


@dataclass(frozen=True)
class ProtocolConfig:
    clients_per_round: int = 5       # K
    local_epochs: int = 10           # U (phase 1)
    split_epochs: int = 1            # passes over pruned data (phase 2)
    batch_size: int = 16
    lr_local: float = 1e-2
    lr_split: float = 1e-2
    optimizer: str = "sgd"           # sgd | adamw
    momentum: float = 0.9
    impl: str = "ref"
    use_pruning: bool = True
    use_local_loss: bool = True      # False => the Fig-6 ablation arm
    return_client_trainable: bool = False
    # ^ also return each client's post-round (tail, prompt) BEFORE FedAvg —
    #   the fed engine stores these as personalized tails in the Population
    dp_clip: float = 0.0             # DP-SGD L2 clip on the client's round
    #   delta (0 disables the DP path entirely)
    dp_noise_multiplier: float = 0.0  # Gaussian noise as a multiple of the
    #   clip; > 0 activates the zCDP accountant
    dp_delta: float = 1e-5           # delta of the reported (eps, delta)


def make_optimizer(pcfg: ProtocolConfig, lr: float) -> Optimizer:
    if pcfg.optimizer == "adamw":
        return adamw(lr)
    return sgd(lr, momentum=pcfg.momentum)


class SFPromptTrainer:
    supports_partial = True   # round() accepts a participation dict

    def __init__(self, model: SplitModel, pcfg: ProtocolConfig,
                 aggregator=None, *, mesh=None, fsdp: bool = False,
                 donate_cohort: bool = False, tracer=None):
        self.model = model
        self.pcfg = pcfg
        # flight recorder (repro.obs): pure observation — the default NOOP
        # records nothing and the round math never reads it
        self.tracer = tracer if tracer is not None else NOOP
        self.opt_local = make_optimizer(pcfg, pcfg.lr_local)
        self.opt_split = make_optimizer(pcfg, pcfg.lr_split)
        # frozen segments enter the cohort vmap UNBATCHED (in_axes=None) so
        # no K copies of the body ever materialize — except for MoE, whose
        # ragged_dot vmap rule requires every operand batched at dim 0
        self._batch_frozen = getattr(model.cfg, "moe", None) is not None
        # mesh-sharded cohort dispatch: with a mesh, _round jits with
        # explicit in/out shardings — the K axis over the ('pod','data')
        # client plane, params replicated (or FSDP over 'data')
        self._mesh = mesh
        self._fsdp = fsdp
        self._donate_cohort = donate_cohort
        self._mesh_jit_cache: Dict[Any, Any] = {}
        # pluggable phase-3 aggregation: default is the clear path,
        # bit-identical to the seed's fedavg_partial; pass
        # aggregation.get_aggregator(secure=True) for masked secure agg
        self.aggregator = aggregator or get_aggregator()
        if pcfg.dp_noise_multiplier > 0 and pcfg.dp_clip <= 0:
            raise ValueError(
                "dp_noise_multiplier > 0 needs dp_clip > 0: the Gaussian "
                "noise is calibrated to the clip (sensitivity)")
        # zCDP ledger across rounds — only a noised mechanism has a
        # finite epsilon to account for
        self.accountant = (
            PrivacyAccountant(noise_multiplier=pcfg.dp_noise_multiplier,
                              l2_clip=pcfg.dp_clip, delta=pcfg.dp_delta)
            if pcfg.dp_noise_multiplier > 0 else None)
        self.meter = TrafficMeter()   # measured bytes across rounds
        self.meter.attach_tracer(self.tracer)
        self.last_client_trainable = None   # per-client (tail, prompt) of
        # the most recent round, populated iff pcfg.return_client_trainable
        self._round_jit = jax.jit(self._round) if mesh is None else None
        self._eval_jit = jax.jit(self._eval_batches)

    # ------------------------------------------------------- mesh dispatch
    def _frozen_arg(self, tree, k: int):
        """(operand, in_axes) for a frozen pytree entering the cohort vmap:
        unbatched with in_axes=None by default (HBM then scales with
        K * trainable, not K * model). MoE narrows the batched fallback to
        the ragged-dot EXPERT leaves only — jax.lax.ragged_dot has no vmap
        rule for an unbatched rhs, but attention/norm/router leaves vmap
        fine unbatched, so they stay in_axes=None and keep the no-K-copies
        HBM win outside the expert stacks."""
        if not self._batch_frozen:
            return tree, None

        def is_expert(path):
            return any(getattr(p, "key", None) == "experts" for p in path)

        operand = jax.tree_util.tree_map_with_path(
            lambda p, x: jnp.broadcast_to(x[None], (k,) + x.shape)
            if is_expert(p) else x, tree)
        axes = jax.tree_util.tree_map_with_path(
            lambda p, x: 0 if is_expert(p) else None, tree)
        return operand, axes

    def _sharding_tree(self, pspec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(self._mesh, s), pspec_tree,
            is_leaf=lambda x: isinstance(x, PartitionSpec))

    def _build_mesh_jit(self, state, client_data, participation, init_tails):
        """jit of _round with explicit shardings over self._mesh: ONE
        dispatch trains the whole cohort, the K axis laid out on the
        client plane, frozen params replicated (FSDP over 'data' when
        enabled). donate_cohort=True additionally donates the state and
        the K-stacked cohort buffers — only safe when the caller (the
        FederatedEngine loop) never reuses them after the call."""
        mesh = self._mesh
        params = state["params"]
        k = jax.tree.leaves(client_data)[0].shape[0]
        state_sh = self._sharding_tree(
            {"params": params_pspecs(params, mesh, fsdp=self._fsdp),
             "round": PartitionSpec()})
        data_sh = self._sharding_tree(cohort_pspecs(client_data, mesh))
        part_sh = self._sharding_tree(cohort_pspecs(participation, mesh))
        tails_sh = (None if init_tails is None else
                    self._sharding_tree(cohort_pspecs(init_tails, mesh)))
        repl = NamedSharding(mesh, PartitionSpec())
        extras_sh: Any = {}
        if self.pcfg.return_client_trainable:
            proto = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct((k,) + x.shape, x.dtype),
                {"tail": params["tail"], "prompt": params["prompt"]})
            extras_sh = {"trainable": self._sharding_tree(
                cohort_pspecs(proto, mesh))}
        # surface any divisibility fallbacks the spec builders recorded —
        # a rule that wanted 'model'/'data' but could not divide it means
        # this mesh silently replicates something it was sized to shard
        report_fallbacks("protocol.mesh_jit", self.tracer)
        donate = (0, 1, 3) if self._donate_cohort else ()
        return jax.jit(
            self._round,
            in_shardings=(state_sh, data_sh, part_sh, tails_sh),
            out_shardings=(state_sh, repl, extras_sh),
            donate_argnums=donate)

    def _get_round_jit(self, state, client_data, participation, init_tails):
        if self._mesh is None:
            return self._round_jit
        k = jax.tree.leaves(client_data)[0].shape[0]
        key = (k, init_tails is None)
        if key not in self._mesh_jit_cache:
            self._mesh_jit_cache[key] = self._build_mesh_jit(
                state, client_data, participation, init_tails)
        return self._mesh_jit_cache[key]

    # ------------------------------------------------------------- state
    def init(self, key) -> Params:
        return {"params": self.model.init(key),
                "round": jnp.zeros((), jnp.int32)}

    def phase2_keep(self, n_local: int) -> int:
        """Samples per client that actually train phase 2 — `n_local`
        shrunk by EL2N pruning when it is active. Static per shape, so
        the async engine can weight buffered contributions with exactly
        the factor `_round` folds into its FedAvg weights."""
        if self.pcfg.use_pruning and self.model.split.prune_gamma > 0:
            return pruning.pruned_keep_count(
                n_local, self.model.split.prune_gamma, self.pcfg.batch_size)
        return n_local

    # ------------------------------------------------------------- phase 2
    def _split_loss(self, params_frozen, trainable, batch, wire_key):
        """Phase-2 loss with the head->body and body->tail hops crossing the
        real wire: codec'd forward activations, codec'd backward gradients
        (via the boundary custom-VJP), measured bytes in the aux."""
        model, pcfg = self.model, self.pcfg
        k_hb, k_bt = jax.random.split(wire_key)
        ho = model.head_fwd(params_frozen["head"], trainable["prompt"], batch,
                            mode="train", impl=pcfg.impl)
        x_hb, b_hb = model.wire.head_body.transmit(
            ho["smashed"], key=k_hb, train=True)
        bo = model.body_fwd(params_frozen["body"], x_hb, ho)
        x_bt, b_bt = model.wire.body_tail.transmit(
            bo["smashed"], key=k_bt, train=True)
        to = model.tail_fwd(trainable["tail"], x_bt, ho, batch)
        out = {"logits": to["logits"], "n_prefix": to.get("n_prefix", 0),
               "aux": ho["aux"] + bo["aux"] + to["aux"]}
        loss, _ = losses.task_loss(model.cfg, out, batch, impl=pcfg.impl)
        return loss, {"wire": {"head_body": b_hb, "body_tail": b_bt}}

    def _split_epochs(self, frozen, trainable, opt_state, data, wire_key):
        pcfg = self.pcfg
        n = jax.tree.leaves(data)[0].shape[0]
        nb = max(1, n // pcfg.batch_size)
        batched = jax.tree.map(
            lambda x: x[: nb * pcfg.batch_size].reshape(
                (nb, pcfg.batch_size) + x.shape[1:]), data)
        grad_fn = jax.value_and_grad(
            lambda tr, b, k: self._split_loss(frozen, tr, b, k),
            has_aux=True)

        def one_batch(carry, batch):
            tr, os, acc, wire, step = carry
            (loss, aux), grads = grad_fn(
                tr, batch, jax.random.fold_in(wire_key, step))
            updates, os = self.opt_split.update(grads, os, tr)
            tr = apply_updates(tr, updates)
            wire = jax.tree.map(jnp.add, wire, aux["wire"])
            return (tr, os, acc + loss, wire, step + 1), None

        def one_epoch(carry, _):
            carry, _ = jax.lax.scan(one_batch, carry, batched)
            return carry, None

        wire0 = {"head_body": jnp.float32(0.0),
                 "body_tail": jnp.float32(0.0)}
        (trainable, opt_state, acc, wire, _), _ = jax.lax.scan(
            one_epoch,
            (trainable, opt_state, jnp.float32(0.0), wire0, jnp.int32(0)),
            None, length=pcfg.split_epochs)
        return trainable, opt_state, acc / (pcfg.split_epochs * nb), wire

    # ------------------------------------------------------------- round
    def _round(self, state: Params, client_data, participation,
               init_tails) -> Tuple[Params, Dict, Dict]:
        """client_data: pytree with leading (K, n_local, ...) axes — the
        SAMPLED COHORT gathered from a (possibly huge) population, not the
        population itself.

        participation: {"transmit": (K,), "aggregate": (K,)} from a
        `fed.RoundPlan` — transmit scales each client's measured wire bytes
        (a straggler cut off mid-round only sent part of its traffic),
        aggregate weights phase-3 FedAvg (0 drops the client). All-ones
        reproduces the seed repo's synchronous full-participation round
        byte-for-byte.

        init_tails: optional K-stacked tail pytree — each client starts
        phase 1 from its OWN tail (the fed engine's personalized-tail
        regime) instead of the broadcast global tail; None broadcasts."""
        model, pcfg = self.model, self.pcfg
        params = state["params"]
        K = jax.tree.leaves(client_data)[0].shape[0]
        n_local = jax.tree.leaves(client_data)[0].shape[1]

        trainable = broadcast_to_clients(
            {"tail": params["tail"], "prompt": params["prompt"]}, K)
        if init_tails is not None:
            trainable = dict(trainable, tail=init_tails)
        metrics: Dict[str, Any] = {}

        # ---- Phase 1a: local-loss self-update (vmap over clients; the
        # frozen head rides UNBATCHED through in_axes=None — no K copies)
        if pcfg.use_local_loss and pcfg.local_epochs > 0:
            opt_state = jax.vmap(self.opt_local.init)(trainable)
            head_arg, head_ax = self._frozen_arg(params["head"], K)

            def one_client(hd, tr, os, d):
                return local_epochs(
                    model, hd, tr, self.opt_local, os, d,
                    batch_size=pcfg.batch_size, n_epochs=pcfg.local_epochs,
                    impl=pcfg.impl)

            trainable, opt_state, local_loss = jax.vmap(
                one_client, in_axes=(head_ax, 0, 0, 0))(
                head_arg, trainable, opt_state, client_data)
            metrics["local_loss"] = local_loss.mean()

        # ---- Phase 1b: EL2N pruning (vmap over clients)
        if pcfg.use_pruning and model.split.prune_gamma > 0:
            head_arg, head_ax = self._frozen_arg(params["head"], K)

            def score_one(hd, tr, d):
                return pruning.score_client_data(
                    model, hd, tr["tail"], tr["prompt"], d,
                    batch_size=pcfg.batch_size, impl=pcfg.impl)

            scores = jax.vmap(score_one, in_axes=(head_ax, 0, 0))(
                head_arg, trainable, client_data)
            keep = self.phase2_keep(n_local)
            order = jnp.argsort(-scores, axis=1)[:, :keep]
            pruned = jax.tree.map(
                lambda x: jnp.take_along_axis(
                    x, order.reshape((K, keep) + (1,) * (x.ndim - 2)),
                    axis=1) if x.ndim > 2 else
                jnp.take_along_axis(x, order, axis=1),
                client_data)
            metrics["el2n_mean"] = scores.mean()
            metrics["kept_frac"] = keep / n_local
        else:
            pruned, keep = client_data, n_local

        # ---- Phase 2: split training (vmap over clients; the frozen
        # {head, body} enter unbatched — phase-2 peak HBM scales with
        # K * (tail + prompt + opt state), not K * body — batched only on
        # the MoE path whose ragged_dot vmaps solely at dim 0)
        opt_state = jax.vmap(self.opt_split.init)(trainable)
        frozen_arg, frozen_ax = self._frozen_arg(
            {"head": params["head"], "body": params["body"]}, K)
        wire_keys = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(WIRE_SEED),
                               state["round"]), K)

        def split_one(fz, tr, os, d, wk):
            return self._split_epochs(fz, tr, os, d, wk)

        trainable, opt_state, split_loss, wire = jax.vmap(
            split_one, in_axes=(frozen_ax, 0, 0, 0, 0))(
            frozen_arg, trainable, opt_state, pruned, wire_keys)
        metrics["split_loss"] = split_loss.mean()
        transmit = participation["transmit"].astype(jnp.float32)
        for name, per_client in wire.items():
            # a straggler that died / hit the deadline only sent a fraction
            # of its phase-2 traffic — scale the measured per-client bytes
            metrics[f"wire/{name}_bytes"] = (per_client * transmit).sum()

        # ---- DP-SGD on the client update: clip the round delta against
        # the broadcast globals, add calibrated Gaussian noise — BEFORE the
        # server (or the masked aggregator) ever sees the upload
        if pcfg.dp_clip > 0:
            # the reference is pure tree arithmetic (no model ops), so it
            # rides unbatched on every architecture
            reference = {"tail": params["tail"], "prompt": params["prompt"]}
            dp_keys = jax.random.split(
                jax.random.fold_in(jax.random.PRNGKey(DP_SEED),
                                   state["round"]), K)

            def dp_one(tr, ref, dk):
                return dp_clip_and_noise(
                    tr, ref, dk, l2_clip=pcfg.dp_clip,
                    noise_multiplier=pcfg.dp_noise_multiplier)

            trainable, dp_norm = jax.vmap(dp_one, in_axes=(0, None, 0))(
                trainable, reference, dp_keys)
            metrics["dp/delta_norm"] = dp_norm.mean()

        # ---- Phase 3: participation-corrected weighted FedAvg of
        # (tail, prompt) through the pluggable aggregator; dropped clients
        # are excluded, a fully-lost round falls back to the pre-round
        # globals. The secure path uploads masked uint32 ring tensors the
        # server cannot invert (see repro/privacy/secure_agg.py).
        aggregate = participation["aggregate"].astype(jnp.float32)
        weights = jnp.float32(keep) * aggregate
        fallback = {"tail": params["tail"], "prompt": params["prompt"]}
        agg, agg_wire = self.aggregator.aggregate(trainable, weights,
                                                  fallback, state["round"])
        new_params = dict(params)
        new_params["tail"] = agg["tail"]
        new_params["prompt"] = agg["prompt"]
        n_up = (aggregate > 0).sum()
        param_bytes = jnp.float32(sum(
            x.size * x.dtype.itemsize
            for x in jax.tree.leaves(fallback)))
        if agg_wire:
            # metered aggregator: fp32 broadcast down to all K; the uplink
            # is whatever the aggregator metered (masked ring uploads on
            # the secure path) or the clear survivors-only default; key
            # agreement / escrow reveals and the hierarchical edge->global
            # backhaul ride their own streams
            up = agg_wire.get("params_up", n_up * param_bytes)
            metrics["wire/params_bytes"] = K * param_bytes + up
            if SECURE in agg_wire:
                metrics[f"wire/{SECURE}_bytes"] = agg_wire[SECURE]
            if EDGE in agg_wire:
                metrics[f"wire/{EDGE}_bytes"] = agg_wire[EDGE]
        else:
            # clear path: (tail, prompt) travel server->client for all K at
            # round start and client->server only for the survivors
            metrics["wire/params_bytes"] = (K + n_up) * param_bytes
        metrics["cohort/active"] = n_up
        metrics["cohort/transmit_sum"] = transmit.sum()

        extras = ({"trainable": trainable}
                  if pcfg.return_client_trainable else {})
        return ({"params": new_params, "round": state["round"] + 1},
                metrics, extras)

    def round(self, state: Params, client_data,
              participation: Optional[Dict[str, Any]] = None,
              init_tails=None) -> Tuple[Params, Dict]:
        """Run one global round on a sampled cohort. `participation` is a
        `fed.RoundPlan.participation()` dict; None means every client is on
        time (the seed behavior). `init_tails` (K-stacked) starts each
        client from its own personalized tail."""
        K = jax.tree.leaves(client_data)[0].shape[0]
        if participation is None:
            ones = jnp.ones((K,), jnp.float32)
            participation = {"transmit": ones, "aggregate": ones}
        tracer = self.tracer
        with tracer.span("round") as sp:
            round_jit = self._get_round_jit(state, client_data,
                                            participation, init_tails)
            if tracer.enabled:
                tracer.event("round.dispatch", level=2, cohort=K,
                             personalized_tails=init_tails is not None)
            with tracer.annotate("sfprompt.round"):
                state, metrics, extras = round_jit(state, client_data,
                                                   participation, init_tails)
            self.last_client_trainable = extras.get("trainable")
            metrics = {k: float(v) for k, v in metrics.items()}
            if self.accountant is not None:
                # one Gaussian release of each sampled client's update per
                # round — the ledger tracks the per-client (local-model)
                # view
                self.accountant.spend()
                metrics["dp/epsilon"] = self.accountant.epsilon()
            wire = {k.removeprefix("wire/").removesuffix("_bytes"): v
                    for k, v in metrics.items() if k.startswith("wire/")}
            self.meter.absorb(wire, clients=metrics.get("cohort/active"))
            if tracer.enabled:
                # the span carries the SAME floats the meter absorbed —
                # per-span byte attrs sum exactly to the stream totals
                sp.set(round=self.meter.rounds, cohort=K,
                       active=metrics.get("cohort/active"), **wire)
        return state, metrics

    def client_updates(self, state: Params, client_data,
                       transmit=None) -> Tuple[Any, Dict]:
        """Phases 1-2 (+ the per-client DP step) for a dispatched cohort
        WITHOUT phase-3 aggregation — the async runtime's dispatch
        primitive. Returns (K-stacked (tail, prompt) contributions,
        round metrics); the global params are untouched.

        Implemented as the ordinary jitted round with an all-zero
        `aggregate` vector: `fedavg_partial` then returns the pre-round
        globals bit-exactly, so the SAME compiled round serves both the
        synchronous barrier and async dispatch (the bit-identity the
        async tests pin depends on this — no second lowering of phase 2
        exists to drift). The metered `params` stream carries only the
        K-client downlink; uploads are billed when each delta reaches
        the server's buffer. `transmit` (K,) scales phase-2 wire bytes
        for clients that die mid-flight (fraction sent before death).
        Requires ProtocolConfig(return_client_trainable=True)."""
        if not self.pcfg.return_client_trainable:
            raise ValueError(
                "client_updates needs ProtocolConfig("
                "return_client_trainable=True) — without it the jitted "
                "round aggregates and discards the per-client trees")
        K = jax.tree.leaves(client_data)[0].shape[0]
        if transmit is None:
            transmit = jnp.ones((K,), jnp.float32)
        participation = {"transmit": jnp.asarray(transmit, jnp.float32),
                         "aggregate": jnp.zeros((K,), jnp.float32)}
        _, metrics = self.round(state, client_data, participation)
        return self.last_client_trainable, metrics

    # ------------------------------------------------------------- eval
    def _eval_batches(self, params, batched):
        def one(carry, batch):
            out = self.model.forward(params, batch, route="split",
                                     mode="train", impl=self.pcfg.impl)
            loss, m = losses.task_loss(self.model.cfg, out, batch,
                                       impl=self.pcfg.impl)
            return carry, (m["ce"], m["acc"])

        _, (ce, acc) = jax.lax.scan(one, None, batched)
        return ce.mean(), acc.mean()

    def evaluate(self, params: Params, data, *, batch_size: int = 32) -> Dict:
        n = jax.tree.leaves(data)[0].shape[0]
        nb = max(1, n // batch_size)
        batched = jax.tree.map(
            lambda x: x[: nb * batch_size].reshape(
                (nb, batch_size) + x.shape[1:]), data)
        ce, acc = self._eval_jit(params, batched)
        return {"ce": float(ce), "acc": float(acc)}
