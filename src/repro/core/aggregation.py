"""Phase-3 parameter aggregation (SFPrompt Sec. 3.4, Eq. (3)).

Sample-count-weighted FedAvg of the tail model and prompt parameters across
the K selected clients. Under pjit with the client axis sharded over
('pod','data'), the weighted mean lowers to exactly one all-reduce —
the mesh-native image of the paper's server-side aggregation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fedavg(client_trees, weights: jnp.ndarray, fallback=None):
    """client_trees: pytree with leading client axis K on every leaf.
    weights: (K,) sample counts n_k; normalized internally.

    An all-zero weight vector has no defined mean — aligned with
    `fedavg_partial`'s explicit semantics: pass `fallback` (a tree without
    the client axis) to return it in that case, or, with no fallback, a
    concretely all-zero `weights` raises instead of silently emitting the
    near-zero params the old epsilon-division produced. (Traced weights
    can't be inspected — pass `fallback` when the zero case is reachable
    under jit, as `fedavg_partial` always does.)"""
    w = weights.astype(jnp.float32)
    total = w.sum()
    if fallback is None and not isinstance(total, jax.core.Tracer):
        if float(total) <= 0:
            raise ValueError(
                "fedavg weights sum to 0 (every client weightless) — the "
                "mean is undefined; pass fallback= to return pre-round "
                "params instead")
    if fallback is not None:
        return fedavg_partial(client_trees, weights, fallback)
    w = w / jnp.maximum(total, 1e-9)

    def mean(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(wb * x.astype(jnp.float32), axis=0).astype(x.dtype)

    return jax.tree.map(mean, client_trees)


def fedavg_partial(client_trees, weights: jnp.ndarray, fallback):
    """FedAvg over a PARTIALLY participating cohort (stragglers dropped or
    down-weighted by the RoundScheduler).

    weights: (K,) >= 0 — n_k * participation_k; clients at 0 (dropped) are
    excluded and the mean renormalizes over the survivors, which is the
    partial-participation-corrected FedAvg (the estimator stays unbiased
    when the scheduler's drop process is client-independent). If EVERY
    client dropped the round is lost and `fallback` (the pre-round global
    params, no client axis) is returned unchanged — well-defined under jit.

    The async runtime reuses this unchanged: a buffer flush passes its
    staleness-scaled weights (fed/buffer.flush_weights) over the flush
    cohort axis, and the all-zero-weight fallback is also what lets async
    DISPATCH run through the compiled round without touching the globals
    (core/protocol.py client_updates).
    """
    w = weights.astype(jnp.float32)
    total = w.sum()
    safe = jnp.maximum(total, 1e-9)

    def mean(x, fb):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        avg = (jnp.sum(wb * x.astype(jnp.float32), axis=0) / safe)
        return jnp.where(total > 0, avg.astype(x.dtype), fb)

    return jax.tree.map(mean, client_trees, fallback)


def hierarchical_fedavg(client_trees, weights: jnp.ndarray, fallback,
                        assignment, n_edges: int):
    """Two-tier (edge -> global) weighted FedAvg.

    assignment: (K,) int — the edge each client reports to (from
    `fed.topology.EdgeTopology`); n_edges must be static under jit.
    Tier 1 reduces each edge's survivors to a per-edge mean (survivor-
    renormalized exactly like `fedavg_partial`); tier 2 FedAvgs the edge
    means weighted by each edge's surviving weight mass W_e. Because
    sum_e W_e * (S_e / W_e) / sum_e W_e == sum_k w_k x_k / sum_k w_k, the
    result equals the flat weighted mean up to float reassociation — an
    edge whose clients ALL dropped has W_e = 0 and is excluded; when every
    edge drops, `fallback` is returned (the flat all-dropped semantics)."""
    seg = jnp.asarray(assignment, jnp.int32)
    w = weights.astype(jnp.float32)
    w_edge = jax.ops.segment_sum(w, seg, num_segments=n_edges)     # (E,)
    total = w_edge.sum()
    safe_e = jnp.maximum(w_edge, 1e-9)
    safe_t = jnp.maximum(total, 1e-9)

    def mean(x, fb):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        sums = jax.ops.segment_sum(wb * x.astype(jnp.float32), seg,
                                   num_segments=n_edges)           # (E, ...)
        edge_means = sums / safe_e.reshape((-1,) + (1,) * (x.ndim - 1))
        we = w_edge.reshape((-1,) + (1,) * (x.ndim - 1))
        avg = jnp.sum(we * edge_means, axis=0) / safe_t
        return jnp.where(total > 0, avg.astype(x.dtype), fb)

    return jax.tree.map(mean, client_trees, fallback)


def broadcast_to_clients(tree, k: int):
    """Replicate aggregated params back to K per-client copies."""
    if k <= 0:
        raise ValueError(
            f"broadcast_to_clients needs a positive cohort size, got k={k} "
            "— an empty-leading-axis tree would only fail later, deep "
            "inside the cohort vmap")
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), tree)


def get_aggregator(secure: bool = False, *, n_edges: int = 0,
                   cohort_size: int = 0, **kw):
    """The phase-3 aggregation path as a pluggable object.

    secure=False -> ClearAggregator (bit-identical to `fedavg_partial`,
    the seed behavior); secure=True -> the privacy engine's masked
    SecureAggregator (kwargs: frac_bits, impl, seed — see
    repro/privacy/secure_agg.py). n_edges > 0 -> the hierarchical
    (edge -> global) topology from fed/topology.py wrapping per-edge
    clear/secure aggregators; needs cohort_size (K) to lay out the edges.
    Imported lazily so the core layer has no hard dependency on the
    privacy or fed subsystems."""
    if n_edges > 0:
        from repro.fed.topology import EdgeTopology, HierarchicalAggregator
        return HierarchicalAggregator(
            EdgeTopology(cohort_size, n_edges), secure=secure, **kw)
    from repro.privacy.secure_agg import ClearAggregator, SecureAggregator
    if secure:
        return SecureAggregator(**kw)
    if kw:
        raise ValueError(f"clear aggregation takes no options, got {kw}")
    return ClearAggregator()
