"""Phase-3 parameter aggregation (SFPrompt Sec. 3.4, Eq. (3)).

Sample-count-weighted FedAvg of the tail model and prompt parameters across
the K selected clients. Under pjit with the client axis sharded over
('pod','data'), the weighted mean lowers to exactly one all-reduce —
the mesh-native image of the paper's server-side aggregation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fedavg(client_trees, weights: jnp.ndarray):
    """client_trees: pytree with leading client axis K on every leaf.
    weights: (K,) sample counts n_k; normalized internally."""
    w = weights.astype(jnp.float32)
    w = w / jnp.maximum(w.sum(), 1e-9)

    def mean(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(wb * x.astype(jnp.float32), axis=0).astype(x.dtype)

    return jax.tree.map(mean, client_trees)


def fedavg_partial(client_trees, weights: jnp.ndarray, fallback):
    """FedAvg over a PARTIALLY participating cohort (stragglers dropped or
    down-weighted by the RoundScheduler).

    weights: (K,) >= 0 — n_k * participation_k; clients at 0 (dropped) are
    excluded and the mean renormalizes over the survivors, which is the
    partial-participation-corrected FedAvg (the estimator stays unbiased
    when the scheduler's drop process is client-independent). If EVERY
    client dropped the round is lost and `fallback` (the pre-round global
    params, no client axis) is returned unchanged — well-defined under jit.
    """
    w = weights.astype(jnp.float32)
    total = w.sum()
    safe = jnp.maximum(total, 1e-9)

    def mean(x, fb):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        avg = (jnp.sum(wb * x.astype(jnp.float32), axis=0) / safe)
        return jnp.where(total > 0, avg.astype(x.dtype), fb)

    return jax.tree.map(mean, client_trees, fallback)


def broadcast_to_clients(tree, k: int):
    """Replicate aggregated params back to K per-client copies."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), tree)
