"""Decode fast path: multi-token scan stepping, the decode-attention
kernel, and KV-cache donation safety.

The scan path reuses the per-token decode body inside lax.scan, so the
identity tests pin that the amortization never changes a single logit; the
kernel tests sweep GQA / sliding-window / ragged per-slot lengths against
the jnp oracle; the donation tests replay a trace through donated caches
and require byte- and token-identical results (use-after-donate would
crash or corrupt)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SplitConfig, SplitModel
from repro.core.comm import serve_comm_breakdown
from repro.kernels.flash_attention.decode import decode_attention
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.runtime import WireSpec
from repro.serve import (Request, ServeConfig, ServeEngine, TenantBank,
                         WorkloadConfig, synthetic_requests)

KEY = jax.random.PRNGKey(0)
MAX_SEQ = 48
PROMPT_LEN = 4


def build_model(wire="fp32"):
    cfg = get_config("qwen2.5-14b").reduced(
        n_layers=3, d_model=64, d_ff=128, vocab_size=128)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=PROMPT_LEN)
    return cfg, SplitModel(cfg, split, WireSpec.make(wire))


@pytest.fixture(scope="module")
def setup():
    cfg, model = build_model()
    params = model.init(KEY)
    bank = TenantBank.replicate(params["tail"], params["prompt"], 3)
    return cfg, model, params, bank


# ragged max_new + staggered arrivals: slots join and retire mid-scan
REQS = [
    Request(rid=0, tenant=0, tokens=np.arange(9, dtype=np.int32) % 128,
            max_new=5, arrival=0),
    Request(rid=1, tenant=1, tokens=(np.arange(14, dtype=np.int32) * 3)
            % 128, max_new=11, arrival=0),
    Request(rid=2, tenant=2, tokens=(np.arange(6, dtype=np.int32) * 7)
            % 128, max_new=2, arrival=2),
    Request(rid=3, tenant=1, tokens=(np.arange(11, dtype=np.int32) * 5)
            % 128, max_new=7, arrival=3),
]


def run_engine(model, params, bank, *, decode_block, donate=True,
               reqs=REQS, n_slots=2):
    engine = ServeEngine(model, params, bank,
                         ServeConfig(n_slots=n_slots, max_seq=MAX_SEQ,
                                     decode_block=decode_block,
                                     donate=donate),
                         collect_logits=True)
    stats = engine.run(reqs)
    return {f.req.rid: f for f in stats["finished"]}, stats


# ------------------------------------------------------- scan stepping
def test_scan_decode_logit_identical_to_per_token(setup):
    """decode_block=8 (scan stepping, power-of-two buckets, deferred
    retirement) produces the same tokens AND fp32 logits as per-token
    dispatch for every request in a ragged 4-request trace."""
    cfg, model, params, bank = setup
    per_tok, s1 = run_engine(model, params, bank, decode_block=1)
    scanned, s8 = run_engine(model, params, bank, decode_block=8)
    assert set(per_tok) == set(scanned) == {r.rid for r in REQS}
    for rid in per_tok:
        np.testing.assert_array_equal(per_tok[rid].tokens,
                                      scanned[rid].tokens,
                                      err_msg=f"rid={rid}")
        np.testing.assert_allclose(per_tok[rid].logits, scanned[rid].logits,
                                   rtol=1e-6, atol=1e-6,
                                   err_msg=f"rid={rid}")
    # every generated token was delivered, none invented by garbage steps
    assert s1["tokens_out"] == s8["tokens_out"] == sum(
        r.max_new for r in REQS)


def test_scan_decode_wire_bytes_match_per_token(setup):
    """Deferred retirement must not meter dead slots: the scan path's
    measured bytes equal the per-token path's exactly (the per-step
    `remaining > t` mask stops counting a slot the moment it retires)."""
    cfg, model, params, bank = setup
    _, s1 = run_engine(model, params, bank, decode_block=1)
    _, s8 = run_engine(model, params, bank, decode_block=8)
    for name in ("head_body", "body_tail", "total"):
        assert s1["wire_bytes"][name] == pytest.approx(
            s8["wire_bytes"][name]), name


@pytest.mark.parametrize("wire", ["fp32", "int8"])
def test_scan_decode_metered_vs_analytical(wire):
    """The analytical per-token serve model still matches within 5% when
    tokens are generated through the scanned fast path."""
    cfg, model = build_model(wire)
    params = model.init(KEY)
    bank = TenantBank.replicate(params["tail"], params["prompt"], 2)
    wl = WorkloadConfig(n_requests=6, mean_interarrival=1.0,
                        prompt_choices=(6, 10), new_token_choices=(3, 5),
                        n_tenants=2, vocab_size=cfg.vocab_size, seed=3)
    reqs = synthetic_requests(wl)
    engine = ServeEngine(model, params, bank,
                         ServeConfig(n_slots=3, max_seq=MAX_SEQ,
                                     decode_block=4))
    stats = engine.run(reqs)
    analytical = serve_comm_breakdown(
        model.wire, d_model=cfg.d_model, soft_prompt_len=PROMPT_LEN,
        requests=[(len(r.tokens), r.max_new) for r in reqs])
    for name, ref in analytical.items():
        got = stats["wire_bytes"][name]
        assert ref > 0
        assert abs(got - ref) / ref <= 0.05, (name, got, ref)


# --------------------------------------------------- decode attention
def _ragged_cache(B, W, Hkv, D, lens, *, ring=False):
    k = jax.random.normal(jax.random.PRNGKey(1), (B, W, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, W, Hkv, D))
    pos = np.full((B, W), -1, np.int32)
    for b, L in enumerate(lens):
        slots = (np.arange(L) + 3 * b) % W if ring else np.arange(L)
        pos[b, slots] = np.arange(L)
    return k, v, jnp.asarray(pos)


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2), (4, 1)])
@pytest.mark.parametrize("kw", [
    {},
    dict(sliding_window=16),
    dict(softcap=10.0),
    dict(sliding_window=9, softcap=5.0),
])
def test_decode_attention_kernel_vs_ref(Hq, Hkv, kw):
    """Pallas decode kernel (interpret) and the grouped XLA path vs the
    jnp oracle, across GQA ratios, sliding windows, softcap, and ragged
    ring-ordered per-slot lengths."""
    B, W, D = 3, 64, 32
    lens = [7, 33, 64]
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, Hq, D))
    k, v, pos = _ragged_cache(B, W, Hkv, D, lens, ring=True)
    qpos = jnp.asarray([L - 1 for L in lens], jnp.int32)
    ref = decode_attention(q, k, v, q_positions=qpos, kv_positions=pos,
                           impl="ref", **kw)
    for impl in ("xla", "interpret"):
        out = decode_attention(q, k, v, q_positions=qpos, kv_positions=pos,
                               impl=impl, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"impl={impl} {kw}")


def test_flash_attention_auto_routes_decode_to_fast_path():
    """impl='auto' off-TPU must reach the grouped decode path for Sq=1
    cache reads (bit-identical to decode_attention impl='xla'), not fall
    back to the oracle before the decode dispatch."""
    B, W, Hq, Hkv, D = 2, 32, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, Hq, D))
    k, v, pos = _ragged_cache(B, W, Hkv, D, [9, 25], ring=True)
    qpos = jnp.asarray([8, 24], jnp.int32)
    from repro.kernels.flash_attention.ops import flash_attention
    auto = flash_attention(q, k, v, q_offset=qpos, kv_positions=pos,
                           impl="auto")
    xla = decode_attention(q, k, v, q_positions=qpos, kv_positions=pos,
                           impl="xla")
    assert jax.default_backend() == "cpu"
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(xla))


def test_decode_attention_rejects_multi_query():
    B, W, H, D = 1, 16, 2, 8
    q = jnp.zeros((B, 3, H, D))
    k = jnp.zeros((B, W, H, D))
    pos = jnp.broadcast_to(jnp.arange(W, dtype=jnp.int32)[None], (B, W))
    with pytest.raises(AssertionError):
        decode_attention(q, k, k, q_positions=jnp.zeros((B,), jnp.int32),
                         kv_positions=pos, impl="xla")


def test_decode_attention_empty_slots_ignored():
    """Cache rows marked -1 must contribute nothing, whatever they hold."""
    B, W, H, D = 2, 32, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, D))
    k, v, pos = _ragged_cache(B, W, H, D, [5, 20])
    poison = jnp.where((pos == -1)[..., None, None], 1e6, 0.0)
    ref = decode_attention(q, k, v, q_positions=jnp.asarray([4, 19]),
                           kv_positions=pos, impl="ref")
    for impl in ("xla", "interpret"):
        out = decode_attention(q, k + poison, v + poison,
                               q_positions=jnp.asarray([4, 19]),
                               kv_positions=pos, impl=impl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ donation
def test_donated_engine_matches_undonated(setup):
    """Cache donation must be invisible to results: same tokens, logits,
    and measured wire bytes with donation on and off."""
    cfg, model, params, bank = setup
    with_d, sd = run_engine(model, params, bank, decode_block=4,
                            donate=True)
    without, sn = run_engine(model, params, bank, decode_block=4,
                             donate=False)
    for rid in with_d:
        np.testing.assert_array_equal(with_d[rid].tokens,
                                      without[rid].tokens)
        np.testing.assert_array_equal(with_d[rid].logits,
                                      without[rid].logits)
    assert sd["wire_bytes"]["total"] == pytest.approx(
        sn["wire_bytes"]["total"])


def test_donated_replay_after_reset_stats(setup):
    """No use-after-donate: one warm engine (donated caches, scan path)
    re-serves the trace after reset_stats() with identical counters,
    tokens, and meter totals."""
    cfg, model, params, bank = setup
    engine = ServeEngine(model, params, bank,
                         ServeConfig(n_slots=2, max_seq=MAX_SEQ,
                                     decode_block=8, donate=True))
    first = engine.run(REQS)
    snap1 = (engine.decode_steps, engine.tokens_out, engine.prefill_count,
             first["wire_bytes"]["total"])
    engine.reset_stats()
    second = engine.run(REQS)
    snap2 = (engine.decode_steps, engine.tokens_out, engine.prefill_count,
             second["wire_bytes"]["total"])
    assert snap1 == snap2
    toks1 = {f.req.rid: f.tokens.tolist() for f in first["finished"]}
    toks2 = {f.req.rid: f.tokens.tolist() for f in second["finished"]}
    assert toks1 == toks2


def test_launch_steps_donated_cache_matches(setup):
    """launch/steps.py donate_cache=True: prefill+decode through donated
    caches equals the undonated jitted path bit-for-bit."""
    cfg, model, params, bank = setup
    tokens = jnp.asarray(np.arange(7, dtype=np.int32)[None] % 128)

    def roll(donate):
        prefill = (make_prefill_step(model, dtype=jnp.float32,
                                     donate_cache=True) if donate
                   else jax.jit(make_prefill_step(model,
                                                  dtype=jnp.float32)))
        decode = (make_decode_step(model, dtype=jnp.float32,
                                   donate_cache=True) if donate
                  else jax.jit(make_decode_step(model, dtype=jnp.float32)))
        cache = model.init_cache(1, seq_len=MAX_SEQ)
        logits, cache = prefill(params, {"tokens": tokens}, cache)
        outs = [np.asarray(logits)]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = jnp.asarray([7 + PROMPT_LEN], jnp.int32)
        for i in range(3):
            tok, logits, cache = decode(
                params, {"tokens": tok[:, None], "pos": pos + i}, cache)
            outs.append(np.asarray(logits))
        return np.concatenate(outs)

    np.testing.assert_array_equal(roll(donate=False), roll(donate=True))
