"""Privacy engine: masked secure aggregation == clear FedAvg (incl. under
scheduler dropouts), blinded uploads, wire-byte cross-checks, DP-SGD
clipping, and the zCDP ledger's byte-identical kill-and-restart resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.core.aggregation import fedavg, fedavg_partial, get_aggregator
from repro.core.comm import secure_agg_breakdown
from repro.core.local_update import dp_clip_and_noise
from repro.data import DATASETS, synthetic_image_dataset
from repro.fed import (ClientSampler, FederatedEngine, Population,
                       RoundScheduler, StragglerConfig)
from repro.kernels.secure_mask.ops import (encode, masked_encode, ring_size,
                                           summed_mask)
from repro.privacy import PrivacyAccountant, SecureAggregator, calibrate_noise
from repro.privacy.fixed_point import roundtrip_tol
from repro.runtime import WireSpec

KEY = jax.random.PRNGKey(0)
N_CLIENTS = 40
N_LOCAL = 8
BATCH = 4
K = 4


def random_cohort_tree(key, k):
    return {"tail": {"w": jax.random.normal(key, (k, 7, 3)),
                     "b": jax.random.normal(jax.random.fold_in(key, 1),
                                            (k, 5))},
            "prompt": jax.random.normal(jax.random.fold_in(key, 2),
                                        (k, 4, 8))}


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=32, d_ff=64)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2,
                        prune_gamma=0.3, local_epochs=1)
    data = synthetic_image_dataset(DATASETS["cifar10-syn"],
                                   N_CLIENTS * N_LOCAL, seed=0, image_hw=32)
    pop = Population.from_partition(data, N_CLIENTS, scheme="dirichlet",
                                    alpha=0.1, seed=0)
    return cfg, split, data, pop


def make_trainer(cfg, split, *, aggregator=None, dp_noise=0.0, dp_clip=0.0):
    model = SplitModel(cfg, split, WireSpec.make("fp32"))
    pcfg = ProtocolConfig(clients_per_round=K, local_epochs=1,
                          batch_size=BATCH, momentum=0.0,
                          dp_clip=dp_clip, dp_noise_multiplier=dp_noise)
    return SFPromptTrainer(model, pcfg, aggregator)


# ------------------------------------------------------- aggregator level
@pytest.mark.parametrize("weights", [
    [3.0, 2.0, 7.0, 1.0, 5.0],            # full participation
    [3.0, 2.0, 0.0, 1.0, 5.0],            # one dropout
    [0.0, 2.0, 0.0, 0.0, 5.0],            # most dropped
])
def test_secure_aggregate_equals_clear(weights):
    """The masked ring sum decodes to exactly fedavg_partial's survivor-
    weighted mean, within fixed-point tolerance — dropped clients' dangling
    masks are reconstructed from escrowed seeds and subtracted."""
    k = len(weights)
    tree = random_cohort_tree(KEY, k)
    w = jnp.asarray(weights)
    fb = jax.tree.map(lambda x: jnp.full_like(x[0], -1.0), tree)
    clear = fedavg_partial(tree, w, fb)
    sec, wire = SecureAggregator(impl="ref").aggregate(tree, w, fb,
                                                       jnp.int32(2))
    tol = roundtrip_tol(k)
    for a, b in zip(jax.tree.leaves(clear), jax.tree.leaves(sec)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=tol)
    assert float(wire["params_up"]) > 0 and float(wire["secure"]) > 0


def test_secure_aggregate_all_dropped_falls_back():
    tree = random_cohort_tree(KEY, 4)
    fb = jax.tree.map(lambda x: jnp.full_like(x[0], 3.5), tree)
    sec, _ = SecureAggregator(impl="ref").aggregate(
        tree, jnp.zeros((4,)), fb, jnp.int32(0))
    for a, b in zip(jax.tree.leaves(sec), jax.tree.leaves(fb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_masked_upload_is_blinded():
    """One client's on-wire payload must look nothing like its plaintext
    encoding: virtually every ring element differs and the high bit is
    ~uniform (the pairwise PRG stream dominates the payload)."""
    n = ring_size(1000)
    x = jax.random.normal(KEY, (n,)) * 0.1
    seeds = jax.random.bits(KEY, (4,), jnp.uint32)
    signs = jnp.array([1, 1, -1, -1], jnp.int32)
    upload = masked_encode(x, seeds, signs, impl="ref")
    plain = encode(x)
    assert float(jnp.mean(upload == plain)) < 0.01
    high_bit = np.asarray(upload >> 31, np.float64)
    assert 0.4 < high_bit.mean() < 0.6


def test_upload_minus_regenerated_mask_is_plaintext():
    """summed_mask regenerates exactly the stream masked_encode folded in
    (same impl) — the dropout-recovery contract."""
    n = ring_size(300)
    x = jax.random.normal(KEY, (n,))
    seeds = jax.random.bits(KEY, (3,), jnp.uint32)
    signs = jnp.array([1, -1, 1], jnp.int32)
    upload = masked_encode(x, seeds, signs, impl="ref")
    mask = summed_mask(seeds, signs, n, impl="ref")
    np.testing.assert_array_equal(np.asarray(upload - mask),
                                  np.asarray(encode(x)))


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="pltpu PRNG has no CPU/interpret lowering; the "
                           "Pallas mask kernel validates on TPU")
def test_pallas_aggregate_matches_ref():
    """Mask bits differ across impls by design, but the cohort ring sum
    (masks cancelled / recovered) is impl-independent."""
    tree = random_cohort_tree(KEY, 4)
    w = jnp.array([2.0, 1.0, 0.0, 3.0])
    fb = jax.tree.map(lambda x: jnp.zeros_like(x[0]), tree)
    ref, _ = SecureAggregator(impl="ref").aggregate(tree, w, fb, 1)
    pal, _ = SecureAggregator(impl="pallas").aggregate(tree, w, fb, 1)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(pal)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=roundtrip_tol(4))


# ---------------------------------------------------------- protocol level
def cohort_data(pop, ids):
    return {k: jnp.asarray(v) for k, v in pop.gather(ids).items()}


def test_secure_round_equals_clear_round(setup):
    """One full protocol round (local epochs, pruning, split training,
    aggregation) with the secure aggregator lands on the clear round's
    params within fixed-point tolerance — including under a straggler
    plan that drops a client mid-round."""
    cfg, split, _, pop = setup
    data = cohort_data(pop, np.arange(K))
    part = {"transmit": jnp.array([1.0, 0.4, 1.0, 1.0]),
            "aggregate": jnp.array([1.0, 0.0, 1.0, 1.0])}

    tr_clear = make_trainer(cfg, split)
    st_c, m_c = tr_clear.round(tr_clear.init(KEY), data, dict(part))
    tr_sec = make_trainer(
        cfg, split, aggregator=get_aggregator(secure=True, impl="ref"))
    st_s, m_s = tr_sec.round(tr_sec.init(KEY), data, dict(part))

    tol = roundtrip_tol(K)
    for a, b in zip(jax.tree.leaves(st_c["params"]),
                    jax.tree.leaves(st_s["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=tol)
    # phase-2 smashed traffic is identical; only phase 3 changed
    assert m_c["wire/head_body_bytes"] == m_s["wire/head_body_bytes"]
    assert m_s["wire/secure_bytes"] > 0


def test_secure_wire_bytes_match_analytical(setup):
    """Metered secure-round bytes == comm.secure_agg_breakdown within 5%
    (exact in practice: both count the same padded payload shapes)."""
    cfg, split, _, pop = setup
    data = cohort_data(pop, np.arange(K))
    part = {"transmit": jnp.ones((K,)),
            "aggregate": jnp.array([1.0, 1.0, 0.0, 1.0])}
    tr = make_trainer(
        cfg, split, aggregator=get_aggregator(secure=True, impl="ref"))
    st = tr.init(KEY)
    st, m = tr.round(st, data, dict(part))

    trainable = {"tail": st["params"]["tail"],
                 "prompt": st["params"]["prompt"]}
    n_tr = sum(int(np.prod(x.shape))
               for x in jax.tree.leaves(trainable))
    pb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(trainable))
    bd = secure_agg_breakdown(n_trainable=n_tr, param_nbytes=pb, K=K,
                              n_uploads=3)
    for name in ("params", "secure"):
        got = tr.meter.totals[name]
        assert abs(got - bd[name]) <= 0.05 * bd[name], (name, got, bd[name])


def test_secure_all_dropped_round_falls_back(setup):
    cfg, split, _, pop = setup
    data = cohort_data(pop, np.arange(K))
    part = {"transmit": jnp.zeros((K,)), "aggregate": jnp.zeros((K,))}
    tr = make_trainer(
        cfg, split, aggregator=get_aggregator(secure=True, impl="ref"))
    st0 = tr.init(KEY)
    before = jax.tree.map(np.asarray, st0["params"])
    st1, _ = tr.round(st0, data, part)
    for name in ("tail", "prompt"):
        for a, b in zip(jax.tree.leaves(before[name]),
                        jax.tree.leaves(st1["params"][name])):
            np.testing.assert_array_equal(a, np.asarray(b))


# ------------------------------------------------------------------ DP
def test_dp_clip_bounds_delta():
    """Clipping caps the update's L2 against the reference; zero noise
    multiplier adds nothing."""
    ref = {"a": jnp.zeros((6,)), "b": jnp.zeros((2, 3))}
    big = {"a": jnp.full((6,), 10.0), "b": jnp.full((2, 3), -10.0)}
    out, norm = dp_clip_and_noise(big, ref, KEY, l2_clip=1.0,
                                  noise_multiplier=0.0)
    delta_sq = sum(float(jnp.sum(jnp.square(x)))
                   for x in jax.tree.leaves(out))
    assert delta_sq <= 1.0 + 1e-5
    assert float(norm) > 1.0
    # under the clip: identity
    small = {"a": jnp.full((6,), 0.01), "b": jnp.full((2, 3), 0.01)}
    out2, _ = dp_clip_and_noise(small, ref, KEY, l2_clip=1.0,
                                noise_multiplier=0.0)
    for a, b in zip(jax.tree.leaves(out2), jax.tree.leaves(small)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_dp_noise_requires_clip():
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=32, d_ff=64)
    split = SplitConfig(prompt_len=2, local_epochs=1)
    with pytest.raises(ValueError, match="dp_clip"):
        make_trainer(cfg, split, dp_noise=1.0, dp_clip=0.0)


def test_accountant_composition_and_calibration():
    """rho composes additively; epsilon is monotone in rounds; the
    calibrated noise lands a full run exactly on the target epsilon."""
    z = calibrate_noise(8.0, 1e-5, rounds=10)
    acct = PrivacyAccountant(noise_multiplier=z, l2_clip=1.0, delta=1e-5)
    eps_seen = []
    for _ in range(10):
        acct.spend()
        eps_seen.append(acct.epsilon())
    assert all(a < b for a, b in zip(eps_seen, eps_seen[1:]))
    assert abs(eps_seen[-1] - 8.0) < 1e-9
    assert acct.releases == 10
    # tighter target -> more noise
    assert calibrate_noise(1.0, 1e-5, 10) > z


def test_fedavg_zero_weights_regression():
    """Satellite: all-zero weights must not silently divide by epsilon —
    raise without a fallback, return the fallback with one."""
    tree = random_cohort_tree(KEY, 3)
    with pytest.raises(ValueError, match="sum to 0"):
        fedavg(tree, jnp.zeros((3,)))
    fb = jax.tree.map(lambda x: jnp.full_like(x[0], 2.0), tree)
    out = fedavg(tree, jnp.zeros((3,)), fb)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(fb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # nonzero weights: unchanged semantics
    w = jnp.array([1.0, 2.0, 3.0])
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(fedavg(tree, w))[0]),
        np.asarray(jax.tree.leaves(fedavg(tree, w, fb))[0]),
        rtol=1e-6, atol=1e-7)


# ------------------------------------------------------------- engine level
def build_engine(cfg, split, pop, *, secure=False, dp=False, seed=7):
    agg = get_aggregator(secure=True, impl="ref") if secure else None
    tr = make_trainer(cfg, split, aggregator=agg,
                      dp_noise=(0.8 if dp else 0.0),
                      dp_clip=(1.0 if dp else 0.0))
    sampler = ClientSampler(pop.n_clients, K, seed=seed)
    sched = RoundScheduler(
        StragglerConfig(dropout_rate=0.25, late_mode="drop"), seed=seed)
    return FederatedEngine(tr, pop, sampler, sched)


def test_dp_secure_engine_resume_byte_identical(setup, tmp_path):
    """Kill-and-restart with DP + secure aggregation: params AND the zCDP
    ledger of the resumed run are byte-identical to the uninterrupted one."""
    cfg, split, data, _ = setup

    def build():
        pop = Population.from_partition(data, N_CLIENTS, scheme="dirichlet",
                                        alpha=0.1, seed=0)
        return build_engine(cfg, split, pop, secure=True, dp=True)

    ref = build()
    ref.init(KEY)
    for _ in range(3):
        ref.run_round()

    eng = build()
    eng.init(KEY)
    for _ in range(2):
        eng.run_round()
    ckpt = str(tmp_path / "ckpt")
    eng.save(ckpt)

    res = build()
    assert res.restore(ckpt)
    assert res.round_idx == 2
    # ledger restored exactly at the kill point (2 releases), then composes
    assert res.trainer.accountant.releases == 2
    assert res.trainer.accountant.rho == eng.trainer.accountant.rho
    res.run_round()

    for a, b in zip(jax.tree.leaves(ref.state["params"]),
                    jax.tree.leaves(res.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ref.trainer.accountant.rho == res.trainer.accountant.rho
    assert ref.trainer.accountant.epsilon() == res.trainer.accountant.epsilon()
    assert ref.trainer.meter.as_dict() == res.trainer.meter.as_dict()


def test_resume_clear_checkpoint_under_secure_fails(setup, tmp_path):
    """The aggregator rides the trainer fingerprint: a clear-agg checkpoint
    must not silently resume under secure aggregation."""
    cfg, split, _, pop = setup
    eng = build_engine(cfg, split, pop, secure=False)
    eng.state = eng.trainer.init(KEY)
    ckpt = str(tmp_path / "ckpt")
    eng.save(ckpt)
    eng2 = build_engine(cfg, split, pop, secure=True)
    with pytest.raises(ValueError, match="hyperparameters"):
        eng2.restore(ckpt)


def test_resume_changed_dp_flags_fails(setup, tmp_path):
    """A resumed run with a different noise multiplier would invalidate
    the epsilon ledger — must fail loudly."""
    cfg, split, _, pop = setup
    eng = build_engine(cfg, split, pop, dp=True)
    eng.state = eng.trainer.init(KEY)
    eng.trainer.accountant.spend(2)
    ckpt = str(tmp_path / "ckpt")
    eng.save(ckpt)

    other = build_engine(cfg, split, pop, dp=True)
    other.trainer.accountant.noise_multiplier = 0.3   # simulate new flags
    with pytest.raises(ValueError):
        other.restore(ckpt)
