"""True tensor-parallel frozen body: the 'model' mesh axis carries COMPUTE.

Training: the cohort round jitted against a 2D (data=2, model=4) host mesh
— frozen body leaves enter with their params_pspecs 'model' shardings, so
the scan-stacked blocks run attention head-parallel / MLP d_ff-parallel
with XLA's collectives stitching partial sums — must match the
single-device vmap round (params allclose at fp32, every metered byte
exact, clear AND secure aggregation), and the compiled executable must
hold NO full-size frozen-body buffer per device.

Serving: the same TP shardings threaded through the serve steps (dense and
paged engines) must be logit-identical to the unsharded engines, with the
KV pools sharded along the kv-heads dim.

The multi-device tests need >= 8 visible devices — run under
XLA_FLAGS=--xla_force_host_platform_device_count=8 (CI's test-mesh8 job);
on the default 1-device run they skip. The rule/fallback unit tests run
anywhere (they only consult mesh.shape via a stub)."""
import re
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.core.aggregation import get_aggregator
from repro.data import DATASETS, synthetic_image_dataset, synthetic_lm_dataset
from repro.launch.mesh import make_host_mesh, report_sharding_fallbacks
from repro.runtime import WireSpec
from repro.serve import (PagedServeConfig, PagedServeEngine, Request,
                         ServeConfig, ServeEngine, TenantBank)
from repro.sharding import (cache_pspecs, params_pspecs,
                            pop_sharding_fallbacks)

KEY = jax.random.PRNGKey(0)
N_LOCAL = 4
BATCH = 4
TP = 4          # 'model' axis size of the test mesh: (data=2, model=4)

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="tensor-parallel tests need 8 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


class _FakeMesh:
    """Shape-only mesh stub: the pspec builders consult nothing beyond
    mesh.shape, so rule/fallback unit tests run on any device count."""
    shape = {"data": 2, "model": TP}


@pytest.fixture(scope="module")
def setup():
    # same distinctive dims as test_mesh_round (32 / 48): every 'model'
    # rule divides TP=4 except the 10-class head (a deliberate fallback)
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=32, d_ff=48)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2,
                        prune_gamma=0.5, local_epochs=1)
    return cfg, split


def make_trainer(cfg, split, *, k, aggregator=None, mesh=None):
    model = SplitModel(cfg, split)
    pcfg = ProtocolConfig(clients_per_round=k, local_epochs=1,
                          batch_size=BATCH, momentum=0.0)
    return SFPromptTrainer(model, pcfg, aggregator, mesh=mesh)


def cohort_batch(k, *, seed=0):
    data = synthetic_image_dataset(DATASETS["cifar10-syn"], k * N_LOCAL,
                                   seed=seed, image_hw=32)
    return {name: jnp.asarray(v).reshape((k, N_LOCAL) + v.shape[1:])
            for name, v in data.items()}


def tp_mesh():
    return make_host_mesh(8, model=TP)


# -------------------------------------------------------------- mesh shape
@needs_mesh
def test_make_host_mesh_2d():
    mesh = tp_mesh()
    assert dict(mesh.shape) == {"data": 2, "model": TP}
    assert dict(make_host_mesh(8).shape) == {"data": 8}


def test_make_host_mesh_rejects_indivisible_model():
    n = jax.device_count()
    with pytest.raises(ValueError, match="does not divide"):
        make_host_mesh(model=n + 7 if (n % (n + 7)) else 3)


# ------------------------------------------------------ TP training rounds
@needs_mesh
@pytest.mark.parametrize("secure", [False, True], ids=["clear", "secure"])
def test_tp_round_matches_single_device(setup, secure):
    """K=64 on the (data=2, model=4) mesh == the single-device vmap round:
    params within fp32 reassociation tolerance (the TP all-reduce sums
    partials in a different order), every metric close, and every METERED
    BYTE exactly equal — wire accounting is shape-derived and must not
    notice the layout."""
    cfg, split = setup
    k = 64
    data = cohort_batch(k)
    part = {"transmit": jnp.ones((k,), jnp.float32),
            "aggregate": jnp.ones((k,), jnp.float32)}

    def agg():
        return (get_aggregator(secure=True, impl="ref", seed=11)
                if secure else None)

    ref = make_trainer(cfg, split, k=k, aggregator=agg())
    st_r, m_r = ref.round(ref.init(KEY), data, dict(part))
    tp = make_trainer(cfg, split, k=k, aggregator=agg(), mesh=tp_mesh())
    st_t, m_t = tp.round(tp.init(KEY), data, dict(part))

    for a, b in zip(jax.tree.leaves(st_r["params"]),
                    jax.tree.leaves(st_t["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    assert set(m_r) == set(m_t)
    for name in m_r:
        np.testing.assert_allclose(m_r[name], m_t[name], rtol=1e-5,
                                   err_msg=name)
    assert ref.meter.totals.keys() == tp.meter.totals.keys()
    for name in ref.meter.totals:
        assert ref.meter.totals[name] == tp.meter.totals[name], name


@needs_mesh
def test_tp_client_updates_match_single_device(setup):
    """The async dispatch primitive rides the same TP-jitted round."""
    cfg, split = setup
    k = 8
    data = cohort_batch(k)
    model = SplitModel(cfg, split)
    pcfg = ProtocolConfig(clients_per_round=k, local_epochs=1,
                          batch_size=BATCH, momentum=0.0,
                          return_client_trainable=True)
    ref = SFPromptTrainer(model, pcfg)
    tr_r, _ = ref.client_updates(ref.init(KEY), data)
    tp = SFPromptTrainer(model, pcfg, mesh=tp_mesh())
    tr_t, _ = tp.client_updates(tp.init(KEY), data)
    for a, b in zip(jax.tree.leaves(tr_r), jax.tree.leaves(tr_t)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@needs_mesh
def test_tp_round_no_full_size_body_leaf_per_device(setup):
    """Per-device storage proof: in the compiled TP round, every frozen
    body leaf with a 'model'-sharded spec enters the ENTRY computation at
    its 1/|model| LOCAL shape — the full-size shape must not appear among
    the entry parameters. memory_analysis() backs the accounting."""
    cfg, split = setup
    k = 16
    data = cohort_batch(k)
    part = {"transmit": jnp.ones((k,), jnp.float32),
            "aggregate": jnp.ones((k,), jnp.float32)}
    mesh = tp_mesh()
    tr = make_trainer(cfg, split, k=k, mesh=mesh)
    state = tr.init(KEY)
    round_jit = tr._get_round_jit(state, data, part, None)
    compiled = round_jit.lower(state, data, part, None).compile()
    assert compiled.memory_analysis() is not None

    entry = re.search(r"ENTRY [^\n]*", compiled.as_text()).group(0)
    entry_shapes = set(re.findall(r"f32\[[0-9,]+\]", entry))

    specs = params_pspecs(state["params"], mesh)["body"]
    checked = 0
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_leaves_with_path(state["params"]["body"]),
            jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))):
        local = tuple(
            d // mesh.shape[a] if a in ("model",) else d
            for d, a in zip(leaf.shape,
                            tuple(spec) + (None,) * leaf.ndim))
        if local == tuple(leaf.shape):
            continue                      # replicated leaf (norms, biases)
        name = jax.tree_util.keystr(path)
        full_s = "f32[" + ",".join(map(str, leaf.shape)) + "]"
        local_s = "f32[" + ",".join(map(str, local)) + "]"
        assert local_s in entry_shapes, (name, local_s)
        assert full_s not in entry_shapes, (
            f"body leaf {name} enters full-size ({full_s}) on every "
            f"device — the 'model' axis is storage-dead")
        checked += 1
    assert checked >= 4   # q/k/v/o + up/down across the stacked cycles


@needs_mesh
def test_tp_hbm_ratio_on_devices(setup):
    """Honest device measurement: body bytes actually resident per device
    under TP shardings vs the replicated total — the benchmarks/mesh_tp.py
    hbm_ratio metric, floored at 3.0 in BENCH_kernels.json."""
    cfg, split = setup
    mesh = tp_mesh()
    model = SplitModel(cfg, split)
    params = model.init(KEY)
    specs = params_pspecs(params, mesh)["body"]
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                      is_leaf=lambda x: isinstance(x, P))
    put = jax.device_put(params["body"], sh)
    full = sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves(params["body"]))
    per_dev = sum(x.addressable_shards[0].data.size * x.dtype.itemsize
                  for x in jax.tree.leaves(put))
    assert full / per_dev >= 3.0


# --------------------------------------------------------- MoE narrowing
def test_moe_frozen_arg_batches_only_expert_leaves():
    """The MoE fallback broadcasts ONLY the ragged-dot expert leaves to
    the client axis; attention/norm/router leaves stay unbatched
    (in_axes=None) — the PR-6 HBM win survives for MoE configs."""
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced(n_layers=3)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2,
                        prune_gamma=0.5, local_epochs=1)
    model = SplitModel(cfg, split)
    tr = SFPromptTrainer(model, ProtocolConfig(clients_per_round=2,
                                               batch_size=2, momentum=0.0))
    assert tr._batch_frozen
    params = model.init(KEY)
    k = 3
    operand, axes = tr._frozen_arg(params["body"], k)
    n_expert = n_other = 0
    for (path, leaf), (_, src), (_, ax) in zip(
            jax.tree_util.tree_leaves_with_path(operand),
            jax.tree_util.tree_leaves_with_path(params["body"]),
            jax.tree_util.tree_leaves_with_path(
                axes, is_leaf=lambda x: x is None or isinstance(x, int))):
        if "experts" in jax.tree_util.keystr(path):
            assert ax == 0
            assert leaf.shape == (k,) + src.shape
            n_expert += 1
        else:
            assert ax is None
            assert leaf is src            # untouched, not even copied
            n_other += 1
    assert n_expert >= 3 and n_other >= 3


def test_moe_round_keeps_attention_unbatched_in_hlo():
    """Compiled proof of the narrowing: the jitted MoE round contains
    K-stacked EXPERT tensors (the ragged-dot fallback) but NO K-stacked
    attention projection — the frozen non-expert body never materializes
    per-client copies. End-to-end round still trains.

    n_layers=4 gives the body TWO stacked cycles while head/tail keep one,
    so a K-stacked body leaf has a shape no trainable (legitimately
    K-stacked) tail leaf can collide with."""
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced(n_layers=4)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2,
                        prune_gamma=0.5, local_epochs=1)
    model = SplitModel(cfg, split)
    k = 2
    tr = SFPromptTrainer(model, ProtocolConfig(clients_per_round=k,
                                               local_epochs=1,
                                               batch_size=2, momentum=0.0))
    toks = synthetic_lm_dataset(k * N_LOCAL, 16, cfg.vocab_size,
                                seed=0)["tokens"]
    data = {"tokens": jnp.asarray(toks).reshape(k, N_LOCAL, -1)}
    part = {"transmit": jnp.ones((k,), jnp.float32),
            "aggregate": jnp.ones((k,), jnp.float32)}
    state = tr.init(KEY)
    hlo = tr._round_jit.lower(state, data, part, None).compile().as_text()

    body = state["params"]["body"]
    attn = [leaf for path, leaf in jax.tree_util.tree_leaves_with_path(body)
            if "attn" in jax.tree_util.keystr(path) and leaf.ndim >= 3]
    experts = [leaf for path, leaf
               in jax.tree_util.tree_leaves_with_path(body)
               if "experts" in jax.tree_util.keystr(path)]
    assert attn and experts

    def stacked(leaf):
        return "f32[" + ",".join(map(str, (k,) + leaf.shape)) + "]"

    # trainable tail/prompt leaves ARE K-stacked by design — skip any body
    # leaf whose stacked shape a trainable leaf could also produce
    trainable = {stacked(leaf) for leaf in
                 jax.tree.leaves(state["params"]["tail"])}
    attn = [leaf for leaf in attn if stacked(leaf) not in trainable]
    assert attn
    for leaf in attn:
        assert stacked(leaf) not in hlo, (
            f"attention leaf {leaf.shape} is K-stacked — the MoE fallback "
            f"is broadcasting more than the expert leaves")
    assert any(stacked(leaf) in hlo for leaf in experts)

    state, metrics = tr.round(state, data, dict(part))
    assert np.isfinite(metrics["split_loss"])
    assert int(state["round"]) == 1


# ------------------------------------------------------- paged cache rules
def test_cache_pspecs_paged_pool():
    """Page-pool leaves (n_layers, n_pages, page_size, heads, dh): the
    page axis must stay REPLICATED (any block table may reference any
    page) while kv-heads shard over 'model'; dense leaves keep their slot
    dim on the client plane."""
    mesh = _FakeMesh()
    pool = {"stack": {"pos0": {
        "k": jax.ShapeDtypeStruct((3, 10, 8, 4, 8), jnp.float32),
        "v": jax.ShapeDtypeStruct((3, 10, 8, 4, 8), jnp.float32),
        "positions": jax.ShapeDtypeStruct((3, 10, 8), jnp.int32)}}}
    paged = cache_pspecs(pool, mesh, paged=True)["stack"]["pos0"]
    assert paged["k"] == P(None, None, None, "model", None)
    assert paged["v"] == P(None, None, None, "model", None)
    assert paged["positions"] == P(None, None, None)

    dense = cache_pspecs(pool, mesh)["stack"]["pos0"]
    assert dense["k"] == P(None, "data", None, "model", None)
    assert dense["positions"] == P(None, "data", None)
    pop_sharding_fallbacks()   # drain anything this unit test recorded


def test_cache_pspecs_paged_guards_indivisible_heads():
    """kv-heads that do not divide 'model' replicate — and the fallback is
    RECORDED, not silent."""
    mesh = _FakeMesh()
    pool = {"k": jax.ShapeDtypeStruct((3, 10, 8, 6, 8), jnp.float32)}
    pop_sharding_fallbacks()
    spec = cache_pspecs(pool, mesh, paged=True)["k"]
    assert spec == P(None, None, None, None, None)
    fallbacks = pop_sharding_fallbacks()
    assert any(axis == "model" and shape == (3, 10, 8, 6, 8)
               for _, axis, shape in fallbacks)


# --------------------------------------------------- fallback surfacing
def test_divisibility_fallbacks_recorded_and_reported():
    mesh = _FakeMesh()
    params = {"body": {"q": {"w": jax.ShapeDtypeStruct((32, 48),
                                                       jnp.float32)}},
              "tail": {"head": {"w": jax.ShapeDtypeStruct((32, 10),
                                                          jnp.float32)}}}
    pop_sharding_fallbacks()
    specs = params_pspecs(params, mesh)
    assert specs["body"]["q"]["w"] == P(None, "model")   # 48 % 4 == 0
    assert specs["tail"]["head"]["w"] == P(None, None)   # 10 % 4 != 0
    with pytest.warns(UserWarning, match="head/w"):
        entries = report_sharding_fallbacks("unit")
    assert ("tail/head/w", "model", (32, 10)) in entries
    # the report DRAINED the log: a second report has nothing to say
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert report_sharding_fallbacks() == ()


def test_fallbacks_skip_mesh_absent_axes_and_unit_dims():
    """Mesh-absent 'model' drops (1-D data mesh) and size-1 dims are
    intentional replication, never reported."""
    mesh1d = type("M", (), {"shape": {"data": 2}})()
    pop_sharding_fallbacks()
    params_pspecs({"q": {"w": jax.ShapeDtypeStruct((32, 48),
                                                   jnp.float32)}}, mesh1d)
    cache_pspecs({"k": jax.ShapeDtypeStruct((3, 1, 8, 4, 8), jnp.float32)},
                 _FakeMesh())   # slot dim 1 on data=2: free replication
    assert pop_sharding_fallbacks() == ()


# ------------------------------------------------------------- TP serving
def _serve_fixture():
    cfg = get_config("qwen2.5-14b").reduced(n_layers=3, d_model=128,
                                            d_ff=256, vocab_size=128)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4)
    model = SplitModel(cfg, split, WireSpec.make("fp32"))
    params = model.init(KEY)
    tails = [params["tail"],
             jax.tree.map(lambda x: x * 1.1, params["tail"])]
    prompts = [params["prompt"], params["prompt"] * 0.9]
    bank = TenantBank.from_lists(tails, prompts)
    reqs = [Request(rid=0, tenant=0,
                    tokens=np.arange(9, dtype=np.int32) % 128,
                    max_new=5, arrival=0),
            Request(rid=1, tenant=1,
                    tokens=(np.arange(14, dtype=np.int32) * 3) % 128,
                    max_new=4, arrival=0),
            Request(rid=2, tenant=0,
                    tokens=(np.arange(6, dtype=np.int32) * 7) % 128,
                    max_new=6, arrival=1)]
    return model, params, bank, reqs


@needs_mesh
def test_serve_decode_tp_logit_identity():
    """Dense engine, TP vs single-device: same tokens, logits allclose,
    metered wire bytes exactly equal — decode attention runs head-parallel
    (4 kv heads over model=4) without the tenants noticing."""
    model, params, bank, reqs = _serve_fixture()
    scfg = ServeConfig(n_slots=4, max_seq=48, decode_block=4)
    ref = ServeEngine(model, params, bank, scfg, collect_logits=True)
    s_r = ref.run(list(reqs))
    tp = ServeEngine(model, params, bank, scfg, collect_logits=True,
                     mesh=tp_mesh())
    s_t = tp.run(list(reqs))
    by_r = {f.req.rid: f for f in s_r["finished"]}
    by_t = {f.req.rid: f for f in s_t["finished"]}
    assert by_r.keys() == by_t.keys()
    for rid in by_r:
        np.testing.assert_array_equal(by_r[rid].tokens, by_t[rid].tokens)
        np.testing.assert_allclose(by_r[rid].logits, by_t[rid].logits,
                                   rtol=1e-5, atol=1e-5)
    assert s_r["wire_bytes"] == s_t["wire_bytes"]


@needs_mesh
def test_serve_paged_tp_identity():
    """paged == dense ON THE 2D MESH: the head-sharded page pool
    (cache_pspecs paged=True) must not perturb a single logit or byte
    relative to the head-sharded dense cache."""
    model, params, bank, reqs = _serve_fixture()
    mesh = tp_mesh()
    dense = ServeEngine(model, params, bank,
                        ServeConfig(n_slots=4, max_seq=48, decode_block=4),
                        collect_logits=True, mesh=mesh)
    s_d = dense.run(list(reqs))
    paged = PagedServeEngine(
        model, params, bank,
        PagedServeConfig(n_slots=4, max_seq=48, decode_block=4,
                         page_size=8),
        collect_logits=True, mesh=mesh)
    s_p = paged.run(list(reqs))
    by_d = {f.req.rid: f for f in s_d["finished"]}
    by_p = {f.req.rid: f for f in s_p["finished"]}
    assert by_d.keys() == by_p.keys()
    for rid in by_d:
        np.testing.assert_array_equal(by_d[rid].tokens, by_p[rid].tokens)
        np.testing.assert_allclose(by_d[rid].logits, by_p[rid].logits,
                                   rtol=1e-6, atol=1e-6)
    assert s_d["wire_bytes"] == s_p["wire_bytes"]


@needs_mesh
def test_serve_paged_tp_prefix_and_chunks_run():
    """COW shared prefixes + chunked prefill still work with the pool
    sharded over 'model' (copy_page/gather/scatter keep the sharding)."""
    model, params, bank, reqs = _serve_fixture()
    eng = PagedServeEngine(
        model, params, bank,
        PagedServeConfig(n_slots=4, max_seq=48, decode_block=4,
                         page_size=8, shared_prefix=(5, 9, 2),
                         prefill_chunk=6),
        collect_logits=True, mesh=tp_mesh())
    stats = eng.run(list(reqs))
    assert stats["n_finished"] == len(reqs)
    assert stats["page_copies"] >= 1
    assert stats["prefill_chunks"] >= 1
    assert eng.pool_alloc.n_used == 0        # everything released
