"""Integration tests: the three-phase SFPrompt protocol and the baselines
run end-to-end on a tiny ViT and actually learn."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (BaselineConfig, FLTrainer, ProtocolConfig,
                        SFLTrainer, SFPromptTrainer, SplitConfig, SplitModel)
from repro.core import pruning
from repro.core.aggregation import broadcast_to_clients, fedavg
from repro.data import (DATASETS, iid_partition, select_clients,
                        stack_clients, synthetic_image_dataset)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=64, d_ff=128)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4,
                        prune_gamma=0.5, local_epochs=1)
    model = SplitModel(cfg, split)
    data = synthetic_image_dataset(DATASETS["cifar10-syn"], 320, seed=0,
                                   image_hw=32)
    clients = iid_partition(data, 8, seed=0)
    test = synthetic_image_dataset(DATASETS["cifar10-syn"], 64, seed=1,
                                   image_hw=32)
    return cfg, split, model, clients, test


def _round_batch(clients, k, r):
    idx = select_clients(len(clients), k, seed=0, round_idx=r)
    return {kk: jnp.asarray(v) for kk, v in
            stack_clients(clients, idx).items()}


def test_sfprompt_round_learns(tiny_setup):
    cfg, split, model, clients, test = tiny_setup
    pcfg = ProtocolConfig(clients_per_round=3, local_epochs=1, batch_size=8,
                          lr_local=0.05, lr_split=0.05, momentum=0.0)
    tr = SFPromptTrainer(model, pcfg)
    state = tr.init(KEY)
    losses = []
    for r in range(3):
        state, m = tr.round(state, _round_batch(clients, 3, r))
        losses.append(m["split_loss"])
        assert m["kept_frac"] <= 0.6  # gamma=0.5 pruning active
    assert losses[-1] < losses[0]
    ev = tr.evaluate(state["params"], test, batch_size=32)
    assert np.isfinite(ev["ce"])


def test_sfprompt_only_tail_and_prompt_change(tiny_setup):
    cfg, split, model, clients, _ = tiny_setup
    pcfg = ProtocolConfig(clients_per_round=2, local_epochs=1, batch_size=8)
    tr = SFPromptTrainer(model, pcfg)
    state = tr.init(KEY)
    p0 = jax.tree.map(jnp.copy, state["params"])
    state, _ = tr.round(state, _round_batch(clients, 2, 0))
    p1 = state["params"]
    same = lambda a, b: all(
        bool(jnp.array_equal(x, y)) for x, y in
        zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    diff = lambda a, b: any(
        not bool(jnp.array_equal(x, y)) for x, y in
        zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    assert same(p0["head"], p1["head"])    # frozen on the client
    assert same(p0["body"], p1["body"])    # frozen on the server
    assert diff(p0["tail"], p1["tail"])    # trained
    assert diff(p0["prompt"], p1["prompt"])


def test_local_loss_ablation_arm(tiny_setup):
    """use_local_loss=False (Fig-6 arm) still runs and aggregates."""
    cfg, split, model, clients, _ = tiny_setup
    pcfg = ProtocolConfig(clients_per_round=2, local_epochs=1, batch_size=8,
                          use_local_loss=False)
    tr = SFPromptTrainer(model, pcfg)
    state = tr.init(KEY)
    state, m = tr.round(state, _round_batch(clients, 2, 0))
    assert "local_loss" not in m
    assert np.isfinite(m["split_loss"])


def test_no_pruning_arm(tiny_setup):
    cfg, split, model, clients, _ = tiny_setup
    pcfg = ProtocolConfig(clients_per_round=2, local_epochs=1, batch_size=8,
                          use_pruning=False)
    tr = SFPromptTrainer(model, pcfg)
    state = tr.init(KEY)
    state, m = tr.round(state, _round_batch(clients, 2, 0))
    assert "kept_frac" not in m


def test_fl_baseline(tiny_setup):
    cfg, split, model, clients, _ = tiny_setup
    tr = FLTrainer(model, BaselineConfig(local_epochs=1, batch_size=8,
                                         lr=0.05))
    state = tr.init(KEY)
    p0 = jax.tree.map(jnp.copy, state["params"])
    state, m = tr.round(state, _round_batch(clients, 2, 0))
    assert np.isfinite(m["train_loss"])
    # FL trains everything including the body
    assert any(not bool(jnp.array_equal(x, y)) for x, y in
               zip(jax.tree.leaves(p0["body"]),
                   jax.tree.leaves(state["params"]["body"])))


@pytest.mark.parametrize("mode", ["ff", "linear"])
def test_sfl_baselines(tiny_setup, mode):
    cfg, split, model, clients, _ = tiny_setup
    tr = SFLTrainer(model, BaselineConfig(local_epochs=1, batch_size=8,
                                          lr=0.05), mode=mode)
    state = tr.init(KEY)
    p0 = jax.tree.map(jnp.copy, state["params"])
    state, m = tr.round(state, _round_batch(clients, 2, 0))
    assert np.isfinite(m["train_loss"])
    body_changed = any(
        not bool(jnp.array_equal(x, y)) for x, y in
        zip(jax.tree.leaves(p0["body"]),
            jax.tree.leaves(state["params"]["body"])))
    head_changed = any(
        not bool(jnp.array_equal(x, y)) for x, y in
        zip(jax.tree.leaves(p0["head"]),
            jax.tree.leaves(state["params"]["head"])))
    if mode == "ff":
        assert body_changed and head_changed
    else:
        assert not body_changed and not head_changed


def test_fedavg_weighted():
    trees = {"w": jnp.stack([jnp.ones((3,)), 3 * jnp.ones((3,))])}
    out = fedavg(trees, jnp.array([1.0, 3.0]))
    np.testing.assert_allclose(np.asarray(out["w"]), 2.5 * np.ones(3))
    back = broadcast_to_clients(out, 2)
    assert back["w"].shape == (2, 3)


def test_score_client_data_scores_every_sample(tiny_setup):
    """Regression: n % batch_size != 0 used to silently drop the last
    partial batch from EL2N scoring, so `prune_indices` never ranked those
    samples. The padded+masked final batch must score all n, identically
    to any other batching of the same data."""
    cfg, split, model, clients, _ = tiny_setup
    data = {k: jnp.asarray(v[:19]) for k, v in clients[0].items()}
    params = model.init(KEY)
    args = (model, params["head"], params["tail"], params["prompt"], data)
    s_odd = pruning.score_client_data(*args, batch_size=8)   # 19 % 8 != 0
    assert s_odd.shape == (19,)
    s_one = pruning.score_client_data(*args, batch_size=1)
    np.testing.assert_allclose(np.asarray(s_odd), np.asarray(s_one),
                               rtol=1e-5, atol=1e-6)
    # every sample is rankable: keep-all returns a permutation of range(n)
    idx = pruning.prune_indices(s_odd, gamma=0.0)
    assert sorted(np.asarray(idx).tolist()) == list(range(19))
