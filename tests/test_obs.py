"""Observability contract: the flight recorder observes, never
participates.

Pins the three promises repro.obs makes (see obs/trace.py):
  * tracing OFF is bit-identical — round params, metered bytes, served
    tokens/logits all match an untraced run exactly;
  * tracing ON accounts bytes EXACTLY — per-stream sums over the
    `meter.absorb` events equal the TrafficMeter totals with ==;
  * traces are deterministic modulo wall time — two same-seed runs
    produce equal records once `strip_times` removes t_ns/dur_ns.

Plus the satellite contracts: sharding fallbacks surface as ONE
structured event per drain (warnings path intact), the TrafficMeter
state_dict round-trips (including wall streams and legacy restores),
and the exporters / tools/trace_check.py validate what the launchers
actually write.
"""
import importlib.util
import io
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.data import (DATASETS, iid_partition, select_clients,
                        stack_clients, synthetic_image_dataset)
from repro.launch.mesh import report_sharding_fallbacks
from repro.obs import (LEVELS, MetricsRegistry, NOOP, Tracer, chrome_trace,
                       make_tracer, prometheus_text, strip_times, sum_stream)
from repro.obs.export import meter_final_record, write_jsonl
from repro.runtime import WireSpec
from repro.runtime.meter import TrafficMeter, WALL_STREAMS
from repro.serve import (PagedServeConfig, PagedServeEngine, Request,
                         TenantBank)
from repro.sharding import rules
from repro.sharding.rules import params_pspecs, pop_sharding_fallbacks

KEY = jax.random.PRNGKey(0)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------ fixtures
@pytest.fixture(scope="module")
def proto_setup():
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=64, d_ff=128)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4,
                        prune_gamma=0.5, local_epochs=1)
    model = SplitModel(cfg, split)
    data = synthetic_image_dataset(DATASETS["cifar10-syn"], 160, seed=0,
                                   image_hw=32)
    clients = iid_partition(data, 6, seed=0)
    return model, clients


def _run_rounds(model, clients, tracer=None, n=2, k=2):
    pcfg = ProtocolConfig(clients_per_round=k, local_epochs=1, batch_size=8,
                          lr_local=0.05, lr_split=0.05, momentum=0.0)
    tr = SFPromptTrainer(model, pcfg, tracer=tracer)
    state = tr.init(KEY)
    for r in range(n):
        idx = select_clients(len(clients), k, seed=0, round_idx=r)
        batch = {kk: jnp.asarray(v) for kk, v in
                 stack_clients(clients, idx).items()}
        state, _ = tr.round(state, batch)
    return tr, state


@pytest.fixture(scope="module")
def serve_setup():
    cfg = get_config("qwen2.5-14b").reduced(
        n_layers=3, d_model=64, d_ff=128, vocab_size=128)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4)
    model = SplitModel(cfg, split, WireSpec.make("fp32"))
    params = model.init(KEY)
    tails, prompts = [], []
    for t in range(2):
        key = jax.random.fold_in(jax.random.PRNGKey(7), t)
        leaves, treedef = jax.tree.flatten(params["tail"])
        ks = jax.random.split(key, len(leaves) + 1)
        tails.append(jax.tree.unflatten(treedef, [
            x + 0.2 * jax.random.normal(kk, x.shape, x.dtype)
            for x, kk in zip(leaves, ks[:-1])]))
        prompts.append(params["prompt"] + 0.2 * jax.random.normal(
            ks[-1], params["prompt"].shape))
    bank = TenantBank.from_lists(tails, prompts)
    return model, params, bank


def _toks(n, mult):
    return (np.arange(n, dtype=np.int32) * mult) % 128


SERVE_REQS = [
    Request(rid=0, tenant=0, tokens=_toks(9, 1), max_new=4, arrival=0),
    Request(rid=1, tenant=1, tokens=_toks(12, 3), max_new=3, arrival=0),
    Request(rid=2, tenant=1, tokens=_toks(6, 7), max_new=4, arrival=2),
]


def _run_serve(model, params, bank, tracer=None):
    eng = PagedServeEngine(
        model, params, bank,
        PagedServeConfig(n_slots=2, max_seq=48, decode_block=2,
                         page_size=8, shared_prefix=(3, 5, 7, 11),
                         prefill_chunk=8),
        collect_logits=True, tracer=tracer)
    return eng, eng.run(list(SERVE_REQS))


def _trees_equal(a, b):
    return all(bool(jnp.array_equal(x, y)) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ----------------------------------------- tracing off == never happened
def test_round_bit_identical_with_and_without_tracing(proto_setup):
    """Headline criterion half 1: a traced round computes the SAME
    params and meters the SAME bytes as an untraced one."""
    model, clients = proto_setup
    tr_off, st_off = _run_rounds(model, clients, tracer=None)
    tr_on, st_on = _run_rounds(model, clients, tracer=Tracer("step"))
    assert tr_off.tracer is NOOP          # default wiring
    assert _trees_equal(st_off["params"], st_on["params"])
    assert tr_off.meter.totals == tr_on.meter.totals   # exact floats
    assert tr_off.tracer.records() == ()  # and recorded nothing
    assert len(tr_on.tracer.records()) > 0


def test_serve_bit_identical_with_and_without_tracing(serve_setup):
    """...and the paged serve engine: greedy tokens, per-step logits,
    and metered wire bytes are unchanged by tracing."""
    model, params, bank = serve_setup
    _, off = _run_serve(model, params, bank)
    eng_on, on = _run_serve(model, params, bank, tracer=Tracer("step"))
    offs = {f.req.rid: f for f in off["finished"]}
    ons = {f.req.rid: f for f in on["finished"]}
    assert set(offs) == set(ons) == {r.rid for r in SERVE_REQS}
    for rid in offs:
        np.testing.assert_array_equal(np.asarray(offs[rid].tokens),
                                      np.asarray(ons[rid].tokens))
        np.testing.assert_array_equal(np.asarray(offs[rid].logits),
                                      np.asarray(ons[rid].logits))
    assert off["wire_bytes"] == on["wire_bytes"]   # exact floats
    assert len(eng_on.tracer.records()) > 0


# -------------------------------------------------- exact byte accounting
def test_round_trace_bytes_sum_exactly_to_meter(proto_setup):
    """Headline criterion half 2: per-stream sums over the meter.absorb
    events equal the TrafficMeter totals with ==, not allclose."""
    model, clients = proto_setup
    tr, _ = _run_rounds(model, clients, tracer=Tracer("step"), n=3)
    recs = tr.tracer.records()
    for stream, total in tr.meter.totals.items():
        assert sum_stream(recs, "meter.absorb", stream) == total
    # the round spans carry the same folded floats as attributes
    spans = [r for r in recs if r["name"] == "round"]
    assert len(spans) == 3
    for stream in ("head_body", "body_tail", "params"):
        assert sum(s["attrs"][stream] for s in spans) == \
            tr.meter.totals[stream]


def test_serve_trace_bytes_sum_exactly_to_meter(serve_setup):
    model, params, bank = serve_setup
    eng, _ = _run_serve(model, params, bank, tracer=Tracer("step"))
    recs = eng.tracer.records()
    for stream, total in eng.meter.totals.items():
        assert sum_stream(recs, "meter.absorb", stream) == total


# ------------------------------------------------------------ determinism
def test_round_trace_deterministic_modulo_walltime(proto_setup):
    model, clients = proto_setup
    tr1, _ = _run_rounds(model, clients, tracer=Tracer("step"))
    tr2, _ = _run_rounds(model, clients, tracer=Tracer("step"))
    assert strip_times(tr1.tracer.records()) == \
        strip_times(tr2.tracer.records())


def test_serve_trace_deterministic_modulo_walltime(serve_setup):
    model, params, bank = serve_setup
    eng1, _ = _run_serve(model, params, bank, tracer=Tracer("step"))
    eng2, _ = _run_serve(model, params, bank, tracer=Tracer("step"))
    assert strip_times(eng1.tracer.records()) == \
        strip_times(eng2.tracer.records())


# ------------------------------------------------- tracer unit behaviour
def test_levels_and_noop_singleton():
    assert make_tracer("off") is NOOP
    assert make_tracer(None) is NOOP
    assert make_tracer(0) is NOOP
    assert not NOOP.enabled and NOOP.records() == ()
    t = make_tracer("round")
    t.event("kept")
    t.event("dropped", level=LEVELS["step"])   # above the tracer's level
    assert [r["name"] for r in t.records()] == ["kept"]


def test_span_nesting_depth_and_ring_capacity():
    t = Tracer("step", capacity=4)
    with t.span("outer"):
        with t.span("inner", a=1):
            t.event("leaf")
    recs = t.records()
    # push-at-exit: leaf (depth 2), inner (1), outer (0)
    assert [(r["name"], r["depth"]) for r in recs] == \
        [("leaf", 2), ("inner", 1), ("outer", 0)]
    for i in range(10):
        t.event("spam", i=i)
    assert len(t.records()) == 4      # ring kept the newest
    assert t.dropped == 9
    assert t.records()[-1]["attrs"]["i"] == 9


def test_sim_clock_records():
    t = Tracer("round")
    t.span_at("flight", 1.5, 4.0, lane=3, client=7)
    t.event_at("arrival", 4.0, client=7)
    span, ev = t.records()
    assert span["t_sim"] == 1.5 and span["dur_sim"] == 2.5
    assert span["lane"] == 3
    assert ev["t_sim"] == 4.0


# ------------------------------------------ sharding fallback routing (S1)
def test_fallback_event_exactly_once_per_drain():
    mesh = type("_FakeMesh", (), {"shape": {"data": 2, "model": 4}})()
    params = {"tail": {"head": {"w": jax.ShapeDtypeStruct((32, 10),
                                                          jnp.float32)}}}
    pop_sharding_fallbacks()
    specs = params_pspecs(params, mesh)
    assert specs["tail"]["head"]["w"][1] is None   # 10 % 4 -> replicated
    tracer = Tracer("round")
    with pytest.warns(UserWarning, match=r"(?s)\[unit\].*head/w"):
        entries = rules.report_fallbacks("unit", tracer)
    assert ("tail/head/w", "model", (32, 10)) in entries
    events = [r for r in tracer.records()
              if r["name"] == "sharding.fallback"]
    assert len(events) == 1
    assert events[0]["attrs"]["context"] == "unit"
    assert events[0]["attrs"]["n"] == len(entries)
    assert ["tail/head/w", "model", [32, 10]] in \
        events[0]["attrs"]["entries"]
    # the drain emptied the log: a second report emits NOTHING
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert rules.report_fallbacks("unit", tracer) == ()
    assert len([r for r in tracer.records()
                if r["name"] == "sharding.fallback"]) == 1


def test_fallback_warning_path_survives_untraced():
    rules._SHARDING_FALLBACKS.append(("x/w", "model", (3, 5)))
    with pytest.warns(UserWarning, match=r"(?s)\[site\].*x/w"):
        assert report_sharding_fallbacks("site") != ()


def test_traced_round_build_reports_fallback_once(proto_setup):
    """The protocol's mesh-jit build site drains into ONE structured
    event (context protocol.mesh_jit) when the mesh triggers fallbacks.
    Simulated by seeding the log before the build-site drain."""
    model, clients = proto_setup
    tracer = Tracer("round")
    rules._SHARDING_FALLBACKS.append(("tail/w", "model", (7, 3)))
    with pytest.warns(UserWarning, match=r"(?s)\[protocol\.mesh_jit\]"):
        rules.report_fallbacks("protocol.mesh_jit", tracer)
    events = [r for r in tracer.records()
              if r["name"] == "sharding.fallback"]
    assert len(events) == 1
    assert events[0]["attrs"]["context"] == "protocol.mesh_jit"


# ------------------------------------------------- meter round-trip (S2)
def test_meter_state_dict_roundtrip_including_wall():
    m = TrafficMeter()
    m.absorb({"head_body": 10.0, "body_tail": 3.5, "params": 100.25},
             clients=4)
    m.absorb_wall(server_busy_s=1.5, client_compute_s=7.25, wire_s=2.0,
                  span_s=4.0)
    m2 = TrafficMeter()
    m2.load_state_dict(m.state_dict())
    assert m2.totals == m.totals
    assert m2.wall == m.wall
    assert (m2.rounds, m2.client_rounds) == (m.rounds, m.client_rounds)


def test_meter_legacy_state_without_wall_restores_zeroed():
    m = TrafficMeter()
    m.absorb({"head_body": 8.0})
    state = {k: v for k, v in m.state_dict().items()
             if not k.startswith("wall/")}
    m2 = TrafficMeter()
    m2.absorb_wall(span_s=9.0)   # stale value the restore must clear
    m2.load_state_dict(state)
    assert m2.totals == m.totals
    assert m2.wall == {n: 0.0 for n in WALL_STREAMS}


def test_meter_state_dict_roundtrip_property():
    pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (pip install -r "
               "requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    finite = st.floats(0.0, 1e12, allow_nan=False, allow_infinity=False)

    @given(byte_rounds=st.lists(
               st.dictionaries(st.sampled_from(
                   ("head_body", "body_tail", "params", "secure",
                    "edge_global", "not_a_stream")), finite, max_size=4),
               max_size=5),
           clients=finite,
           wall=st.lists(st.tuples(finite, finite, finite, finite),
                         max_size=3))
    @settings(max_examples=25, deadline=None)
    def roundtrip(byte_rounds, clients, wall):
        m = TrafficMeter()
        for counts in byte_rounds:
            m.absorb(counts, clients=clients)
        for s, c, w, sp in wall:
            m.absorb_wall(server_busy_s=s, client_compute_s=c, wire_s=w,
                          span_s=sp)
        m2 = TrafficMeter()
        m2.load_state_dict(m.state_dict())
        assert m2.totals == m.totals
        assert m2.wall == m.wall
        assert m2.rounds == m.rounds
        assert m2.client_rounds == m.client_rounds
        assert m2.state_dict() == m.state_dict()

    roundtrip()


def test_meter_absorb_events_match_totals_exactly():
    """Unit-level exactness: the absorb event carries the floats the
    totals folded, unknown streams excluded."""
    tracer = Tracer("round")
    m = TrafficMeter()
    m.attach_tracer(tracer)
    m.absorb({"head_body": 0.1, "params": 0.2, "bogus": 9.9})
    m.absorb({"head_body": 0.3})
    recs = tracer.records()
    assert sum_stream(recs, "meter.absorb", "head_body") == \
        m.totals["head_body"]
    assert all("bogus" not in r["attrs"] for r in recs)
    m.attach_tracer(NOOP)     # disabled tracer detaches
    assert m.tracer is None


# ------------------------------------------------------------- exporters
def _sample_records():
    t = Tracer("step")
    m = TrafficMeter()
    m.attach_tracer(t)
    with t.span("round", cohort=2):
        m.absorb({"head_body": 64.0, "params": 128.0})
    t.span_at("async.client", 0.5, 2.0, lane=4, client=4)
    t.event_at("async.flush", 2.0, version=1)
    return t, m


def test_write_jsonl_appends_meter_final(tmp_path):
    t, m = _sample_records()
    path = str(tmp_path / "trace.jsonl")
    n = write_jsonl(path, t.records(), m)
    lines = [json.loads(line) for line in open(path)]
    assert len(lines) == n == len(t.records()) + 1
    final = lines[-1]
    assert final["name"] == "meter.final"
    assert final["attrs"]["head_body"] == m.totals["head_body"]
    assert final["seq"] == lines[-2]["seq"] + 1


def test_chrome_trace_layout():
    t, m = _sample_records()
    doc = chrome_trace(t.records(), m)
    events = doc["traceEvents"]
    span = next(e for e in events if e["name"] == "round")
    assert span["ph"] == "X" and span["pid"] == 0
    flight = next(e for e in events if e["name"] == "async.client")
    assert flight["ph"] == "X" and flight["pid"] == 1
    assert flight["tid"] == 4
    assert flight["ts"] == 0.5e6 and flight["dur"] == 1.5e6
    lanes = [e for e in events if e.get("name") == "thread_name"]
    assert any(e["args"]["name"] == "lane 4" for e in lanes)
    assert any(e["name"] == "meter.final" and e["ph"] == "i"
               for e in events)


def test_prometheus_text_sanitizes_and_skips_nonnumeric():
    reg = MetricsRegistry()
    reg.counter("tokens_out").inc(5, labels={"tenant": "1"})
    reg.register_source("meter", lambda: {"totals/head_body": 2.5,
                                          "note": "text-skipped"})
    text = prometheus_text(reg.snapshot())
    assert 'tokens_out{tenant="1"} 5.0' in text
    assert "meter_totals_head_body 2.5" in text
    assert "note" not in text


def test_registry_instruments_and_sources():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    assert reg.counter("hits") is c            # idempotent by name
    with pytest.raises(ValueError):
        reg.gauge("hits")                      # cross-kind clash
    c.inc(2)
    g = reg.gauge("fill")
    g.set_fn(lambda: 0.75)
    h = reg.histogram("lat", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    snap = reg.snapshot()
    assert snap["hits"] == 2.0
    assert snap["fill"] == 0.75
    assert snap['lat_bucket{le="1.0"}'] == 1
    assert snap['lat_bucket{le="+Inf"}'] == 2
    assert snap["lat_count"] == 2


def test_registry_binds_live_engine(serve_setup):
    model, params, bank = serve_setup
    eng, stats = _run_serve(model, params, bank)
    reg = MetricsRegistry()
    reg.bind_engine(eng)
    reg.bind_pool(eng.pool_alloc)
    snap = reg.snapshot()
    assert snap["serve/tokens_out"] == stats["tokens_out"]
    assert snap["serve/wire_bytes/total"] == stats["wire_bytes"]["total"]
    assert snap["pages/n_pages"] == eng.pool_alloc.n_pages
    assert snap["pages/n_used"] == eng.pool_alloc.n_used


# ------------------------------------------------------- trace_check (S5)
@pytest.fixture(scope="module")
def trace_check():
    path = os.path.join(REPO, "tools", "trace_check.py")
    spec = importlib.util.spec_from_file_location("trace_check", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _check_str(trace_check, text):
    return trace_check.check(io.StringIO(text))


def test_trace_check_accepts_real_export(trace_check, tmp_path,
                                         proto_setup):
    model, clients = proto_setup
    tr, _ = _run_rounds(model, clients, tracer=Tracer("step"))
    path = str(tmp_path / "run.jsonl")
    write_jsonl(path, tr.tracer.records(), tr.meter)
    with open(path) as f:
        assert trace_check.check(f) == 0


def test_trace_check_rejects_byte_drift(trace_check):
    recs = [
        {"seq": 0, "kind": "event", "name": "meter.absorb", "depth": 0,
         "t_ns": 1, "attrs": {"head_body": 4.0}},
        {"seq": 1, "kind": "event", "name": "meter.final", "depth": 0,
         "attrs": {"head_body": 5.0, "rounds": 1}},
    ]
    text = "".join(json.dumps(r) + "\n" for r in recs)
    assert _check_str(trace_check, text) == 1


def test_trace_check_rejects_schema_violations(trace_check):
    bad = [
        '{"kind": "span", "name": "x", "seq": 0, "depth": 0}\n',   # no dur
        '{"kind": "what", "name": "x", "seq": 0, "depth": 0, '
        '"t_ns": 1, "attrs": {}}\n',                               # bad kind
        'not json\n',
    ]
    for text in bad:
        assert _check_str(trace_check, text) == 1
    # out-of-order seq
    ok = {"seq": 5, "kind": "event", "name": "e", "depth": 0, "t_ns": 1,
          "attrs": {}}
    text = json.dumps(ok) + "\n" + json.dumps(dict(ok, seq=4)) + "\n"
    assert _check_str(trace_check, text) == 1
    assert _check_str(trace_check, json.dumps(ok) + "\n") == 0


def test_trace_check_empty_is_failure(trace_check):
    assert _check_str(trace_check, "") == 1
