"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import fedavg
from repro.core.comm import (CostInputs, sfl_comm, sfprompt_comm,
                             sfprompt_compute_paper, sfl_compute)
from repro.core.pruning import prune_indices
from repro.kernels.el2n.ops import el2n_scores
from repro.models.layers import apply_rope, rope_cos_sin
from repro.optim import adamw, apply_updates, sgd

SETTINGS = dict(max_examples=25, deadline=None)


# ------------------------------------------------------------------ EL2N
@given(n=st.integers(2, 16), v=st.integers(2, 80),
       scale=st.floats(0.1, 20.0), seed=st.integers(0, 2**30))
@settings(**SETTINGS)
def test_el2n_bounds_and_identity(n, v, scale, seed):
    """0 <= EL2N <= sqrt(2); fused identity == naive computation."""
    k = jax.random.PRNGKey(seed)
    logits = scale * jax.random.normal(k, (n, v))
    labels = jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, v)
    el2n, ce = el2n_scores(logits, labels, impl="ref")
    assert np.all(np.asarray(el2n) >= -1e-6)
    assert np.all(np.asarray(el2n) <= np.sqrt(2) + 1e-5)
    assert np.all(np.asarray(ce) >= -1e-5)
    probs = jax.nn.softmax(logits, -1)
    naive = jnp.linalg.norm(probs - jax.nn.one_hot(labels, v), axis=-1)
    np.testing.assert_allclose(np.asarray(el2n), np.asarray(naive),
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------------ FedAvg
@given(k=st.integers(1, 6), seed=st.integers(0, 2**30))
@settings(**SETTINGS)
def test_fedavg_convexity(k, seed):
    """Weighted mean stays within per-coordinate min/max; identical client
    trees aggregate to themselves."""
    key = jax.random.PRNGKey(seed)
    trees = {"a": jax.random.normal(key, (k, 5)),
             "b": {"c": jax.random.normal(jax.random.fold_in(key, 1),
                                          (k, 2, 3))}}
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (k,))) + 0.1
    agg = fedavg(trees, w)
    for leaf, full in ((agg["a"], trees["a"]),
                       (agg["b"]["c"], trees["b"]["c"])):
        lo = np.asarray(full).min(0) - 1e-5
        hi = np.asarray(full).max(0) + 1e-5
        assert np.all(np.asarray(leaf) >= lo) and np.all(np.asarray(leaf) <= hi)
    same = jax.tree.map(lambda x: jnp.broadcast_to(x[:1], x.shape), trees)
    agg2 = fedavg(same, w)
    np.testing.assert_allclose(np.asarray(agg2["a"]),
                               np.asarray(trees["a"][0]), rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------ pruning
@given(n=st.integers(4, 100), gamma=st.floats(0.0, 0.9),
       seed=st.integers(0, 2**30))
@settings(**SETTINGS)
def test_prune_keeps_top_scores(n, gamma, seed):
    scores = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    idx = prune_indices(scores, gamma)
    keep = len(idx)
    assert keep == max(1, n - int(gamma * n))
    kept = np.asarray(scores)[np.asarray(idx)]
    dropped = np.delete(np.asarray(scores), np.asarray(idx))
    if len(dropped):
        assert kept.min() >= dropped.max() - 1e-6


@given(n=st.integers(4, 64), seed=st.integers(0, 2**30),
       gamma=st.sampled_from([0.0, 1.0 / 64, 0.5, 0.97, 0.999]))
@settings(**SETTINGS)
def test_prune_edge_gammas_and_ordering(n, seed, gamma):
    """gamma=0 keeps everything (in descending-score order), gamma≈1 still
    keeps >= 1, and the kept block is always sorted descending."""
    scores = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    idx = np.asarray(prune_indices(scores, gamma))
    assert len(idx) == max(1, n - int(gamma * n))
    assert len(set(idx.tolist())) == len(idx)          # no duplicates
    kept = np.asarray(scores)[idx]
    assert np.all(np.diff(kept) <= 1e-6)               # descending
    if gamma == 0.0:
        assert sorted(idx.tolist()) == list(range(n))  # permutation of all


@given(n=st.integers(4, 40), gamma=st.floats(0.0, 0.99),
       n_values=st.integers(1, 3), seed=st.integers(0, 2**30))
@settings(**SETTINGS)
def test_prune_duplicate_scores(n, gamma, n_values, seed):
    """Ties (few distinct score values, incl. ALL-equal) never break the
    keep-count/validity/ordering invariants."""
    key = jax.random.PRNGKey(seed)
    values = jax.random.normal(key, (n_values,))
    scores = values[jax.random.randint(jax.random.fold_in(key, 1),
                                       (n,), 0, n_values)]
    idx = np.asarray(prune_indices(scores, gamma))
    assert len(idx) == max(1, n - int(gamma * n))
    assert len(set(idx.tolist())) == len(idx)
    assert np.all((idx >= 0) & (idx < n))
    kept = np.asarray(scores)[idx]
    dropped = np.delete(np.asarray(scores), idx)
    if len(dropped):
        assert kept.min() >= dropped.max() - 1e-6


# ------------------------------------------------------------------ int8 codec
@given(rows=st.integers(1, 6), d=st.integers(2, 96),
       scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**30))
@settings(max_examples=10, deadline=None)
def test_int8_roundtrip_stochastic_unbiased(rows, d, scale, seed):
    """Stochastic int8 rounding is unbiased: averaging the round-trip over
    many independent noise draws converges to x within the standard error
    of the per-row quantization step, for arbitrary shapes/scales."""
    from repro.runtime.codec import get_codec
    codec = get_codec("int8", impl="ref")
    key = jax.random.PRNGKey(seed)
    x = scale * jax.random.normal(key, (rows, d), jnp.float32)
    draws = 256
    u = jax.random.uniform(jax.random.fold_in(key, 1),
                           (draws, rows, d), jnp.float32)
    decoded = jax.vmap(
        lambda ui: codec.decode(codec.encode(x, ui), jnp.float32))(u)
    mean_err = np.asarray(jnp.abs(decoded.mean(0) - x))
    step = np.asarray(jnp.max(jnp.abs(x), -1, keepdims=True)) / 127.0
    # se of a mean of `draws` uniform-rounding errors is <= step/(2 sqrt n)
    tol = 4.0 * step / (2.0 * np.sqrt(draws)) + 1e-7
    assert np.all(mean_err <= tol), (mean_err.max(), tol.min())
    # and a single draw is always within one quantization step
    one = np.asarray(jnp.abs(decoded[0] - x))
    assert np.all(one <= step + 1e-6)


# ------------------------------------------------------------------ comm model
@given(W=st.floats(1e6, 1e12), D=st.integers(10, 10_000),
       U=st.integers(1, 20), gamma_keep=st.floats(0.05, 1.0),
       q=st.floats(1e3, 1e7))
@settings(**SETTINGS)
def test_cost_model_orderings(W, D, U, gamma_keep, q):
    """Paper's qualitative claims hold in the implemented Table-1 model:
    (i) SFPrompt comm < SFL comm;
    (ii) pruning more (smaller gamma_keep) never increases SFPrompt comm;
    (iii) client compute of split methods < FL's."""
    c = CostInputs(W=W, alpha=0.1, tau=0.8, q=q, D=D, U=U,
                   gamma_keep=gamma_keep)
    assert sfprompt_comm(c) < sfl_comm(c)
    c_less = CostInputs(W=W, alpha=0.1, tau=0.8, q=q, D=D, U=U,
                        gamma_keep=gamma_keep * 0.5)
    assert sfprompt_comm(c_less) <= sfprompt_comm(c) + 1e-6
    assert sfprompt_compute_paper(c) < 6 * D * W * U  # < FL per-client
    assert sfl_compute(c) < 6 * D * W * U


# ------------------------------------------------------------------ RoPE
@given(s=st.integers(2, 32), d=st.integers(2, 32).map(lambda x: 2 * x),
       theta=st.floats(100.0, 1e6), seed=st.integers(0, 2**30))
@settings(**SETTINGS)
def test_rope_preserves_norm_and_relative(s, d, theta, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, s, 2, d))
    pos = jnp.arange(s)[None, :]
    cos, sin = rope_cos_sin(pos, d, theta)
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-4, atol=1e-4)
    # relative property: <R(p)q, R(p+k)v> depends only on k
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 1, d))
    def dot_at(p1, p2):
        c1, s1 = rope_cos_sin(jnp.array([[p1]]), d, theta)
        c2, s2 = rope_cos_sin(jnp.array([[p2]]), d, theta)
        return float(jnp.sum(apply_rope(q, c1, s1) * apply_rope(v, c2, s2)))
    assert abs(dot_at(0, 3) - dot_at(5, 8)) < 1e-3


# ------------------------------------------------------------------ optim
@given(lr=st.floats(1e-4, 0.5), seed=st.integers(0, 2**30))
@settings(**SETTINGS)
def test_sgd_descends_quadratic(lr, seed):
    key = jax.random.PRNGKey(seed)
    x = {"p": jax.random.normal(key, (6,))}
    opt = sgd(lr)
    state = opt.init(x)
    f = lambda t: 0.5 * jnp.sum(t["p"] ** 2)
    for _ in range(3):
        g = jax.grad(f)(x)
        upd, state = opt.update(g, state, x)
        x_new = apply_updates(x, upd)
        assert f(x_new) <= f(x) + 1e-6
        x = x_new


# ----------------------------------------------------------- fixed point
@given(n=st.integers(1, 64), log_scale=st.floats(-4.0, 4.0),
       seed=st.integers(0, 2**30))
@settings(**SETTINGS)
def test_fixed_point_roundtrip_across_magnitudes(n, log_scale, seed):
    """encode/decode round-trips within half a fixed-point step plus the
    f32 representation error of the scaled value, from 1e-4 to 1e4
    (clamped inside the saturation edge — saturation itself is pinned by
    test_ring_boundary_overflow_wraps)."""
    from repro.privacy.fixed_point import headroom, resolution
    from repro.kernels.secure_mask.ops import decode, encode
    x = (10.0 ** log_scale) * jax.random.normal(
        jax.random.PRNGKey(seed), (n,), jnp.float32)
    x = jnp.clip(x, -0.9 * headroom(), 0.9 * headroom())
    got = np.asarray(decode(encode(x)))
    tol = 0.5 * resolution() + 4e-7 * np.abs(np.asarray(x)) + 1e-7
    assert np.all(np.abs(got - np.asarray(x)) <= tol)


@given(k=st.integers(2, 8), n_blocks=st.integers(1, 3),
       seed=st.integers(0, 2**30))
@settings(max_examples=15, deadline=None)
def test_mask_cancellation_sum_identity(k, n_blocks, seed):
    """For ANY cohort size, summing every client's masked upload cancels
    the pairwise masks EXACTLY (ring identity, not approximately): the
    ring sum of uploads equals the ring sum of plain encodings."""
    from repro.kernels.secure_mask.ops import LANES, encode, masked_encode
    from repro.privacy.masking import client_pairs, pair_seeds, round_key
    n = n_blocks * LANES
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (k, n), jnp.float32)
    seeds = pair_seeds(round_key(seed, 0), k)
    total = jnp.zeros((n,), jnp.uint32)
    for c in range(k):
        peers, signs = client_pairs(k, c)
        total = total + masked_encode(x[c], seeds[c, peers],
                                      jnp.asarray(signs), impl="ref")
    expect = jnp.zeros((n,), jnp.uint32)
    for c in range(k):
        expect = expect + encode(x[c])
    np.testing.assert_array_equal(np.asarray(total), np.asarray(expect))


@given(frac=st.floats(0.55, 0.95), seed=st.integers(0, 2**30))
@settings(**SETTINGS)
def test_ring_boundary_overflow_wraps(frac, seed):
    """At the ring edge: a single encode saturates, but a SUM crossing
    2^31 ring units wraps around to the negative half — the documented
    price of fixed-point headroom (privacy/fixed_point.py)."""
    from repro.privacy.fixed_point import headroom
    from repro.kernels.secure_mask.ops import decode, encode
    edge = headroom()
    a = jnp.float32(frac * edge)
    # saturation: anything past the edge encodes like the edge
    np.testing.assert_array_equal(np.asarray(encode(jnp.float32(10 * edge))),
                                  np.asarray(encode(jnp.float32(edge))))
    # wraparound: 2a crosses the signed boundary and re-enters at
    # 2a - 2^(32 - frac_bits), in the negative half
    wrapped = float(decode(encode(a) + encode(a)))
    expect = 2.0 * float(a) - 2.0 ** 16
    assert wrapped < 0
    # error budget: one f32 round of x*2^16 per encode + one uint32->f32
    # conversion, each <= 128 ring units at this magnitude
    assert abs(wrapped - expect) <= 1.0


def test_adamw_state_shapes():
    x = {"a": jnp.ones((3, 4)), "b": jnp.zeros((2,))}
    opt = adamw(1e-3, weight_decay=0.01)
    st_ = opt.init(x)
    g = jax.tree.map(jnp.ones_like, x)
    upd, st2 = opt.update(g, st_, x)
    assert jax.tree.structure(upd) == jax.tree.structure(x)
    assert int(st2["step"]) == 1
