"""SplitModel: the three-way partition behaves like one model; the local
route (head->tail) skips the body; caches work through the split path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.core.split import SplitConfig, SplitModel

KEY = jax.random.PRNGKey(0)
SPLIT = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4,
                    prune_gamma=0.5, local_epochs=2)

from tests.test_models import make_batch  # reuse batch builder


def build(arch):
    cfg = get_config(arch).reduced(n_layers=4)
    # reduced() keeps >= 1 cycle; ensure enough cycles for a 1/1/≥1 split
    if cfg.n_cycles < 3:
        import dataclasses
        cyc = len(cfg.layer_pattern)
        cfg = dataclasses.replace(
            cfg, n_layers=cfg.n_dense_layers + 3 * cyc)
    return cfg, SplitModel(cfg, SPLIT)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_split_forward_shapes(arch):
    cfg, model = build(arch)
    params = model.init(KEY)
    batch = make_batch(cfg, with_labels=True)
    out = model.forward(params, batch, route="split", mode="train")
    assert out["logits"].shape[-1] == (cfg.num_classes or cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out["logits"])))


@pytest.mark.parametrize("arch", ["stablelm-12b", "zamba2-2.7b",
                                  "phi3.5-moe-42b-a6.6b"])
def test_local_route_skips_body(arch):
    """Local route output is independent of the body parameters."""
    cfg, model = build(arch)
    params = model.init(KEY)
    batch = make_batch(cfg)
    out1 = model.forward(params, batch, route="local", mode="train")
    params2 = dict(params)
    params2["body"] = jax.tree.map(lambda x: x * 0.0 + 7.0, params["body"])
    out2 = model.forward(params2, batch, route="local", mode="train")
    np.testing.assert_array_equal(np.asarray(out1["logits"]),
                                  np.asarray(out2["logits"]))
    # ...but the split route IS affected
    out3 = model.forward(params2, batch, route="split", mode="train")
    assert np.abs(np.asarray(out3["logits"]) -
                  np.asarray(out1["logits"])).max() > 1e-4


def test_split_decode_matches_train():
    cfg, model = build("qwen2.5-14b")
    params = model.init(KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    P = SPLIT.prompt_len
    full = model.forward(params, {"tokens": toks}, route="split",
                         mode="train")
    cache = model.init_cache(B, seq_len=64)
    pre = model.forward(params, {"tokens": toks[:, :S]}, route="split",
                        mode="prefill", cache=cache)
    dec = model.forward(params, {"tokens": toks[:, S:S + 1],
                                 "pos": jnp.full((B,), S + P, jnp.int32)},
                        route="split", mode="decode", cache=pre["cache"])
    np.testing.assert_allclose(np.asarray(dec["logits"][:, 0]),
                               np.asarray(full["logits"][:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_prompt_changes_output_and_grads_flow():
    """Prompts injected at the head must affect logits, and grads must flow
    back through the frozen body to the prompt (the phase-2 relay)."""
    cfg, model = build("stablelm-12b")
    params = model.init(KEY)
    batch = make_batch(cfg)

    def loss(prompt):
        out = model.forward(params, batch, route="split", mode="train",
                            prompt=prompt)
        return jnp.sum(out["logits"] ** 2)

    g = jax.grad(loss)(params["prompt"])
    assert float(jnp.abs(g).sum()) > 0


def test_segment_fractions():
    cfg, model = build("stablelm-12b")
    alpha, tau = model.segment_fractions()
    assert 0 < alpha < 1 and 0 < tau < 1 and alpha + tau < 1.2


def test_split_validation():
    cfg = get_config("stablelm-12b").reduced(n_layers=2)
    with pytest.raises(ValueError):
        SplitModel(cfg, SplitConfig(head_cycles=1, tail_cycles=1))


def test_whisper_cross_attention_uses_encoder():
    """Decoder logits must depend on the encoder output (cross-attention),
    and the split keeps the encoder client-side (in the head segment)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    cfg, model = build("whisper-base")
    params = model.init(KEY)
    assert "encoder" in params["head"]          # client-side feature extractor
    B = 2
    toks = jax.random.randint(KEY, (B, 12), 0, cfg.vocab_size)
    fr1 = 0.05 * jax.random.normal(KEY, (B, cfg.encoder.n_frames, cfg.d_model))
    out1 = model.forward(params, {"tokens": toks, "frames": fr1},
                         route="split", mode="train")
    out2 = model.forward(params, {"tokens": toks, "frames": fr1 * -1.0},
                         route="split", mode="train")
    assert np.abs(np.asarray(out1["logits"] - out2["logits"])).max() > 1e-4


def test_comm_model_consistent_with_split_fractions():
    """The Table-1 cost model's alpha/tau must come from the real split."""
    from repro.core.comm import cost_inputs_from
    cfg, model = build("stablelm-12b")
    ci = cost_inputs_from(cfg, SPLIT, tokens_per_sample=64, D=100, model=model)
    a, t = model.segment_fractions()
    assert abs(ci.alpha - a) < 1e-9 and abs(ci.tau - t) < 1e-9
    assert ci.W == cfg.param_count()
