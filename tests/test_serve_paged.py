"""Paged-KV serving engine: differential harness against the dense engine.

The paged engine must be an invisible MEMORY optimization: with a
dense-equivalent pool (page_size | max_seq, default n_pages) its greedy
tokens, per-step logits, AND metered wire bytes are bit-identical to
`ServeEngine` at fp32 across ragged joins/leaves. On top of that it must
deliver the paging wins the dense engine cannot: page-granular admission
(last-page slack), chunked prefill, and copy-on-write shared prefixes
whose lifecycle (prefilled once per tenant, one boundary copy per join,
pages cascade back when the last sharer drains) is pinned by counters.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SplitConfig, SplitModel
from repro.kernels.flash_attention import (decode_attention,
                                           paged_decode_attention)
from repro.runtime import WireSpec
from repro.serve import (PagedServeConfig, PagedServeEngine, Request,
                         ServeConfig, ServeEngine, TenantBank)

KEY = jax.random.PRNGKey(0)
MAX_SEQ = 48
PROMPT_LEN = 4
PAGE = 8                       # divides MAX_SEQ -> capacity == max_seq


def build_model(wire="fp32"):
    cfg = get_config("qwen2.5-14b").reduced(
        n_layers=3, d_model=64, d_ff=128, vocab_size=128)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=PROMPT_LEN)
    return cfg, SplitModel(cfg, split, WireSpec.make(wire))


def make_bank(model, params, n_tenants=3, jitter=0.2):
    tails, prompts = [], []
    for t in range(n_tenants):
        key = jax.random.fold_in(jax.random.PRNGKey(7), t)
        leaves, treedef = jax.tree.flatten(params["tail"])
        ks = jax.random.split(key, len(leaves) + 1)
        tails.append(jax.tree.unflatten(treedef, [
            x + jitter * jax.random.normal(k, x.shape, x.dtype)
            for x, k in zip(leaves, ks[:-1])]))
        prompts.append(params["prompt"] + jitter * jax.random.normal(
            ks[-1], params["prompt"].shape))
    return TenantBank.from_lists(tails, prompts)


@pytest.fixture(scope="module")
def setup():
    cfg, model = build_model()
    params = model.init(KEY)
    bank = make_bank(model, params)
    return cfg, model, params, bank


def _toks(L, mult):
    return (np.arange(L, dtype=np.int32) * mult) % 128


# ragged joins/leaves: staggered arrivals, mixed lengths, a tenant repeat
REQS = [
    Request(rid=0, tenant=0, tokens=_toks(9, 1), max_new=5, arrival=0),
    Request(rid=1, tenant=1, tokens=_toks(14, 3), max_new=4, arrival=0),
    Request(rid=2, tenant=2, tokens=_toks(6, 7), max_new=6, arrival=2),
    Request(rid=3, tenant=1, tokens=_toks(11, 5), max_new=3, arrival=3),
]


def run_dense(model, params, bank, reqs, *, max_seq=MAX_SEQ, **kw):
    eng = ServeEngine(model, params, bank,
                      ServeConfig(n_slots=3, max_seq=max_seq,
                                  decode_block=2),
                      collect_logits=True)
    return eng, eng.run(reqs, **kw)


def run_paged(model, params, bank, reqs, *, max_seq=MAX_SEQ, **cfg_kw):
    eng = PagedServeEngine(
        model, params, bank,
        PagedServeConfig(n_slots=3, max_seq=max_seq, decode_block=2,
                         page_size=PAGE, **cfg_kw),
        collect_logits=True)
    return eng, eng.run(reqs)


# ------------------------------------------------------------ differential
def test_paged_matches_dense_bitwise(setup):
    """THE tentpole invariant: with a dense-equivalent pool the paged
    engine is bit-identical to the dense engine — greedy tokens, every
    per-step logit row, and every metered wire byte (fp32)."""
    cfg, model, params, bank = setup
    _, dense = run_dense(model, params, bank, REQS)
    peng, paged = run_paged(model, params, bank, REQS)
    assert paged["n_finished"] == dense["n_finished"] == len(REQS)
    d = {f.req.rid: f for f in dense["finished"]}
    p = {f.req.rid: f for f in paged["finished"]}
    for rid in d:
        np.testing.assert_array_equal(p[rid].tokens, d[rid].tokens,
                                      err_msg=f"rid={rid}")
        np.testing.assert_array_equal(p[rid].logits, d[rid].logits,
                                      err_msg=f"rid={rid}")
    # paging is memory-only: the serve wire protocol is untouched
    assert paged["wire_bytes"] == dense["wire_bytes"]
    # and the pool fully drains
    assert paged["pages_in_use"] == 0
    assert peng.pool_alloc.n_free == peng.pool_alloc.n_pages - 2


def test_paged_kernel_matches_dense_gather():
    """Op-level differential: `paged_decode_attention` over a shuffled
    page pool equals `decode_attention` over the gathered dense caches —
    bit-exact on ref/xla, allclose under Pallas interpret."""
    B, nb, Hq, Hkv, Dh = 3, 3, 4, 2, 32
    P = 2 + B * nb + 3          # reserved + live + spare pages
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, Dh))
    k_pool = jax.random.normal(ks[1], (P, PAGE, Hkv, Dh))
    v_pool = jax.random.normal(ks[2], (P, PAGE, Hkv, Dh))
    # shuffled non-contiguous page assignment, ragged lengths
    perm = np.random.default_rng(11).permutation(np.arange(2, P))
    tables = jnp.asarray(perm[:B * nb].reshape(B, nb), jnp.int32)
    lens = np.asarray([20, 7, 24])
    kv_pos = np.full((P, PAGE), -1, np.int32)
    for b in range(B):
        for j in range(nb):
            base = j * PAGE
            n = int(np.clip(lens[b] - base, 0, PAGE))
            kv_pos[perm[b * nb + j], :n] = base + np.arange(n)
    kv_pos = jnp.asarray(kv_pos)
    q_pos = jnp.asarray(lens - 1, jnp.int32)

    kd = k_pool[tables].reshape(B, nb * PAGE, Hkv, Dh)
    vd = v_pool[tables].reshape(B, nb * PAGE, Hkv, Dh)
    kvd = kv_pos[tables].reshape(B, nb * PAGE)
    for impl in ("ref", "xla"):
        want = decode_attention(q, kd, vd, q_positions=q_pos,
                                kv_positions=kvd, impl=impl)
        got = paged_decode_attention(q, k_pool, v_pool,
                                     block_tables=tables, q_positions=q_pos,
                                     kv_positions=kv_pos, impl=impl)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=impl)
    want = decode_attention(q, kd, vd, q_positions=q_pos,
                            kv_positions=kvd, impl="ref")
    got = paged_decode_attention(q, k_pool, v_pool, block_tables=tables,
                                 q_positions=q_pos, kv_positions=kv_pos,
                                 impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("prefix", [None, (3, 1, 4, 1, 5, 9, 2, 6)])
def test_tenant_isolation_under_join(setup, prefix):
    """Tenant A's outputs don't change when tenant B joins mid-flight —
    with and without prefix sharing enabled."""
    cfg, model, params, bank = setup
    a = Request(rid=0, tenant=0, tokens=_toks(8, 1), max_new=6, arrival=0)
    b = Request(rid=1, tenant=2, tokens=_toks(12, 11), max_new=4, arrival=2)

    def run(reqs):
        _, stats = run_paged(model, params, bank, reqs,
                             shared_prefix=prefix)
        return {f.req.rid: f for f in stats["finished"]}

    alone, joined = run([a])[0], run([a, b])[0]
    np.testing.assert_array_equal(alone.tokens, joined.tokens)
    np.testing.assert_array_equal(alone.logits, joined.logits)


def test_chunked_prefill_matches_monolithic(setup):
    """Streaming prompts in 5-token chunks changes neither the tokens nor
    the metered bytes (chunking reshapes dispatches, not traffic); logits
    agree to float tolerance."""
    cfg, model, params, bank = setup
    mono_eng, mono = run_paged(model, params, bank, REQS)
    chunk_eng, chunk = run_paged(model, params, bank, REQS,
                                 prefill_chunk=5)
    assert chunk_eng.prefill_chunks > 0
    m = {f.req.rid: f for f in mono["finished"]}
    c = {f.req.rid: f for f in chunk["finished"]}
    for rid in m:
        np.testing.assert_array_equal(c[rid].tokens, m[rid].tokens,
                                      err_msg=f"rid={rid}")
        np.testing.assert_allclose(c[rid].logits, m[rid].logits,
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"rid={rid}")
    assert chunk["wire_bytes"] == mono["wire_bytes"]
    assert chunk["pages_in_use"] == 0


PREFIX = (3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5)        # 11 tokens: L_pre = 15
#                                                   -> 1 full page + boundary


def test_shared_prefix_matches_dense_prepended(setup):
    """Prefix sharing is semantics-preserving: a paged engine with
    `shared_prefix=F` serves the same tokens as a dense engine fed
    `F + tokens`, while metering FEWER OR EQUAL prefill bytes (a prefix
    hit skips re-transmitting the prefix activations)."""
    cfg, model, params, bank = setup
    # overlapping same-tenant pair so the second join is a prefix HIT
    reqs = [
        Request(rid=0, tenant=1, tokens=_toks(9, 3), max_new=6, arrival=0),
        Request(rid=1, tenant=0, tokens=_toks(7, 1), max_new=5, arrival=0),
        Request(rid=2, tenant=1, tokens=_toks(5, 5), max_new=6, arrival=1),
    ]
    prepended = [
        Request(rid=r.rid, tenant=r.tenant,
                tokens=np.concatenate([np.asarray(PREFIX, np.int32),
                                       r.tokens]),
                max_new=r.max_new, arrival=r.arrival)
        for r in reqs]
    _, dense = run_dense(model, params, bank, prepended,
                         max_seq=MAX_SEQ + len(PREFIX) + PAGE)
    peng, paged = run_paged(model, params, bank, reqs,
                            max_seq=MAX_SEQ, shared_prefix=PREFIX)
    assert peng.prefix_hits >= 1
    d = {f.req.rid: f for f in dense["finished"]}
    p = {f.req.rid: f for f in paged["finished"]}
    for rid in d:
        np.testing.assert_array_equal(p[rid].tokens, d[rid].tokens,
                                      err_msg=f"rid={rid}")
        np.testing.assert_allclose(p[rid].logits, d[rid].logits,
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"rid={rid}")
    for name in ("head_body", "body_tail", "total"):
        assert paged["wire_bytes"][name] < dense["wire_bytes"][name]


def test_cow_lifecycle_counters(setup):
    """The COW ledger: the prefix is prefilled ONCE per tenant (every other
    prefill dispatch is a continuation chunk), each join copies exactly one
    boundary page, and draining the last sharer returns every page."""
    cfg, model, params, bank = setup
    reqs = [
        Request(rid=0, tenant=1, tokens=_toks(9, 3), max_new=6, arrival=0),
        Request(rid=1, tenant=1, tokens=_toks(5, 5), max_new=6, arrival=1),
    ]
    peng, stats = run_paged(model, params, bank, reqs,
                            shared_prefix=PREFIX)
    assert stats["n_finished"] == 2
    # prefix computed once: the only full-prefill dispatch built the entry
    assert peng.prefill_step_calls == 1
    assert (peng.prefix_misses, peng.prefix_hits) == (1, 1)
    assert stats["prefix_hit_ratio"] == 0.5
    # one boundary-page copy per join (L_pre=15 has a partial page)
    assert peng.page_copies == 2
    # last sharer drained -> entry evicted, every page back in the pool
    assert not peng._prefix
    assert stats["pages_in_use"] == 0
    assert peng.pool_alloc.n_free == peng.pool_alloc.n_pages - 2


def test_warm_replay_after_reset(setup):
    """reset_stats() clears the paged counters too; a warm engine replays
    the trace with identical schedule, tokens, and ledger."""
    cfg, model, params, bank = setup
    eng = PagedServeEngine(
        model, params, bank,
        PagedServeConfig(n_slots=3, max_seq=MAX_SEQ, decode_block=2,
                         page_size=PAGE, shared_prefix=PREFIX,
                         prefill_chunk=4),
        collect_logits=True)

    def snap(stats):
        return (eng.decode_steps, eng.tokens_out, eng.prefill_count,
                eng.prefill_step_calls, eng.prefill_chunks,
                eng.page_copies, eng.prefix_hits, eng.prefix_misses,
                eng.peak_pages, stats["wire_bytes"]["total"],
                {f.req.rid: f.tokens.tolist() for f in stats["finished"]})

    first = snap(eng.run(REQS))
    eng.reset_stats()
    assert eng.peak_pages == 0 and eng.page_copies == 0
    second = snap(eng.run(REQS))
    assert first == second
    assert eng.pool_alloc.n_used == 0


# --------------------------------------------------------------- admission
def test_page_granular_admission(setup):
    """A request a few tokens over `max_seq` but inside the last page's
    slack is REJECTED by the dense window and ADMITTED by the paged engine
    (capacity rounds up to whole pages)."""
    cfg, model, params, bank = setup
    ps = 10                                   # 48 -> 5 pages, capacity 50
    over = Request(rid=0, tenant=0, tokens=_toks(40, 1),
                   max_new=5, arrival=0)      # total = 40 + 4 + 5 = 49
    dense = ServeEngine(model, params, bank,
                        ServeConfig(n_slots=2, max_seq=MAX_SEQ))
    with pytest.raises(ValueError):
        dense.submit(over)
    peng = PagedServeEngine(
        model, params, bank,
        PagedServeConfig(n_slots=2, max_seq=MAX_SEQ, page_size=ps))
    stats = peng.run([over])
    assert stats["n_finished"] == 1
    assert stats["finished"][0].tokens.shape == (5,)
    # but a request beyond even the page-rounded capacity still fails loud
    with pytest.raises(ValueError):
        peng.submit(Request(rid=1, tenant=0, tokens=_toks(46, 1),
                            max_new=5, arrival=0))


def test_pool_exhaustion_head_of_line_wait(setup):
    """With pages for only one request in flight, the queue's head WAITS
    for the pool instead of being dropped — both requests finish."""
    cfg, model, params, bank = setup
    nb = -(-MAX_SEQ // PAGE)
    reqs = [Request(rid=i, tenant=i % 3, tokens=_toks(10 + i, 3),
                    max_new=4, arrival=0) for i in range(3)]
    peng = PagedServeEngine(
        model, params, bank,
        PagedServeConfig(n_slots=3, max_seq=MAX_SEQ, page_size=PAGE,
                         n_pages=nb + 2 + 1))   # one window + reserved + 1
    stats = peng.run(reqs)
    assert stats["n_finished"] == 3
    assert peng.peak_pages <= peng.pool_alloc.n_pages - 2
    assert stats["pages_in_use"] == 0


def test_paged_engine_rejects_unsupported_arch():
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=64, d_ff=128)
    model = SplitModel(cfg, SplitConfig(head_cycles=1, tail_cycles=1,
                                        prompt_len=4))
    params = model.init(KEY)
    bank = TenantBank.replicate(params["tail"], params["prompt"], 2)
    with pytest.raises(ValueError):
        PagedServeEngine(model, params, bank,
                         PagedServeConfig(n_slots=2, max_seq=32,
                                          page_size=8))
