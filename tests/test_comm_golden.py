"""Golden-value tests for the analytical Table-1 comm model.

Every expected number below is HAND-COMPUTED from the paper's Table-1
formulas (see core/comm.py's conventions docstring) with binary-exact
inputs, so any refactor that drifts the analytical model — a factor of 2,
a misplaced K, a bytes term — fails loudly against a literal constant
instead of passing a self-consistent-but-wrong crosscheck.

Shared inputs: W=1e6, alpha=0.25, tau=0.5 (=> Wt = 0.25e6 = 250_000),
q=1000, D=100, U=10, E=1, K=5, p=2000, gamma_keep=0.6.
"""
import pytest

from repro.core.comm import (CostInputs, fl_comm, sfl_comm, sfprompt_comm,
                             sfprompt_comm_breakdown,
                             sfprompt_comm_breakdown_partial)


def make_inputs(bytes_smashed):
    return CostInputs(W=1e6, alpha=0.25, tau=0.5, q=1000, D=100, U=10,
                      E=1, K=5, p=2000, gamma_keep=0.6,
                      bytes_smashed=bytes_smashed, bytes_param=4)


# One boundary carries 2q (fwd activation + bwd gradient) per sample per
# phase-2 pass over the kept subset, for each of K clients:
#   per_boundary = 2 * q * gamma_keep * D * E * bytes_smashed * K
#               = 2 * 1000 * 0.6 * 100 * 1 * bytes_smashed * 5
#               = 600_000 * bytes_smashed
# (tail + prompt) go up + down once per round for each of K clients:
#   params = 2 * (Wt + p) * bytes_param * K
#          = 2 * (250_000 + 2000) * 4 * 5 = 10_080_000
@pytest.mark.parametrize("bytes_smashed,per_boundary", [
    (4.0, 2_400_000.0),     # fp32 smashed tensors
    (2.0, 1_200_000.0),     # bf16
    (1.25, 750_000.0),      # int8 + per-row scale overhead
])
def test_sfprompt_breakdown_full_cohort_golden(bytes_smashed, per_boundary):
    c = make_inputs(bytes_smashed)
    got = sfprompt_comm_breakdown(c)
    assert got["head_body"] == pytest.approx(per_boundary, rel=1e-12)
    assert got["body_tail"] == pytest.approx(per_boundary, rel=1e-12)
    assert got["params"] == pytest.approx(10_080_000.0, rel=1e-12)
    # the scalar total is exactly the sum of the per-link breakdown
    assert sfprompt_comm(c) == pytest.approx(sum(got.values()), rel=1e-12)


def test_sfprompt_breakdown_partial_cohort_golden():
    """Partial participation (fed.RoundPlan): transmit_sum = 3.5 (one
    straggler sent half), n_uploads = 3 survivors, k_down = 5 sampled.

      per_boundary_client = 2 * 1000 * 0.6 * 100 * 1 * 4 = 480_000
      head_body = body_tail = 480_000 * 3.5 = 1_680_000
      params    = (250_000 + 2000) * 4 * (5 + 3) = 8_064_000
    """
    c = make_inputs(4.0)
    got = sfprompt_comm_breakdown_partial(c, transmit_sum=3.5, n_uploads=3,
                                          k_down=5)
    assert got["head_body"] == pytest.approx(1_680_000.0, rel=1e-12)
    assert got["body_tail"] == pytest.approx(1_680_000.0, rel=1e-12)
    assert got["params"] == pytest.approx(8_064_000.0, rel=1e-12)


@pytest.mark.parametrize("bytes_smashed", [4.0, 2.0, 1.25])
def test_partial_reduces_to_synchronous_at_full_participation(bytes_smashed):
    """transmit_sum = n_uploads = k_down = K must reproduce the
    synchronous breakdown exactly, link by link."""
    c = make_inputs(bytes_smashed)
    sync = sfprompt_comm_breakdown(c)
    part = sfprompt_comm_breakdown_partial(c, transmit_sum=c.K,
                                           n_uploads=c.K, k_down=c.K)
    for name in sync:
        assert part[name] == pytest.approx(sync[name], rel=1e-12), name


def test_fl_and_sfl_comm_golden():
    """FL: 2|W|K * bytes = 2 * 1e6 * 5 * 4 = 40_000_000.
    SFL: (4q D U * bytes_smashed + 2 (1-tau)|W| * bytes_param) * K
       = (4*1000*100*10*4 + 2*500_000*4) * 5 = (16e6 + 4e6) * 5 = 1e8."""
    c = make_inputs(4.0)
    assert fl_comm(c) == pytest.approx(40_000_000.0, rel=1e-12)
    assert sfl_comm(c) == pytest.approx(100_000_000.0, rel=1e-12)


def test_sfprompt_comm_total_golden():
    """fp32: 2 * 2_400_000 + 10_080_000 = 14_880_000 bytes/round —
    2.7x under SFL's 1e8 even before int8 smashed payloads."""
    assert sfprompt_comm(make_inputs(4.0)) == pytest.approx(
        14_880_000.0, rel=1e-12)
