import os
import sys

# keep smoke tests and benches on 1 device; ONLY dryrun.py forces 512
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
