"""End-to-end behaviour tests for the SFPrompt system: the full federated
fine-tuning path (pretrain -> split -> 3-phase rounds -> aggregate -> eval)
on a tiny ViT, plus the launch-layer step factories on CPU."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (ProtocolConfig, SFPromptTrainer, SplitConfig,
                        SplitModel)
from repro.core.comm import cost_inputs_from, summarize
from repro.data import (DATASETS, iid_partition, select_clients,
                        stack_clients, synthetic_image_dataset)
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_split_loss, make_train_step)
from repro.optim import sgd

KEY = jax.random.PRNGKey(0)


def test_full_pipeline_improves_eval():
    """Eval must improve over rounds once the frozen backbone has
    non-random features (mirrors the paper's pretrained-ViT setting by
    warm-starting the backbone with a few centralized steps).

    Train/test/pretrain all slice ONE generative draw: the synthetic class
    anchors are seed-dependent, so datasets drawn with different seeds have
    different label functions and cross-seed eval is pure noise (the old
    flake). The margin is a relative-CE check, robust to tiny-batch
    accuracy quantization."""
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=64, d_ff=128)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4,
                        prune_gamma=0.3, local_epochs=1)
    model = SplitModel(cfg, split)
    full = synthetic_image_dataset(DATASETS["cifar10-syn"], 480 + 96 + 256,
                                   seed=0, image_hw=32)
    data = {k: v[:480] for k, v in full.items()}
    test = {k: v[480:576] for k, v in full.items()}
    pre = {k: v[576:] for k, v in full.items()}
    clients = iid_partition(data, 6, seed=0)

    pcfg = ProtocolConfig(clients_per_round=3, local_epochs=1, batch_size=8,
                          lr_local=0.01, lr_split=0.01, momentum=0.0)
    tr = SFPromptTrainer(model, pcfg)
    state = tr.init(KEY)

    # ---- centralized warm-start of the frozen backbone ("pre-training")
    from repro.core import losses
    from repro.optim import apply_updates
    params = state["params"]
    opt = sgd(0.05)
    opt_state = opt.init(params)

    @jax.jit
    def pretrain_step(params, opt_state, batch):
        def loss_fn(p):
            out = model.forward(p, batch, route="split", mode="train")
            return losses.task_loss(cfg, out, batch, impl="ref")[0]
        g = jax.grad(loss_fn)(params)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state

    for i in range(16):
        sl = slice((i * 16) % 256, (i * 16) % 256 + 16)
        batch = {k: jnp.asarray(v[sl]) for k, v in pre.items()}
        params, opt_state = pretrain_step(params, opt_state, batch)
    state = {"params": params, "round": state["round"]}

    ev0 = tr.evaluate(state["params"], test, batch_size=32)
    for r in range(3):
        idx = select_clients(6, 3, seed=0, round_idx=r)
        batch = {k: jnp.asarray(v) for k, v in
                 stack_clients(clients, idx).items()}
        state, _ = tr.round(state, batch)
    ev1 = tr.evaluate(state["params"], test, batch_size=32)
    # robust relative-improvement: the rounds must cut CE by >= 10% and
    # must not lose accuracy vs the warm start (one-sample slack on the
    # 96-sample eval set for borderline flips)
    assert np.isfinite(ev1["ce"])
    assert ev1["ce"] <= 0.9 * ev0["ce"], (ev0, ev1)
    assert ev1["acc"] >= ev0["acc"] - 1.5 / 96, (ev0, ev1)


def test_launch_train_step_cpu():
    """The dry-run train step (vmapped clients, microbatching, fedavg) runs
    numerically on CPU with K=2 clients."""
    cfg = get_config("qwen2.5-14b").reduced(n_layers=3)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4)
    model = SplitModel(cfg, split)
    K, b, S = 2, 4, 16
    train_step, opt = make_train_step(model, n_clients=K, microbatches=2,
                                      remat=True)
    params = model.init(KEY)
    frozen = {"head": params["head"], "body": params["body"]}
    trainable = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape),
        {"tail": params["tail"], "prompt": params["prompt"]})
    opt_state = jax.vmap(opt.init)(trainable)
    batch = {"tokens": jax.random.randint(KEY, (K, b, S), 0, cfg.vocab_size)}
    tr2, os2, loss = jax.jit(train_step)(frozen, trainable, opt_state, batch)
    assert np.isfinite(float(loss))
    # after fedavg the K client copies are identical
    for leaf in jax.tree.leaves(tr2):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                                   rtol=1e-6, atol=1e-6)


def test_fused_loss_matches_logits_loss():
    """Beyond-paper fused vocab-parallel loss == paper-faithful logits loss."""
    cfg = get_config("qwen2.5-14b").reduced(n_layers=3)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4)
    model = SplitModel(cfg, split)
    params = model.init(KEY)
    frozen = {"head": params["head"], "body": params["body"]}
    trainable = {"tail": params["tail"], "prompt": params["prompt"]}
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    l_logits = make_split_loss(model, loss_mode="logits", remat=False)(
        trainable, frozen, batch)
    l_fused = make_split_loss(model, loss_mode="fused", remat=False)(
        trainable, frozen, batch)
    # fused path computes the matmul in bf16 -> small tolerance
    assert abs(float(l_logits) - float(l_fused)) < 0.05


def test_launch_serve_steps_cpu():
    cfg = get_config("gemma2-9b").reduced(n_layers=6)  # 3 cycles of 2
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2)
    model = SplitModel(cfg, split)
    params = model.init(KEY)
    B, S = 2, 12
    prefill = make_prefill_step(model)
    decode = make_decode_step(model)
    cache = model.init_cache(B, seq_len=48)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits, cache = jax.jit(prefill)(params, {"tokens": toks}, cache)
    assert logits.shape == (B, cfg.vocab_size)
    pos = jnp.full((B,), S + split.prompt_len, jnp.int32)
    nxt, logits2, cache = jax.jit(decode)(
        params, {"tokens": jnp.argmax(logits, -1)[:, None].astype(jnp.int32),
                 "pos": pos}, cache)
    assert nxt.shape == (B,)
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_cost_model_binds_to_models():
    cfg = get_config("vit-base")
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=16,
                        prune_gamma=0.8)
    ci = cost_inputs_from(cfg, split, tokens_per_sample=197, D=1000, K=5,
                          U=10)
    s = summarize(ci)
    assert s["SFPrompt"]["comm_bytes"] < s["SFL"]["comm_bytes"]
    assert s["SFPrompt"]["client_flops"] < s["FL"]["client_flops"] * 0.01
