"""Page-allocator invariants (serve/paging.py).

Deterministic tests always run; the randomized property suite additionally
runs wherever hypothesis is installed (CI installs requirements-dev.txt).
Invariants under test:

  * alloc/free roundtrip in reverse order restores the free-list EXACTLY;
  * no page is ever owned by two slots — refcount > 1 happens only through
    `share` (shared-prefix pages);
  * a page's refcount hits zero iff the page returns to the pool;
  * exhaustion raises `PagePoolExhausted` loudly (and `alloc_many` is
    all-or-nothing) instead of aliasing a live page;
  * the reserved NULL/SCRATCH pages are never handed out and never freed.
"""
import pytest

from repro.serve.paging import PagePool, PagePoolExhausted

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SETTINGS = dict(max_examples=50, deadline=None)


# -------------------------------------------------------- deterministic
def test_reserved_pages_never_allocated():
    pool = PagePool(8, 4)
    got = [pool.alloc() for _ in range(pool.n_free)]
    assert PagePool.NULL_PAGE not in got
    assert PagePool.SCRATCH_PAGE not in got
    assert sorted(got) == list(range(PagePool.N_RESERVED, 8))


def test_alloc_free_roundtrip_restores_free_list():
    pool = PagePool(10, 4)
    before = pool.free_list()
    pages = [pool.alloc() for _ in range(5)]
    for pid in reversed(pages):
        assert pool.free(pid)          # refcount 1 -> released
    assert pool.free_list() == before


def test_exhaustion_raises_not_aliases():
    pool = PagePool(5, 4)
    got = {pool.alloc() for _ in range(3)}
    assert len(got) == 3               # 3 distinct live pages
    with pytest.raises(PagePoolExhausted):
        pool.alloc()
    assert pool.n_used == 3            # failed alloc changed nothing


def test_alloc_many_is_all_or_nothing():
    pool = PagePool(6, 4)
    pool.alloc()
    free_before = pool.free_list()
    with pytest.raises(PagePoolExhausted):
        pool.alloc_many(4)             # only 3 free
    assert pool.free_list() == free_before
    pages = pool.alloc_many(3)
    assert len(set(pages)) == 3
    assert pool.n_free == 0


def test_share_and_release_cascade():
    pool = PagePool(6, 4)
    pid = pool.alloc()
    pool.share(pid)
    pool.share(pid)
    assert pool.refcount(pid) == 3
    assert not pool.free(pid)          # two owners left
    assert not pool.free(pid)
    assert pool.refcount(pid) == 1
    assert pool.free(pid)              # last owner -> back to the pool
    assert pid in pool.free_list()
    assert pool.refcount(pid) == 0


def test_misuse_raises():
    pool = PagePool(6, 4)
    with pytest.raises(ValueError):
        pool.free(PagePool.NULL_PAGE)
    with pytest.raises(ValueError):
        pool.free(PagePool.SCRATCH_PAGE)
    with pytest.raises(ValueError):
        pool.share(4)                  # unallocated
    pid = pool.alloc()
    pool.free(pid)
    with pytest.raises(ValueError):
        pool.free(pid)                 # double free
    with pytest.raises(ValueError):
        PagePool(2, 4)                 # nothing beyond the reserved pages
    with pytest.raises(ValueError):
        PagePool(8, 0)


# ----------------------------------------------------------- properties
if HAVE_HYPOTHESIS:
    @st.composite
    def op_sequences(draw):
        """Interleaved alloc/share/free traces against a small pool."""
        n_pages = draw(st.integers(4, 24))
        ops = draw(st.lists(
            st.tuples(st.sampled_from(["alloc", "share", "free"]),
                      st.integers(0, 2 ** 30)),
            min_size=1, max_size=80))
        return n_pages, ops

    @given(op_sequences())
    @settings(**SETTINGS)
    def test_ownership_model(seq):
        """Replay a random trace against a reference ownership model: no
        page is handed out while live, refcount > 1 only via share, and
        refcount-zero <=> page is in the free list."""
        n_pages, ops = seq
        pool = PagePool(n_pages, 4)
        owners = {}                       # pid -> reference count
        for kind, pick in ops:
            live = sorted(owners)
            if kind == "alloc":
                try:
                    pid = pool.alloc()
                except PagePoolExhausted:
                    assert len(owners) == n_pages - PagePool.N_RESERVED
                    continue
                assert pid not in owners, "aliased a live page"
                assert pid >= PagePool.N_RESERVED
                owners[pid] = 1
            elif kind == "share" and live:
                pid = live[pick % len(live)]
                pool.share(pid)
                owners[pid] += 1
            elif kind == "free" and live:
                pid = live[pick % len(live)]
                released = pool.free(pid)
                owners[pid] -= 1
                assert released == (owners[pid] == 0)
                if owners[pid] == 0:
                    del owners[pid]
            # global invariants after every op
            for pid, rc in owners.items():
                assert pool.refcount(pid) == rc
            free = set(pool.free_list())
            assert free.isdisjoint(owners)
            assert len(free) + len(owners) == n_pages - PagePool.N_RESERVED

    @given(st.integers(4, 32), st.integers(1, 16))
    @settings(**SETTINGS)
    def test_lifo_roundtrip_exact(n_pages, n_take):
        """Allocating k pages and freeing them in reverse order restores
        the free list EXACTLY (LIFO), for any k up to the pool size."""
        pool = PagePool(n_pages, 8)
        k = min(n_take, pool.n_free)
        before = pool.free_list()
        pages = [pool.alloc() for _ in range(k)]
        for pid in reversed(pages):
            assert pool.free(pid)
        assert pool.free_list() == before
