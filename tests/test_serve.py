"""Serving-engine correctness: continuous batching is logit-equivalent to
sequential decoding, tenants are isolated, and the metered wire traffic
matches the analytical per-token model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SplitConfig, SplitModel
from repro.core.comm import serve_comm_breakdown
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.runtime import WireSpec
from repro.serve import (Request, ServeConfig, ServeEngine, TenantBank,
                         WorkloadConfig, synthetic_requests)

KEY = jax.random.PRNGKey(0)
MAX_SEQ = 48
PROMPT_LEN = 4


def build_model(wire="fp32"):
    cfg = get_config("qwen2.5-14b").reduced(
        n_layers=3, d_model=64, d_ff=128, vocab_size=128)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=PROMPT_LEN)
    return cfg, SplitModel(cfg, split, WireSpec.make(wire))


def make_bank(model, params, n_tenants=3, jitter=0.2):
    """Distinct per-tenant (tail, prompt) so cross-tenant leakage would
    actually change logits."""
    tails, prompts = [], []
    for t in range(n_tenants):
        key = jax.random.fold_in(jax.random.PRNGKey(7), t)
        leaves, treedef = jax.tree.flatten(params["tail"])
        ks = jax.random.split(key, len(leaves) + 1)
        tails.append(jax.tree.unflatten(treedef, [
            x + jitter * jax.random.normal(k, x.shape, x.dtype)
            for x, k in zip(leaves, ks[:-1])]))
        prompts.append(params["prompt"] + jitter * jax.random.normal(
            ks[-1], params["prompt"].shape))
    return TenantBank.from_lists(tails, prompts)


@pytest.fixture(scope="module")
def setup():
    cfg, model = build_model()
    params = model.init(KEY)
    bank = make_bank(model, params)
    return cfg, model, params, bank


def sequential_reference(cfg, model, params, bank, req):
    """Per-request batch=1 prefill + decode with the request's tenant
    (tail, prompt) — the no-batching ground truth, fp32 activations."""
    p = {"head": params["head"], "body": params["body"],
         "tail": bank.tail(req.tenant), "prompt": bank.prompt(req.tenant)}
    prefill = jax.jit(make_prefill_step(model, dtype=jnp.float32))
    decode = jax.jit(make_decode_step(model, dtype=jnp.float32))
    cache = model.init_cache(1, seq_len=MAX_SEQ)
    logits, cache = prefill(p, {"tokens": jnp.asarray(req.tokens)[None]},
                            cache)
    toks = [int(jnp.argmax(logits[0]))]
    outs = [np.asarray(logits[0], np.float32)]
    pos0 = len(req.tokens) + PROMPT_LEN
    for i in range(req.max_new - 1):
        tok = jnp.asarray([[toks[-1]]], jnp.int32)
        posi = jnp.asarray([pos0 + i], jnp.int32)
        _, logits, cache = decode(p, {"tokens": tok, "pos": posi}, cache)
        toks.append(int(jnp.argmax(logits[0])))
        outs.append(np.asarray(logits[0], np.float32))
    return np.asarray(toks, np.int32), np.stack(outs)


REQS = [
    Request(rid=0, tenant=0, tokens=np.arange(9, dtype=np.int32) % 128,
            max_new=5, arrival=0),
    Request(rid=1, tenant=1, tokens=(np.arange(14, dtype=np.int32) * 3)
            % 128, max_new=4, arrival=0),
    Request(rid=2, tenant=2, tokens=(np.arange(6, dtype=np.int32) * 7)
            % 128, max_new=6, arrival=2),
    Request(rid=3, tenant=1, tokens=(np.arange(11, dtype=np.int32) * 5)
            % 128, max_new=3, arrival=3),
]


def test_batched_continuous_matches_sequential(setup):
    """4 requests, 2 slots: queueing + mid-flight joins + slot reuse.
    Every request's greedy tokens AND per-step logits equal its standalone
    sequential decode at fp32."""
    cfg, model, params, bank = setup
    engine = ServeEngine(model, params, bank,
                         ServeConfig(n_slots=2, max_seq=MAX_SEQ),
                         collect_logits=True)
    stats = engine.run(REQS)
    assert stats["n_finished"] == len(REQS)
    by_rid = {f.req.rid: f for f in stats["finished"]}
    for req in REQS:
        want_toks, want_logits = sequential_reference(
            cfg, model, params, bank, req)
        got = by_rid[req.rid]
        np.testing.assert_array_equal(got.tokens, want_toks,
                                      err_msg=f"rid={req.rid}")
        np.testing.assert_allclose(got.logits, want_logits,
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"rid={req.rid}")


def test_tenant_isolation_mid_batch_join(setup):
    """Tenant A's outputs are bit-identical whether or not tenant B's
    request joins the batch mid-flight."""
    cfg, model, params, bank = setup
    a = Request(rid=0, tenant=0,
                tokens=np.arange(8, dtype=np.int32), max_new=6, arrival=0)
    b = Request(rid=1, tenant=2,
                tokens=(np.arange(12, dtype=np.int32) * 11) % 128,
                max_new=4, arrival=2)

    def run(reqs):
        eng = ServeEngine(model, params, bank,
                          ServeConfig(n_slots=2, max_seq=MAX_SEQ),
                          collect_logits=True)
        return {f.req.rid: f for f in eng.run(reqs)["finished"]}

    alone = run([a])[0]
    joined = run([a, b])[0]
    np.testing.assert_array_equal(alone.tokens, joined.tokens)
    np.testing.assert_array_equal(alone.logits, joined.logits)


@pytest.mark.parametrize("wire", ["fp32", "int8"])
def test_metered_serve_bytes_match_analytical(wire):
    """Engine-measured wire traffic vs `serve_comm_breakdown` <= 5% per
    boundary (decode bytes counted per OCCUPIED slot only)."""
    cfg, model = build_model(wire)
    params = model.init(KEY)
    bank = make_bank(model, params, n_tenants=2)
    wl = WorkloadConfig(n_requests=6, mean_interarrival=1.0,
                        prompt_choices=(6, 10), new_token_choices=(3, 5),
                        n_tenants=2, vocab_size=cfg.vocab_size, seed=3)
    reqs = synthetic_requests(wl)
    engine = ServeEngine(model, params, bank,
                         ServeConfig(n_slots=3, max_seq=MAX_SEQ))
    stats = engine.run(reqs)
    analytical = serve_comm_breakdown(
        model.wire, d_model=cfg.d_model, soft_prompt_len=PROMPT_LEN,
        requests=[(len(r.tokens), r.max_new) for r in reqs])
    for name, ref in analytical.items():
        got = stats["wire_bytes"][name]
        assert ref > 0
        assert abs(got - ref) / ref <= 0.05, (name, got, ref)
    assert stats["wire_per_token"]["total"] == pytest.approx(
        stats["wire_bytes"]["total"] / stats["tokens_out"])


def test_reset_stats_replays_trace_identically(setup):
    """reset_stats() lets one warm engine re-serve a trace from step 0
    with clean counters — same schedule, same tokens, same meter."""
    cfg, model, params, bank = setup
    engine = ServeEngine(model, params, bank,
                         ServeConfig(n_slots=2, max_seq=MAX_SEQ))
    first = engine.run(REQS)
    snap1 = (engine.decode_steps, engine.tokens_out, engine.prefill_count,
             first["wire_bytes"]["total"])
    engine.reset_stats()
    assert engine.decode_steps == 0 and engine.tokens_out == 0
    second = engine.run(REQS)
    snap2 = (engine.decode_steps, engine.tokens_out, engine.prefill_count,
             second["wire_bytes"]["total"])
    assert snap1 == snap2
    toks1 = {f.req.rid: f.tokens.tolist() for f in first["finished"]}
    toks2 = {f.req.rid: f.tokens.tolist() for f in second["finished"]}
    assert toks1 == toks2
    # guard: resetting mid-flight is an error
    engine.submit(REQS[0])
    engine.step()
    with pytest.raises(RuntimeError):
        engine.reset_stats()


def test_slot_cache_write_read_roundtrip(setup):
    cfg, model, params, bank = setup
    shared = model.init_cache(3, seq_len=16)
    single = jax.tree.map(
        lambda x: jnp.full_like(x, 3.0) if jnp.issubdtype(
            x.dtype, jnp.floating) else jnp.full_like(x, 3),
        model.blank_slot_cache(16))
    written = model.cache_write_slot(shared, single, jnp.int32(1))
    back = model.cache_read_slot(written, jnp.int32(1))
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(single)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the other slots are untouched
    other = model.cache_read_slot(written, jnp.int32(0))
    for a, b in zip(jax.tree.leaves(other),
                    jax.tree.leaves(model.cache_read_slot(shared, 0))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_admission_control_and_validation(setup):
    cfg, model, params, bank = setup
    engine = ServeEngine(model, params, bank,
                         ServeConfig(n_slots=1, max_seq=MAX_SEQ,
                                     max_queue=2))
    mk = lambda rid: Request(rid=rid, tenant=0,
                             tokens=np.arange(4, dtype=np.int32),
                             max_new=2, arrival=0)
    assert engine.submit(mk(0)) and engine.submit(mk(1))
    assert not engine.submit(mk(2))          # queue full -> rejected
    assert engine.rejected == 1
    with pytest.raises(ValueError):          # window overflow
        engine.submit(Request(rid=9, tenant=0,
                              tokens=np.zeros(MAX_SEQ, np.int32),
                              max_new=8, arrival=0))
    with pytest.raises(ValueError):          # unknown tenant
        engine.submit(Request(rid=10, tenant=99,
                              tokens=np.arange(4, dtype=np.int32),
                              max_new=2, arrival=0))


def test_workload_is_pure_function_of_seed():
    wl = WorkloadConfig(n_requests=12, seed=5)
    a, b = synthetic_requests(wl), synthetic_requests(wl)
    assert [(r.arrival, r.tenant, r.max_new) for r in a] == \
           [(r.arrival, r.tenant, r.max_new) for r in b]
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.tokens, rb.tokens)
    c = synthetic_requests(WorkloadConfig(n_requests=12, seed=6))
    assert any(not np.array_equal(ra.tokens, rc.tokens)
               for ra, rc in zip(a, c))


def test_engine_rejects_non_token_archs():
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=64, d_ff=128)
    model = SplitModel(cfg, SplitConfig(head_cycles=1, tail_cycles=1,
                                        prompt_len=4))
    params = model.init(KEY)
    with pytest.raises(ValueError):
        ServeEngine(model, params,
                    TenantBank.replicate(params["tail"], params["prompt"],
                                         2),
                    ServeConfig(n_slots=2, max_seq=32))
