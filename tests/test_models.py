"""Per-arch smoke tests (assignment requirement): a REDUCED same-family
variant of each of the 10 assigned architectures runs one forward/train step
on CPU with correct output shapes and no NaNs; decode against the KV cache
matches the full-sequence forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.core import losses
from repro.models.transformer import Transformer
from repro.optim import apply_updates, sgd

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=24, with_labels=False, seed=0):
    k = jax.random.PRNGKey(seed)
    if cfg.arch_type == "vit":
        b = {"patches": 0.1 * jax.random.normal(k, (B, 16, 16 * 16 * 3))}
        if with_labels:
            b["labels"] = jax.random.randint(k, (B,), 0, cfg.num_classes)
        return b
    b = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.arch_type == "vlm":
        b["patch_embeds"] = 0.02 * jax.random.normal(k, (B, 8, cfg.d_model))
        grid = jnp.stack(jnp.meshgrid(jnp.arange(2), jnp.arange(2),
                                      jnp.arange(2), indexing="ij"))
        b["mrope_positions"] = jnp.broadcast_to(
            grid.reshape(3, 8)[None], (B, 3, 8)).astype(jnp.int32)
    if cfg.arch_type == "audio":
        b["frames"] = 0.02 * jax.random.normal(
            k, (B, cfg.encoder.n_frames, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_smoke_forward(arch):
    cfg = get_config(arch).reduced()
    model = Transformer(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)
    out = model.apply(params, batch, mode="train")
    B = 2
    T = out["logits"].shape[1]
    assert out["logits"].shape[0] == B
    assert out["logits"].shape[-1] == (cfg.num_classes or cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out["logits"])))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_smoke_train_step(arch):
    """One SGD step decreases (or at least computes) the LM/classifier loss
    with finite grads."""
    cfg = get_config(arch).reduced()
    model = Transformer(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg, with_labels=True)
    opt = sgd(1e-2)

    def loss_fn(p):
        out = model.apply(p, batch, mode="train")
        loss, _ = losses.task_loss(cfg, out, batch, impl="ref")
        return loss

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss0))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    updates, _ = opt.update(grads, opt.init(params), params)
    loss1 = loss_fn(apply_updates(params, updates))
    assert bool(jnp.isfinite(loss1))


@pytest.mark.parametrize("arch", [a for a in ASSIGNED])
def test_decode_matches_full(arch):
    cfg = get_config(arch).reduced()
    if cfg.arch_type == "vit":
        pytest.skip("classifier: no decode")
    model = Transformer(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    bf = {"tokens": toks}
    bp = {"tokens": toks[:, :S]}
    extra = 0
    if cfg.arch_type == "vlm":
        pe = 0.02 * jax.random.normal(KEY, (B, 8, cfg.d_model))
        bf["patch_embeds"] = pe
        bp["patch_embeds"] = pe
        extra = 8
    if cfg.arch_type == "audio":
        fr = 0.02 * jax.random.normal(KEY, (B, cfg.encoder.n_frames,
                                            cfg.d_model))
        bf["frames"] = fr
        bp["frames"] = fr
    full = model.apply(params, bf, mode="train")["logits"]
    cache = model.init_cache(B, seq_len=64)
    pre = model.apply(params, bp, mode="prefill", cache=cache)
    dec = model.apply(params, {"tokens": toks[:, S:S + 1],
                               "pos": jnp.full((B,), S + extra, jnp.int32)},
                      mode="decode", cache=pre["cache"])
    np.testing.assert_allclose(
        np.asarray(dec["logits"][:, 0]), np.asarray(full[:, -1]),
        rtol=2e-4, atol=2e-4)


def test_ring_buffer_decode_matches_full_window():
    """gemma2 sliding-window decode with a ring-buffer cache smaller than
    the sequence == full-cache decode (the window hides the difference)."""
    cfg = get_config("gemma2-9b").reduced()
    model = Transformer(cfg)
    params = model.init(KEY)
    B, S = 1, 40
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    full_cache = model.init_cache(B, seq_len=S + 1)
    ring_cache = model.init_cache(B, seq_len=S + 1,
                                  window=cfg.attention.sliding_window)
    outs = []
    for cache in (full_cache, ring_cache):
        pre = model.apply(params, {"tokens": toks[:, :S]}, mode="prefill",
                          cache=cache)
        dec = model.apply(params, {"tokens": toks[:, S:S + 1],
                                   "pos": jnp.full((B,), S, jnp.int32)},
                          mode="decode", cache=pre["cache"])
        outs.append(np.asarray(dec["logits"][:, 0]))
    # local layers see identical windows; global layers differ only beyond
    # the ring window — with S < window they are identical too
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)


def test_multi_step_decode_consistency():
    """Greedy 4-step decode == teacher-forced full forward (stablelm)."""
    cfg = get_config("stablelm-12b").reduced()
    model = Transformer(cfg)
    params = model.init(KEY)
    B, S, n_new = 1, 12, 4
    toks = jax.random.randint(KEY, (B, S + n_new), 0, cfg.vocab_size)
    full = model.apply(params, {"tokens": toks}, mode="train")["logits"]
    cache = model.init_cache(B, seq_len=S + n_new)
    pre = model.apply(params, {"tokens": toks[:, :S]}, mode="prefill",
                      cache=cache)
    cache = pre["cache"]
    for i in range(n_new):
        out = model.apply(params, {"tokens": toks[:, S + i:S + i + 1],
                                   "pos": jnp.full((B,), S + i, jnp.int32)},
                          mode="decode", cache=cache)
        cache = out["cache"]
        np.testing.assert_allclose(np.asarray(out["logits"][:, 0]),
                                   np.asarray(full[:, S + i - 1 + 1]),
                                   rtol=2e-4, atol=2e-4)


def test_zamba2_weight_sharing():
    """The shared attention block is one parameter set used at every site."""
    cfg = get_config("zamba2-2.7b").reduced()
    model = Transformer(cfg)
    params = model.init(KEY)
    assert "shared_attn" in params
    # shared positions carry no per-layer weights of their own
    for i, kind in enumerate(cfg.layer_pattern):
        if kind == "shared_attn":
            assert f"pos{i}" not in params["cycle"]


def test_param_count_sane():
    """Analytic param_count is within 2% of the actual initialized count
    for every full-size assigned config (drives the Table-1 cost model)."""
    for arch in ASSIGNED:
        cfg = get_config(arch)
        model = Transformer(cfg)
        shapes = jax.eval_shape(model.init, KEY)
        actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.02, \
            (arch, actual, analytic)
