"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes, plus the loop-free analysis variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.el2n.ops import el2n_scores
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mamba2_scan.ops import mamba2_scan
from repro.kernels.rwkv6_scan.ops import rwkv6_scan

K = jax.random.PRNGKey


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (1, 128, 4, 4, 32),      # MHA
    (2, 192, 8, 2, 64),      # GQA 4x
    (1, 96, 4, 1, 32),       # MQA, non-multiple-of-block seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, S, Hq, Hkv, D, dtype):
    q = jax.random.normal(K(0), (B, S, Hq, D), dtype)
    k = jax.random.normal(K(1), (B, S, Hkv, D), dtype)
    v = jax.random.normal(K(2), (B, S, Hkv, D), dtype)
    ref = flash_attention(q, k, v, impl="ref")
    out = flash_attention(q, k, v, impl="interpret", block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("kw", [
    dict(sliding_window=50),
    dict(softcap=30.0),
    dict(causal=False),
    dict(sliding_window=33, softcap=10.0),
])
def test_flash_attention_variants(kw):
    B, S, Hq, Hkv, D = 2, 160, 4, 2, 32
    q = jax.random.normal(K(0), (B, S, Hq, D))
    k = jax.random.normal(K(1), (B, S, Hkv, D))
    v = jax.random.normal(K(2), (B, S, Hkv, D))
    ref = flash_attention(q, k, v, impl="ref", **kw)
    pallas = flash_attention(q, k, v, impl="interpret", block_q=64,
                             block_kv=64, **kw)
    blocked = flash_attention(q, k, v, impl="blocked", **kw)
    np.testing.assert_allclose(np.asarray(pallas), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_decode_ring_buffer():
    """Ref path with explicit kv positions = ring-buffer decode semantics."""
    B, W, Hq, D = 2, 32, 2, 16
    q = jax.random.normal(K(0), (B, 1, Hq, D))
    k = jax.random.normal(K(1), (B, W, Hq, D))
    v = jax.random.normal(K(2), (B, W, Hq, D))
    # slots hold positions 40-71 in ring order; query at 71
    pos = (40 + (jnp.arange(W) + 8) % W)[None, :].repeat(B, 0)
    out = flash_attention(q, k, v, q_offset=jnp.full((B,), 71),
                          kv_positions=pos)
    # equivalent: sort kv by position, plain causal
    order = jnp.argsort(pos[0])
    out2 = flash_attention(q, k[:, order], v[:, order],
                           q_offset=jnp.full((B,), 71),
                           kv_positions=pos[:, order])
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------------------------- el2n
@pytest.mark.parametrize("N,V", [(4, 64), (32, 1000), (64, 4096), (16, 33000)])
def test_el2n_kernel(N, V):
    logits = jax.random.normal(K(0), (N, V)) * 4
    labels = jax.random.randint(K(1), (N,), 0, V)
    r_e, r_c = el2n_scores(logits, labels, impl="ref")
    k_e, k_c = el2n_scores(logits, labels, impl="interpret", block_v=512)
    np.testing.assert_allclose(np.asarray(k_e), np.asarray(r_e), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(k_c), np.asarray(r_c), rtol=1e-5,
                               atol=1e-4)


def test_el2n_matches_naive():
    N, V = 16, 300
    logits = jax.random.normal(K(0), (N, V)) * 3
    labels = jax.random.randint(K(1), (N,), 0, V)
    el2n, ce = el2n_scores(logits, labels, impl="ref")
    probs = jax.nn.softmax(logits, -1)
    onehot = jax.nn.one_hot(labels, V)
    naive = jnp.linalg.norm(probs - onehot, axis=-1)
    np.testing.assert_allclose(np.asarray(el2n), np.asarray(naive),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------- rwkv6
@pytest.mark.parametrize("T,chunk", [(64, 16), (100, 32), (128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_kernel(T, chunk, dtype):
    B, H, Kd, V = 2, 2, 16, 16
    r = jax.random.normal(K(0), (B, T, H, Kd), dtype)
    k = jax.random.normal(K(1), (B, T, H, Kd), dtype)
    v = jax.random.normal(K(2), (B, T, H, V), dtype)
    w = -jnp.exp(jax.random.normal(K(3), (B, T, H, Kd))).astype(dtype)
    u = jax.random.normal(K(4), (H, Kd), dtype)
    s0 = jax.random.normal(K(5), (B, H, Kd, V))
    y_ref, f_ref = rwkv6_scan(r, k, v, w, u, s0, impl="ref")
    y_pal, f_pal = rwkv6_scan(r, k, v, w, u, s0, impl="interpret",
                              chunk=chunk)
    y_chk, f_chk = rwkv6_scan(r, k, v, w, u, s0, impl="chunked", chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(f_pal), np.asarray(f_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(y_chk, np.float32),
                               np.asarray(y_ref, np.float32), **_tol(dtype))


def test_rwkv6_strong_decay_stable():
    """Chunked form must not overflow for strong decays (the instability
    that rules out the naive factorized GLA form)."""
    B, T, H, Kd, V = 1, 96, 1, 8, 8
    r = jax.random.normal(K(0), (B, T, H, Kd))
    k = jax.random.normal(K(1), (B, T, H, Kd))
    v = jax.random.normal(K(2), (B, T, H, V))
    w = jnp.full((B, T, H, Kd), -12.0)  # decay ~ e^-12 per step
    u = jax.random.normal(K(4), (H, Kd))
    y_ref, _ = rwkv6_scan(r, k, v, w, u, impl="ref")
    y_chk, _ = rwkv6_scan(r, k, v, w, u, impl="chunked", chunk=32)
    assert bool(jnp.all(jnp.isfinite(y_chk)))
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- mamba2
@pytest.mark.parametrize("T,G,chunk", [(64, 1, 16), (100, 2, 32),
                                       (128, 4, 64)])
def test_mamba2_kernel(T, G, chunk):
    B, H, P, N = 2, 4, 16, 8
    x = jax.random.normal(K(0), (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(K(1), (B, T, H)))
    A = -jnp.exp(jax.random.normal(K(2), (H,)))
    Bm = jax.random.normal(K(3), (B, T, G, N))
    Cm = jax.random.normal(K(4), (B, T, G, N))
    h0 = jax.random.normal(K(5), (B, H, P, N))
    y_ref, f_ref = mamba2_scan(x, dt, A, Bm, Cm, h0, impl="ref")
    y_pal, f_pal = mamba2_scan(x, dt, A, Bm, Cm, h0, impl="interpret",
                               chunk=chunk)
    y_chk, f_chk = mamba2_scan(x, dt, A, Bm, Cm, h0, impl="chunked",
                               chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(f_pal), np.asarray(f_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(f_chk), np.asarray(f_ref),
                               rtol=1e-3, atol=1e-3)


def test_mamba2_streaming_equals_full():
    """Processing in two halves with carried state == one pass."""
    B, T, H, P, G, N = 1, 64, 2, 8, 1, 8
    x = jax.random.normal(K(0), (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(K(1), (B, T, H)))
    A = -jnp.exp(jax.random.normal(K(2), (H,)))
    Bm = jax.random.normal(K(3), (B, T, G, N))
    Cm = jax.random.normal(K(4), (B, T, G, N))
    y_full, f_full = mamba2_scan(x, dt, A, Bm, Cm, impl="ref")
    y1, h = mamba2_scan(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32],
                        impl="ref")
    y2, f2 = mamba2_scan(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:],
                         h, impl="ref")
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f_full),
                               rtol=1e-5, atol=1e-5)
