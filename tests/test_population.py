"""Population-scale federated rounds: sampler determinism, straggler
scheduling, partial-participation FedAvg, partial-cohort wire metering vs
the analytical model, and byte-identical kill-and-restart resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.core.aggregation import fedavg_partial
from repro.core.comm import (crosscheck, measured_cost_inputs,
                             sfprompt_comm_breakdown_partial)
from repro.data import DATASETS, synthetic_image_dataset
from repro.fed import (ClientSampler, FederatedEngine, Population,
                       RoundScheduler, StragglerConfig)
from repro.runtime import WireSpec

KEY = jax.random.PRNGKey(0)
N_CLIENTS = 1000
N_LOCAL = 8
BATCH = 4


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=32, d_ff=64)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2,
                        prune_gamma=0.3, local_epochs=1)
    data = synthetic_image_dataset(DATASETS["cifar10-syn"],
                                   N_CLIENTS * N_LOCAL, seed=0, image_hw=32)
    pop = Population.from_partition(data, N_CLIENTS, scheme="dirichlet",
                                    alpha=0.1, seed=0)
    return cfg, split, data, pop


def make_trainer(cfg, split, *, codec="fp32", k=4):
    model = SplitModel(cfg, split, WireSpec.make(codec))
    pcfg = ProtocolConfig(clients_per_round=k, local_epochs=1,
                          batch_size=BATCH, momentum=0.0)
    return SFPromptTrainer(model, pcfg)


# --------------------------------------------------------------- sampler
def test_sampler_determinism():
    for kind, w in (("uniform", None), ("round_robin", None),
                    ("weighted", np.arange(1.0, 101.0))):
        s = ClientSampler(100, 8, kind=kind, seed=5, weights=w)
        for r in (0, 3, 17):
            a, b = s.sample(r), s.sample(r)
            np.testing.assert_array_equal(a, b)
            assert len(set(a.tolist())) == 8      # without replacement
        assert not np.array_equal(s.sample(0), s.sample(1))


def test_sampler_round_robin_covers_population():
    s = ClientSampler(40, 8, kind="round_robin", seed=1)
    seen = set()
    for r in range(5):   # 5 * 8 == 40
        seen.update(s.sample(r).tolist())
    assert seen == set(range(40))


def test_weighted_sampler_skips_zero_weight_clients():
    w = np.ones(50)
    w[:25] = 0.0
    s = ClientSampler(50, 10, kind="weighted", seed=2, weights=w)
    for r in range(10):
        assert s.sample(r).min() >= 25


def test_sampler_state_roundtrip():
    a = ClientSampler(100, 8, kind="round_robin", seed=5)
    b = ClientSampler(100, 8, kind="round_robin", seed=999)
    b.load_state_dict(a.state_dict())
    for r in range(4):
        np.testing.assert_array_equal(a.sample(r), b.sample(r))
    with pytest.raises(ValueError):
        ClientSampler(100, 4, kind="uniform", seed=0).load_state_dict(
            a.state_dict())   # K mismatch must be loud


# ----------------------------------------------------------- aggregation
def test_fedavg_partial_weights():
    trees = {"w": jnp.stack([1.0 * jnp.ones(3), 3.0 * jnp.ones(3),
                             5.0 * jnp.ones(3)])}
    fallback = {"w": jnp.full((3,), -7.0)}
    # client 1 dropped: mean of {1 (w=2), 5 (w=2)} = 3
    out = fedavg_partial(trees, jnp.array([2.0, 0.0, 2.0]), fallback)
    np.testing.assert_allclose(np.asarray(out["w"]), 3.0 * np.ones(3))
    # unequal weights renormalize over survivors
    out = fedavg_partial(trees, jnp.array([1.0, 0.0, 3.0]), fallback)
    np.testing.assert_allclose(np.asarray(out["w"]), 4.0 * np.ones(3))
    # everyone dropped -> the round is lost, fallback returned
    out = fedavg_partial(trees, jnp.zeros(3), fallback)
    np.testing.assert_allclose(np.asarray(out["w"]), -7.0 * np.ones(3))


# -------------------------------------------------------------- scheduler
def test_scheduler_deterministic_and_bounded():
    sched = RoundScheduler(StragglerConfig(dropout_rate=0.4), seed=9)
    cohort = np.arange(16)
    a, b = sched.plan(cohort, 3), sched.plan(cohort, 3)
    np.testing.assert_array_equal(a.transmit, b.transmit)
    np.testing.assert_array_equal(a.aggregate, b.aggregate)
    assert (a.transmit >= 0).all() and (a.transmit <= 1).all()
    assert (a.aggregate >= 0).all()
    # dropped clients never aggregate; on-time clients fully transmit
    assert (a.aggregate[a.dropped] == 0).all()
    ontime = ~(a.dropped | a.late)
    assert (a.transmit[ontime] == 1).all()
    assert not np.array_equal(sched.plan(cohort, 4).dropped, a.dropped) or \
        not np.allclose(sched.plan(cohort, 4).latency_s, a.latency_s)


def test_scheduler_min_survivors():
    sched = RoundScheduler(StragglerConfig(dropout_rate=1.0,
                                           min_survivors=2), seed=0)
    plan = sched.plan(np.arange(8), 0)
    assert plan.n_active >= 2


def test_scheduler_partial_mode():
    cfg = StragglerConfig(deadline_factor=1.01, late_mode="partial",
                          partial_weight=0.25, speed_sigma=0.8)
    plan = RoundScheduler(cfg, seed=4).plan(np.arange(32), 0)
    assert plan.late.any()   # tight deadline + wide spread => stragglers
    # partial mode: late clients transmitted everything, aggregate reduced
    assert (plan.transmit[plan.late] == 1).all()
    assert (plan.aggregate[plan.late] == 0.25).all()


def test_scheduler_persistent_client_factors():
    sched = RoundScheduler(StragglerConfig(), seed=3)
    ids = np.array([5, 900, 31])
    link1, comp1 = sched.client_factors(ids)
    link2, comp2 = sched.client_factors(ids)
    np.testing.assert_allclose(link1, link2)
    np.testing.assert_allclose(comp1, comp2)
    assert not np.allclose(link1, comp1)   # independent draws


def test_scheduler_regime_changes_who_straggles():
    """LINK_REGIMES must be behavioral: on edge_wan the slow-LINK clients
    miss the deadline, in a datacenter the slow-COMPUTE ones do — the late
    sets and latencies differ across regimes for the same cohort."""
    cohort = np.arange(32)
    plans = {}
    for regime in ("edge_wan", "datacenter"):
        sched = RoundScheduler(
            StragglerConfig(regime=regime, deadline_factor=1.3), seed=3,
            round_bytes_per_client=2e6, round_flops_per_client=5e12)
        plans[regime] = sched.plan(cohort, 0)
    assert plans["edge_wan"].late.any()
    assert not np.array_equal(plans["edge_wan"].late,
                              plans["datacenter"].late)
    # absolute latencies scale with the link: edge_wan is slower overall
    assert (np.median(plans["edge_wan"].latency_s)
            > np.median(plans["datacenter"].latency_s))


def test_meter_per_client_round():
    from repro.runtime import TrafficMeter
    m = TrafficMeter()
    m.absorb({"head_body": 300.0, "params": 600.0}, clients=3)
    m.absorb({"head_body": 100.0, "params": 200.0}, clients=1)
    assert m.client_rounds == 4
    per = m.per_client_round()
    assert per["head_body"] == 100.0 and per["total"] == 300.0
    assert "active client-rounds" in m.report()


def test_sampler_streams_disjoint_from_scheduler():
    """Cohort draws and straggler draws must come from different RNG
    domains: SeedSequence drops trailing zeros, so an untagged sampler
    stream at round 7 would equal the scheduler's client-0 factor stream."""
    s = ClientSampler(1000, 8, kind="uniform", seed=0)
    for collision_word in (7, 11):   # scheduler domain tags
        untagged = np.random.default_rng(
            np.random.SeedSequence((0, collision_word)))
        assert not np.array_equal(
            s.sample(collision_word),
            np.asarray(untagged.choice(1000, size=8, replace=False),
                       dtype=np.int64))


# -------------------------------------------------------------- population
def test_population_gather_layout(setup):
    _, _, data, pop = setup
    assert pop.n_clients == N_CLIENTS
    cohort = [0, 500, 999]
    stacked = pop.gather(cohort)
    assert stacked["patches"].shape[:2] == (3, pop.n_local)
    # gathered rows really are that client's shard
    np.testing.assert_array_equal(
        stacked["labels"][1], data["labels"][pop.client_indices[500]])
    # alpha=0.1 Dirichlet: per-client label marginals are skewed
    fracs = []
    for cid in range(0, N_CLIENTS, 50):
        lbl = data["labels"][pop.client_indices[cid]]
        _, counts = np.unique(lbl, return_counts=True)
        fracs.append(counts.max() / counts.sum())
    assert np.mean(fracs) > 0.35


def test_population_participation_state(setup):
    _, _, _, pop_ref = setup
    pop = Population(pop_ref.data, pop_ref.client_indices, pop_ref.sizes)
    pop.record_participation([3, 7], 0)
    pop.record_participation([7], 1)
    assert pop.times_sampled[7] == 2 and pop.times_sampled[3] == 1
    assert pop.last_round[7] == 1 and pop.last_round[3] == 0
    state = pop.state_dict()
    pop2 = Population(pop_ref.data, pop_ref.client_indices, pop_ref.sizes)
    pop2.load_state_dict(state)
    np.testing.assert_array_equal(pop.times_sampled, pop2.times_sampled)
    # a DIFFERENT partition must refuse the state — resuming against
    # rebuilt-with-other-flags data silently diverges otherwise
    other = Population.from_partition(pop_ref.data, N_CLIENTS,
                                      scheme="iid", seed=1)
    with pytest.raises(ValueError, match="population mismatch"):
        other.load_state_dict(state)


# ------------------------------------------- cohort training + comm check
@pytest.mark.parametrize("k", [5, 32])
def test_population_cohort_comm_matches_analytical(setup, k):
    """A >=1000-client population trains via a sampled K-cohort with
    dropouts; the TrafficMeter's partial-cohort bytes match the analytical
    model within 5% (the comm_cost.py --check contract, now under
    stragglers)."""
    cfg, split, _, pop = setup
    tr = make_trainer(cfg, split, codec="int8", k=k)
    sampler = ClientSampler(pop.n_clients, k, kind="uniform", seed=11)
    sched = RoundScheduler(StragglerConfig(dropout_rate=0.3), seed=11)
    engine = FederatedEngine(tr, pop, sampler, sched)
    engine.init(KEY)
    plan, metrics = engine.run_round()
    # these seeds genuinely straggle (K=5: 1 dropped; K=32: 9 dropped,
    # 3 late) — the check below is a PARTIAL-cohort crosscheck, not the
    # synchronous one
    assert plan.n_active < k

    n_tokens = 1 + (32 // 16) ** 2
    ci = measured_cost_inputs(tr.model, tokens_per_sample=n_tokens,
                              n_local=N_LOCAL, batch_size=BATCH, K=k)
    analytical = sfprompt_comm_breakdown_partial(
        ci, transmit_sum=float(plan.transmit.sum()),
        n_uploads=plan.n_active, k_down=k)
    cc = crosscheck(tr.meter.totals, ci, analytical)
    assert set(cc) == {"head_body", "body_tail", "params"}
    for name, entry in cc.items():
        assert abs(entry["err_pct"]) <= 5.0, (name, entry)
    # dropped stragglers really removed traffic vs the synchronous round
    if plan.n_active < k:
        sync = sfprompt_comm_breakdown_partial(
            ci, transmit_sum=k, n_uploads=k, k_down=k)
        assert tr.meter.totals["params"] < sync["params"]


# ------------------------------------------------------------------ resume
def test_resume_is_byte_identical(setup, tmp_path):
    """Kill-and-restart: run rounds 0-1, checkpoint, restore in a FRESH
    engine/trainer, run round 2 — params, meter totals, and sampled cohorts
    must be byte-identical to the uninterrupted 3-round run."""
    cfg, split, data, _ = setup

    def build():
        pop = Population.from_partition(data, N_CLIENTS, scheme="dirichlet",
                                        alpha=0.1, seed=0)
        tr = make_trainer(cfg, split, k=4)
        sampler = ClientSampler(pop.n_clients, 4, kind="weighted", seed=7,
                                weights=pop.sizes.astype(float))
        sched = RoundScheduler(
            StragglerConfig(dropout_rate=0.25, late_mode="partial"), seed=7)
        return FederatedEngine(tr, pop, sampler, sched)

    # uninterrupted reference: 3 rounds
    ref = build()
    ref.init(KEY)
    for _ in range(3):
        ref.run_round()

    # interrupted run: 2 rounds, checkpoint, die
    eng = build()
    eng.init(KEY)
    for _ in range(2):
        eng.run_round()
    ckpt_dir = str(tmp_path / "ckpt")
    eng.save(ckpt_dir)

    # fresh process stand-in: new trainer, new population, restore, 1 round
    res = build()
    assert res.restore(ckpt_dir)
    assert res.round_idx == 2
    res.run_round()

    # cohort sequence identical: rounds 2 of both runs drew the same clients
    np.testing.assert_array_equal(ref.cohort_history[2],
                                  res.cohort_history[0])
    # params byte-identical
    for a, b in zip(jax.tree.leaves(ref.state["params"]),
                    jax.tree.leaves(res.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(ref.state["round"]) == int(res.state["round"]) == 3
    # meter totals identical (cumulative across the kill)
    assert ref.trainer.meter.as_dict() == res.trainer.meter.as_dict()
    assert ref.trainer.meter.rounds == res.trainer.meter.rounds
    # per-client participation state identical
    np.testing.assert_array_equal(ref.population.times_sampled,
                                  res.population.times_sampled)


def test_resume_with_changed_straggler_flags_fails_loudly(setup, tmp_path):
    """A checkpoint from one straggler config must not silently resume
    under another — the replayed plans would diverge from the
    uninterrupted run."""
    cfg, split, _, pop = setup
    tr = make_trainer(cfg, split, k=4)
    eng = FederatedEngine(
        tr, pop, ClientSampler(pop.n_clients, 4, seed=7),
        RoundScheduler(StragglerConfig(dropout_rate=0.25), seed=7))
    eng.state = tr.init(KEY)   # no training needed for the state check
    ckpt_dir = str(tmp_path / "ckpt")
    eng.save(ckpt_dir)
    other = FederatedEngine(
        make_trainer(cfg, split, k=4), pop,
        ClientSampler(pop.n_clients, 4, seed=7),
        RoundScheduler(StragglerConfig(dropout_rate=0.5), seed=7))
    with pytest.raises(ValueError, match="scheduler mismatch"):
        other.restore(ckpt_dir)
    # changed trainer hyperparameters must fail loudly too
    model_lr = SplitModel(cfg, split, WireSpec.make("fp32"))
    pcfg_lr = ProtocolConfig(clients_per_round=4, local_epochs=1,
                             batch_size=BATCH, momentum=0.0, lr_split=0.5)
    hot = FederatedEngine(
        SFPromptTrainer(model_lr, pcfg_lr), pop,
        ClientSampler(pop.n_clients, 4, seed=7),
        RoundScheduler(StragglerConfig(dropout_rate=0.25), seed=7))
    with pytest.raises(ValueError, match="trainer mismatch"):
        hot.restore(ckpt_dir)
    # a personalize_tails flip must also fail loudly, not silently diverge
    pcfg_pt = ProtocolConfig(clients_per_round=4, local_epochs=1,
                             batch_size=BATCH, momentum=0.0,
                             return_client_trainable=True)
    model_pt = SplitModel(cfg, split, WireSpec.make("fp32"))
    flipped = FederatedEngine(
        SFPromptTrainer(model_pt, pcfg_pt), pop,
        ClientSampler(pop.n_clients, 4, seed=7),
        RoundScheduler(StragglerConfig(dropout_rate=0.25), seed=7),
        personalize_tails=True)
    with pytest.raises(ValueError, match="personalize_tails mismatch"):
        flipped.restore(ckpt_dir)


def test_personalized_init_tails_enter_training(setup):
    """round(init_tails=...) really starts clients from the given tails:
    feeding the broadcast global tail reproduces the default round, a
    perturbed tail changes the aggregate."""
    cfg, split, _, pop = setup
    tr = make_trainer(cfg, split, k=2)
    state = tr.init(KEY)
    data = {k: jnp.asarray(v) for k, v in pop.gather([0, 1]).items()}
    ref_state, _ = tr.round(state, data)
    same = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (2,) + x.shape),
        state["params"]["tail"])
    same_state, _ = tr.round(state, data, None, same)
    for a, b in zip(jax.tree.leaves(ref_state["params"]["tail"]),
                    jax.tree.leaves(same_state["params"]["tail"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    bumped = jax.tree.map(lambda x: x + 0.1, same)
    diff_state, _ = tr.round(state, data, None, bumped)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(ref_state["params"]["tail"]),
                        jax.tree.leaves(diff_state["params"]["tail"])))


def test_restore_empty_dir_is_noop(setup, tmp_path):
    cfg, split, _, pop = setup
    tr = make_trainer(cfg, split, k=4)
    engine = FederatedEngine(tr, pop,
                             ClientSampler(pop.n_clients, 4, seed=0))
    assert not engine.restore(str(tmp_path / "nothing"))
    assert engine.state is None
