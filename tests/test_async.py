"""Buffered-async runtime: sync bit-equivalence at buffer == cohort,
byte-identical resume through a non-empty buffer and in-flight clients,
staleness-weight semantics, secure-agg flush cohorts under mid-flush
dropout, and the meter's wall-clock overlap accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.core.aggregation import get_aggregator
from repro.data import DATASETS, synthetic_image_dataset
from repro.fed import (AsyncConfig, AsyncRoundEngine, ClientSampler,
                       FederatedEngine, Population, RoundScheduler,
                       StragglerConfig)
from repro.fed.buffer import (BufferEntry, DeltaBuffer, StalenessLedger,
                              flush_weights, staleness_weight)
from repro.privacy.fixed_point import roundtrip_tol
from repro.runtime import WireSpec
from repro.runtime.meter import TrafficMeter

KEY = jax.random.PRNGKey(0)
N_CLIENTS = 40
N_LOCAL = 8
BATCH = 4
K = 4


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=32, d_ff=64)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2,
                        prune_gamma=0.3, local_epochs=1)
    data = synthetic_image_dataset(DATASETS["cifar10-syn"],
                                   N_CLIENTS * N_LOCAL, seed=0, image_hw=32)
    return cfg, split, data


def make_trainer(cfg, split, *, return_client=True):
    model = SplitModel(cfg, split, WireSpec.make("fp32"))
    pcfg = ProtocolConfig(clients_per_round=K, local_epochs=1,
                          batch_size=BATCH, momentum=0.0,
                          return_client_trainable=return_client)
    return SFPromptTrainer(model, pcfg)


def make_pop(data):
    return Population.from_partition(data, N_CLIENTS, scheme="dirichlet",
                                     alpha=0.1, seed=0)


def wan_sched(seed=3, dropout=0.0):
    return RoundScheduler(StragglerConfig(regime="wan", dropout_rate=dropout),
                          seed=seed, round_bytes_per_client=1e6,
                          round_flops_per_client=1e12)


def leaves_equal(a, b):
    la = jax.tree.leaves(jax.tree.map(np.asarray, a))
    lb = jax.tree.leaves(jax.tree.map(np.asarray, b))
    return len(la) == len(lb) and all(
        np.array_equal(x, y) for x, y in zip(la, lb))


# ------------------------------------------------- sync equivalence anchor
def test_async_buffer_eq_cohort_matches_sync_bitwise(setup):
    """buffer_size == K, concurrency 1, beta 0: one flush IS one sync
    round — aggregated params and every metered byte stream identical."""
    cfg, split, data = setup

    tr_s = make_trainer(cfg, split)
    sync = FederatedEngine(tr_s, make_pop(data), ClientSampler(N_CLIENTS, K,
                                                               seed=3))
    sync.init(KEY)
    sync.run_round()

    tr_a = make_trainer(cfg, split)
    eng = AsyncRoundEngine(tr_a, make_pop(data),
                           ClientSampler(N_CLIENTS, K, seed=3),
                           acfg=AsyncConfig(buffer_size=K, concurrency=1,
                                            staleness_beta=0.0))
    eng.init(KEY)
    stats = eng.run_flushes(1)

    assert stats["flushes"] == 1 and stats["arrivals"] == K
    assert stats["max_staleness"] == 0
    assert leaves_equal(sync.params, eng.params)
    sm, am = tr_s.meter.as_dict(), eng.meter.as_dict()
    assert set(sm) <= set(am)
    for k in sm:
        assert sm[k] == am[k], f"meter stream {k}: {sm[k]} != {am[k]}"


def test_async_rejects_misconfigured_trainer(setup):
    cfg, split, data = setup
    sampler = ClientSampler(N_CLIENTS, K, seed=3)
    with pytest.raises(ValueError, match="return_client_trainable"):
        AsyncRoundEngine(make_trainer(cfg, split, return_client=False),
                         make_pop(data), sampler)
    with pytest.raises(ValueError, match="CLEAR aggregator"):
        model = SplitModel(cfg, split, WireSpec.make("fp32"))
        pcfg = ProtocolConfig(clients_per_round=K, local_epochs=1,
                              batch_size=BATCH, momentum=0.0,
                              return_client_trainable=True)
        tr = SFPromptTrainer(model, pcfg,
                             get_aggregator(secure=True, seed=0))
        AsyncRoundEngine(tr, make_pop(data), sampler)
    with pytest.raises(ValueError, match="group_size"):
        AsyncRoundEngine(None, None, sampler,
                         acfg=AsyncConfig(group_size=K + 1))


# ------------------------------------------------------------------ resume
def test_resume_byte_identical_with_nonempty_buffer(setup, tmp_path):
    """Kill mid-flush (entries buffered, clients in flight), restore into
    a FRESH engine, drive both to the same flush count as an
    uninterrupted reference: params, meter, clock, ledger all identical."""
    cfg, split, data = setup
    acfg = AsyncConfig(buffer_size=3, concurrency=2, staleness_beta=0.5)

    def mk():
        return AsyncRoundEngine(make_trainer(cfg, split), make_pop(data),
                                ClientSampler(N_CLIENTS, K, seed=3),
                                wan_sched(dropout=0.2), acfg)

    ref = mk()
    ref.init(KEY)
    ref.run_flushes(4)

    a = mk()
    a.init(KEY)
    a.run_flushes(2)
    a.step_event()
    a.step_event()
    assert len(a.buffer) > 0 and len(a.in_flight) > 0
    a.save(str(tmp_path))

    b = mk()
    assert b.restore(str(tmp_path))
    assert len(b.buffer.entries) == len(a.buffer.entries)
    assert len(b.in_flight) == len(a.in_flight)
    while a.version < 4:
        a.step_event()
    while b.version < 4:
        b.step_event()

    for other in (a, b):
        assert leaves_equal(ref.params, other.params)
        assert other.t_sim == ref.t_sim
        assert other.arrivals == ref.arrivals
        rm, om = ref.meter.state_dict(), other.meter.state_dict()
        assert set(rm) == set(om)
        for k in rm:
            assert rm[k] == om[k], f"meter stream {k}"
        np.testing.assert_array_equal(ref.ledger.applied, other.ledger.applied)
    assert ref.ledger.mean_staleness() == b.ledger.mean_staleness()


def test_resume_refuses_mismatched_config(setup, tmp_path):
    cfg, split, data = setup
    a = AsyncRoundEngine(make_trainer(cfg, split), make_pop(data),
                         ClientSampler(N_CLIENTS, K, seed=3),
                         wan_sched(), AsyncConfig(buffer_size=3))
    a.init(KEY)
    a.run_flushes(1)
    a.save(str(tmp_path))
    b = AsyncRoundEngine(make_trainer(cfg, split), make_pop(data),
                         ClientSampler(N_CLIENTS, K, seed=3),
                         wan_sched(), AsyncConfig(buffer_size=4))
    with pytest.raises(ValueError, match="buffer_size"):
        b.restore(str(tmp_path))
    c = AsyncRoundEngine(None, None, ClientSampler(N_CLIENTS, K, seed=3),
                         wan_sched(), AsyncConfig(buffer_size=3))
    with pytest.raises(ValueError, match="fingerprint|clock-only"):
        c.restore(str(tmp_path))


# ---------------------------------------------------------------- staleness
def test_staleness_weight_monotone_and_normalized():
    s = np.arange(0, 8)
    w = staleness_weight(s, alpha=1.0, beta=0.5)
    assert w[0] == 1.0                       # fresh update: full weight
    assert np.all(np.diff(w) < 0)            # strictly decreasing in s
    np.testing.assert_allclose(
        staleness_weight(s, alpha=1.0, beta=0.0), np.ones_like(w))
    np.testing.assert_allclose(
        staleness_weight(s, alpha=0.25, beta=0.0), 0.25 * np.ones_like(w))
    # steeper decay never crosses a flatter one
    w2 = staleness_weight(s, alpha=1.0, beta=2.0)
    assert np.all(w2[1:] < w[1:])


def test_flush_weights_zero_dropped_and_scale_staleness():
    def entry(cid, version, *, dropped=False, size=8, keep=6):
        return BufferEntry(client_id=cid, dispatch_idx=0, position=cid,
                           version=version, arrival_t=float(cid),
                           dropped=dropped, size=size, keep=keep,
                           contribution=None)

    entries = [entry(0, 3), entry(1, 1), entry(2, 3, dropped=True)]
    w = flush_weights(entries, alpha=1.0, beta=0.5, version=3)
    assert w.dtype == np.float32
    assert w[2] == 0.0                       # dropped row contributes nothing
    # staleness 0 vs 2 at identical (size, keep): fresher weighs more
    assert w[0] > w[1] > 0.0
    np.testing.assert_allclose(
        w[1] / w[0], staleness_weight(2, alpha=1.0, beta=0.5), rtol=1e-6)


def test_buffer_full_counts_live_entries_only():
    buf = DeltaBuffer(buffer_size=2)

    def entry(cid, *, dropped):
        return BufferEntry(client_id=cid, dispatch_idx=cid, position=0,
                           version=0, arrival_t=0.0, dropped=dropped,
                           size=8, keep=6, contribution=None)

    buf.append(entry(0, dropped=True))
    buf.append(entry(1, dropped=False))
    assert not buf.full                      # one live entry out of two
    buf.append(entry(2, dropped=False))
    assert buf.full
    drained = buf.drain()
    assert [e.client_id for e in drained] == [0, 1, 2]   # dispatch order
    assert len(buf) == 0


def test_ledger_tracks_applied_staleness():
    led = StalenessLedger(4)
    led.record(0, 0)
    led.record(1, 3)
    led.record(0, 1)
    assert led.mean_staleness() == pytest.approx(4 / 3)
    assert led.max_staleness == 3
    fresh = StalenessLedger(4)
    fresh.load_state_dict(led.state_dict())
    assert fresh.mean_staleness() == led.mean_staleness()
    with pytest.raises(ValueError):
        StalenessLedger(5).load_state_dict(led.state_dict())


# ------------------------------------------------------------- secure flush
def test_secure_flush_matches_clear_under_dropout(setup):
    """The flush cohort is the secure-agg unit: with mid-flush dropouts
    (zero-weight rows exercising dangling-mask recovery) the first flush
    through the masked ring stays within fixed-point tolerance of the
    clear flush, and bills a non-zero secure stream."""
    cfg, split, data = setup
    acfg = AsyncConfig(buffer_size=3, concurrency=2, staleness_beta=0.5)

    def mk(aggregator=None):
        eng = AsyncRoundEngine(make_trainer(cfg, split), make_pop(data),
                               ClientSampler(N_CLIENTS, K, seed=3),
                               wan_sched(dropout=0.3, seed=3), acfg,
                               aggregator=aggregator)
        eng.init(KEY)
        eng.run_flushes(1)
        return eng

    clear = mk()
    secure = mk(get_aggregator(secure=True, seed=0))
    # identical clocks and cohorts — only the aggregation path differs
    assert secure.t_sim == clear.t_sim
    assert secure.arrivals == clear.arrivals
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(clear.params),
                              jax.tree.leaves(secure.params)))
    assert err <= roundtrip_tol(acfg.buffer_size)
    assert secure.meter.as_dict().get("secure", 0.0) > 0.0
    # the secure path bills its own uplink at flush time; totals must not
    # double-count the clear per-arrival billing
    assert secure.meter.as_dict()["params"] > 0.0


# ------------------------------------------------------- wall-clock streams
def test_meter_wall_overlap_accounting():
    m = TrafficMeter()
    m.absorb_wall(client_compute_s=3.0, wire_s=1.0, span_s=2.0)
    m.absorb_wall(server_busy_s=0.5, span_s=2.0)
    ov = m.overlap()
    assert ov["client_compute_s"] == pytest.approx(3.0 / 4.0)
    assert ov["wire_s"] == pytest.approx(1.0 / 4.0)
    assert ov["server_busy_s"] == pytest.approx(0.5 / 4.0)
    assert ov["parallelism"] == pytest.approx(4.5 / 4.0)
    # round-trips through state_dict, including into a pre-wall-era state
    fresh = TrafficMeter()
    fresh.load_state_dict(m.state_dict())
    assert fresh.overlap() == ov
    legacy = {k: v for k, v in m.state_dict().items()
              if not k.startswith("wall/")}
    old = TrafficMeter()
    old.load_state_dict(legacy)               # wall keys optional on load
    assert old.overlap()["parallelism"] == 0.0


def test_async_overlap_exceeds_one_with_concurrency(setup):
    """Two dispatch groups in flight must overlap work inside the span:
    the parallelism ratio exceeds 1x (the barrier's ceiling is ~1)."""
    cfg, split, data = setup
    eng = AsyncRoundEngine(None, None, ClientSampler(N_CLIENTS, 8, seed=3),
                           wan_sched(),
                           AsyncConfig(buffer_size=4, concurrency=3,
                                       group_size=4))
    eng.init(None)
    stats = eng.run_flushes(6)
    assert stats["flushes"] == 6
    assert eng.meter.overlap()["parallelism"] > 1.0
