"""Repo tooling: the benchmark-regression gate's pin-preservation
contract and the stale-docs checker."""
import importlib.util
import json
import os
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(rel):
    path = os.path.join(REPO, rel)
    name = os.path.splitext(os.path.basename(rel))[0]
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def check_regression():
    return load("benchmarks/check_regression.py")


@pytest.fixture(scope="module")
def docs_check():
    return load("tools/docs_check.py")


# ------------------------------------------------- check_regression pins
def write_json(path, payload):
    with open(path, "w") as f:
        json.dump(payload, f)


def baseline_with_pins(path):
    write_json(path, {
        "kernels": {"attn/blocked_speedup": 4.7, "decode/scan_speedup": 3.0},
        "pins": {"attn/blocked_speedup": 2.0},
        "floors": {"async_rounds/throughput_speedup": 1.5},
    })


def test_update_preserves_pins_and_floors(check_regression, tmp_path):
    baseline = str(tmp_path / "baseline.json")
    results = str(tmp_path / "results.json")
    baseline_with_pins(baseline)
    write_json(results, {"attn": {"ref_us": 10.0, "blocked_us": 2.0},
                         "async_rounds": {"throughput_speedup": 1.7}})
    assert check_regression.main(
        ["--baseline", baseline, "--results", results, "--update"]) == 0
    out = json.load(open(baseline))
    # measured section refreshed...
    assert out["kernels"]["attn/blocked_speedup"] == pytest.approx(5.0)
    assert out["kernels"]["async_rounds/throughput_speedup"] == 1.7
    # ...pins and floors byte-for-byte as committed
    assert out["pins"] == {"attn/blocked_speedup": 2.0}
    assert out["floors"] == {"async_rounds/throughput_speedup": 1.5}


def test_update_pins_refreshes_to_measured(check_regression, tmp_path):
    baseline = str(tmp_path / "baseline.json")
    results = str(tmp_path / "results.json")
    baseline_with_pins(baseline)
    write_json(results, {"attn": {"ref_us": 10.0, "blocked_us": 2.0}})
    assert check_regression.main(
        ["--baseline", baseline, "--results", results,
         "--update", "--update-pins"]) == 0
    out = json.load(open(baseline))
    # the pinned key follows this run's measurement; no new pins appear
    assert out["pins"] == {"attn/blocked_speedup": pytest.approx(5.0)}
    assert out["floors"] == {"async_rounds/throughput_speedup": 1.5}


def test_update_pins_keeps_unmeasured_pin(check_regression, tmp_path):
    baseline = str(tmp_path / "baseline.json")
    results = str(tmp_path / "results.json")
    baseline_with_pins(baseline)
    write_json(results, {"decode": {"ref_us": 9.0, "scan_us": 3.0}})
    assert check_regression.main(
        ["--baseline", baseline, "--results", results,
         "--update", "--update-pins"]) == 0
    out = json.load(open(baseline))
    # nothing measured for the pinned key this run -> prior value survives
    assert out["pins"] == {"attn/blocked_speedup": 2.0}


def test_update_pins_requires_update(check_regression, tmp_path):
    with pytest.raises(SystemExit):
        check_regression.main(["--update-pins"])


def test_pins_overlay_and_floors_gate(check_regression, tmp_path, capsys):
    baseline = str(tmp_path / "baseline.json")
    results = str(tmp_path / "results.json")
    baseline_with_pins(baseline)
    # measured 1.9x vs pinned 2.0 is within the 25% drift gate (the 4.7
    # reference measurement is overlaid by the pin), but the hard floor
    # fails the under-1.5 async speedup verbatim
    write_json(results, {"attn": {"ref_us": 19.0, "blocked_us": 10.0},
                         "async_rounds": {"throughput_speedup": 1.4}})
    rc = check_regression.main(["--baseline", baseline, "--results", results])
    out = capsys.readouterr().out
    assert rc == 1
    assert "ok   attn/blocked_speedup" in out
    assert "HARD floor" in out


# ----------------------------------------------------------- docs_check
def test_docs_check_passes_on_this_repo(docs_check):
    assert docs_check.main() == 0


def mini_repo(root):
    (root / "src" / "repro" / "launch").mkdir(parents=True)
    (root / "src" / "repro" / "launch" / "train.py").write_text(
        'ap.add_argument("--rounds", type=int)\n')
    (root / "src" / "repro" / "fed").mkdir()
    (root / "src" / "repro" / "fed" / "engine.py").write_text("")
    (root / "benchmarks").mkdir()
    (root / "benchmarks" / "run.py").write_text(
        'SUITES = {\n    "async": ("m", "d"),\n}\n'
        'ap.add_argument("--only", action="append")\n')
    (root / "docs").mkdir()


def test_docs_check_flags_every_stale_kind(docs_check, tmp_path,
                                           monkeypatch):
    mini_repo(tmp_path)
    doc = tmp_path / "docs" / "GUIDE.md"
    doc.write_text(textwrap.dedent("""\
        Good: `--rounds`, `repro.fed.engine`, `benchmarks/run.py`,
        `python benchmarks/run.py --only async`, [ok](../benchmarks/run.py).
        Stale flag `--no-such-flag`, stale path `src/gone.py`,
        stale module `repro.fed.missing`,
        stale suite `run.py --only nope`,
        [dead](missing.md).
        """))
    monkeypatch.setattr(docs_check, "ROOT", tmp_path)
    monkeypatch.setattr(docs_check, "CHECKED_DOCS", ("docs/GUIDE.md",))
    assert docs_check.main() == 1
    stale = docs_check.check_doc("docs/GUIDE.md", docs_check.defined_flags(),
                                 docs_check.defined_suites())
    kinds = "\n".join(stale)
    assert "--no-such-flag" in kinds
    assert "src/gone.py" in kinds
    assert "repro.fed.missing" in kinds
    assert "suite `nope`" in kinds
    assert "missing.md" in kinds
    assert len(stale) == 5          # nothing valid was flagged


def test_docs_check_ignores_prose_and_fence_noise(docs_check, tmp_path,
                                                  monkeypatch):
    mini_repo(tmp_path)
    doc = tmp_path / "docs" / "GUIDE.md"
    doc.write_text(textwrap.dedent("""\
        Prose mentioning --not-code or bare.py stays advisory (no
        backticks). Inline math like `alpha / (1 + s)^beta` and ASCII
        art are not references:

        ```
        c0 ██████ --rounds 4
        weights = keep * size
        ```
        """))
    monkeypatch.setattr(docs_check, "ROOT", tmp_path)
    monkeypatch.setattr(docs_check, "CHECKED_DOCS", ("docs/GUIDE.md",))
    assert docs_check.main() == 0


def test_docs_check_fails_on_missing_doc(docs_check, tmp_path, monkeypatch):
    mini_repo(tmp_path)
    monkeypatch.setattr(docs_check, "ROOT", tmp_path)
    monkeypatch.setattr(docs_check, "CHECKED_DOCS", ("docs/ABSENT.md",))
    assert docs_check.main() == 1
