"""HLO collective-parsing unit tests (the roofline's collective source)."""
from repro.launch.hlo import (collective_bytes, collective_bytes_tripcounted,
                              count_ops)

SIMPLE = """
HloModule test

ENTRY %main.1 (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add.1
  %ag = bf16[32,64]{1,0} all-gather(%p0), dimensions={0}
  ROOT %out = f32[16,128]{1,0} copy(%ar)
}
"""

NESTED = """
HloModule test

%body.2 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arr = f32[8,8]{1,0} all-reduce(%x), to_apply=%add.9
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %arr)
}

%helper.7 (q: f32[4,4]) -> f32[4,4] {
  %cp = f32[4,4]{1,0} collective-permute(%q), source_target_pairs={{0,1}}
  ROOT %r = f32[4,4]{1,0} copy(%cp)
}

ENTRY %main.9 (p0: f32[8,8]) -> f32[8,8] {
  %w = (s32[], f32[8,8]) while(%init), condition=%cond.3, body=%body.2, backend_config={"known_trip_count":{"n":"10"}}
  %h = f32[4,4]{1,0} call(%p1), to_apply=%helper.7
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_collective_bytes_simple():
    c = collective_bytes(SIMPLE)
    assert c["all-reduce"] == 16 * 128 * 4
    assert c["all-gather"] == 32 * 64 * 2
    assert c["total"] == 16 * 128 * 4 + 32 * 64 * 2


def test_tripcount_multiplies_while_bodies():
    c = collective_bytes_tripcounted(NESTED)
    assert c["all-reduce"] == 10 * 8 * 8 * 4        # x known_trip_count
    assert c["collective-permute"] == 4 * 4 * 4     # call target counted 1x


def test_unparsed_computations_still_counted_once():
    # a computation with collectives but no parsed call chain must not drop
    orphan = NESTED.replace("body=%body.2", "body=%somewhere.else")
    c = collective_bytes_tripcounted(orphan)
    assert c["all-reduce"] >= 8 * 8 * 4             # counted at least once


def test_count_ops():
    ops = count_ops(SIMPLE)
    assert ops["all-reduce"] == 1 and ops["all-gather"] == 1
