"""Data pipeline + checkpoint + sharding-rule tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (latest_checkpoint, load_checkpoint,
                              load_latest, save_checkpoint)
from repro.data import (DATASETS, dirichlet_partition, iid_partition,
                        select_clients, stack_clients,
                        synthetic_image_dataset, synthetic_lm_dataset)
from repro.sharding.rules import (batch_pspec, guard_divisibility,
                                  params_pspecs)
from jax.sharding import PartitionSpec as P


# ------------------------------------------------------------------ data
def test_iid_partition_sizes():
    data = synthetic_image_dataset(DATASETS["cifar10-syn"], 100, image_hw=32)
    clients = iid_partition(data, 10, seed=0)
    assert len(clients) == 10
    assert all(len(c["labels"]) == 10 for c in clients)
    all_idx = np.concatenate([c["labels"] for c in clients])
    assert len(all_idx) == 100


def test_dirichlet_partition_skew():
    data = synthetic_image_dataset(DATASETS["cifar10-syn"], 1000, image_hw=32)
    clients = dirichlet_partition(data, 10, alpha=0.1, seed=0)
    assert all(len(c["labels"]) == 100 for c in clients)
    # alpha=0.1 should give strongly skewed label marginals per client
    fracs = []
    for c in clients:
        _, counts = np.unique(c["labels"], return_counts=True)
        fracs.append(counts.max() / counts.sum())
    assert np.mean(fracs) > 0.35  # IID would be ~0.1


def test_selection_deterministic():
    a = select_clients(50, 5, seed=3, round_idx=7)
    b = select_clients(50, 5, seed=3, round_idx=7)
    np.testing.assert_array_equal(a, b)
    assert len(set(a.tolist())) == 5


def test_stack_clients():
    data = synthetic_lm_dataset(40, 16, 100)
    clients = iid_partition(data, 4, seed=0)
    stacked = stack_clients(clients, [0, 2])
    assert stacked["tokens"].shape == (2, 10, 16)


def test_lm_dataset_in_vocab():
    d = synthetic_lm_dataset(20, 32, 257)
    assert d["tokens"].min() >= 0 and d["tokens"].max() < 257


# ------------------------------------------------------------------ ckpt
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.int32), "d": None}}
    path = save_checkpoint(str(tmp_path / "x.npz"), tree)
    back = load_checkpoint(path)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.arange(6).reshape(2, 3))
    np.testing.assert_array_equal(np.asarray(back["b"]["c"]), np.ones(4))
    assert back["b"]["d"] is None


def test_latest_checkpoint(tmp_path):
    for step in (3, 11, 7):
        save_checkpoint(str(tmp_path), {"x": jnp.ones(2)}, step=step)
    assert latest_checkpoint(str(tmp_path)).endswith("ckpt_00000011.npz")


def test_keep_last_one_prunes_all_but_newest(tmp_path):
    """keep_last=1 — the tightest retention the engine offers — must leave
    exactly the newest step on disk after every save."""
    import os
    for step in (1, 2, 5):
        save_checkpoint(str(tmp_path), {"x": jnp.full(2, float(step))},
                        step=step, keep_last=1)
        files = sorted(f for f in os.listdir(tmp_path)
                       if f.endswith(".npz"))
        assert files == [f"ckpt_{step:08d}.npz"]
    back = load_latest(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(back["x"]), np.full(2, 5.0))


def test_keep_last_zero_rejected(tmp_path):
    import pytest
    with pytest.raises(ValueError, match="keep_last"):
        save_checkpoint(str(tmp_path), {"x": jnp.ones(2)}, step=1,
                        keep_last=0)


def test_load_latest_empty_and_missing_dir(tmp_path):
    """No checkpoints -> None (engine.restore reports 'nothing to resume'
    instead of crashing), for both an empty and a nonexistent directory."""
    assert load_latest(str(tmp_path)) is None
    assert load_latest(str(tmp_path / "never_created")) is None


def test_load_latest_skips_corrupt_tail(tmp_path):
    """A torn/damaged newest file must not kill the resume: load_latest
    falls back to the newest INTACT checkpoint — load-bearing now that the
    DP accountant's epsilon ledger rides the run checkpoint."""
    save_checkpoint(str(tmp_path), {"x": jnp.full(2, 1.0)}, step=1)
    with open(tmp_path / "ckpt_00000002.npz", "wb") as f:
        f.write(b"PK\x03\x04 torn mid-write")      # zip magic, no payload
    back = load_latest(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(back["x"]), np.full(2, 1.0))
    # a directory holding ONLY corrupt files degrades to None, not a crash
    (tmp_path / "ckpt_00000001.npz").unlink()
    assert load_latest(str(tmp_path)) is None


# ------------------------------------------------------------------ sharding
def _mesh2d():
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


def test_guard_divisibility():
    mesh = _mesh2d()
    spec = guard_divisibility(("data", "model"), (10, 16), mesh)
    assert spec == P("data", "model")  # axis size 1 divides everything


def test_params_pspecs_rules():
    mesh = _mesh2d()
    tree = {
        "embed": {"tok": jax.ShapeDtypeStruct((1000, 64), jnp.float32)},
        "cycle": {"pos0": {"attn": {
            "q": {"w": jax.ShapeDtypeStruct((4, 64, 128), jnp.float32)},
            "o": {"w": jax.ShapeDtypeStruct((4, 128, 64), jnp.float32)},
        }}},
        "head": {"w": jax.ShapeDtypeStruct((64, 1000), jnp.float32)},
        "norm": {"scale": jax.ShapeDtypeStruct((64,), jnp.float32)},
    }
    specs = params_pspecs(tree, mesh)
    assert specs["embed"]["tok"] == P("model", None)
    assert specs["cycle"]["pos0"]["attn"]["q"]["w"] == P(None, None, "model")
    assert specs["cycle"]["pos0"]["attn"]["o"]["w"] == P(None, "model", None)
    assert specs["head"]["w"] == P(None, "model")
    assert specs["norm"]["scale"] == P(None)


def test_params_pspecs_client_axis():
    mesh = _mesh2d()
    tree = {"prompt": jax.ShapeDtypeStruct((8, 16, 64), jnp.float32)}
    specs = params_pspecs(tree, mesh, client_axis=True)
    assert specs["prompt"][0] == "data"


def test_batch_pspec():
    mesh = _mesh2d()
    tree = {"tokens": jax.ShapeDtypeStruct((16, 4, 128), jnp.int32)}
    specs = batch_pspec(tree, mesh)
    assert specs["tokens"][0] == "data"
    assert specs["tokens"][1] is None
