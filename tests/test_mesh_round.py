"""Mega-cohort rounds on the device mesh: sharded cohort dispatch is
bit-comparable to the single-device vmap round (clear AND secure, with
dropouts), the frozen body stays UNBATCHED in the compiled HLO, and
hierarchical (client -> edge -> global) aggregation matches flat FedAvg
plus a two-tier metered wire breakdown.

The multi-device tests need >= 8 visible devices — run the suite under
XLA_FLAGS=--xla_force_host_platform_device_count=8 (CI's test-mesh8 job);
on the default 1-device run they skip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.core.aggregation import (broadcast_to_clients, fedavg_partial,
                                    get_aggregator, hierarchical_fedavg)
from repro.core.comm import (hierarchical_edge_breakdown,
                             hierarchical_secure_agg_breakdown)
from repro.data import (DATASETS, synthetic_image_dataset,
                        synthetic_lm_dataset)
from repro.fed import (ClientSampler, EdgeTopology, FederatedEngine,
                       HierarchicalAggregator, Population, RoundScheduler,
                       StragglerConfig)
from repro.launch.mesh import make_host_mesh
from repro.privacy.fixed_point import roundtrip_tol
from repro.sharding import cohort_pspecs, params_pspecs

KEY = jax.random.PRNGKey(0)
N_LOCAL = 4
BATCH = 4

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="sharded-cohort tests need 8 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@pytest.fixture(scope="module")
def setup():
    # distinctive dims (32 / 48) so HLO shape strings are unambiguous
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=32, d_ff=48)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2,
                        prune_gamma=0.5, local_epochs=1)
    return cfg, split


def make_trainer(cfg, split, *, k, aggregator=None, mesh=None):
    model = SplitModel(cfg, split)
    pcfg = ProtocolConfig(clients_per_round=k, local_epochs=1,
                          batch_size=BATCH, momentum=0.0)
    return SFPromptTrainer(model, pcfg, aggregator, mesh=mesh)


def cohort_batch(k, *, seed=0):
    data = synthetic_image_dataset(DATASETS["cifar10-syn"], k * N_LOCAL,
                                   seed=seed, image_hw=32)
    return {name: jnp.asarray(v).reshape((k, N_LOCAL) + v.shape[1:])
            for name, v in data.items()}


def dropout_participation(k, *, n_dropped, n_late=0):
    transmit = np.ones(k, np.float32)
    aggregate = np.ones(k, np.float32)
    aggregate[:n_dropped] = 0.0
    transmit[:n_dropped] = 0.0
    transmit[n_dropped:n_dropped + n_late] = 0.4
    return {"transmit": jnp.asarray(transmit),
            "aggregate": jnp.asarray(aggregate)}


def trainable_nbytes(params):
    tr = {"tail": params["tail"], "prompt": params["prompt"]}
    return float(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tr)))


def random_cohort_tree(key, k):
    return {"tail": {"w": jax.random.normal(key, (k, 7, 3)),
                     "b": jax.random.normal(jax.random.fold_in(key, 1),
                                            (k, 5))},
            "prompt": jax.random.normal(jax.random.fold_in(key, 2),
                                        (k, 4, 8))}


# ------------------------------------------------------------- guardrails
def test_broadcast_to_clients_rejects_nonpositive_k():
    """Regression: k <= 0 must fail HERE with the cohort size in the
    message, not later as an opaque zero-length vmap axis error."""
    tree = {"w": jnp.ones((3, 2))}
    for bad in (0, -4):
        with pytest.raises(ValueError, match="cohort"):
            broadcast_to_clients(tree, bad)
    out = broadcast_to_clients(tree, 2)
    assert out["w"].shape == (2, 3, 2)


def test_edge_topology_validation():
    with pytest.raises(ValueError, match="positive"):
        EdgeTopology(0, 1)
    with pytest.raises(ValueError, match="more edges"):
        EdgeTopology(4, 8)
    with pytest.raises(ValueError, match="not divisible"):
        EdgeTopology(10, 4)
    topo = EdgeTopology(8, 2)
    assert topo.edge_size == 4
    np.testing.assert_array_equal(topo.assignment,
                                  [0, 0, 0, 0, 1, 1, 1, 1])
    assert topo.members(1) == slice(4, 8)


def test_pspecs_on_data_only_host_mesh():
    """Regression: rule tables mention 'model', but a host mesh has only
    'data' — mesh-absent axes must drop instead of KeyError-ing, and the
    cohort leading axis must land on the client plane."""
    mesh = make_host_mesh()
    params = {"body": {"w": jnp.zeros((32, 48))},
              "tail": {"w": jnp.zeros((32, 10))}}
    specs = params_pspecs(params, mesh)
    assert all(isinstance(s, P) for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    k = jax.device_count()
    cohort = {"x": jnp.zeros((k, 5)), "flag": jnp.zeros((k,))}
    cspecs = cohort_pspecs(cohort, mesh)
    assert cspecs["x"] == P("data", None)
    assert cspecs["flag"] == P("data")
    # a K that does not divide the device count replicates, never fails
    # (vacuous on a 1-device mesh — everything divides 1)
    if jax.device_count() > 1:
        odd = cohort_pspecs(
            {"x": jnp.zeros((jax.device_count() * 2 + 1, 3))}, mesh)
        assert odd["x"] == P(None, None)


def test_make_host_mesh_rejects_oversubscription():
    with pytest.raises(ValueError, match="device"):
        make_host_mesh(jax.device_count() + 1)


# -------------------------------------------------- hierarchical == flat
@pytest.mark.parametrize("weights", [
    [3.0, 2.0, 7.0, 1.0, 5.0, 4.0],       # full participation
    [3.0, 0.0, 7.0, 1.0, 0.0, 4.0],       # dropouts across edges
    [0.0, 0.0, 7.0, 1.0, 5.0, 4.0],       # edge 0 entirely dropped
])
def test_hierarchical_fedavg_matches_flat(weights):
    """Two-tier survivor-weighted mean == flat fedavg_partial up to float
    reassociation, including when a whole edge drops (W_e = 0)."""
    k = len(weights)
    tree = random_cohort_tree(KEY, k)
    w = jnp.asarray(weights)
    fb = jax.tree.map(lambda x: jnp.ones_like(x[0]), tree)
    topo = EdgeTopology(k, 3)
    hier = hierarchical_fedavg(tree, w, fb, jnp.asarray(topo.assignment), 3)
    flat = fedavg_partial(tree, w, fb)
    for a, b in zip(jax.tree.leaves(hier), jax.tree.leaves(flat)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_hierarchical_fedavg_all_dropped_falls_back():
    tree = random_cohort_tree(KEY, 4)
    fb = jax.tree.map(lambda x: jnp.full_like(x[0], 3.25), tree)
    out = hierarchical_fedavg(tree, jnp.zeros((4,)), fb,
                              jnp.asarray(EdgeTopology(4, 2).assignment), 2)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(fb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hierarchical_clear_round_matches_flat(setup):
    """A full protocol round through the edge topology lands on the flat
    round's params, and the edge_global stream meters exactly
    (E + live_edges) * param_bytes."""
    cfg, split = setup
    k, n_edges = 4, 2
    data = cohort_batch(k)
    part = dropout_participation(k, n_dropped=1)   # edge 0 keeps 1 client

    flat = make_trainer(cfg, split, k=k)
    st_f, m_f = flat.round(flat.init(KEY), data, dict(part))
    hier = make_trainer(cfg, split, k=k,
                        aggregator=get_aggregator(n_edges=n_edges,
                                                  cohort_size=k))
    st_h, m_h = hier.round(hier.init(KEY), data, dict(part))

    for a, b in zip(jax.tree.leaves(st_f["params"]),
                    jax.tree.leaves(st_h["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-6, atol=1e-7)
    # phase-2 smashed traffic identical; phase-3 uplink accounting too
    # (clear edges keep the seed-exact (K + survivors) * param_bytes)
    assert m_f["wire/head_body_bytes"] == m_h["wire/head_body_bytes"]
    assert m_f["wire/params_bytes"] == m_h["wire/params_bytes"]
    pb = trainable_nbytes(st_h["params"])
    live_edges = 2.0   # the dropout left a survivor on both edges
    expect = hierarchical_edge_breakdown(param_nbytes=pb, n_edges=n_edges,
                                         live_edges=live_edges)
    assert m_h["wire/edge_global_bytes"] == expect["edge_global"]
    assert hier.meter.totals["edge_global"] == expect["edge_global"]
    assert "wire/edge_global_bytes" not in m_f


def test_hierarchical_secure_round_matches_clear(setup):
    """Per-edge masked aggregation composes with the topology: the secure
    hierarchical round matches the clear hierarchical round within
    fixed-point tolerance, and the metered two-tier bytes match the
    analytical breakdown within 5% — under a straggler plan."""
    cfg, split = setup
    k, n_edges = 4, 2
    data = cohort_batch(k)
    part = dropout_participation(k, n_dropped=1, n_late=1)

    clear = make_trainer(cfg, split, k=k,
                         aggregator=get_aggregator(n_edges=n_edges,
                                                   cohort_size=k))
    st_c, _ = clear.round(clear.init(KEY), data, dict(part))
    sec = make_trainer(
        cfg, split, k=k,
        aggregator=get_aggregator(secure=True, n_edges=n_edges,
                                  cohort_size=k, impl="ref", seed=3))
    st_s, m_s = sec.round(sec.init(KEY), data, dict(part))

    tol = roundtrip_tol(k)
    for a, b in zip(jax.tree.leaves(st_c["params"]),
                    jax.tree.leaves(st_s["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=tol)

    params = st_s["params"]
    trainable = {"tail": params["tail"], "prompt": params["prompt"]}
    n_tr = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(trainable))
    pb = trainable_nbytes(params)
    # edge 0 lost client 0, both edges live: uploads (1, 2) of sizes (2, 2)
    bd = hierarchical_secure_agg_breakdown(
        n_trainable=n_tr, param_nbytes=pb,
        edge_sizes=[2, 2], edge_uploads=[1.0, 2.0])
    for name in ("params", "secure", "edge_global"):
        got = sec.meter.totals[name]
        assert abs(got - bd[name]) <= 0.05 * bd[name], (name, got, bd[name])


def test_hierarchical_aggregator_validates_cohort_size():
    agg = HierarchicalAggregator(EdgeTopology(4, 2))
    tree = random_cohort_tree(KEY, 6)
    fb = jax.tree.map(lambda x: x[0], tree)
    with pytest.raises(ValueError, match="topology"):
        agg.aggregate(tree, jnp.ones((6,)), fb, 0)
    with pytest.raises(ValueError, match="no options"):
        HierarchicalAggregator(EdgeTopology(4, 2), impl="ref")


# --------------------------------------------------- MoE batched fallback
def test_moe_round_uses_batched_fallback():
    """MoE ragged ops have no vmap rule for unbatched operands — the
    trainer must detect that and fall back to K-broadcast frozen trees,
    and the round must still run end to end on token data."""
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced(n_layers=3)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=2,
                        prune_gamma=0.5, local_epochs=1)
    model = SplitModel(cfg, split)
    k = 2
    pcfg = ProtocolConfig(clients_per_round=k, local_epochs=1,
                          batch_size=2, momentum=0.0)
    tr = SFPromptTrainer(model, pcfg)
    assert tr._batch_frozen          # MoE -> broadcast path
    toks = synthetic_lm_dataset(k * N_LOCAL, 16, cfg.vocab_size,
                                seed=0)["tokens"]
    data = {"tokens": jnp.asarray(toks).reshape(k, N_LOCAL, -1)}
    state, metrics = tr.round(tr.init(KEY), data)
    assert np.isfinite(metrics["split_loss"])
    assert int(state["round"]) == 1


def test_dense_round_keeps_frozen_unbatched(setup):
    cfg, split = setup
    tr = make_trainer(cfg, split, k=2)
    assert not tr._batch_frozen      # dense -> in_axes=None frozen operands


# ------------------------------------------------- sharded cohort dispatch
@needs_mesh
@pytest.mark.parametrize("secure", [False, True],
                         ids=["clear", "secure"])
def test_sharded_round_matches_vmap_round(setup, secure):
    """K=64 as ONE sharded dispatch over the 8-device host mesh == the
    single-device vmap round: params and EVERY metric (including metered
    wire bytes) agree, under a straggler plan with dropouts."""
    cfg, split = setup
    k = 64
    data = cohort_batch(k)
    part = dropout_participation(k, n_dropped=5, n_late=3)

    def agg():
        return (get_aggregator(secure=True, impl="ref", seed=11)
                if secure else None)

    ref = make_trainer(cfg, split, k=k, aggregator=agg())
    st_r, m_r = ref.round(ref.init(KEY), data, dict(part))
    mesh = make_host_mesh()
    shard = make_trainer(cfg, split, k=k, aggregator=agg(), mesh=mesh)
    st_s, m_s = shard.round(shard.init(KEY), data, dict(part))

    for a, b in zip(jax.tree.leaves(st_r["params"]),
                    jax.tree.leaves(st_s["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    assert set(m_r) == set(m_s)
    for name in m_r:
        np.testing.assert_allclose(m_r[name], m_s[name], rtol=1e-5,
                                   err_msg=name)
    # the meter saw identical traffic on both layouts
    assert ref.meter.totals.keys() == shard.meter.totals.keys()
    for name in ref.meter.totals:
        np.testing.assert_allclose(ref.meter.totals[name],
                                   shard.meter.totals[name], rtol=1e-5,
                                   err_msg=name)


@needs_mesh
def test_sharded_round_body_stays_unbatched(setup):
    """The compiled sharded round must contain NO K-stacked copy of any
    frozen body leaf — phase-2 cohort HBM scales with K * (tail + prompt +
    opt state), not K * body. Checked against the compiled HLO text, with
    memory_analysis available as the accounting source."""
    cfg, split = setup
    k = 64
    data = cohort_batch(k)
    ones = jnp.ones((k,), jnp.float32)
    part = {"transmit": ones, "aggregate": ones}
    mesh = make_host_mesh()
    tr = make_trainer(cfg, split, k=k, mesh=mesh)
    state = tr.init(KEY)
    round_jit = tr._get_round_jit(state, data, part, None)
    compiled = round_jit.lower(state, data, part, None).compile()
    hlo = compiled.as_text()
    body_leaves = [x for x in jax.tree.leaves(state["params"]["body"])
                   if x.ndim >= 2]
    assert body_leaves
    for leaf in body_leaves:
        stacked = "f32[" + ",".join(str(d)
                                    for d in (k,) + leaf.shape) + "]"
        assert stacked not in hlo, (
            f"frozen body leaf {leaf.shape} appears K-stacked as {stacked}")
    assert compiled.memory_analysis() is not None


@needs_mesh
def test_sharded_jit_cache_reused_across_rounds(setup):
    """Repeated rounds at the same cohort shape reuse ONE mesh-jitted
    executable (no recompile per round)."""
    cfg, split = setup
    k = 8
    data = cohort_batch(k)
    tr = make_trainer(cfg, split, k=k, mesh=make_host_mesh())
    state = tr.init(KEY)
    state, _ = tr.round(state, data)
    assert len(tr._mesh_jit_cache) == 1
    state, _ = tr.round(state, data)
    assert len(tr._mesh_jit_cache) == 1
    assert int(state["round"]) == 2


# ------------------------------------------------------------------ resume
def test_hierarchical_engine_resume_byte_identical(setup, tmp_path):
    """Kill-and-restart with a hierarchical aggregator: params, meter
    totals (including edge_global), and cohorts are byte-identical to the
    uninterrupted run — and a changed topology refuses the checkpoint."""
    cfg, split = setup
    n_clients, k = 40, 4
    data = synthetic_image_dataset(DATASETS["cifar10-syn"],
                                   n_clients * N_LOCAL, seed=0, image_hw=32)

    def build(n_edges=2):
        pop = Population.from_partition(data, n_clients, scheme="dirichlet",
                                        alpha=0.1, seed=0)
        tr = make_trainer(cfg, split, k=k,
                          aggregator=get_aggregator(n_edges=n_edges,
                                                    cohort_size=k))
        sampler = ClientSampler(pop.n_clients, k, kind="uniform", seed=7)
        sched = RoundScheduler(StragglerConfig(dropout_rate=0.25), seed=7)
        return FederatedEngine(tr, pop, sampler, sched)

    ref = build()
    ref.init(KEY)
    for _ in range(2):
        ref.run_round()

    eng = build()
    eng.init(KEY)
    eng.run_round()
    ckpt = str(tmp_path / "ckpt")
    eng.save(ckpt)

    # topology change must fail loudly — it is part of the fingerprint
    with pytest.raises(ValueError, match="trainer mismatch"):
        build(n_edges=4).restore(ckpt)

    res = build()
    assert res.restore(ckpt)
    res.run_round()
    for a, b in zip(jax.tree.leaves(ref.state["params"]),
                    jax.tree.leaves(res.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ref.trainer.meter.as_dict() == res.trainer.meter.as_dict()
    assert ref.trainer.meter.totals["edge_global"] > 0
