"""The transport-aware segment pipeline: codecs, boundaries, traffic meter,
per-client splits. Covers the codec round-trip error bounds, the custom-VJP
gradient wire, measured-vs-analytical byte accounting, int8 phase-2
convergence, and heterogeneous cut points."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ProtocolConfig, SFPromptTrainer, SplitConfig, SplitModel
from repro.core.comm import CostInputs, crosscheck
from repro.data import (DATASETS, iid_partition, stack_clients,
                        synthetic_image_dataset)
from repro.kernels.quant.ops import dequantize_int8, quantize_int8
from repro.runtime import (Boundary, Int8Codec, WireSpec,
                           get_codec)
from repro.runtime.hetero import ClientPlan, HeteroSFPromptTrainer

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ codecs
@pytest.mark.parametrize("name,bound", [
    ("fp32", 0.0),          # exact
    ("bf16", 2.0 ** -8),    # one bf16 mantissa step, relative
])
def test_codec_roundtrip_exactish(name, bound):
    codec = get_codec(name)
    x = jax.random.normal(KEY, (6, 33, 48)) * 5
    y = codec.roundtrip(x, 0.5, 0.5)
    err = jnp.max(jnp.abs(y - x) / jnp.maximum(jnp.abs(x), 1e-6))
    assert float(err) <= bound


@pytest.mark.parametrize("u_mode", ["stochastic", "nearest"])
def test_int8_roundtrip_within_quant_step(u_mode):
    codec = Int8Codec(impl="ref")
    x = jax.random.normal(KEY, (10, 64)) * 3
    u = (jax.random.uniform(jax.random.fold_in(KEY, 1), x.shape)
         if u_mode == "stochastic" else 0.5)
    values, scales = codec.encode(x, u)
    y = codec.decode((values, scales), x.dtype)
    step = scales  # one quant step per row
    max_err = jnp.max(jnp.abs(y - x) / step)
    # stochastic rounding errs < 1 step; nearest <= 0.5 step
    assert float(max_err) <= (1.0 if u_mode == "stochastic" else 0.5) + 1e-5


def test_int8_stochastic_rounding_unbiased():
    codec = Int8Codec(impl="ref")
    x = jax.random.normal(KEY, (4, 32)) * 2
    ys = []
    for i in range(64):
        u = jax.random.uniform(jax.random.fold_in(KEY, i), x.shape)
        ys.append(codec.decode(codec.encode(x, u), x.dtype))
    bias = jnp.mean(jnp.stack(ys), 0) - x
    scales = codec.encode(x, 0.5)[1]
    # empirical mean within a fraction of a quant step of the true value
    assert float(jnp.max(jnp.abs(bias) / scales)) < 0.2


def test_int8_kernel_matches_ref_bitwise():
    """Pallas (interpret) quant/dequant == pure-jnp ref on the same noise."""
    x = jax.random.normal(KEY, (40, 96)) * 3
    u = jax.random.uniform(jax.random.fold_in(KEY, 1), x.shape)
    vr, sr = quantize_int8(x, u, impl="ref")
    vi, si = quantize_int8(x, u, impl="interpret")
    np.testing.assert_array_equal(np.asarray(vr), np.asarray(vi))
    np.testing.assert_allclose(np.asarray(sr), np.asarray(si), rtol=1e-7)
    yr = dequantize_int8(vr, sr, impl="ref")
    yi = dequantize_int8(vi, si, impl="interpret")
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yi), rtol=1e-7)


def test_boundary_backward_gradient_is_quantized():
    """The custom VJP pushes the cotangent through the codec with the
    boundary's backward noise — the wire is int8 in BOTH directions."""
    codec = Int8Codec(impl="ref")
    b = Boundary("head_body", codec)
    x = jax.random.normal(KEY, (4, 8, 16)) * 2
    key = jax.random.PRNGKey(3)
    g = jax.random.normal(jax.random.fold_in(KEY, 2), x.shape)

    y, _ = b.transmit(x, key=key)
    _, vjp = jax.vjp(lambda t: b.transmit(t, key=key)[0], x)
    (gx,) = vjp(g)

    _, u_bwd = b._noise(key, x.shape)
    expected = codec.decode(codec.encode(g, u_bwd), g.dtype)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(expected),
                               rtol=1e-6, atol=1e-6)
    # and the forward value is a genuine int8 roundtrip, not identity
    assert float(jnp.max(jnp.abs(y - x))) > 0


def test_transmit_byte_counts():
    x = jnp.zeros((2, 10, 64))
    for name, per_elem, row_overhead in [("fp32", 4, 0), ("bf16", 2, 0),
                                         ("int8", 1, 4)]:
        b = Boundary("head_body", get_codec(name))
        _, nb_train = b.transmit(x, train=True)
        _, nb_infer = b.transmit(x, train=False)
        expect = 2 * 10 * 64 * per_elem + 2 * 10 * row_overhead
        assert int(nb_infer) == expect, name
        assert int(nb_train) == 2 * expect, name


# --------------------------------------------------- measured vs analytical
def _tiny_setup(codec_name, *, K=2, n_local=48, batch=8, seed=0, data=None):
    cfg = get_config("vit-base").reduced(n_layers=3, d_model=64, d_ff=128)
    split = SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4,
                        prune_gamma=0.3, local_epochs=1)
    wire = WireSpec.make(codec_name)
    model = SplitModel(cfg, split, wire)
    pcfg = ProtocolConfig(clients_per_round=K, local_epochs=1,
                          batch_size=batch, lr_local=0.01, lr_split=0.01,
                          momentum=0.0)
    tr = SFPromptTrainer(model, pcfg)
    if data is None:
        data = synthetic_image_dataset(DATASETS["cifar10-syn"], K * n_local,
                                       seed=seed, image_hw=32)
    data = {k: v[: K * n_local] for k, v in data.items()}
    clients = iid_partition(data, K, seed=0)
    cbatch = {k: jnp.asarray(v) for k, v in
              stack_clients(clients, list(range(K))).items()}
    return cfg, split, model, tr, cbatch, data


def test_meter_matches_analytical_within_5pct():
    """TrafficMeter's measured per-boundary bytes vs comm.sfprompt_comm's
    breakdown on reduced vit_base, int8 wire."""
    K, n_local, batch = 2, 48, 8
    cfg, split, model, tr, cbatch, _ = _tiny_setup("int8", K=K,
                                                   n_local=n_local,
                                                   batch=batch)
    state = tr.init(KEY)
    _, metrics = tr.round(state, cbatch)

    n_tokens = 1 + (32 // 16) ** 2 + split.prompt_len
    keep = max(batch, n_local - int(split.prune_gamma * n_local))
    keep -= keep % batch
    h, b, t = (model._segment_params_count(s)
               for s in ("head", "body", "tail"))
    W = h + b + t
    ci = CostInputs(W=W, alpha=h / W, tau=b / W,
                    q=n_tokens * cfg.d_model, D=n_local, U=1, E=1, K=K,
                    p=split.prompt_len * cfg.d_model,
                    gamma_keep=keep / n_local,
                    bytes_smashed=model.wire.head_body.codec.bytes_per_float(
                        (batch, n_tokens, cfg.d_model)))
    cc = crosscheck(tr.meter.totals, ci)
    assert set(cc) == {"head_body", "body_tail", "params"}
    for name, entry in cc.items():
        assert abs(entry["err_pct"]) <= 5.0, (name, entry)
    assert tr.meter.total_bytes() > 0


def test_meter_accumulates_rounds():
    _, _, _, tr, cbatch, _ = _tiny_setup("bf16")
    state = tr.init(KEY)
    state, m1 = tr.round(state, cbatch)
    per_round = dict(tr.meter.totals)
    state, m2 = tr.round(state, cbatch)
    assert tr.meter.rounds == 2
    for k, v in tr.meter.totals.items():
        np.testing.assert_allclose(v, 2 * per_round[k], rtol=1e-6)
    assert "wire/head_body_bytes" in m1 and m1["wire/head_body_bytes"] > 0


# --------------------------------------------------------- gradient flow
def test_phase2_converges_through_int8_wire():
    """Phase-2 training through the stochastic int8 boundary must still
    learn: split loss drops and eval accuracy lands within 1 point of the
    fp32-wire run from the same init/data. Eval uses a 480-sample superset
    of the training draw so 1 accuracy point spans ~5 samples."""
    K, n_local = 2, 96
    full = synthetic_image_dataset(DATASETS["cifar10-syn"], 480, seed=0,
                                   image_hw=32)
    results = {}
    for codec_name in ("fp32", "int8"):
        _, _, _, tr, cbatch, _ = _tiny_setup(codec_name, K=K,
                                             n_local=n_local, batch=8,
                                             data=full)
        state = tr.init(KEY)
        losses = []
        for _ in range(4):
            state, m = tr.round(state, cbatch)
            losses.append(m["split_loss"])
        ev = tr.evaluate(state["params"], full, batch_size=32)
        results[codec_name] = (losses, ev)

    for codec_name, (losses, ev) in results.items():
        assert losses[-1] < losses[0] * 0.95, (codec_name, losses)
        assert np.isfinite(ev["ce"])
    acc_fp32 = results["fp32"][1]["acc"]
    acc_int8 = results["int8"][1]["acc"]
    assert abs(acc_int8 - acc_fp32) <= 0.01 + 1e-6, (acc_fp32, acc_int8)


# ------------------------------------------------------------- hetero
def test_hetero_round_different_cut_points():
    """Two client groups with different head/tail cycle counts train in one
    round; the prompt is globally aggregated, tails stay per-group."""
    cfg = get_config("vit-base").reduced(n_layers=5, d_model=64, d_ff=128)
    plans = [
        ClientPlan(SplitConfig(head_cycles=1, tail_cycles=1, prompt_len=4,
                               prune_gamma=0.0, local_epochs=1), 2, "phone"),
        ClientPlan(SplitConfig(head_cycles=2, tail_cycles=2, prompt_len=4,
                               prune_gamma=0.0, local_epochs=1), 2, "ws"),
    ]
    pcfg = ProtocolConfig(clients_per_round=2, local_epochs=1, batch_size=8,
                          lr_local=0.01, lr_split=0.01, momentum=0.0)
    ht = HeteroSFPromptTrainer(cfg, plans, pcfg, WireSpec.make("int8"))
    states = ht.init(KEY)
    # tails really differ across groups (different cut points)
    t0 = jax.tree.leaves(states[0]["params"]["tail"])
    t1 = jax.tree.leaves(states[1]["params"]["tail"])
    assert sum(x.size for x in t0) != sum(x.size for x in t1)

    data = synthetic_image_dataset(DATASETS["cifar10-syn"], 2 * 2 * 48,
                                   seed=0, image_hw=32)
    groups = []
    for g in range(2):
        part = {k: v[g * 96:(g + 1) * 96] for k, v in data.items()}
        clients = iid_partition(part, 2, seed=g)
        groups.append({k: jnp.asarray(v) for k, v in
                       stack_clients(clients, [0, 1]).items()})
    states, metrics = ht.round(states, groups)

    np.testing.assert_allclose(
        np.asarray(states[0]["params"]["prompt"]),
        np.asarray(states[1]["params"]["prompt"]), rtol=1e-6)
    assert metrics["wire/head_body_bytes"] > 0
    assert ht.meter.rounds == 1
    assert np.isfinite(metrics["phone/split_loss"])
    assert np.isfinite(metrics["ws/split_loss"])
    ev = ht.evaluate(states, data)
    assert np.isfinite(ev["ce"])


def test_hetero_rejects_mismatched_prompts():
    cfg = get_config("vit-base").reduced(n_layers=5, d_model=64, d_ff=128)
    plans = [ClientPlan(SplitConfig(prompt_len=4), 1),
             ClientPlan(SplitConfig(prompt_len=8), 1)]
    with pytest.raises(ValueError, match="prompt_len"):
        HeteroSFPromptTrainer(cfg, plans, ProtocolConfig())
